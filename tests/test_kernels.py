"""Per-kernel validation: shape/dtype sweeps vs the ref.py pure-jnp oracles,
all in interpret mode (CPU container; TPU is the target)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, hst, settings

from repro.core import build_score_table, random_cpts, random_dag
from repro.core.order_scoring import score_order_ref
from repro.data import ancestral_sample
from repro.kernels import count_contingency, flash_attention, order_score
from repro.kernels.count.ops import encode_parent_configs
from repro.kernels.count.ref import count_ref
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.order_score.ref import order_score_ref as kernel_ref


# ---------------------------------------------------------------- order_score
@pytest.fixture(scope="module")
def score_problem():
    rng = np.random.default_rng(0)
    adj = random_dag(rng, 10, 3, 0.4)
    cpts = random_cpts(rng, adj, 3)
    data = ancestral_sample(rng, adj, cpts, 500, 3)
    return build_score_table(data, q=3, s=3)


@pytest.mark.parametrize("block_s", [8, 32, 128, 1024])
def test_order_score_block_sweep(score_problem, block_s):
    st = score_problem
    rng = np.random.default_rng(7)
    for _ in range(3):
        pos = jnp.asarray(rng.permutation(st.n).astype(np.int32))
        sc, idx, ls = order_score(st.table, st.pst, pos, block_s=block_s,
                                  interpret=True)
        rv, ri = kernel_ref(st.table, st.pst, pos)
        np.testing.assert_allclose(float(sc), float(rv.sum()), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ri))
        np.testing.assert_allclose(np.asarray(ls), np.asarray(rv), rtol=1e-6)


@pytest.mark.parametrize("n,s,q", [(5, 2, 2), (8, 4, 2), (12, 3, 3)])
def test_order_score_shape_sweep(n, s, q):
    rng = np.random.default_rng(n * 7 + s)
    adj = random_dag(rng, n, s, 0.4)
    cpts = random_cpts(rng, adj, q)
    data = ancestral_sample(rng, adj, cpts, 200, q)
    st = build_score_table(data, q=q, s=s)
    pos = jnp.asarray(rng.permutation(n).astype(np.int32))
    sc, idx, _ = order_score(st.table, st.pst, pos, block_s=64, interpret=True)
    want, widx, _ = score_order_ref(st.table, st.pst, pos)  # core oracle
    np.testing.assert_allclose(float(sc), float(want), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(widx))


def test_order_score_kernel_agrees_with_core_scorer(score_problem):
    """The kernel is a drop-in for core.order_scoring (same MCMC contract)."""
    st = score_problem
    pos = jnp.asarray(np.arange(st.n, dtype=np.int32))
    a = order_score(st.table, st.pst, pos, interpret=True)
    b = score_order_ref(st.table, st.pst, pos)
    np.testing.assert_allclose(float(a[0]), float(b[0]), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


# ---------------------------------------------------------------------- count
@pytest.mark.parametrize("q,s,m,C,block_m", [
    (2, 2, 100, 5, 64), (3, 3, 257, 9, 128), (3, 4, 512, 3, 256),
    (4, 2, 64, 17, 64),
])
def test_count_sweep(q, s, m, C, block_m):
    rng = np.random.default_rng(q * 100 + s)
    n = 6
    D = rng.integers(0, q, (m, n)).astype(np.int32)
    data_ext = jnp.asarray(np.concatenate([D, np.zeros((m, 1), np.int32)], 1))
    pcols = jnp.asarray(rng.integers(0, n + 1, (C, s)).astype(np.int32))
    child = data_ext[:, 2]
    got = count_contingency(data_ext, child, pcols, q=q, s=s,
                            block_m=block_m, interpret=True)
    codes = encode_parent_configs(data_ext, pcols, q)
    want = count_ref(codes, jax.nn.one_hot(child, q, dtype=jnp.float32),
                     Q=q ** s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # counts sum to m per parent set
    np.testing.assert_allclose(np.asarray(got).sum(axis=(1, 2)), m, atol=1e-4)


def test_count_pad_rows_masked_in_kernel():
    """Regression (m not divisible by block_m): the kernel must mask padded
    sample rows out of the child one-hot itself. A child one-hot built from a
    0-padded child array has valid-looking rows in the pad region — before
    the in-kernel mask, those rows corrupted the counts of parent-config 0."""
    from repro.kernels.count.kernel import count_pallas

    rng = np.random.default_rng(31)
    q, s, m, C, block_m = 3, 2, 100, 6, 64          # pad = 28 rows
    n = 5
    D = rng.integers(0, q, (m, n)).astype(np.int32)
    data_ext = jnp.asarray(np.concatenate([D, np.zeros((m, 1), np.int32)], 1))
    pcols = jnp.asarray(rng.integers(0, n + 1, (C, s)).astype(np.int32))
    child = data_ext[:, 1]
    codes = encode_parent_configs(data_ext, pcols, q)
    want = count_ref(codes, jax.nn.one_hot(child, q, dtype=jnp.float32),
                     Q=q ** s)
    pad = (-m) % block_m
    codes_p = jnp.pad(codes, ((0, 0), (0, pad)), constant_values=-1)
    # simulate one_hot(0-padded child): pad rows are one-hot of state 0
    child_bad = jnp.concatenate(
        [child, jnp.zeros((pad,), child.dtype)])
    child_oh_bad = jax.nn.one_hot(child_bad, q, dtype=jnp.float32)
    got = count_pallas(codes_p, child_oh_bad, Q=q ** s, block_m=block_m,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    np.testing.assert_allclose(np.asarray(got).sum(axis=(1, 2)), m, atol=1e-4)


@given(hst.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_count_odd_m_property(seed):
    """count_contingency at m coprime to block_m == pure-jnp oracle."""
    rng = np.random.default_rng(seed)
    q, s, C = 2, 3, 5
    m = int(rng.integers(33, 200))
    if m % 64 == 0:
        m += 1
    D = rng.integers(0, q, (m, 6)).astype(np.int32)
    data_ext = jnp.asarray(np.concatenate([D, np.zeros((m, 1), np.int32)], 1))
    pcols = jnp.asarray(rng.integers(0, 7, (C, s)).astype(np.int32))
    child = data_ext[:, 3]
    got = count_contingency(data_ext, child, pcols, q=q, s=s, block_m=64,
                            interpret=True)
    codes = encode_parent_configs(data_ext, pcols, q)
    want = count_ref(codes, jax.nn.one_hot(child, q, dtype=jnp.float32),
                     Q=q ** s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_local_scores_chunk_use_pallas_path():
    """Satellite: the count kernel wired into core/scores scoring — the
    use_pallas flag must reproduce the einsum path."""
    from repro.core.scores import local_scores_chunk
    from repro.core.combinatorics import build_pst

    rng = np.random.default_rng(37)
    n, q, s, m = 6, 2, 3, 100                       # m % 512 != 0: pads
    D = rng.integers(0, q, (m, n)).astype(np.int32)
    data_ext = jnp.asarray(np.concatenate([D, np.zeros((m, 1), np.int32)], 1))
    pst, psizes = build_pst(n - 1, s)
    import math as _math
    args = dict(q=q, s=s, log_gamma=float(_math.log(0.1)), ess=1.0)
    want = local_scores_chunk(data_ext, jnp.int32(2), jnp.asarray(pst),
                              jnp.asarray(psizes), **args)
    got = local_scores_chunk(data_ext, jnp.int32(2), jnp.asarray(pst),
                             jnp.asarray(psizes), use_pallas=True, **args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-6)


@given(hst.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_count_property_total_mass(seed):
    rng = np.random.default_rng(seed)
    q, s, m, C = 3, 2, 128, 4
    D = rng.integers(0, q, (m, 5)).astype(np.int32)
    data_ext = jnp.asarray(np.concatenate([D, np.zeros((m, 1), np.int32)], 1))
    pcols = jnp.asarray(rng.integers(0, 6, (C, s)).astype(np.int32))
    got = count_contingency(data_ext, data_ext[:, 0], pcols, q=q, s=s,
                            block_m=128, interpret=True)
    assert np.asarray(got).min() >= 0
    np.testing.assert_allclose(np.asarray(got).sum(axis=(1, 2)), m, atol=1e-4)


# ------------------------------------------------------------ flash attention
def _ref_gqa(q, k, v, causal):
    B, T, Hq, Dh = q.shape
    rep = Hq // k.shape[2]
    kr = jnp.repeat(k, rep, 2) if rep > 1 else k
    vr = jnp.repeat(v, rep, 2) if rep > 1 else v
    out = attention_ref(q.transpose(0, 2, 1, 3).reshape(B * Hq, T, Dh),
                        kr.transpose(0, 2, 1, 3).reshape(B * Hq, -1, Dh),
                        vr.transpose(0, 2, 1, 3).reshape(B * Hq, -1, Dh),
                        causal=causal)
    return out.reshape(B, Hq, T, Dh).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("T,Hq,Hkv,Dh,bq,bk,causal,dtype", [
    (128, 4, 4, 64, 64, 64, True, jnp.float32),
    (256, 8, 2, 64, 128, 64, True, jnp.float32),
    (256, 4, 1, 128, 64, 128, True, jnp.float32),   # MQA
    (128, 2, 2, 64, 32, 64, False, jnp.float32),
    (256, 4, 2, 64, 128, 128, True, jnp.bfloat16),
])
def test_flash_sweep(T, Hq, Hkv, Dh, bq, bk, causal, dtype):
    keys = jax.random.split(jax.random.key(T + Hq), 3)
    B = 2
    q = jax.random.normal(keys[0], (B, T, Hq, Dh), dtype)
    k = jax.random.normal(keys[1], (B, T, Hkv, Dh), dtype)
    v = jax.random.normal(keys[2], (B, T, Hkv, Dh), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    ref = _ref_gqa(q, k, v, causal)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_flash_cross_attention_shape():
    """Tk != Tq (encoder-decoder cross attention path)."""
    B, Tq, Tk, H, Dh = 1, 128, 256, 2, 64
    q = jax.random.normal(jax.random.key(0), (B, Tq, H, Dh))
    k = jax.random.normal(jax.random.key(1), (B, Tk, H, Dh))
    v = jax.random.normal(jax.random.key(2), (B, Tk, H, Dh))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    ref = _ref_gqa(q, k, v, False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    assert out.shape == (B, Tq, H, Dh)
