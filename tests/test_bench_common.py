"""benchmarks/common.py merge-by-config writer: smoke runs must never evict
gate rows from a BENCH_*.json trajectory (the clobbering was the satellite
bug that erased the n = 64 gate evidence from the repo root)."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
import common  # noqa: E402


@pytest.fixture
def bench_dirs(tmp_path, monkeypatch):
    results = tmp_path / "bench"
    root = tmp_path / "root"
    results.mkdir()
    root.mkdir()
    monkeypatch.setattr(common, "RESULTS_DIR", str(results))
    monkeypatch.setattr(common, "ROOT_DIR", str(root))
    return results, root


def _read(d, name):
    with open(os.path.join(str(d), f"{name}.json")) as f:
        return json.load(f)


GATE_ROW = {"n": 64, "q": 2, "s": 2, "m": 400, "S": 43745,
            "dense_s": 30.0, "fused_s": 1.5, "speedup": 20.0}
SMOKE_ROW = {"n": 16, "q": 2, "s": 2, "m": 100, "S": 577,
             "dense_s": 0.8, "fused_s": 1.1, "speedup": 0.7}


def test_smoke_save_cannot_evict_gate_row(bench_dirs):
    """The satellite regression: gate row first, smoke row second — BOTH
    must be present afterwards, in both mirror locations."""
    results, root = bench_dirs
    common.save("BENCH_preprocess", [GATE_ROW])
    common.save("BENCH_preprocess", [SMOKE_ROW])
    for d in (results, root):
        rows = _read(d, "BENCH_preprocess")
        ns = sorted(r["n"] for r in rows)
        assert ns == [16, 64], rows


def test_same_config_row_is_replaced_not_duplicated(bench_dirs):
    results, _ = bench_dirs
    common.save("BENCH_preprocess", [GATE_ROW])
    newer = dict(GATE_ROW, speedup=22.5, dense_s=31.0)
    common.save("BENCH_preprocess", [newer])
    rows = _read(results, "BENCH_preprocess")
    assert len(rows) == 1
    assert rows[0]["speedup"] == 22.5


def test_mode_and_delta_distinguish_stream_rows(bench_dirs):
    """A stream-mode row at the same (n, q, s, m) is a DIFFERENT config."""
    results, _ = bench_dirs
    stream = dict(GATE_ROW, mode="stream", prune_delta=20.0,
                  stream_s=2.0, speedup=1.4)
    common.save("BENCH_preprocess", [GATE_ROW])
    common.save("BENCH_preprocess", [stream])
    rows = _read(results, "BENCH_preprocess")
    assert len(rows) == 2


def test_merge_survives_legacy_single_dict_payload(bench_dirs):
    """Pre-fix files sometimes held a bare dict; the merge writer must read
    them and keep merging rather than crash or clobber."""
    results, _ = bench_dirs
    path = os.path.join(str(results), "legacy.json")
    with open(path, "w") as f:
        json.dump(GATE_ROW, f)
    common.save("legacy", [SMOKE_ROW])
    rows = _read(results, "legacy")
    assert len(rows) == 2


def test_merge_rows_pure_function():
    merged = common.merge_rows([GATE_ROW], [SMOKE_ROW, dict(GATE_ROW,
                                                            speedup=9.0)])
    assert len(merged) == 2
    assert merged[0]["speedup"] == 9.0       # same config replaced in place
    assert merged[1]["n"] == 16
