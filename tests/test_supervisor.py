"""Fault-tolerant run supervisor (ISSUE 8): verified checkpoints, chaos
injection, telemetry-driven chain healing, and the crash-resume determinism
contract."""
import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, CheckpointCorruptError,
                              io_retry, latest_step, quarantine_step,
                              restore_checkpoint, restore_latest_verified,
                              save_checkpoint)
from repro.runtime.faults import (FaultEvent, InjectedCrash,
                                  corrupt_checkpoint_dir, parse_fault_plan,
                                  poison_chain_state)


# ------------------------------------------------------------- fault plans
def test_fault_plan_grammar():
    plan = parse_fault_plan(
        "crash@2:before; corrupt@1:leaf=leaf_3:truncate,"
        "poison@0:chain=1:inf;stall@3;cache@2:delete", seed=7)
    kinds = [(e.kind, e.segment) for e in plan.events]
    # sorted by (segment, kind order)
    assert kinds == [("poison", 0), ("corrupt", 1), ("crash", 2),
                     ("cache", 2), ("stall", 3)]
    ev = {e.kind: e for e in plan.events}
    assert ev["crash"].mode == "before"
    assert ev["corrupt"].leaf == "leaf_3" and ev["corrupt"].mode == "truncate"
    assert ev["poison"].chain == 1 and ev["poison"].mode == "inf"
    assert ev["cache"].mode == "delete"
    assert plan.pre_segment(0) == [ev["poison"]]
    assert plan.checkpoint_events(2) == (True, [], False)
    assert plan.checkpoint_events(1) == (False, [ev["corrupt"]], False)
    # defaults
    d = parse_fault_plan("crash@0;corrupt@0;poison@0;cache@0")
    modes = {e.kind: e.mode for e in d.events}
    assert modes == {"crash": "after", "corrupt": "bitflip",
                     "poison": "nan", "cache": "truncate"}
    assert not parse_fault_plan("")          # empty spec -> falsy plan
    assert not parse_fault_plan("  ;  ")


def test_fault_plan_rejects_garbage():
    with pytest.raises(ValueError, match="unknown fault kind"):
        parse_fault_plan("explode@1")
    with pytest.raises(ValueError, match="integer segment"):
        parse_fault_plan("crash@soon")
    with pytest.raises(ValueError, match="bad option"):
        parse_fault_plan("crash@1:sideways")


def test_fault_plan_seeded_choices_are_deterministic(tmp_path):
    d = str(tmp_path / "ck")
    tree = tuple(np.arange(8, dtype=np.float32) + i for i in range(5))
    save_checkpoint(d, 1, tree)
    import shutil
    picked = []
    for _ in range(2):
        plan = parse_fault_plan("corrupt@0:bitflip", seed=123)
        picked.append(os.path.basename(
            plan.corrupt_checkpoint(d, plan.events[0])))
        shutil.rmtree(d)                 # pristine files for the next round
        save_checkpoint(d, 1, tree)
    assert picked[0] == picked[1]       # same seed -> same target leaf


def test_poison_chain_state_hits_cached_scores_only():
    class S:
        pass
    score = jnp.zeros(4)
    cur_ls = jnp.zeros((4, 3))
    best = jnp.ones(4)
    from collections import namedtuple
    St = namedtuple("St", "score cur_ls best_score pos")
    st = St(score, cur_ls, best, jnp.arange(4))
    out = poison_chain_state(st, 2, "inf")
    assert np.isinf(np.asarray(out.score)[2])
    assert np.isinf(np.asarray(out.cur_ls)[2]).all()
    assert np.isfinite(np.asarray(out.score)[[0, 1, 3]]).all()
    np.testing.assert_array_equal(np.asarray(out.pos), np.arange(4))


# --------------------------------------------- verified checkpoint restore
def _tree():
    return (np.arange(6, dtype=np.float32),
            np.arange(12, dtype=np.int32).reshape(3, 4))


def test_digest_verify_quarantine_and_fallback(tmp_path):
    d = str(tmp_path / "ck")
    t1 = _tree()
    t2 = tuple(a + 1 for a in t1)
    save_checkpoint(d, 10, t1)
    save_checkpoint(d, 20, t2)
    # corrupt the newest step's first leaf
    rng = np.random.default_rng(0)
    corrupt_checkpoint_dir(d, rng, leaf="leaf_0", mode="bitflip")
    with pytest.raises(CheckpointCorruptError, match="digest mismatch"):
        restore_checkpoint(d, t1, step=20)
    # verified restore falls back to step 10 and quarantines step 20
    tree, meta, step = restore_latest_verified(d, t1)
    assert step == 10
    np.testing.assert_array_equal(tree[0], t1[0])
    assert os.path.isdir(os.path.join(d, "corrupt_step_0000000020"))
    assert latest_step(d) == 10            # quarantined dirs are invisible
    # all steps corrupt -> FileNotFoundError (start from scratch)
    corrupt_checkpoint_dir(d, rng, leaf="leaf_1", mode="truncate")
    with pytest.raises(FileNotFoundError):
        restore_latest_verified(d, t1)


def test_quarantine_name_collision(tmp_path):
    d = str(tmp_path / "ck")
    save_checkpoint(d, 5, _tree())
    q1 = quarantine_step(d, 5)
    save_checkpoint(d, 5, _tree())
    q2 = quarantine_step(d, 5)
    assert q1 != q2 and os.path.isdir(q1) and os.path.isdir(q2)


def test_truncated_leaf_detected_without_digests(tmp_path):
    # even a pre-digest snapshot (manifest without 'digests') must not
    # restore a truncated array silently: np.load fails -> corrupt error
    d = str(tmp_path / "ck")
    save_checkpoint(d, 1, _tree())
    man = os.path.join(d, "step_0000000001", "manifest.json")
    with open(man) as f:
        m = json.load(f)
    del m["digests"]
    with open(man, "w") as f:
        json.dump(m, f)
    leaf = os.path.join(d, "step_0000000001", "leaf_1.npy")
    with open(leaf, "r+b") as f:
        f.truncate(os.path.getsize(leaf) // 2)
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(d, _tree(), step=1)


def test_io_retry_backs_off_then_succeeds():
    calls = []

    def flaky():
        calls.append(time.monotonic())
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    assert io_retry(flaky, what="flaky", backoff_s=0.01) == "ok"
    assert len(calls) == 3
    # non-OSError is NOT retried
    def boom():
        calls.append(None)
        raise ValueError("logic bug")
    calls.clear()
    with pytest.raises(ValueError):
        io_retry(boom, what="boom", backoff_s=0.01)
    assert len(calls) == 1


def test_async_checkpointer_surfaces_writer_errors(tmp_path, monkeypatch):
    ck = AsyncCheckpointer(str(tmp_path / "ck"), keep=2)
    import repro.checkpoint.checkpointer as mod

    def raising_save(*a, **k):
        raise OSError("disk on fire")

    monkeypatch.setattr(mod, "save_checkpoint", raising_save)
    ck.save(1, _tree())
    # the failure happened on the writer thread; the NEXT call must surface
    # it instead of silently leaving a hole in the trajectory
    with pytest.raises(OSError, match="disk on fire"):
        ck.wait()
    # the error is consumed once raised; writes work again after the patch
    monkeypatch.undo()
    ck.save(2, _tree())
    ck.wait()
    assert latest_step(str(tmp_path / "ck")) == 2
    # ... and save() itself re-raises a pending writer failure
    monkeypatch.setattr(mod, "save_checkpoint", raising_save)
    ck.save(3, _tree())
    with pytest.raises(OSError, match="disk on fire"):
        ck.save(4, _tree())
    monkeypatch.undo()


# --------------------------------------------------- NaN/inf-safe exchange
def test_exchange_step_never_donates_from_poisoned_chain():
    from repro.core.combinatorics import build_pst, n_parent_sets
    from repro.core.mcmc import exchange_step, init_chain
    from repro.core.order_scoring import score_order_chunked
    import functools

    n, s = 8, 2
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    pad = (-S) % 16
    table = jnp.pad(table, ((0, 0), (0, pad)), constant_values=-3e38)
    pst = jnp.pad(jnp.asarray(pst), ((0, pad), (0, 0)), constant_values=-1)
    fn = functools.partial(score_order_chunked, table, pst, block=16)
    keys = jax.random.split(jax.random.key(0), 4)
    states = jax.vmap(lambda k: init_chain(k, n, fn))(keys)

    # poison the would-be donor: the masked rank must re-route the exchange
    donor = int(np.argmax(np.asarray(states.best_score)))
    poisoned = poison_chain_state(states, donor, "nan")
    out = jax.jit(exchange_step)(poisoned)
    # poisoned chain ranks -inf -> it is the RECIPIENT: its pos/caches are
    # overwritten by the best remaining finite chain
    finite = np.isfinite(np.asarray(poisoned.best_score))
    best_left = int(np.argmax(np.where(finite,
                                       np.asarray(poisoned.best_score),
                                       -np.inf)))
    np.testing.assert_array_equal(np.asarray(out.pos[donor]),
                                  np.asarray(states.pos[best_left]))
    assert np.isfinite(np.asarray(out.best_score)).all()

    # and on all-finite inputs the masked rank is bitwise the old behaviour
    clean = jax.jit(exchange_step)(states)
    w = int(np.argmin(np.asarray(states.best_score)))
    b = int(np.argmax(np.asarray(states.best_score)))
    np.testing.assert_array_equal(np.asarray(clean.pos[w]),
                                  np.asarray(states.pos[b]))


def test_best_finite_chain():
    from repro.runtime.straggler import best_finite_chain
    assert best_finite_chain(np.array([1.0, 5.0, 3.0])) == 1
    assert best_finite_chain(np.array([1.0, np.nan, 3.0])) == 2
    assert best_finite_chain(np.array([np.inf, 2.0, np.nan])) == 1
    assert best_finite_chain(np.array([np.nan, np.nan])) in (0, 1)


# --------------------------------------------- supervised-run determinism
def _bn_data(n=10, m=160):
    rng = np.random.default_rng(0)
    return rng.integers(0, 2, size=(m, n)).astype(np.int8)


def _cfg(tmp_path, name, **over):
    from repro.launch.bn_learn import LearnConfig
    base = dict(q=2, s=2, iters=64, chains=4, seed=5, window=4,
                exchange_every=8, check_every=32,
                trace_dir=str(tmp_path / "traces"), run_name=name)
    base.update(over)
    return LearnConfig(**base)


def test_supervised_run_matches_plain_bitwise(tmp_path):
    from repro.launch.bn_learn import learn_structure
    data = _bn_data()
    o1 = learn_structure(data, _cfg(tmp_path, "plain", telemetry=True))
    o2 = learn_structure(data, _cfg(tmp_path, "sup", telemetry=True,
                                    supervise=True))
    assert o1["score"] == o2["score"]
    np.testing.assert_array_equal(o1["adjacency"], o2["adjacency"])
    assert o1["chain_accept_rates"] == o2["chain_accept_rates"]
    assert o2["heals"] == []


def test_crash_resume_is_bitwise_identical(tmp_path):
    from repro.launch.bn_learn import learn_structure
    data = _bn_data()
    ref = learn_structure(data, _cfg(
        tmp_path, "ref", supervise=True, checkpoint_every=32,
        checkpoint_dir=str(tmp_path / "ck_ref")))
    ckd = str(tmp_path / "ck")
    with pytest.raises(InjectedCrash):
        learn_structure(data, _cfg(
            tmp_path, "crash", supervise=True, checkpoint_every=32,
            checkpoint_dir=ckd,
            fault_plan="corrupt@0:bitflip;crash@0:after"))
    # resume: crash/corrupt events not re-armed (the arm-once discipline)
    res = learn_structure(data, _cfg(
        tmp_path, "resume", supervise=True, checkpoint_every=32,
        checkpoint_dir=ckd))
    assert ref["score"] == res["score"]
    np.testing.assert_array_equal(ref["adjacency"], res["adjacency"])
    assert ref["chain_accept_rates"] == res["chain_accept_rates"]
    # the corrupt step was quarantined on restore
    assert any(d.startswith("corrupt_step_") for d in os.listdir(ckd))


def test_poison_healed_within_one_interval(tmp_path):
    from repro.launch.bn_learn import learn_structure
    data = _bn_data()
    out = learn_structure(data, _cfg(
        tmp_path, "heal", telemetry=True, supervise=True, exchange_every=0,
        fault_plan="poison@1:chain=2:nan"))
    assert [h["chain"] for h in out["heals"]] == [2]
    h = out["heals"][0]
    assert h["reason"] == "nonfinite" and h["iter"] == 64
    assert np.isfinite(out["score"])
    # the heal row landed in the JSONL trace and the file still validates
    from repro.telemetry.validate import validate_file
    info = validate_file(out["telemetry"]["trace_path"])
    assert info["kinds"].get("heal") == 1


def test_stall_healed_by_progress_guard(tmp_path):
    from repro.launch.bn_learn import learn_structure
    data = _bn_data()
    out = learn_structure(data, _cfg(
        tmp_path, "stall", supervise=True, iters=96,
        fault_plan="stall@0:chain=1"))
    assert any(h["chain"] == 1 and h["reason"] == "stalled"
               for h in out["heals"])
    assert np.isfinite(out["score"])


def test_graceful_degradation_without_heal(tmp_path):
    # poisoned chain, NO --supervise: the run must still complete with a
    # finite best score (NaN-safe exchange + finite-guarded accumulators)
    from repro.launch.bn_learn import learn_structure
    data = _bn_data()
    out = learn_structure(data, _cfg(
        tmp_path, "degrade", telemetry=True,
        fault_plan="poison@1:chain=2:nan"))
    assert np.isfinite(out["score"])
    assert out["heals"] == []


# ------------------------------------------------------ cache chaos fault
def test_truncated_cache_entry_degrades_to_rebuild(tmp_path, caplog):
    import logging
    from repro.preprocess import build_score_table_fused
    from repro.runtime.faults import corrupt_cache_dir

    data = _bn_data(n=7, m=120)
    d = str(tmp_path / "cache")
    _, i1 = build_score_table_fused(data, q=2, s=2, cache_dir=d,
                                    return_info=True)
    assert not i1["cache_hit"]
    assert corrupt_cache_dir(d, np.random.default_rng(0),
                             mode="truncate") is not None
    with caplog.at_level(logging.WARNING, logger="repro.preprocess.cache"):
        st2, i2 = build_score_table_fused(data, q=2, s=2, cache_dir=d,
                                          return_info=True)
    assert not i2["cache_hit"]              # corrupt entry = logged miss
    assert any("ignoring" in r.message for r in caplog.records)
    # the rebuild repaired the entry in place: third call hits again
    _, i3 = build_score_table_fused(data, q=2, s=2, cache_dir=d,
                                    return_info=True)
    assert i3["cache_hit"]
    # delete mode nukes the whole entry -> plain miss
    assert corrupt_cache_dir(d, np.random.default_rng(1),
                             mode="delete") is not None
    _, i4 = build_score_table_fused(data, q=2, s=2, cache_dir=d,
                                    return_info=True)
    assert not i4["cache_hit"]


# --------------------------------------------- concurrent writers (ISSUE 10)
def test_checkpoint_concurrent_writers_second_wins(tmp_path, monkeypatch):
    """Two writers racing on the SAME entry (deduped service jobs sharing a
    cache/checkpoint dir) must each stage in a private tmp dir, and the
    second publisher must win WHOLE — never a mixed tree. The interleave is
    forced deterministically: writer A is paused at its publish point while
    writer B stages and publishes, then A publishes over B."""
    import repro.checkpoint.checkpointer as cp
    d = str(tmp_path / "ck")
    tree_a = (np.full(4, 1.0), np.arange(3))
    tree_b = (np.full(4, 2.0), np.arange(3) * 10)
    real_replace = os.replace
    raced = []

    def racing_replace(src, dst):
        if dst.endswith("step_0000000007") and not raced:
            raced.append(True)
            save_checkpoint(d, 7, tree_b)     # B lands while A is mid-publish
        return real_replace(src, dst)

    monkeypatch.setattr(cp.os, "replace", racing_replace)
    save_checkpoint(d, 7, tree_a)             # A staged first, published last
    restored, _ = restore_checkpoint(d, tree_a)   # digests must all verify
    np.testing.assert_array_equal(restored[0], tree_a[0])
    np.testing.assert_array_equal(restored[1], tree_a[1])
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


def test_checkpoint_resave_same_step_overwrites(tmp_path):
    """Re-saving a step (the single-writer race with one's own past self)
    replaces the published tree atomically instead of ENOTEMPTY-failing."""
    d = str(tmp_path / "ck")
    save_checkpoint(d, 3, (np.zeros(5),))
    save_checkpoint(d, 3, (np.ones(5),))
    restored, _ = restore_checkpoint(d, (np.empty(5),))
    np.testing.assert_array_equal(restored[0], np.ones(5))
    assert latest_step(d) == 3
