"""bnlint: per-rule fixture tests, registry regression, baseline/suppression
round-trips, and the meta-test that the analyzer runs clean over src/."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import pytest

from repro.analysis import (PYTREE_REGISTRY, RULES, lint, registered_leaves,
                            write_baseline)
from repro.analysis.engine import BaselineError, load_baseline, load_project
from repro.analysis.vmem import estimate_project

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join("tests", "fixtures", "bnlint")


def _findings(*relpaths, rule=None):
    paths = [os.path.join(FIXTURES, p) for p in relpaths] or [FIXTURES]
    res = lint(paths, root=REPO, baseline_path=None)
    fs = res.all_findings
    return [f for f in fs if f.rule == rule] if rule else fs


# ---------------------------------------------------------------- per-rule


def test_pr5_eager_retrace_replica_is_flagged():
    fs = _findings("bad_retrace_eager_switch.py", rule="retrace-eager-switch")
    assert any(f.anchor == "propose_move" for f in fs), \
        "the PR-5 propose_move pattern must be flagged"


def test_undeclared_static_range_bound():
    fs = _findings("bad_retrace_eager_switch.py",
                   rule="retrace-undeclared-static")
    assert any("window" in f.anchor for f in fs)


def test_loop_varying_static():
    fs = _findings("bad_retrace_eager_switch.py",
                   rule="retrace-loop-varying-static")
    assert any("tiled_sum.block" in f.anchor for f in fs)


def test_hostsync_in_scan_body():
    fs = _findings("bad_hostsync_scan.py", rule="hostsync-in-hot-path")
    lines = {f.line for f in fs}
    assert {13, 14, 15} <= lines, f"scan-body syncs missed: {sorted(lines)}"
    assert any("_norm_of" in f.anchor for f in fs), \
        "transitively-hot helper missed"
    assert not any("drain" in f.anchor for f in fs), \
        "host-side boundary code must NOT be flagged"


def test_pallas_blockspec_mismatches():
    fs = _findings("bad_pallas_blockspec.py", rule="pallas-spec-mismatch")
    msgs = " | ".join(f.message for f in fs)
    assert "index_map takes 1 args but the grid has 2" in msgs
    assert "rank 3 but out_shape[0] is rank 2" in msgs


def test_pallas_interpret_hardcoded():
    fs = _findings("bad_pallas_blockspec.py",
                   rule="pallas-interpret-hardcoded")
    assert len(fs) == 1 and "interpret=True" in fs[0].message


def test_pytree_unregistered_field():
    fs = _findings("bad_pytree_field.py", rule="pytree-unregistered-field")
    assert len(fs) == 1
    assert "temperature" in fs[0].message
    assert "adapt_err" in fs[0].message and "step" in fs[0].message


def test_telemetry_unknown_kind():
    fs = _findings("bad_telemetry_kind.py", rule="telemetry-unknown-kind")
    assert len(fs) == 1 and "wibble" in fs[0].message, \
        "undeclared kind flagged once; the declared 'segment' row is clean"


def test_bench_config_rules():
    near = _findings("bad_bench_config.py", rule="bench-unknown-config-key")
    assert len(near) == 1 and "flipp" in near[0].message \
        and "flip_p" in near[0].message
    none = _findings("bad_bench_config.py", rule="bench-row-no-config")
    assert len(none) == 1


def test_clean_fixture_has_zero_findings():
    assert _findings("good_clean.py") == []


# ---------------------------------------------------- registry regression


def test_registry_pins_chainstate_13_and_tracestate_7():
    assert registered_leaves("ChainState") == 13
    assert registered_leaves("TraceState") == 7


def test_registry_matches_live_namedtuples():
    from repro.core.mcmc import ChainState
    from repro.telemetry.taps import TraceState
    assert ChainState._fields == PYTREE_REGISTRY["ChainState"]["fields"]
    assert TraceState._fields == PYTREE_REGISTRY["TraceState"]["fields"]
    # the positional checkpoint layout counts jax pytree leaves, so pin the
    # leaf counts too (one leaf per field for array-valued states)
    chain = ChainState(*[jnp.zeros(()) for _ in ChainState._fields])
    trace = TraceState(*[jnp.zeros(()) for _ in TraceState._fields])
    import jax
    assert len(jax.tree_util.tree_leaves(chain)) == 13
    assert len(jax.tree_util.tree_leaves(trace)) == 7


# ------------------------------------------------- baseline & suppression


def test_baseline_requires_reasons(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({"findings": [
        {"rule": "r", "path": "p.py", "anchor": "f", "reason": "  "}]}))
    with pytest.raises(BaselineError):
        load_baseline(str(p))


def test_baseline_roundtrip_and_staleness(tmp_path):
    fs = _findings("bad_telemetry_kind.py")
    p = tmp_path / "baseline.json"
    write_baseline(str(p), fs, {f.key: "fixture corpus" for f in fs})
    res = lint([os.path.join(FIXTURES, "bad_telemetry_kind.py")], root=REPO,
               baseline_path=str(p))
    assert res.new == [] and len(res.baselined) == len(fs)
    # the same baseline against a clean file reports every entry as stale
    res2 = lint([os.path.join(FIXTURES, "good_clean.py")], root=REPO,
                baseline_path=str(p))
    assert set(res2.stale_baseline) == {f.key for f in fs}


def test_inline_suppression_comment(tmp_path):
    src = ('def emit(c, run):\n'
           '    c._emit({"schema": "s", "kind": "zork",'
           ' "run": run})  # bnlint: disable=telemetry-unknown-kind\n')
    f = tmp_path / "suppressed.py"
    f.write_text(src)
    res = lint([str(f)], root=str(tmp_path), baseline_path=None)
    assert res.new == [] and len(res.suppressed) == 1


def test_shipped_baseline_entries_all_have_reasons():
    from repro.analysis.engine import DEFAULT_BASELINE
    entries = load_baseline(DEFAULT_BASELINE)
    assert entries, "shipped baseline should document the in-scan helpers"
    for key, reason in entries.items():
        assert len(reason) > 40, f"{key}: reason too thin to justify anything"


# ----------------------------------------------------------- integration


def test_src_is_clean_under_shipped_baseline():
    res = lint(["src", "benchmarks"], root=REPO)
    assert res.new == [], "unbaselined findings in src/:\n" + "\n".join(
        f.render() for f in res.new)
    assert res.stale_baseline == []


def test_cli_fails_on_fixture_corpus():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", FIXTURES, "--no-baseline",
         "--fail-on-findings"],
        cwd=REPO, capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert proc.returncode == 2, proc.stdout + proc.stderr


def test_rules_listing_is_complete():
    assert len(RULES) == 12
    fired = {f.rule for f in _findings()}
    assert fired <= set(RULES)


# ------------------------------------------------------------------ vmem


def test_vmem_estimates_cover_every_kernel():
    project = load_project(["src/repro/kernels", "src/repro/preprocess"],
                           root=REPO)
    rows = estimate_project(project)
    names = {r["variant"] for r in rows}
    assert {"count_pallas", "flash_attention_pallas",
            "order_score_window_pallas", "fused_scores_pallas"} <= names
    for r in rows:
        assert r["mode"] == "static"
        assert 0 < r["vmem_bytes"] < 16 * 2**20, \
            f"{r['variant']} estimate implausible: {r['vmem_bytes']}"
        assert r["vmem_frac_of_budget"] < 1.0
