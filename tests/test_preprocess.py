"""Preprocessing subsystem: fused pipeline vs the core/scores oracle, sparse
table semantics (lookup + pruning guarantee), planner, and disk cache."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _propcheck import given, hst, settings

from repro.core.combinatorics import build_pst, rank_parent_set
from repro.core.order_scoring import (score_order_blocked, score_order_pruned,
                                      score_order_pruned_delta)
from repro.core.scores import build_score_table
from repro.core.sharded_scoring import pad_table
from repro.preprocess import (SparseScoreTable, build_score_table_fused,
                              plan_preprocess, prune_table)
from repro.preprocess.fused import (encode_subset_codes, fused_scores_pallas,
                                    fused_scores_ref, score_luts)


def _rand_problem(rng, n, q, m):
    return rng.integers(0, q, size=(m, n)).astype(np.int32)


# ------------------------------------------------------------ fused == oracle
@given(hst.data())
@settings(max_examples=6, deadline=None)
def test_fused_matches_oracle_property(data_strategy):
    """Fused pipeline == build_score_table over random (n, q, s, m) to the
    ISSUE's 1e-4 absolute gate (bitwise on CPU by construction)."""
    rng_seed = data_strategy.draw(hst.integers(0, 2**31 - 1))
    rng = np.random.default_rng(rng_seed)
    n = data_strategy.draw(hst.integers(5, 11))
    q = data_strategy.draw(hst.integers(2, 4))
    s = data_strategy.draw(hst.integers(1, 3))
    m = data_strategy.draw(hst.integers(40, 200))
    data = _rand_problem(rng, n, q, m)
    want = np.asarray(build_score_table(data, q=q, s=s).table)
    got = np.asarray(build_score_table_fused(data, q=q, s=s).table)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=0)


def test_fused_matches_oracle_with_prior():
    rng = np.random.default_rng(3)
    n, q, s, m = 9, 2, 3, 150
    data = _rand_problem(rng, n, q, m)
    R = np.full((n, n), 0.5, np.float32)
    R[1, 0] = 0.95
    R[4, 2] = 0.1
    want = np.asarray(build_score_table(data, q=q, s=s, prior_matrix=R).table)
    got = np.asarray(build_score_table_fused(data, q=q, s=s,
                                             prior_matrix=R).table)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=0)


def test_fused_small_chunk_matches():
    """Chunking must not change values (multiple chunks per device scan)."""
    rng = np.random.default_rng(4)
    n, q, s, m = 8, 2, 2, 120
    data = _rand_problem(rng, n, q, m)
    want = np.asarray(build_score_table_fused(data, q=q, s=s).table)
    got = np.asarray(build_score_table_fused(data, q=q, s=s, chunk=7).table)
    np.testing.assert_array_equal(got, want)


def test_fused_pallas_kernel_matches_ref():
    """Pallas fused count+score == jnp fused chunk (interpret mode), with the
    padded sample rows deliberately CORRUPTED in the child one-hot — the
    in-kernel mask must neutralise them."""
    rng = np.random.default_rng(5)
    n, q, s, m = 7, 3, 2, 100
    data = _rand_problem(rng, n, q, m)
    data_ext = jnp.asarray(np.concatenate([data, np.zeros((m, 1), np.int32)],
                                          axis=1))
    sub, ssz = build_pst(n, s)
    lut_k, lut_j = score_luts(q, s, m, 1.0)
    child_oh = jax.nn.one_hot(data_ext[:, :n].reshape(-1), q,
                              dtype=jnp.float32).reshape(m, n * q)
    want = fused_scores_ref(data_ext, child_oh, jnp.asarray(sub),
                            jnp.asarray(ssz), lut_k, lut_j, q=q, s=s, n=n)
    block_m = 64
    pad = (-m) % block_m
    codes = encode_subset_codes(data_ext, jnp.asarray(sub), q).T
    codes_p = jnp.pad(codes, ((0, 0), (0, pad)), constant_values=-1)
    child_p = jnp.pad(child_oh, ((0, pad), (0, 0)), constant_values=1.0)
    got = fused_scores_pallas(codes_p, child_p, jnp.asarray(ssz), q=q, s=s,
                              n=n, ess=1.0, block_m=block_m, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=0)


# ------------------------------------------------------------ sparse table
@pytest.fixture(scope="module")
def sparse_problem():
    rng = np.random.default_rng(7)
    n, q, s, m = 9, 2, 3, 250
    data = _rand_problem(rng, n, q, m)
    st = build_score_table(data, q=q, s=s)
    return st, prune_table(st, 15.0)


def test_sparse_prune_rule_exact(sparse_problem):
    """Kept set per node == {t : ls >= best - delta} + the empty set."""
    st, sp = sparse_problem
    tbl = np.asarray(st.table)
    best = tbl.max(axis=1)
    ki = np.asarray(sp.kept_idx)
    for i in range(sp.n):
        want = set(np.nonzero(tbl[i] >= best[i] - sp.delta)[0]) | {0}
        got = set(ki[i][ki[i] >= 0].tolist())
        assert got == want


def test_sparse_lookup_matches_dense_on_kept(sparse_problem):
    """Open-addressing lookup returns the exact dense score for every kept
    entry and NEG_INF for pruned ones; works under jit/vmap."""
    st, sp = sparse_problem
    tbl = np.asarray(st.table)
    ki = np.asarray(sp.kept_idx)
    for i in range(sp.n):
        idxs = ki[i][ki[i] >= 0]
        got = np.asarray(sp.lookup(np.full(len(idxs), i), idxs))
        np.testing.assert_array_equal(got, tbl[i, idxs])
        pruned = np.setdiff1d(np.arange(sp.S), idxs)[:50]
        if len(pruned):
            miss = np.asarray(sp.lookup(np.full(len(pruned), i), pruned))
            assert (miss < -1e38).all()
    # jit + vmap usability (the hot-path claim)
    f = jax.jit(jax.vmap(sp.lookup))
    nodes = jnp.asarray([0, 1, 2], jnp.int32)
    idxs = jnp.asarray([0, 0, 0], jnp.int32)
    np.testing.assert_array_equal(np.asarray(f(nodes, idxs)), tbl[:3, 0])


def test_sparse_dense_fallback_exact(sparse_problem):
    """to_dense(): bitwise-equal on kept entries, NEG_INF elsewhere."""
    st, sp = sparse_problem
    dense = np.asarray(sp.table)
    tbl = np.asarray(st.table)
    keep = tbl >= (tbl.max(1)[:, None] - sp.delta)
    keep[:, 0] = True
    np.testing.assert_array_equal(dense[keep], tbl[keep])
    assert (dense[~keep] < -1e38).all()


def test_pruning_guarantee(sparse_problem):
    """Pruned order score <= dense order score, with equality whenever each
    node's dense-consistent argmax survived pruning — and always at
    delta = +inf (exhaustive keep)."""
    st, sp = sparse_problem
    n = sp.n
    table, pst = pad_table(st.table, st.pst, 64)
    sp_inf = prune_table(st, 1e9)
    tbl = np.asarray(st.table)
    best = tbl.max(axis=1)
    rng = np.random.default_rng(11)
    for _ in range(10):
        pos = jnp.asarray(rng.permutation(n).astype(np.int32))
        d_tot, d_idx, d_ls = score_order_blocked(table, pst, pos, block=64)
        p_tot, p_idx, p_ls = score_order_pruned(sp.kept_ls, sp.kept_parents,
                                                sp.kept_idx, pos)
        assert float(p_tot) <= float(d_tot) + 1e-4
        if np.all(np.asarray(d_ls) >= best - sp.delta):
            assert float(p_tot) == float(d_tot)
            np.testing.assert_array_equal(np.asarray(p_idx),
                                          np.asarray(d_idx))
        i_tot, i_idx, _ = score_order_pruned(
            sp_inf.kept_ls, sp_inf.kept_parents, sp_inf.kept_idx, pos)
        assert float(i_tot) == float(d_tot)
        np.testing.assert_array_equal(np.asarray(i_idx), np.asarray(d_idx))


def test_pruned_delta_equals_full(sparse_problem):
    """Windowed incremental rescore == full pruned rescore, bitwise."""
    _, sp = sparse_problem
    n = sp.n
    rng = np.random.default_rng(13)
    kept = (sp.kept_ls, sp.kept_parents, sp.kept_idx)
    pos = jnp.asarray(rng.permutation(n).astype(np.int32))
    _, idx, ls = score_order_pruned(*kept, pos)
    for _ in range(10):
        # bounded-window perturbation: swap inside a window of 4 at lo
        lo = int(rng.integers(0, n - 3))
        a, b = lo + int(rng.integers(0, 4)), lo + int(rng.integers(0, 4))
        posn = np.asarray(pos).copy()
        ia, ib = np.nonzero(posn == a)[0][0], np.nonzero(posn == b)[0][0]
        posn[ia], posn[ib] = b, a
        posn = jnp.asarray(posn)
        want = score_order_pruned(*kept, posn)
        got = score_order_pruned_delta(*kept, posn, ls, idx,
                                       jnp.int32(lo), window=4)
        assert float(got[0]) == float(want[0])
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
        np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))
        pos, idx, ls = posn, want[1], want[2]


# ---------------------------------------------------------------- planner
def test_planner_coverage_and_balance():
    """Every chunk lands on exactly one device; LPT keeps the cost imbalance
    within the classic 4/3 bound of the mean for these unit shapes."""
    sub, ssz = build_pst(20, 3)
    chunk = 64
    pad = (-len(ssz)) % chunk
    ssz_p = np.pad(ssz, (0, pad))
    for ndev in (1, 2, 3, 7):
        plan = plan_preprocess(ssz_p, chunk, m=100, q=2, n_devices=ndev)
        seen = sorted(c for b in plan.device_chunks for c in b)
        assert seen == list(range(plan.n_chunks))
        assert plan.imbalance <= 4 / 3 + 1e-9
        # padded lists all share one width and only repeat real ids
        widths = {len(p) for p in plan.padded_chunks}
        assert len(widths) == 1
        for b, p in zip(plan.device_chunks, plan.padded_chunks):
            assert set(p.tolist()) == set(b)


def test_planner_cost_model():
    """Costs follow the paper's q^{|pi|} * m estimate."""
    ssz = np.asarray([0, 1, 2, 2])
    plan = plan_preprocess(ssz, chunk=2, m=10, q=3, n_devices=1)
    np.testing.assert_allclose(plan.costs, [(1 + 3) * 10, (9 + 9) * 10])


# ------------------------------------------------------------------ cache
def test_cache_roundtrip_and_key_sensitivity(tmp_path):
    rng = np.random.default_rng(17)
    n, q, s, m = 7, 2, 2, 90
    data = _rand_problem(rng, n, q, m)
    d = str(tmp_path)
    st1, i1 = build_score_table_fused(data, q=q, s=s, cache_dir=d,
                                      return_info=True)
    st2, i2 = build_score_table_fused(data, q=q, s=s, cache_dir=d,
                                      return_info=True)
    assert not i1["cache_hit"] and i2["cache_hit"]
    np.testing.assert_array_equal(np.asarray(st1.table), np.asarray(st2.table))
    np.testing.assert_array_equal(np.asarray(st1.pst), np.asarray(st2.pst))
    # different hyperparameters or data must MISS
    _, i3 = build_score_table_fused(data, q=q, s=s, ess=2.0, cache_dir=d,
                                    return_info=True)
    assert not i3["cache_hit"]
    data2 = data.copy()
    data2[0, 0] ^= 1
    _, i4 = build_score_table_fused(data2, q=q, s=s, cache_dir=d,
                                    return_info=True)
    assert not i4["cache_hit"]
    # pruning reuses the dense cache entry
    sp, i5 = build_score_table_fused(data, q=q, s=s, prune_delta=5.0,
                                     cache_dir=d, return_info=True)
    assert i5["cache_hit"] and isinstance(sp, SparseScoreTable)


# ------------------------------------------------- end-to-end via bn_learn
def test_learn_structure_fused_sparse_end_to_end(tmp_path):
    """preprocess -> MCMC -> adjacency through the driver, fused + pruned +
    cached; the second run must hit the preprocessing cache."""
    from repro.launch.bn_learn import LearnConfig, learn_structure

    rng = np.random.default_rng(19)
    from repro.core import random_cpts, random_dag
    from repro.data import ancestral_sample
    adj = random_dag(rng, 8, 2, 0.4)
    cpts = random_cpts(rng, adj, 2)
    data = ancestral_sample(rng, adj, cpts, 300, 2)
    cfg = LearnConfig(q=2, s=2, iters=60, seed=1, window=4,
                      preprocess="fused", prune_delta=25.0,
                      cache_dir=str(tmp_path))
    out1 = learn_structure(data, cfg)
    assert out1["adjacency"].shape == (8, 8)
    assert not out1["preprocess_cache_hit"]
    out2 = learn_structure(data, cfg)
    assert out2["preprocess_cache_hit"]
    assert out1["score"] == out2["score"]
