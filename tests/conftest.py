"""Shared fixtures and suite-wide config.

* forces JAX onto CPU (override with JAX_PLATFORMS=tpu on real hardware) —
  the suite validates numerics; kernels run in interpret mode;
* registers the `slow` marker: heavy/TPU-only tests skip cleanly off-TPU
  unless RUN_SLOW=1;
* small ALARM-like problem + seeded-key fixtures shared across modules.
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: heavy or TPU-only test (skipped off-TPU unless "
                   "RUN_SLOW=1)")


def pytest_collection_modifyitems(config, items):
    import jax
    if jax.default_backend() == "tpu" or os.environ.get("RUN_SLOW"):
        return
    skip = pytest.mark.skip(reason="slow/TPU-only (set RUN_SLOW=1 to force)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.key(0)


@pytest.fixture(scope="session")
def alarm_like():
    """Small ALARM-like problem: (score_table, true_adjacency). n=8, q=2,
    s=3 — big enough for nontrivial parent sets, small enough for the CPU
    suite."""
    from repro.core import build_score_table, random_cpts, random_dag
    from repro.data import ancestral_sample

    rng = np.random.default_rng(0)
    n, q, s, m = 8, 2, 3, 800
    adj = random_dag(rng, n, s, 0.4)
    cpts = random_cpts(rng, adj, q)
    data = ancestral_sample(rng, adj, cpts, m, q)
    return build_score_table(data, q=q, s=s), adj


@pytest.fixture(scope="session")
def padded_random_table():
    """Synthetic (table, pst, block) padded for the blocked/delta scorers —
    scoring cost and correctness depend only on (n, S), so random tables are
    the right fixture for scorer-equivalence tests."""
    import jax.numpy as jnp

    from repro.core.combinatorics import build_pst, n_parent_sets
    from repro.core.order_scoring import NEG_INF

    n, s, block = 12, 3, 64
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(42)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    pad = (-S) % block
    table = jnp.pad(table, ((0, 0), (0, pad)), constant_values=NEG_INF)
    pst = jnp.pad(jnp.asarray(pst), ((0, pad), (0, 0)), constant_values=-1)
    return table, pst, block
