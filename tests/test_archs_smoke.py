"""Per-arch smoke tests (deliverable f): reduced family-preserving configs,
one forward/train step + one decode step on CPU; output shapes + no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update

B, T = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    tok = jax.random.randint(ks[0], (B, T), 0, cfg.vocab)
    lab = jax.random.randint(ks[1], (B, T), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": lab}
    if cfg.family == "encdec":
        batch["enc_feats"] = jax.random.normal(
            ks[2], (B, T // cfg.enc_seq_divisor, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    if cfg.family == "moe":
        # lossless capacity so prefill+decode == forward exactly (capacity
        # dropping itself is covered by test_moe_capacity_drops)
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    model = Model(cfg, tp=1)
    params = model.init(jax.random.key(0))
    return request.param, cfg, model, params


def test_moe_capacity_drops():
    """With capacity_factor ~0, every token is dropped -> MoE output is the
    dense residual only (arctic) or zero (granite-moe)."""
    import dataclasses
    from repro.models.moe import moe_apply
    cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").reduced(),
                              capacity_factor=0.0)
    model = Model(cfg, tp=1)
    params = model.init(jax.random.key(0))
    # capacity floor is 8: use enough tokens that > 8 land on one expert
    x = jax.random.normal(jax.random.key(1), (4, 64, cfg.d_model), jnp.float32)
    blk = jax.tree.map(lambda a: a[0], params["layers"])
    y_low = moe_apply(blk["moe"], x, cfg=cfg, tp=1)
    cfg_hi = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    y_hi = moe_apply(blk["moe"], x, cfg=cfg_hi, tp=1)
    # low capacity must actually change (drop) some token outputs
    assert bool(jnp.any(jnp.abs(y_low - y_hi) > 1e-6))


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, model, params = arch_setup
    batch = _batch(cfg, jax.random.key(1))
    logits, _ = jax.jit(model.forward)(
        params, batch["tokens"], enc_feats=batch.get("enc_feats"))
    assert logits.shape == (B, T, model.v_pad)
    real = logits[:, :, :cfg.vocab]
    assert np.isfinite(np.asarray(real, np.float32)).all(), f"{arch}: NaN/inf logits"


def test_train_step_decreases_nothing_nan(arch_setup):
    arch, cfg, model, params = arch_setup
    batch = _batch(cfg, jax.random.key(2))
    opt = adamw_init(params, AdamWConfig())

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt, m = adamw_update(grads, opt, params, AdamWConfig(lr=1e-3))
        return params, opt, loss

    params2, opt, loss = step(params, opt, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, params2))
    assert moved, f"{arch}: optimizer step was a no-op"
    # a loss near ln(vocab) for random init (weak sanity bound)
    assert float(loss) < 2.0 * np.log(cfg.vocab)


def test_prefill_then_decode_matches_forward(arch_setup):
    """KV-cache/state correctness: prefill T−1 tokens then decode one step
    must reproduce the pure forward logits at the last position."""
    arch, cfg, model, params = arch_setup
    batch = _batch(cfg, jax.random.key(3))
    tok = batch["tokens"]
    enc = batch.get("enc_feats")

    full, _ = jax.jit(model.forward)(params, tok, enc_feats=enc)

    cache = model.init_cache(B, T, dtype=jnp.float32)
    logits_p, cache = jax.jit(model.prefill)(
        params, tok[:, : T - 1], cache, enc_feats=enc)
    logits_d, cache = jax.jit(model.decode_step)(
        params, cache, tok[:, T - 1:])
    assert logits_d.shape == (B, 1, model.v_pad)
    assert int(cache["index"]) == T

    a = np.asarray(full[:, -1, : cfg.vocab], np.float32)
    b = np.asarray(logits_d[:, 0, : cfg.vocab], np.float32)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3,
                               err_msg=f"{arch}: decode != forward")


def test_full_config_matches_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    expect = {
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "rwkv6-7b": (32, 4096, 0, 0, 14336, 65536),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        c = get_config(arch)
        got = (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab)
        assert got == (L, d, h, kv, ff, v), f"{arch}: {got}"
    # MoE extras
    assert get_config("granite-moe-3b-a800m").n_experts == 40
    assert get_config("granite-moe-3b-a800m").experts_top_k == 8
    assert get_config("arctic-480b").n_experts == 128
    assert get_config("arctic-480b").experts_top_k == 2
    assert get_config("arctic-480b").moe_dense_residual
