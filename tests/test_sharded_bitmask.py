"""ISSUE 4: the mesh-native bitmask engine — sharded-planes delta ≡
single-device bitmask delta ≡ full rescore, BITWISE, over 200 randomized
move sequences on a simulated 4-device mesh, with a checkpoint save/restore
mid-run; padded PST ranks (S % (tp·block) != 0) are structurally
inconsistent and can never reach best_idx; bn_learn --sharded runs (and
checkpoint-resumes) end to end.

Subprocess with 4 placeholder devices so the suite itself keeps seeing 1 CPU
device. The 200×2-move property runs inside ONE jitted lax.scan (a Python
loop of shard_map dispatches would pay ~seconds of dispatch overhead per
sequence); all bitwise comparisons happen host-side on the stacked results.
"""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.combinatorics import build_pst, n_parent_sets
    from repro.core.graph import adjacency_from_ranks
    from repro.core.mcmc import init_chain, mcmc_step, propose_move
    from repro.core.order_scoring import (build_membership_planes,
                                          build_violation_planes,
                                          consistent_mask,
                                          planes_consistent_words,
                                          score_order_delta_bitmask,
                                          unpack_mask_words)
    from repro.core.sharded_scoring import (_shard_block,
                                            make_sharded_bitmask_fns,
                                            make_sharded_planes_fn,
                                            make_sharded_score_fn, pad_table,
                                            sharded_chain_step)
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.runtime.jax_compat import make_auto_mesh, mesh_context

    n, s, w, tp, block, SEQS, MOVES = 13, 3, 4, 4, 64, 200, 2
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    pst = jnp.asarray(pst)
    blk = _shard_block(S, tp, block)
    assert S % (tp * blk) != 0, "want a ragged shard boundary for this test"
    tpad, ppad = pad_table(table, pst, tp * blk)
    cm = build_membership_planes(ppad, n)

    mesh = make_auto_mesh((1, tp), ("data", "model"))
    fn = make_sharded_score_fn(table, pst, mesh, block=block)
    bfn, planes_fn = make_sharded_bitmask_fns(table, pst, mesh, window=w,
                                              block=block)

    # padded ranks are STRUCTURALLY inconsistent: every consistency
    # representation rejects them, independent of the table pad value
    pos0 = jnp.asarray(rng.permutation(n).astype(np.int32))
    for i in range(n):
        m = np.asarray(consistent_mask(ppad, jnp.int32(i), pos0))
        assert not m[S:].any(), "padded rank passed consistent_mask"
    pl0 = build_violation_planes(ppad, pos0)
    for i in range(n):
        bits = np.asarray(unpack_mask_words(planes_consistent_words(pl0[i])))
        assert not bits[S:].any(), "padded rank consistent in bit planes"

    def one_move(carry, key):
        pos, planes, ls, idx = carry
        new_pos, lo = propose_move(key, pos, window=w)
        tot_s, idx_s, ls_s, pl_s = bfn.fn(new_pos, lo, ls, idx, pos, planes)
        tot_1, idx_1, ls_1, pl_1 = score_order_delta_bitmask(
            tpad, cm, new_pos, ls, idx, lo, pos, planes, window=w, block=blk)
        tot_f, idx_f, ls_f = fn(new_pos)
        out = (tot_s, tot_1, tot_f, idx_s, idx_1, idx_f, ls_s, ls_1, ls_f,
               jnp.all(pl_s == pl_1))
        return (new_pos, pl_s, ls_s, idx_s), out

    def one_seq(_, key):
        kp, km = jax.random.split(key)
        pos = jax.random.permutation(kp, n).astype(jnp.int32)
        planes = planes_fn(pos)
        _, idx, ls = fn(pos)
        (pos_f, planes_f, _, _), outs = jax.lax.scan(
            one_move, (pos, planes, ls, idx), jax.random.split(km, MOVES))
        planes_ok = jnp.all(planes_f == planes_fn(pos_f))
        return None, outs + (planes_ok,)

    with mesh_context(mesh):
        # sharded per-shard planes build == single-device build, word for word
        np.testing.assert_array_equal(np.asarray(planes_fn(pos0)),
                                      np.asarray(pl0))

        keys = jax.random.split(jax.random.key(7), SEQS)
        _, R = jax.jit(lambda ks: jax.lax.scan(one_seq, None, ks))(keys)
        (tot_s, tot_1, tot_f, idx_s, idx_1, idx_f, ls_s, ls_1, ls_f,
         pl_eq, planes_ok) = [np.asarray(r) for r in R]
        np.testing.assert_array_equal(tot_s, tot_1)   # sharded == single
        np.testing.assert_array_equal(tot_s, tot_f)   # == full rescore
        np.testing.assert_array_equal(idx_s, idx_1)
        np.testing.assert_array_equal(idx_s, idx_f)
        np.testing.assert_array_equal(ls_s, ls_1)
        np.testing.assert_array_equal(ls_s, ls_f)
        assert pl_eq.all(), "sharded patched planes != single-device planes"
        assert planes_ok.all(), "carried planes drifted from rebuild"
        assert int(idx_s.max()) < S, "padded rank leaked into best_idx"
        for row in idx_s[-1]:
            adjacency_from_ranks(row, s=s)            # decodes, never raises

        # checkpoint save/restore mid-run: positions + caches roundtrip, the
        # planes (a derived cache) are REBUILT per shard, and the continued
        # walk stays bitwise on the equivalence
        srng = np.random.default_rng(123)
        pos = jnp.asarray(srng.permutation(n).astype(np.int32))
        planes = planes_fn(pos)
        _, idx, ls = jax.jit(fn)(pos)
        ckpt = tempfile.mkdtemp()
        save_checkpoint(ckpt, 5, (np.asarray(pos), np.asarray(ls),
                                  np.asarray(idx)))
        rest, _ = restore_checkpoint(ckpt, (np.asarray(pos), np.asarray(ls),
                                            np.asarray(idx)), step=5)
        pos2, ls2, idx2 = (jnp.asarray(x) for x in rest)
        planes2 = planes_fn(pos2)
        np.testing.assert_array_equal(np.asarray(planes2),
                                      np.asarray(planes))
        new_pos, lo = propose_move(jax.random.key(9), pos2, window=w)
        got = jax.jit(bfn.fn)(new_pos, lo, ls2, idx2, pos2, planes2)
        want = jax.jit(fn)(new_pos)
        assert float(got[0]) == float(want[0])
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))

        # sharded_chain_step: cached-planes path == mask-recompute path ==
        # vmapped local steps, bitwise; planes always describe current order
        splanes = make_sharded_planes_fn(ppad, mesh, stacked=True)
        keys = jax.random.split(jax.random.key(2), 4)
        states = jax.vmap(lambda k: init_chain(k, n, fn))(keys)
        sm = states._replace(mask_planes=splanes(states.pos))
        sd = sl = states
        for _ in range(3):
            sm = sharded_chain_step(sm, tpad, ppad, mesh, cm, block=blk,
                                    window=w)
            sd = sharded_chain_step(sd, tpad, ppad, mesh, block=blk, window=w)
            sl = jax.vmap(lambda st: mcmc_step(st, fn, None, w))(sl)
        np.testing.assert_array_equal(np.asarray(sm.pos), np.asarray(sd.pos))
        np.testing.assert_array_equal(np.asarray(sm.pos), np.asarray(sl.pos))
        np.testing.assert_array_equal(np.asarray(sm.accepts),
                                      np.asarray(sd.accepts))
        np.testing.assert_array_equal(np.asarray(sm.cur_ls),
                                      np.asarray(sl.cur_ls))
        np.testing.assert_array_equal(np.asarray(sm.mask_planes),
                                      np.asarray(splanes(sm.pos)))
        assert (np.asarray(sm.cur_idx) < S).all()
    print("OK")
""")

LEARN_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    from repro.core import random_cpts
    from repro.data.bn_sampler import ancestral_sample
    from repro.data.networks import synthetic_adjacency
    from repro.launch.bn_learn import LearnConfig, learn_structure

    rng = np.random.default_rng(0)
    adj = synthetic_adjacency(rng, 10)
    data = ancestral_sample(rng, adj, random_cpts(rng, adj, 2), 300, 2)

    cfg = LearnConfig(q=2, s=2, iters=40, chains=2, window=4, sharded=True,
                      block=64)
    out = learn_structure(data, cfg)
    assert out["sharded"] and out["mask_cache"] and out["delta_window"] == 4
    assert np.isfinite(out["score"])

    # checkpointed sharded run + resume (planes rebuilt per shard on restore)
    ckpt = tempfile.mkdtemp()
    cfg2 = LearnConfig(q=2, s=2, iters=40, chains=2, window=4, sharded=True,
                       block=64, checkpoint_dir=ckpt, checkpoint_every=20)
    a = learn_structure(data, cfg2)
    b = learn_structure(data, cfg2)       # resumes from the last snapshot
    assert np.isfinite(a["score"]) and np.isfinite(b["score"])
    assert b["score"] >= a["score"] - 1e-4
    print("OK")
""")


def test_sharded_bitmask_property_and_padded_ranks():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_bn_learn_sharded_end_to_end():
    r = subprocess.run([sys.executable, "-c", LEARN_SCRIPT],
                       capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
