"""Gathered-experts MoE == scatter-dispatch MoE (lossless capacity, 8
placeholder devices in a subprocess)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import Model
    from repro.models.layers import set_mesh

    from repro.runtime.jax_compat import make_auto_mesh, mesh_context
    mesh = make_auto_mesh((2, 4), ("data", "model"))

    for arch in ("granite-moe-3b-a800m", "arctic-480b"):
        cfg = get_config(arch).reduced()
        # lossless capacity so both dispatch strategies drop nothing
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
        B, T = 2, 16
        m0 = Model(cfg, tp=4)
        m1 = Model(cfg, tp=4, moe_gathered=True)
        # fsdp_only flavour: batch occupies every axis, fully local dispatch
        m2 = Model(cfg, tp=4, moe_gathered=True,
                   batch_axes=("data", "model"))
        # expert-parallel a2a flavour: experts resident, tokens travel
        m3 = Model(cfg, tp=4, moe_ep=True)
        params = m0.init(jax.random.key(0))
        tok = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab)
        set_mesh(mesh)
        with mesh_context(mesh):
            a, _ = jax.jit(m0.forward)(params, tok)
            b, _ = jax.jit(m1.forward)(params, tok)
            np.testing.assert_allclose(
                np.asarray(a[..., :cfg.vocab], np.float32),
                np.asarray(b[..., :cfg.vocab], np.float32),
                rtol=3e-3, atol=3e-3, err_msg=arch)
            c, _ = jax.jit(m2.forward)(params, tok)
            np.testing.assert_allclose(
                np.asarray(a[..., :cfg.vocab], np.float32),
                np.asarray(c[..., :cfg.vocab], np.float32),
                rtol=3e-3, atol=3e-3, err_msg=arch + " fsdp_only")
            e, _ = jax.jit(m3.forward)(params, tok)
            np.testing.assert_allclose(
                np.asarray(a[..., :cfg.vocab], np.float32),
                np.asarray(e[..., :cfg.vocab], np.float32),
                rtol=3e-3, atol=3e-3, err_msg=arch + " moe_ep")

            # gradients flow (train-step viability); explicit out_shardings
            # sidestep a gspmd->named conversion bug on grad-of-shard_map
            def loss(p):
                lg, _ = m1.forward(p, tok)
                return jnp.mean(lg[..., : cfg.vocab].astype(jnp.float32) ** 2)
            from jax.sharding import NamedSharding
            outs = jax.tree.map(lambda s: NamedSharding(mesh, s), m1.specs())
            g = jax.jit(jax.grad(loss), out_shardings=outs)(params)
            assert all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
                       for x in jax.tree.leaves(g)), arch
        set_mesh(None)
    print("OK")
""")


def test_moe_gathered_matches_scatter():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, (r.stderr[-4000:], r.stdout[-500:])
    assert "OK" in r.stdout
