"""Launcher CLI smoke tests: train (with checkpoint resume) and serve run
end to end on reduced configs."""
import jax
import numpy as np
import pytest


def test_train_runs_and_loss_drops(tmp_path):
    from repro.launch import train
    out = train.main([
        "--arch", "granite-moe-3b-a800m", "--reduced",
        "--steps", "6", "--batch", "2", "--seq", "32",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
        "--log-every", "3",
    ])
    assert np.isfinite(out["last_loss"])
    # resume: a second invocation continues from the final snapshot
    out2 = train.main([
        "--arch", "granite-moe-3b-a800m", "--reduced",
        "--steps", "8", "--batch", "2", "--seq", "32",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
        "--log-every", "4",
    ])
    assert len(out2["losses"]) <= 3, "resume should skip completed steps"


def test_serve_generates_valid_tokens():
    from repro.launch import serve
    out = serve.main([
        "--arch", "recurrentgemma-9b", "--reduced",
        "--batch", "2", "--prompt-len", "8", "--gen", "4",
    ])
    assert out["tokens"].shape == (2, 12)


def test_bn_learn_cli():
    from repro.launch import bn_learn
    out = bn_learn.main(["--network", "stn", "--iters", "50",
                         "--samples", "200"])
    assert np.isfinite(out["score"])
    assert out["adjacency"].shape == (11, 11)


def test_bn_learn_cli_rejects_degenerate_windows():
    """--window 1 (no in-window move) and --window > n (would be silently
    clamped mid-trace) fail FAST with a readable argparse error."""
    from repro.launch import bn_learn
    for bad in ("1", "-3", "12"):        # stn has n=11 nodes
        with pytest.raises(SystemExit):
            bn_learn.main(["--network", "stn", "--iters", "10",
                           "--samples", "50", "--window", bad])
    # boundary: window == n is legal (delta may still reject via crossover)
    out = bn_learn.main(["--network", "stn", "--iters", "10",
                         "--samples", "50", "--window", "11"])
    assert np.isfinite(out["score"])


def test_bn_learn_cli_adaptive_and_exchange():
    """--adapt-window and --exchange-every compose through the CLI."""
    from repro.launch import bn_learn
    out = bn_learn.main(["--network", "stn", "--iters", "60", "--chains", "2",
                         "--samples", "200", "--adapt-window",
                         "--burn-in", "20", "--exchange-every", "15"])
    assert np.isfinite(out["score"])
    assert out["adaptive_windows"] == [2, 4]       # n=11 caps the set at 4
