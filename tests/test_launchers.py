"""Launcher CLI smoke tests: train (with checkpoint resume) and serve run
end to end on reduced configs."""
import jax
import numpy as np
import pytest


def test_train_runs_and_loss_drops(tmp_path):
    from repro.launch import train
    out = train.main([
        "--arch", "granite-moe-3b-a800m", "--reduced",
        "--steps", "6", "--batch", "2", "--seq", "32",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
        "--log-every", "3",
    ])
    assert np.isfinite(out["last_loss"])
    # resume: a second invocation continues from the final snapshot
    out2 = train.main([
        "--arch", "granite-moe-3b-a800m", "--reduced",
        "--steps", "8", "--batch", "2", "--seq", "32",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
        "--log-every", "4",
    ])
    assert len(out2["losses"]) <= 3, "resume should skip completed steps"


def test_serve_generates_valid_tokens():
    from repro.launch import serve
    out = serve.main([
        "--arch", "recurrentgemma-9b", "--reduced",
        "--batch", "2", "--prompt-len", "8", "--gen", "4",
    ])
    assert out["tokens"].shape == (2, 12)


def test_bn_learn_cli():
    from repro.launch import bn_learn
    out = bn_learn.main(["--network", "stn", "--iters", "50",
                         "--samples", "200"])
    assert np.isfinite(out["score"])
    assert out["adjacency"].shape == (11, 11)
