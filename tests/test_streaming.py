"""Streaming pruned assembly (preprocess/streaming.py): bitwise equality
against the dense build-then-prune oracle, the no-dense-intermediate memory
guarantee, the info-schema contract, and the pruned/verified disk cache."""
import json
import os
import tracemalloc

import numpy as np
import pytest
from _propcheck import given, hst, settings

from repro.core.combinatorics import n_parent_sets
from repro.preprocess import (SparseScoreTable, build_score_table_fused,
                              build_sparse_table_streaming, prune_table)


def _rand_problem(rng, n, q, m):
    return rng.integers(0, q, size=(m, n)).astype(np.int32)


def _assert_tables_bitwise(sp_a, sp_b):
    """Every stored array identical: kept sets, packed lists, hash arrays."""
    for field in ("kept_idx", "kept_ls", "kept_parents", "keys", "vals"):
        a, b = np.asarray(getattr(sp_a, field)), np.asarray(getattr(sp_b, field))
        np.testing.assert_array_equal(a, b, err_msg=field)
    assert sp_a.max_probe == sp_b.max_probe
    assert sp_a.S == sp_b.S and sp_a.K == sp_b.K


# --------------------------------------------- streaming == dense + prune
@given(hst.data())
@settings(max_examples=6, deadline=None)
def test_streaming_matches_dense_prune_property(data_strategy):
    """Property (ISSUE 6): streaming assembly == dense-build-then-prune,
    BITWISE, over random (n, q, s, delta, chunk) — including chunk sizes
    that do not divide the subset count."""
    rng = np.random.default_rng(data_strategy.draw(hst.integers(0, 2**31 - 1)))
    n = data_strategy.draw(hst.integers(6, 11))
    q = data_strategy.draw(hst.integers(2, 4))
    s = data_strategy.draw(hst.integers(1, 3))
    m = data_strategy.draw(hst.integers(40, 150))
    deltas = [1.0, 5.0, 12.0, 1e30]
    delta = deltas[data_strategy.draw(hst.integers(0, len(deltas) - 1))]
    chunk = data_strategy.draw(hst.integers(3, 40))
    data = _rand_problem(rng, n, q, m)
    sp_dense = build_score_table_fused(data, q=q, s=s, chunk=chunk,
                                       prune_delta=delta, streaming=False)
    sp_stream = build_score_table_fused(data, q=q, s=s, chunk=chunk,
                                        prune_delta=delta)
    assert isinstance(sp_stream, SparseScoreTable)
    _assert_tables_bitwise(sp_dense, sp_stream)


def test_streaming_matches_with_prior():
    rng = np.random.default_rng(11)
    n, q, s, m = 9, 2, 3, 120
    data = _rand_problem(rng, n, q, m)
    R = np.full((n, n), 0.5, np.float32)
    R[1, 0] = 0.95
    R[4, 2] = 0.1
    sp_dense = build_score_table_fused(data, q=q, s=s, chunk=33,
                                       prior_matrix=R, prune_delta=8.0,
                                       streaming=False)
    sp_stream = build_score_table_fused(data, q=q, s=s, chunk=33,
                                        prior_matrix=R, prune_delta=8.0)
    _assert_tables_bitwise(sp_dense, sp_stream)


def test_streaming_max_keep_cap():
    """max_keep keeps each node's top-K by score (rank 0 always included);
    capped lists are a subset of the uncapped within-delta lists."""
    rng = np.random.default_rng(13)
    n, q, s, m = 8, 2, 2, 90
    data = _rand_problem(rng, n, q, m)
    full = build_score_table_fused(data, q=q, s=s, prune_delta=1e30)
    capped = build_score_table_fused(data, q=q, s=s, prune_delta=1e30,
                                     max_keep=4)
    assert capped.K <= 4 + 1                      # +1: forced rank 0
    fi, fl = np.asarray(full.kept_idx), np.asarray(full.kept_ls)
    ci, cl = np.asarray(capped.kept_idx), np.asarray(capped.kept_ls)
    for i in range(n):
        fmap = dict(zip(fi[i][fi[i] >= 0].tolist(),
                        fl[i][fi[i] >= 0].tolist()))
        kept = ci[i][ci[i] >= 0]
        assert 0 in kept.tolist()
        # capped scores are the dense scores, and (excluding the forced
        # rank 0, which sits outside the cap) they are the top non-empty ones
        scores = sorted((v for t, v in fmap.items() if t != 0), reverse=True)
        floor = scores[:4][-1]
        for t, v in zip(ci[i].tolist(), cl[i].tolist()):
            if t >= 0:
                assert fmap[t] == v
                if t != 0:
                    assert v >= floor


# ------------------------------------------------ no dense intermediate
def test_streaming_never_materialises_dense(monkeypatch):
    """The streaming path must not touch the dense assembly machinery at all
    and must keep peak host allocation well under the (n, S) table bytes."""
    from repro.preprocess import pipeline as pl

    def _boom(*a, **k):
        raise AssertionError("dense assembly invoked on the streaming path")

    monkeypatch.setattr(pl, "_rank_map", _boom)
    monkeypatch.setattr(pl, "assemble_table", _boom)

    rng = np.random.default_rng(17)
    n, q, s, m, chunk, delta = 64, 2, 3, 60, 512, 6.0
    data = _rand_problem(rng, n, q, m)
    S = n_parent_sets(n - 1, s)
    dense_bytes = n * S * 4
    # warm the jit caches outside the traced window: tracing/compilation
    # allocates MBs of Python-side jaxpr/MLIR state that has nothing to do
    # with the assembly (the trace is keyed on the static n, so warm at
    # full shape)
    build_score_table_fused(data, q=q, s=s, chunk=chunk, prune_delta=delta)
    tracemalloc.start()
    sp, info = build_score_table_fused(data, q=q, s=s, chunk=chunk,
                                       prune_delta=delta, return_info=True)
    _, traced_peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert isinstance(sp, SparseScoreTable)
    assert info["streaming"] is True
    # the acceptance bound: < 25% of the dense table's n*S*4 bytes, on both
    # the self-reported assembly peak and the traced host allocations
    assert info["peak_assembly_bytes"] < 0.25 * dense_bytes, \
        (info["peak_assembly_bytes"], dense_bytes)
    assert traced_peak < 0.25 * dense_bytes, (traced_peak, dense_bytes)


def test_streaming_direct_entrypoint_info():
    rng = np.random.default_rng(19)
    data = _rand_problem(rng, 8, 2, 70)
    sp, sinfo = build_sparse_table_streaming(data, q=2, s=2, delta=6.0)
    assert isinstance(sp, SparseScoreTable)
    for k in ("peak_assembly_bytes", "n_chunks", "n_devices", "imbalance",
              "kept_entries", "K"):
        assert k in sinfo
    assert sinfo["kept_entries"] >= sp.n          # rank 0 on every node


# ------------------------------------------------------- info contract
def test_info_schema_identical_on_hit_and_miss(tmp_path):
    """Satellite bugfix: the cache-hit early return used to omit 'plan'."""
    rng = np.random.default_rng(23)
    data = _rand_problem(rng, 7, 2, 80)
    d = str(tmp_path)
    _, miss = build_score_table_fused(data, q=2, s=2, cache_dir=d,
                                      return_info=True)
    _, hit = build_score_table_fused(data, q=2, s=2, cache_dir=d,
                                     return_info=True)
    assert not miss["cache_hit"] and hit["cache_hit"]
    assert set(miss) == set(hit)
    assert "plan" in hit                     # the key the bug dropped
    # and on the pruned/streaming flavor too
    _, smiss = build_score_table_fused(data, q=2, s=2, prune_delta=4.0,
                                       cache_dir=d, return_info=True)
    _, shit = build_score_table_fused(data, q=2, s=2, prune_delta=4.0,
                                      cache_dir=d, return_info=True)
    assert set(smiss) == set(shit) == set(miss)


# ------------------------------------------------------------- cache
def test_pruned_cache_roundtrip(tmp_path):
    """Streaming runs cache the pruned representation; a second identical
    request restores it bit-for-bit, and a different delta misses."""
    rng = np.random.default_rng(29)
    data = _rand_problem(rng, 8, 2, 90)
    d = str(tmp_path)
    sp1, i1 = build_score_table_fused(data, q=2, s=2, prune_delta=5.0,
                                      cache_dir=d, return_info=True)
    sp2, i2 = build_score_table_fused(data, q=2, s=2, prune_delta=5.0,
                                      cache_dir=d, return_info=True)
    assert not i1["cache_hit"] and i2["cache_hit"]
    _assert_tables_bitwise(sp1, sp2)
    # different delta -> different kept set -> must rebuild, not hit
    _, i3 = build_score_table_fused(data, q=2, s=2, prune_delta=2.0,
                                    cache_dir=d, return_info=True)
    assert not i3["cache_hit"]


def test_cache_key_prior_shape_sensitivity():
    """Satellite bugfix: the digest must separate priors with identical
    bytes but different shapes (e.g. a transposed matrix)."""
    from repro.preprocess.cache import cache_key

    rng = np.random.default_rng(31)
    data = _rand_problem(rng, 6, 2, 40)
    R = rng.random((6, 6)).astype(np.float32)
    k1 = cache_key(data, q=2, s=2, gamma=0.1, ess=1.0, prior_matrix=R)
    k2 = cache_key(data, q=2, s=2, gamma=0.1, ess=1.0,
                   prior_matrix=np.ascontiguousarray(R.T))
    flat = np.ascontiguousarray(R.reshape(4, 9))
    k3 = cache_key(data, q=2, s=2, gamma=0.1, ess=1.0, prior_matrix=flat)
    assert len({k1, k2, k3}) == 3
    # prune_delta/max_keep key the sparse entries separately
    k4 = cache_key(data, q=2, s=2, gamma=0.1, ess=1.0, prior_matrix=R,
                   prune_delta=5.0)
    k5 = cache_key(data, q=2, s=2, gamma=0.1, ess=1.0, prior_matrix=R,
                   prune_delta=5.0, max_keep=8)
    assert len({k1, k4, k5}) == 3


def test_poisoned_cache_manifest_is_logged_miss(tmp_path, caplog):
    """Satellite bugfix: an entry whose manifest disagrees with the request
    (stale/hand-mixed cache dir) must be a logged miss, never served."""
    import logging

    rng = np.random.default_rng(37)
    data = _rand_problem(rng, 7, 2, 80)
    d = str(tmp_path)
    _, i1 = build_score_table_fused(data, q=2, s=2, cache_dir=d,
                                    return_info=True)
    assert not i1["cache_hit"]
    # poison: rewrite the stored manifest to claim a different problem
    entries = os.listdir(d)
    assert len(entries) == 1
    mpath = os.path.join(d, entries[0], "step_0000000000", "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["metadata"]["n"] = 999
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with caplog.at_level(logging.WARNING, logger="repro.preprocess.cache"):
        st, i2 = build_score_table_fused(data, q=2, s=2, cache_dir=d,
                                         return_info=True)
    assert not i2["cache_hit"]               # mismatch = miss, rebuilt
    assert st.table.shape[0] == 7            # and the rebuild is correct
    assert any("manifest mismatch" in r.message for r in caplog.records)


# -------------------------------------------------- bn_learn auto-prune
def test_bn_learn_auto_prune_switch(monkeypatch):
    """Above the size threshold the fused driver defaults to the streaming
    pruned engine; --no-auto-prune (auto_prune=False) keeps it dense."""
    from repro.launch import bn_learn as bl

    rng = np.random.default_rng(41)
    n, q, s, m = 10, 2, 2, 120
    data = _rand_problem(rng, n, q, m)
    # force the threshold below this problem's S so the switch triggers
    monkeypatch.setattr(bl, "AUTO_PRUNE_S", 10)
    cfg = bl.LearnConfig(q=q, s=s, iters=30, seed=3, window=4,
                         preprocess="fused")
    out = bl.learn_structure(data, cfg)
    assert out["auto_pruned"] is True
    assert out["adjacency"].shape == (n, n)
    cfg_off = bl.LearnConfig(q=q, s=s, iters=30, seed=3, window=4,
                             preprocess="fused", auto_prune=False)
    out_off = bl.learn_structure(data, cfg_off)
    assert out_off["auto_pruned"] is False


@pytest.mark.slow
def test_streaming_n100_s4_end_to_end():
    """The ISSUE 6 acceptance gate: synthetic n = 100, s = 4 learned
    end-to-end through the streaming pruned path in bounded memory."""
    from repro.launch.bn_learn import LearnConfig, learn_structure

    rng = np.random.default_rng(43)
    n, q, s = 100, 2, 4
    data = _rand_problem(rng, n, q, 150)
    S = n_parent_sets(n - 1, s)
    sp, info = build_score_table_fused(data, q=q, s=s, chunk=4096,
                                       prune_delta=20.0, return_info=True)
    assert isinstance(sp, SparseScoreTable)
    assert info["streaming"] is True
    assert info["peak_assembly_bytes"] < 0.25 * n * S * 4
    cfg = LearnConfig(q=q, s=s, iters=50, seed=7, window=8,
                      preprocess="fused")
    out = learn_structure(data, cfg)
    assert out["auto_pruned"] is True
    assert out["adjacency"].shape == (n, n)
