"""Order scoring (Eq. 6): oracle vs chunked vs brute-force, and properties."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, hst, settings

from repro.core import (adjacency_from_best, build_score_table, random_cpts,
                        random_dag, score_order_chunked, score_order_ref,
                        topological_order)
from repro.core.order_scoring import NEG_INF, consistent_mask
from repro.data import ancestral_sample


def make_table(n=7, q=2, s=3, m=300, seed=0):
    rng = np.random.default_rng(seed)
    adj = random_dag(rng, n, s, 0.4)
    cpts = random_cpts(rng, adj, q)
    data = ancestral_sample(rng, adj, cpts, m, q)
    return build_score_table(data, q=q, s=s), adj


def brute_force(table, pst, pos):
    """O(n·S) python reference."""
    table = np.asarray(table)
    pst = np.asarray(pst)
    n, S = table.shape
    total, idxs = 0.0, []
    for i in range(n):
        best, besti = -np.inf, -1
        for t in range(S):
            cands = pst[t][pst[t] >= 0]
            pars = cands + (cands >= i)
            if all(pos[p] < pos[i] for p in pars):
                if table[i, t] > best:
                    best, besti = table[i, t], t
        total += best
        idxs.append(besti)
    return total, np.asarray(idxs)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_ref_matches_brute_force(seed):
    st, _ = make_table(seed=seed)
    rng = np.random.default_rng(seed + 10)
    pos = rng.permutation(st.n).astype(np.int32)
    want, want_idx = brute_force(st.table, st.pst, pos)
    got, got_idx, got_ls = score_order_ref(st.table, st.pst, jnp.asarray(pos))
    np.testing.assert_allclose(got, want, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_idx), want_idx)
    np.testing.assert_allclose(np.asarray(got_ls).sum(), want, rtol=1e-5)


@pytest.mark.parametrize("block", [1, 4, 16, 64])
@pytest.mark.parametrize("fn_name", ["chunked", "blocked"])
def test_chunked_matches_ref(block, fn_name):
    from repro.core.order_scoring import score_order_blocked
    fn = {"chunked": score_order_chunked,
          "blocked": score_order_blocked}[fn_name]
    st, _ = make_table()
    S = st.S
    pad = (-S) % block
    table = jnp.pad(st.table, ((0, 0), (0, pad)), constant_values=NEG_INF)
    pst = jnp.pad(st.pst, ((0, pad), (0, 0)), constant_values=-1)
    rng = np.random.default_rng(5)
    for _ in range(3):
        pos = jnp.asarray(rng.permutation(st.n).astype(np.int32))
        a = score_order_ref(st.table, st.pst, pos)
        b = fn(table, pst, pos, block=block)
        np.testing.assert_allclose(a[0], b[0], rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_first_node_gets_empty_parent_set():
    st, _ = make_table()
    pos = jnp.arange(st.n, dtype=jnp.int32)
    _, idx, _ = score_order_ref(st.table, st.pst, pos)
    assert int(idx[0]) == 0  # only the empty set precedes position 0


def test_consistency_mask_basics():
    st, _ = make_table()
    pos = jnp.arange(st.n, dtype=jnp.int32)
    m_first = consistent_mask(st.pst, jnp.int32(0), pos)
    assert bool(m_first[0]) and int(m_first.sum()) == 1
    m_last = consistent_mask(st.pst, jnp.int32(st.n - 1), pos)
    assert int(m_last.sum()) == st.S  # everything precedes the last node


@given(hst.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_score_invariant_under_nonbinding_relabel(seed):
    """Scoring uses only relative positions: applying a strictly monotone map to
    pos leaves score and argmax unchanged."""
    st, _ = make_table()
    rng = np.random.default_rng(seed)
    pos = rng.permutation(st.n).astype(np.int32)
    a = score_order_ref(st.table, st.pst, jnp.asarray(pos))
    b = score_order_ref(st.table, st.pst, jnp.asarray(pos * 3 + 2))
    np.testing.assert_allclose(a[0], b[0], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_best_graph_of_true_order_is_acyclic_and_close():
    st, adj = make_table(n=8, m=2000, seed=7)
    order = topological_order(adj)
    pos = np.empty(8, np.int32)
    pos[order] = np.arange(8)
    _, idx, _ = score_order_ref(st.table, st.pst, jnp.asarray(pos))
    learned = adjacency_from_best(np.asarray(idx), np.asarray(st.pst))
    # learned graph must satisfy the order (hence be a DAG)
    topological_order(learned)
    for m_, i_ in zip(*np.nonzero(learned)):
        assert pos[m_] < pos[i_]
