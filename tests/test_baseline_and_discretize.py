"""The paper's §III-B baseline (sum-based order score) and §II discretization."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import random_cpts, random_dag, roc_point
from repro.core.order_scoring import score_order_ref, score_order_sum
from repro.data.bn_sampler import ancestral_sample
from repro.data.discretize import discretize
from repro.launch.bn_learn import LearnConfig, learn_structure


def test_sum_score_upper_bounds_max_score():
    """log Σ exp ≥ max, per node and in total; the argmax postprocessing
    embedded in the sum scorer must agree with the max scorer's graph."""
    from repro.core.combinatorics import build_pst, n_parent_sets
    n, s = 9, 3
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    pst = jnp.asarray(pst)
    for seed in range(3):
        pos = jnp.asarray(np.random.default_rng(seed).permutation(n)
                          .astype(np.int32))
        mx, idx_m, _ = score_order_ref(table, pst, pos)
        sm, idx_s, _ = score_order_sum(table, pst, pos)
        assert float(sm) >= float(mx) - 1e-4
        np.testing.assert_array_equal(np.asarray(idx_m), np.asarray(idx_s))


def test_sum_baseline_learns_but_max_is_cheaper():
    rng = np.random.default_rng(0)
    truth = random_dag(rng, 8, max_parents=2)
    data = ancestral_sample(rng, truth, random_cpts(rng, truth, 2), 2000, 2)
    out_max = learn_structure(data, LearnConfig(q=2, s=2, iters=600, seed=0))
    out_sum = learn_structure(data, LearnConfig(q=2, s=2, iters=600, seed=0,
                                                scorer="sum"))
    # both samplers learn structure well above chance (the accuracy
    # comparison is benchmarks/baseline_sum.py — single seeds are MCMC noise)
    for out in (out_max, out_sum):
        sk_l = (out["adjacency"] | out["adjacency"].T).astype(bool)
        sk_t = (truth | truth.T).astype(bool)
        assert (sk_l & sk_t).sum() / max(sk_t.sum(), 1) > 0.5
    assert np.isfinite(out_sum["score"])


@pytest.mark.parametrize("method", ["quantile", "width", "mdl"])
def test_discretize_valid_states(method):
    rng = np.random.default_rng(1)
    cont = np.concatenate([rng.normal(0, 1, (300, 3)),
                           rng.normal(4, 0.5, (300, 3))])
    out = discretize(cont, q=3, method=method)
    assert out.shape == cont.shape and out.dtype == np.int32
    assert set(np.unique(out)) <= {0, 1, 2}
    # each state actually used (bimodal data, 3 bins)
    for i in range(3):
        assert len(np.unique(out[:, i])) == 3, method


def test_discretized_pipeline_end_to_end():
    """Continuous observations -> discretize -> learn: the paper's §II flow."""
    rng = np.random.default_rng(2)
    truth = random_dag(rng, 6, max_parents=2)
    states = ancestral_sample(rng, truth, random_cpts(rng, truth, 2), 3000, 2)
    # continuous proxy: state + Gaussian noise (expression-style readout)
    cont = states + rng.normal(0, 0.3, states.shape)
    data = discretize(cont, q=2, method="quantile")
    out = learn_structure(data, LearnConfig(q=2, s=2, iters=800, seed=0))
    sk_l = (out["adjacency"] | out["adjacency"].T).astype(bool)
    sk_t = (truth | truth.T).astype(bool)
    assert (sk_l & sk_t).sum() / max(sk_t.sum(), 1) > 0.5
