"""Fixture: the PR-5 propose_move retrace pattern, verbatim shape.

A module-level function that builds lax.switch branches from fresh local
closures on every call, with NO jitted entry point — each eager call
re-traces and re-compiles all branches. ~800 property-test calls of
exactly this shape exhausted the LLVM JIT code-mapping budget and
segfaulted the seed-era suite.
"""
import functools

import jax
import jax.numpy as jnp


def propose_move(key, pos, window):          # expect: retrace-eager-switch
    n = pos.shape[0]

    def swap(k):
        i = jax.random.randint(k, (), 0, n)
        return pos.at[i].set(pos[(i + 1) % n])

    def insert(k):
        return jnp.roll(pos, 1)

    def reverse(k):
        return pos[::-1]

    kind = jax.random.randint(key, (), 0, 3)
    branches = [swap, insert, reverse]
    return jax.lax.switch(kind, branches, key)


@jax.jit
def stepped_walk(pos, window):               # expect: retrace-undeclared-static
    out = pos
    for _ in range(window):                  # Python loop bound on a traced arg
        out = out + 1
    return out


@functools.partial(jax.jit, static_argnames=("block",))
def tiled_sum(x, block):
    acc = jnp.zeros((block,))                # declared static: fine
    for _ in range(block):
        acc = acc + x[:block]
    return acc


def sweep(xs):
    total = 0.0
    for b in (128, 256, 512):                # expect: retrace-loop-varying-static
        total = total + tiled_sum(xs, block=b).sum()
    return total
