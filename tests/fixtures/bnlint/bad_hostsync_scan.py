"""Fixture: host syncs inside a lax.scan body and a jitted helper.

Each marked line either fails at trace time or forces a blocking
device→host transfer per scan iteration.
"""
import jax
import jax.numpy as jnp
import numpy as np


def _accumulate(carry, x):
    total, best = carry
    step = float(total)                      # expect: hostsync-in-hot-path
    host = np.asarray(x)                     # expect: hostsync-in-hot-path
    flag = x.sum().item()                    # expect: hostsync-in-hot-path
    return (total + x, jnp.maximum(best, x)), (step, host, flag)


def run_chain(xs):
    init = (jnp.zeros(()), jnp.zeros(()))
    return jax.lax.scan(_accumulate, init, xs)


@jax.jit
def normalize(x):
    return x / _norm_of(x)


def _norm_of(x):                             # hot transitively via normalize
    return float(jnp.linalg.norm(x))         # expect: hostsync-in-hot-path


def drain(history):
    """Host-side boundary code — np.asarray here is the designed drain and
    must NOT be flagged (negative control for reachability)."""
    return [np.asarray(h) for h in history]
