"""Fixture: telemetry row with an undeclared kind.

'wibble' is not in telemetry/schema.py REQUIRED — the collector rejects
the row at runtime, deep into a run.
"""


def emit_progress(collector, run_id, step):
    collector._emit({                        # expect: telemetry-unknown-kind
        "schema": "bn-telemetry/v1",
        "kind": "wibble",
        "run": run_id,
        "step": step,
    })


def emit_ok(collector, run_id):
    collector._emit({"schema": "bn-telemetry/v1", "kind": "segment",
                     "run": run_id, "seg": 0, "iters_done": 0,
                     "wall_s": 0.0})         # declared kind: must NOT flag
