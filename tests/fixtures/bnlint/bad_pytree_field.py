"""Fixture: a checkpointed NamedTuple that drifted from the golden registry.

This ChainState inserts a field in the middle and drops two — the
positional checkpoint layout would silently misassign every later leaf on
restore.
"""
from typing import NamedTuple


class ChainState(NamedTuple):                # expect: pytree-unregistered-field
    key: object
    pos: object
    score: object
    temperature: object                      # inserted mid-layout, unregistered
    cur_idx: object
    best_score: object
    best_idx: object
    best_pos: object
    accepts: object
    cur_ls: object
    mask_planes: object
    win_idx: object
    # adapt_err and step dropped
