"""Fixture: pallas_call grid/BlockSpec arithmetic drift + hardcoded interpret.

The index_map of the first in_spec consumes one grid axis but the grid has
two; the out block is rank 3 against a rank-2 out_shape; interpret=True is
baked in so the site can never compile on TPU.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref):
    o_ref[...] = x_ref[...] @ w_ref[...]


def broken_matmul(x, w, *, block_m: int = 128):
    M, K = x.shape
    N = w.shape[1]
    return pl.pallas_call(                   # expect: pallas-spec-mismatch (x3)
        _kernel,
        grid=(M // block_m, N // block_m),
        in_specs=[
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),          # arity 1 != 2
            pl.BlockSpec((K, block_m), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_m, 1),              # rank 3
                               lambda i, j: (i, j)),               # 2 coords
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),       # rank 2
        interpret=True,                      # expect: pallas-interpret-hardcoded
    )(x, w)
