"""Fixture: negative control — idiomatic code that must produce ZERO
findings. Every pattern here is the blessed version of a hazard the other
fixtures trip."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _step_impl(pos, window):
    n = pos.shape[0]                         # shape-derived: trace-static
    idx = jnp.arange(n)
    out = pos
    for _ in range(window):                  # window IS declared static
        out = out + idx
    return out


step = functools.partial(jax.jit, static_argnames=("window",))(_step_impl)


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def doubled(x, *, block_m: int = 128, interpret: bool = False):
    M, K = x.shape
    return pl.pallas_call(
        _kernel,
        grid=(M // block_m,),
        in_specs=[pl.BlockSpec((block_m, K), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_m, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, K), jnp.float32),
        interpret=interpret,                 # plumbed, not hardcoded
    )(x)


def drain_to_host(rows):
    """Boundary code, not reachable from any traced root."""
    return np.asarray(rows)


def emit_segment(collector, run_id):
    collector._emit({"schema": "bn-telemetry/v1", "kind": "segment",
                     "run": run_id, "seg": 1})
