"""Fixture: bench rows with a near-miss config key and with no config at all.

'flipp' is one edit from the declared CONFIG_KEYS entry 'flip_p': the row
silently stops merging by flip rate and a smoke run clobbers the gate row.
The second row carries no config field, so it merges by full-JSON identity
and every re-run appends a duplicate.
"""
from benchmarks.common import save


def run():
    rows = [{"n": 20, "m": 1000, "flipp": 0.1,   # expect: bench-unknown-config-key
             "seconds": 1.23}]
    save("BENCH_fixture", rows)
    save("BENCH_fixture", [{"seconds": 4.56,     # expect: bench-row-no-config
                            "label": "warm"}])
    return rows
