"""Incremental delta order-scoring ≡ full rescore (ISSUE 1 tentpole).

The contract under test (core/order_scoring.py docstring): for ANY order and
ANY bounded-window move, score_order_delta seeded with the previous order's
(best_ls, best_idx) cache returns the SAME (score, best_idx, best_ls) —
bitwise — as a from-scratch blocked rescore of the proposed order.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, hst, settings

from repro.core.combinatorics import build_pst, n_parent_sets
from repro.core.mcmc import mcmc_run, propose_move
from repro.core.order_scoring import (NEG_INF, delta_window,
                                      score_order_blocked,
                                      score_order_chunked, score_order_delta,
                                      score_order_ref, score_order_sum,
                                      score_order_sum_cached,
                                      score_order_sum_delta)


@functools.lru_cache(maxsize=None)
def _random_problem(n=12, s=3, block=64, seed=42):
    """(table, pst) padded to a block multiple — cached so the 200-example
    property test reuses one compiled scorer per (shape, window)."""
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    pad = (-S) % block
    table = jnp.pad(table, ((0, 0), (0, pad)), constant_values=NEG_INF)
    pst = jnp.pad(jnp.asarray(pst), ((0, pad), (0, 0)), constant_values=-1)
    return table, pst


@given(hst.integers(0, 2**31 - 1))
@settings(max_examples=200, deadline=None)
def test_delta_equals_full_rescore(seed):
    """≥200 randomized (order, move) cases: delta result is bitwise equal to
    a fresh full rescore — total, argmax parent sets, and per-node scores."""
    block = 64
    table, pst = _random_problem(block=block)
    n = table.shape[0]
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.permutation(n).astype(np.int32))
    w = int(rng.integers(2, 7))                 # all pass delta_window(12, ·)
    _, idx0, ls0 = score_order_blocked(table, pst, pos, block=block)

    new_pos, lo = propose_move(jax.random.key(seed), pos, window=w)
    got = score_order_delta(table, pst, new_pos, ls0, idx0, lo,
                            window=w, block=block)
    want = score_order_blocked(table, pst, new_pos, block=block)
    assert float(got[0]) == float(want[0])
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))


@given(hst.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_blocked_chunked_ref_agree(seed):
    """score_order_blocked == score_order_chunked == score_order_ref on
    randomized (n, S, s) tables and random orders."""
    shapes = ((8, 2, 16), (10, 3, 64), (12, 2, 32))
    n, s, block = shapes[seed % len(shapes)]
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(-30, 6, (n, S)).astype(np.float32))
    pad = (-S) % block
    tpad = jnp.pad(table, ((0, 0), (0, pad)), constant_values=NEG_INF)
    ppad = jnp.pad(jnp.asarray(pst), ((0, pad), (0, 0)), constant_values=-1)
    pos = jnp.asarray(rng.permutation(n).astype(np.int32))

    ref = score_order_ref(table, jnp.asarray(pst), pos)
    for fn in (score_order_chunked, score_order_blocked):
        got = fn(tpad, ppad, pos, block=block)
        np.testing.assert_allclose(float(got[0]), float(ref[0]), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ref[1]))
        np.testing.assert_allclose(np.asarray(got[2]), np.asarray(ref[2]),
                                   rtol=1e-6)


@given(hst.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_propose_move_is_windowed_permutation(seed):
    """Every move yields a valid permutation whose changes are confined to
    positions [lo, lo+window-1] — the delta-scoring precondition."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 16))
    w = int(rng.integers(2, n + 1))
    pos = jnp.asarray(rng.permutation(n).astype(np.int32))
    new_pos, lo = propose_move(jax.random.key(seed), pos, window=w)
    lo = int(lo)
    assert sorted(np.asarray(new_pos).tolist()) == list(range(n))
    assert 0 <= lo <= n - 1
    for v in np.nonzero(np.asarray(new_pos) != np.asarray(pos))[0]:
        assert lo <= int(pos[v]) <= lo + w - 1
        assert lo <= int(new_pos[v]) <= lo + w - 1


def test_mcmc_delta_chain_is_bitwise_identical(padded_random_table):
    """Same key, same proposals: the delta-path chain and the full-rescore
    chain traverse identical states for 300 iterations."""
    table, pst, block = padded_random_table
    n = table.shape[0]
    fn = functools.partial(score_order_blocked, table, pst, block=block)

    def dfn(pos, lo, prev_ls, prev_idx):
        return score_order_delta(table, pst, pos, prev_ls, prev_idx, lo,
                                 window=4, block=block)

    a, _ = mcmc_run(jax.random.key(3), n, fn, 300, window=4)
    b, _ = mcmc_run(jax.random.key(3), n, fn, 300, delta_fn=dfn, window=4)
    assert float(a.score) == float(b.score)
    assert float(a.best_score) == float(b.best_score)
    assert int(a.accepts) == int(b.accepts)
    np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))
    np.testing.assert_array_equal(np.asarray(a.best_idx),
                                  np.asarray(b.best_idx))
    np.testing.assert_array_equal(np.asarray(a.cur_ls), np.asarray(b.cur_ls))


@given(hst.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_sum_delta_equals_full(seed):
    """The sum (logsumexp) baseline's incremental path (ISSUE 3 satellite):
    the per-node running-logsumexp cache spliced through splice_window is
    bitwise-equal to a full score_order_sum_cached rescore, and the cached
    variant's total matches the original score_order_sum."""
    table, pst = _random_problem()
    n = table.shape[0]
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.permutation(n).astype(np.int32))
    tot, idx, lse = score_order_sum_cached(table, pst, pos)
    ref_tot, ref_idx, _ = score_order_sum(table, pst, pos)
    # cached vs the LEGACY scorer: same math, separately-jitted programs,
    # so only up-to-rounding equality (XLA fuses the reductions differently);
    # the bitwise contract below is delta vs cached-full (shared _sum_nodes)
    np.testing.assert_allclose(float(tot), float(ref_tot), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))
    w = int(rng.integers(2, 7))
    new_pos, lo = propose_move(jax.random.key(seed), pos, window=w)
    got = score_order_sum_delta(table, pst, new_pos, lse, idx, lo, window=w)
    want = score_order_sum_cached(table, pst, new_pos)
    assert float(got[0]) == float(want[0])
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))


def test_delta_window_crossover():
    """The static heuristic: too-wide windows fall back to the full path."""
    assert delta_window(64, 8) == 8
    assert delta_window(12, 8) == 0      # 8 > 0.5 * 12
    assert delta_window(12, 6) == 6
    assert delta_window(100, 1) == 0     # window < 2 is not a move set
    assert delta_window(100, 0) == 0


def test_kernel_delta_matches_kernel_full(alarm_like):
    """The windowed Pallas kernel (interpret mode) splices into the cache
    exactly like the full kernel path."""
    from repro.kernels.order_score import order_score, order_score_delta

    st, _ = alarm_like
    rng = np.random.default_rng(11)
    for seed in range(3):
        pos = jnp.asarray(rng.permutation(st.n).astype(np.int32))
        _, idx0, ls0 = order_score(st.table, st.pst, pos, block_s=64,
                                   interpret=True)
        new_pos, lo = propose_move(jax.random.key(seed), pos, window=3)
        got = order_score_delta(st.table, st.pst, new_pos, ls0, idx0, lo,
                                window=3, block_s=64, interpret=True)
        want = order_score(st.table, st.pst, new_pos, block_s=64,
                           interpret=True)
        np.testing.assert_allclose(float(got[0]), float(want[0]), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
        np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]),
                                   rtol=1e-6)


@pytest.mark.slow
def test_kernel_delta_compiled_on_tpu(alarm_like):
    """Real-hardware run of the windowed kernel (skips off-TPU)."""
    from repro.kernels.order_score import order_score, order_score_delta

    st, _ = alarm_like
    pos = jnp.asarray(np.arange(st.n, dtype=np.int32))
    _, idx0, ls0 = order_score(st.table, st.pst, pos, interpret=False)
    new_pos, lo = propose_move(jax.random.key(0), pos, window=4)
    got = order_score_delta(st.table, st.pst, new_pos, ls0, idx0, lo,
                            window=4, interpret=False)
    want = order_score(st.table, st.pst, new_pos, interpret=False)
    np.testing.assert_allclose(float(got[0]), float(want[0]), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
