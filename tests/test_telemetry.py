"""Convergence telemetry (ISSUE 7): R̂ diagnostics, the in-scan taps, the
JSONL trace schema, checkpoint compatibility and the end-to-end
--telemetry / --stop-on-converge driver path."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adjacency_from_ranks
from repro.core.combinatorics import (binom_table, n_parent_sets,
                                      size_offsets, unrank_parent_set)
from repro.telemetry import (SCHEMA, Collector, TraceState,
                             adjacency_bits_from_ranks, drain, edge_rhat,
                             init_trace, make_tap, median_outliers,
                             read_rows, split_rhat, unrank_parent_sets_jax,
                             validate_row, write_rows)
from repro.telemetry.validate import validate_file


# ------------------------------------------------------------------- R-hat
def test_split_rhat_identical_chains_near_one():
    rng = np.random.default_rng(0)
    base = rng.normal(0.0, 1.0, 256)
    traces = np.stack([base + rng.normal(0, 1e-3, 256) for _ in range(4)])
    r = split_rhat(traces)
    assert np.isfinite(r) and r < 1.05


def test_split_rhat_shifted_chain_large():
    rng = np.random.default_rng(1)
    traces = rng.normal(0.0, 1.0, (4, 256))
    traces[0] += 50.0                       # one chain stuck in another mode
    assert split_rhat(traces) > 2.0


def test_split_rhat_detects_within_chain_drift():
    # split-R̂'s whole point vs plain R̂: halves of ONE drifting chain
    # disagree, so identical-but-drifting chains still flag
    t = np.linspace(0.0, 10.0, 256)[None, :].repeat(4, axis=0)
    assert split_rhat(t) > 1.5


def test_split_rhat_degenerate():
    assert np.isnan(split_rhat(np.zeros((4, 2))))        # too short
    assert split_rhat(np.zeros((4, 64))) == 1.0          # frozen, agreeing
    frozen = np.zeros((2, 64))
    frozen[1] = 3.0                                      # frozen, disjoint
    assert split_rhat(frozen) == np.inf


def test_edge_rhat_concordant_vs_discordant():
    n, T = 6, 200
    rng = np.random.default_rng(2)
    p = rng.uniform(0.2, 0.8, (n, n))
    conc = np.stack([rng.binomial(T, p) for _ in range(4)])
    r_conc, _ = edge_rhat(conc, T)
    assert np.isfinite(r_conc) and r_conc < 1.2

    disc = conc.copy()
    disc[0, 1, 2] = 0
    disc[1, 1, 2] = T                       # chains disagree on edge 1->2
    r_disc, mat = edge_rhat(disc, T)
    assert r_disc > 1.5
    assert mat[1, 2] == r_disc              # the disagreeing edge is the max


def test_edge_rhat_degenerate():
    r, _ = edge_rhat(np.zeros((1, 4, 4)), 10)            # single chain
    assert np.isnan(r)
    r, _ = edge_rhat(np.zeros((3, 4, 4)), 0)             # no samples yet
    assert np.isnan(r)


def test_median_outliers():
    vals = np.array([1.0, 1.1, 0.9, 1.0, 8.0])
    out = median_outliers(vals, 4.0)
    assert out.tolist() == [False, False, False, False, True]
    # floor suppresses flags when all-chain spread is absolutely tiny
    assert not median_outliers(np.array([1.0, 1.0, 1.0001]), 4.0,
                               floor=0.02).any()


# ------------------------------------------------- device-side unranking
@pytest.mark.parametrize("n,s", [(6, 3), (12, 4), (20, 2)])
def test_unrank_jax_matches_host_oracle(n, s):
    S = n_parent_sets(n - 1, s)
    rng = np.random.default_rng(n * 100 + s)
    ranks = rng.integers(0, S, 64).astype(np.int32)
    off = jnp.asarray(size_offsets(n - 1, s), jnp.int32)
    B = jnp.asarray(binom_table(n - 1, s + 1), jnp.int32)
    got = np.asarray(unrank_parent_sets_jax(jnp.asarray(ranks), off, B, s))
    for r, row in zip(ranks, got):
        want = unrank_parent_set(n - 1, s, int(r))
        want = np.pad(np.asarray(want, np.int32), (0, s - len(want)),
                      constant_values=-1)
        np.testing.assert_array_equal(row, want)


@pytest.mark.parametrize("n,s", [(8, 3), (16, 4)])
def test_adjacency_bits_matches_adjacency_from_ranks(n, s):
    S = n_parent_sets(n - 1, s)
    rng = np.random.default_rng(7)
    ranks = rng.integers(0, S, n).astype(np.int32)
    off = jnp.asarray(size_offsets(n - 1, s), jnp.int32)
    B = jnp.asarray(binom_table(n - 1, s + 1), jnp.int32)
    got = np.asarray(adjacency_bits_from_ranks(jnp.asarray(ranks), off, B, s))
    want = adjacency_from_ranks(ranks, s=s)
    np.testing.assert_array_equal(got, np.asarray(want, got.dtype))


# --------------------------------------------------------------- the taps
def _fake_states(n_chains, n, score, accepts, ranks, win_idx=0):
    from repro.core.mcmc import ChainState
    C = n_chains
    return ChainState(
        key=jax.random.split(jax.random.key(0), C),
        pos=jnp.zeros((C, n), jnp.int32),
        score=jnp.full((C,), score, jnp.float32),
        cur_idx=jnp.broadcast_to(jnp.asarray(ranks, jnp.int32), (C, n)),
        best_score=jnp.full((C,), score, jnp.float32),
        best_idx=jnp.zeros((C, n), jnp.int32),
        best_pos=jnp.zeros((C, n), jnp.int32),
        accepts=jnp.full((C,), accepts, jnp.int32),
        cur_ls=jnp.zeros((C, n), jnp.float32),
        mask_planes=jnp.zeros((C, 0), jnp.uint32),
        win_idx=jnp.full((C,), win_idx, jnp.int32),
        adapt_err=jnp.zeros((C,), jnp.float32),
        step=jnp.zeros((C,), jnp.int32),
    )


def test_tap_cadence_and_ring_wrap():
    n, s, C, cap = 6, 2, 2, 4
    tap = make_tap(n, s, trace_every=3)
    trace = init_trace(C, n, cap=cap)
    for it in range(1, 19):
        st = _fake_states(C, n, float(it), it, np.zeros(n, np.int32))
        trace = tap(trace, st, jnp.int32(it))
    # 18 iterations / every 3 = 6 taps into a cap-4 ring
    assert int(trace.taps) == 6
    assert int(trace.edge_taps) == 6
    snap = drain(trace)
    assert snap["scores"].shape == (C, 4)
    # oldest-first linearisation: taps at iterations 9, 12, 15, 18 survive
    np.testing.assert_allclose(snap["scores"][0], [9.0, 12.0, 15.0, 18.0])
    # win_hist counts EVERY iteration, not just taps
    assert snap["win_hist"].sum() == 18 * C


def test_tap_accumulates_edge_counts():
    n, s = 8, 3
    S = n_parent_sets(n - 1, s)
    rng = np.random.default_rng(3)
    ranks = rng.integers(0, S, n).astype(np.int32)
    tap = make_tap(n, s, trace_every=1)
    trace = init_trace(2, n)
    st = _fake_states(2, n, -1.0, 0, ranks)
    trace = tap(trace, st, jnp.int32(1))
    trace = tap(trace, st, jnp.int32(2))
    adj = np.asarray(adjacency_from_ranks(ranks, s=s))
    for c in range(2):
        np.testing.assert_array_equal(np.asarray(trace.edge_counts[c]),
                                      adj * 2)


# ----------------------------------------------------------- JSONL schema
def test_schema_roundtrip_and_validation(tmp_path):
    path = str(tmp_path / "t.jsonl")
    rows = [
        {"schema": SCHEMA, "kind": "meta", "run": "r1", "config": {"n": 8},
         "host": {"backend": "cpu"}},
        {"schema": SCHEMA, "kind": "stage", "run": "r1",
         "stage": "preprocess", "seconds": 0.25},
        {"schema": SCHEMA, "kind": "segment", "run": "r1", "iter": 64,
         "taps": 8, "score_mean": -10.0, "score_rhat": float("nan"),
         "edge_rhat": float("inf"), "accept_rates": [0.4, 0.5],
         "stuck_chains": [], "diverged_chains": [], "converge_hits": 0,
         "converged": False},
        {"schema": SCHEMA, "kind": "final", "run": "r1", "iters_run": 64,
         "stopped_early": False, "score_rhat": 1.01, "edge_rhat": 1.02},
    ]
    write_rows(path, rows)
    back = read_rows(path)
    assert len(back) == 4
    assert np.isnan(back[2]["score_rhat"])          # nan/inf survive JSON
    assert back[2]["edge_rhat"] == float("inf")
    info = validate_file(path)
    assert info["run"] == "r1"
    assert info["kinds"] == {"meta": 1, "stage": 1, "segment": 1, "final": 1}

    with pytest.raises(ValueError, match="missing required field"):
        validate_row({"schema": SCHEMA, "kind": "final", "run": "r1"})
    with pytest.raises(ValueError, match="schema"):
        validate_row({"schema": "bn-telemetry/v0", "kind": "meta"})
    with pytest.raises(ValueError, match="kind"):
        validate_row({"schema": SCHEMA, "kind": "mystery"})


def test_validate_file_rejects_misordered(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"schema": SCHEMA, "kind": "stage", "run": "r",
                            "stage": "x", "seconds": 1.0}) + "\n")
    with pytest.raises(ValueError, match="first row"):
        validate_file(path)


def test_collector_emits_valid_trace(tmp_path):
    col = Collector(str(tmp_path), run_name="unit", rhat_threshold=1.1,
                    patience=2, min_taps=4)
    col.start({"n": 6, "iters": 100})
    col.stage("preprocess", 0.1, plan_s=0.02)
    rng = np.random.default_rng(0)
    base = rng.normal(-50, 1.0, 64)
    snap = {
        "scores": np.stack([base + rng.normal(0, 1e-3, 64)
                            for _ in range(3)]),
        "accepts": np.tile(np.arange(1, 65, dtype=np.int64), (3, 1)),
        "taps": 64, "win_hist": np.ones((3, 1), np.int64),
        "edge_counts": np.tile(rng.binomial(64, 0.5, (6, 6)), (3, 1, 1)),
        "edge_taps": 64, "reseeds": np.zeros(3, np.int64),
    }
    rec1 = col.check(snap, 512)
    assert not rec1["converged"]            # patience 2: one hit is not enough
    rec2 = col.check(snap, 1024)
    assert rec2["converged"]
    col.finalize(iters_run=1024, stopped_early=True)
    info = validate_file(col.path)
    assert info["kinds"] == {"meta": 1, "stage": 1, "segment": 2, "final": 1}


def test_collector_restart_truncates_stale_trace(tmp_path):
    """Reusing a run name (re-run CI smoke, retried acceptance run) must
    truncate the old trace — appending a second meta/final pair would fail
    the single-run validation contract."""
    for _ in range(2):
        col = Collector(str(tmp_path), run_name="reused", min_taps=4)
        col.start({"n": 4})
        col.finalize(iters_run=10, stopped_early=False)
    info = validate_file(col.path)
    assert info["kinds"] == {"meta": 1, "final": 1}


def test_collector_flags_stuck_chain(tmp_path):
    col = Collector(str(tmp_path), run_name="stuck", min_taps=4)
    C, L = 6, 32
    scores = np.random.default_rng(1).normal(-50, 0.5, (C, L))
    accepts = np.tile(np.arange(1, L + 1) * 10, (C, 1))
    accepts[2] = 0                          # chain 2 accepts nothing
    snap = {"scores": scores, "accepts": accepts, "taps": L,
            "win_hist": np.ones((C, 1), np.int64),
            "edge_counts": np.zeros((C, 4, 4), np.int64), "edge_taps": L,
            "reseeds": np.zeros(C, np.int64)}
    rec = col.check(snap, 320)
    assert 2 in rec["stuck_chains"]


# ------------------------------------------- checkpoint schema evolution
def test_old_13_leaf_checkpoint_backfills_trace_leaves(tmp_path):
    """A snapshot written by a pre-telemetry run (exactly the 13 ChainState
    leaves) restores into the telemetry layout: chain leaves land bitwise,
    the appended TraceState leaves keep the fresh template's values
    (allow_missing backfill) — same schema-evolution path as the 9->13 leaf
    upgrade."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.core.mcmc import ChainState
    from repro.launch.bn_learn import _pack_tree, _unpack_tree

    n, C = 6, 2
    fn = lambda pos: (jnp.float32(-1.0), jnp.zeros(n, jnp.int32),
                      jnp.zeros(n, jnp.float32))
    from repro.core.mcmc import init_chain
    states = jax.vmap(lambda k: init_chain(k, n, fn))(
        jax.random.split(jax.random.key(5), C))
    pack = lambda s: jax.tree.map(np.asarray,
                                  s._replace(key=jax.random.key_data(s.key)))
    unpack = lambda t: ChainState(*t)._replace(
        key=jax.random.wrap_key_data(jnp.asarray(t[0])))

    # pre-telemetry snapshot: trace=None -> exactly the 13-leaf layout
    old = _pack_tree(pack, states, None)
    assert len(old) == len(ChainState._fields) == 13
    save_checkpoint(str(tmp_path), 3, old)

    trace = init_trace(C, n)
    template = _pack_tree(pack, states, trace)
    assert len(template) == 13 + len(TraceState._fields)
    with pytest.raises(ValueError, match="mismatch"):
        restore_checkpoint(str(tmp_path), template, step=3)
    restored, meta = restore_checkpoint(str(tmp_path), template, step=3,
                                        allow_missing=True)
    assert len(meta["missing_leaves"]) == len(TraceState._fields)
    st2, tr2 = _unpack_tree(unpack, restored, trace)
    np.testing.assert_array_equal(np.asarray(st2.pos), np.asarray(states.pos))
    assert int(tr2.taps) == 0               # backfilled fresh trace
    assert tr2.edge_counts.shape == (C, n, n)


def test_checkpoint_roundtrip_with_trace(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.core.mcmc import ChainState, init_chain
    from repro.launch.bn_learn import _pack_tree, _unpack_tree

    n, C = 6, 2
    fn = lambda pos: (jnp.float32(-1.0), jnp.zeros(n, jnp.int32),
                      jnp.zeros(n, jnp.float32))
    states = jax.vmap(lambda k: init_chain(k, n, fn))(
        jax.random.split(jax.random.key(6), C))
    pack = lambda s: jax.tree.map(np.asarray,
                                  s._replace(key=jax.random.key_data(s.key)))
    unpack = lambda t: ChainState(*t)._replace(
        key=jax.random.wrap_key_data(jnp.asarray(t[0])))
    trace = init_trace(C, n)._replace(taps=jnp.int32(5),
                                      reseeds=jnp.asarray([1, 2], jnp.int32))
    save_checkpoint(str(tmp_path), 9, _pack_tree(pack, states, trace))
    restored, _ = restore_checkpoint(
        str(tmp_path), _pack_tree(pack, states, init_trace(C, n)), step=9,
        allow_missing=True)
    _, tr2 = _unpack_tree(unpack, restored, init_trace(C, n))
    assert int(tr2.taps) == 5
    np.testing.assert_array_equal(np.asarray(tr2.reseeds), [1, 2])


# ------------------------------------------------------------- end to end
def _synth_data(m=300, n=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2, size=(m, n)).astype(np.int8)


def test_learn_structure_telemetry_end_to_end(tmp_path):
    from repro.launch.bn_learn import LearnConfig, learn_structure

    cfg = LearnConfig(q=2, s=2, iters=300, chains=3, seed=0, window=4,
                      telemetry=True, trace_every=4, check_every=100,
                      trace_dir=str(tmp_path), run_name="e2e",
                      exchange_every=50)
    out = learn_structure(_synth_data(), cfg)
    assert out["iters_run"] == 300 and not out["stopped_early"]
    assert len(out["chain_accept_rates"]) == 3
    assert out["exchange_count"] == 6
    tele = out["telemetry"]
    assert tele is not None and np.isfinite(tele["score_rhat"])
    info = validate_file(os.path.join(str(tmp_path), "e2e.jsonl"))
    assert info["kinds"]["segment"] == 3    # 300 iters / check_every 100
    assert info["kinds"]["final"] == 1
    # segment rows carry the in-run iteration axis
    iters = [r["iter"] for r in read_rows(tele["trace_path"])
             if r["kind"] == "segment"]
    assert iters == [100, 200, 300]


def test_learn_structure_stop_on_converge(tmp_path):
    from repro.launch.bn_learn import LearnConfig, learn_structure

    cfg = LearnConfig(q=2, s=2, iters=2000, chains=4, seed=0, window=4,
                      stop_on_converge=True, trace_every=4, check_every=100,
                      patience=2, rhat_threshold=1.2,
                      trace_dir=str(tmp_path), run_name="conv",
                      exchange_every=50)
    out = learn_structure(_synth_data(), cfg)
    # flat posterior (random data, tiny n): chains mix almost immediately,
    # so the run must stop WELL before the iteration cap
    assert out["stopped_early"] and out["iters_run"] < 2000
    assert out["telemetry"]["converged"]
    rows = read_rows(out["telemetry"]["trace_path"])
    assert rows[-1]["kind"] == "final" and rows[-1]["stopped_early"]


def test_learn_structure_telemetry_resumes_from_plain_checkpoint(tmp_path):
    """Driver-level schema evolution: a checkpointed run WITHOUT telemetry
    leaves 13-leaf snapshots; re-running the same config WITH telemetry
    resumes from them (trace leaves backfilled) and completes."""
    from repro.launch.bn_learn import LearnConfig, learn_structure

    ck = str(tmp_path / "ck")
    data = _synth_data()
    cfg = LearnConfig(q=2, s=2, iters=100, chains=2, seed=0, window=4,
                      checkpoint_dir=ck, checkpoint_every=50)
    learn_structure(data, cfg)
    cfg2 = LearnConfig(q=2, s=2, iters=200, chains=2, seed=0, window=4,
                      checkpoint_dir=ck, checkpoint_every=50,
                      telemetry=True, trace_every=4,
                      trace_dir=str(tmp_path), run_name="resume")
    out = learn_structure(data, cfg2)
    assert out["iters_run"] == 200
    info = validate_file(os.path.join(str(tmp_path), "resume.jsonl"))
    assert info["kinds"]["final"] == 1


def test_telemetry_does_not_change_the_walk():
    """The taps are observers: the same config with and without telemetry
    must land on the identical best score and adjacency."""
    from repro.launch.bn_learn import LearnConfig, learn_structure

    data = _synth_data()
    base = dict(q=2, s=2, iters=150, chains=2, seed=0, window=4,
                exchange_every=30)
    plain = learn_structure(data, LearnConfig(**base))
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        tapped = learn_structure(
            data, LearnConfig(**base, telemetry=True, trace_every=4,
                              check_every=50, trace_dir=td))
    assert plain["score"] == tapped["score"]
    np.testing.assert_array_equal(plain["adjacency"], tapped["adjacency"])
