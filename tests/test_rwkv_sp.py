"""Sequence-parallel RWKV stack == sequential stack (8 placeholder devices,
subprocess so the main suite keeps 1 device). Covers forward logits, the
prefill cache (state + shift tokens), and continued decode equivalence."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import Model
    from repro.models.layers import set_mesh

    from repro.runtime.jax_compat import make_auto_mesh, mesh_context
    mesh = make_auto_mesh((2, 4), ("data", "model"))
    cfg = get_config("rwkv6-7b").reduced()
    B, T = 2, 32                      # T/tp = 8 per shard, chunk 4

    m_seq = Model(cfg, tp=4, rwkv_chunk=4)
    m_sp = Model(cfg, tp=4, rwkv_chunk=4, rwkv_sp=True)
    params = m_seq.init(jax.random.key(0))
    tok = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab)

    set_mesh(mesh)
    with mesh_context(mesh):
        # train-mode forward (no cache)
        a, _ = jax.jit(m_seq.forward)(params, tok)
        b, _ = jax.jit(m_sp.forward)(params, tok)
        np.testing.assert_allclose(
            np.asarray(a[..., :cfg.vocab], np.float32),
            np.asarray(b[..., :cfg.vocab], np.float32), rtol=2e-3, atol=2e-3)

        # prefill cache equivalence + continued decode
        ca = m_seq.init_cache(B, T + 4, dtype=jnp.float32)
        cb = m_sp.init_cache(B, T + 4, dtype=jnp.float32)
        la, ca = jax.jit(m_seq.prefill)(params, tok, ca)
        lb, cb = jax.jit(m_sp.prefill)(params, tok, cb)
        np.testing.assert_allclose(
            np.asarray(la[:, -1, :cfg.vocab], np.float32),
            np.asarray(lb[:, -1, :cfg.vocab], np.float32),
            rtol=2e-3, atol=2e-3)
        st_a = jax.tree.map(np.asarray, ca["layers"])
        st_b = jax.tree.map(np.asarray, cb["layers"])
        for x, y in zip(jax.tree.leaves(st_a), jax.tree.leaves(st_b)):
            np.testing.assert_allclose(x, y, rtol=2e-3, atol=2e-3)

        nxt = jnp.argmax(lb[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
        da, ca = jax.jit(m_seq.decode_step)(params, ca, nxt)
        db, cb = jax.jit(m_sp.decode_step)(params, cb, nxt)
        np.testing.assert_allclose(
            np.asarray(da[..., :cfg.vocab], np.float32),
            np.asarray(db[..., :cfg.vocab], np.float32),
            rtol=2e-3, atol=2e-3)

        # gradients flow through the SP stack (train step viability)
        def loss(fn):
            def f(p):
                lg, _ = fn(p, tok)
                return jnp.mean(lg[..., : cfg.vocab].astype(jnp.float32) ** 2)
            return f
        ga = jax.grad(loss(m_seq.forward))(params)
        gb = jax.grad(loss(m_sp.forward))(params)
        leaves_a, leaves_b = jax.tree.leaves(ga), jax.tree.leaves(gb)
        err = max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                        - y.astype(jnp.float32))))
                  for x, y in zip(leaves_a, leaves_b))
        assert err < 5e-2, f"grad mismatch {err}"
    print("OK")
""")


def test_rwkv_sp_matches_sequential():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, (r.stderr[-4000:], r.stdout[-500:])
    assert "OK" in r.stdout
