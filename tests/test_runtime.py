"""Runtime features: elastic re-meshing plans and straggler mitigation —
including the full BN path (13-leaf ChainState + telemetry trace leaves)
that the run supervisor heals through rebalance_chains."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.elastic import (accum_steps_for_batch, remesh_plan,
                                   reshard_tree)
from repro.runtime.straggler import (StragglerPolicy, best_finite_chain,
                                     rebalance_chains)


def test_remesh_plan_shrink_grows_data_axis():
    # healthy 512-chip 2-pod job
    assert remesh_plan(512, model_parallel=16, prefer_pods=2) == \
        ((2, 16, 16), ("pod", "data", "model"))
    # a pod dies: restart on 256 chips, same model parallelism
    assert remesh_plan(256, model_parallel=16) == ((16, 16), ("data", "model"))
    # odd survivor counts still factor as long as TP divides
    assert remesh_plan(192, model_parallel=16) == ((12, 16), ("data", "model"))
    with pytest.raises(ValueError):
        remesh_plan(250, model_parallel=16)


def test_accum_steps_preserve_global_batch():
    assert accum_steps_for_batch(256, 256) == 1
    assert accum_steps_for_batch(256, 128) == 2   # half the chips -> 2 steps
    with pytest.raises(ValueError):
        accum_steps_for_batch(256, 96)


def test_straggler_chain_cloning():
    from repro.core.combinatorics import build_pst, n_parent_sets
    from repro.core.mcmc import init_chain, mcmc_run
    from repro.core.order_scoring import score_order_chunked

    n, s = 8, 2
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    pad = (-S) % 16
    table = jnp.pad(table, ((0, 0), (0, pad)), constant_values=-3e38)
    pst = jnp.pad(jnp.asarray(pst), ((0, pad), (0, 0)), constant_values=-1)
    fn = functools.partial(score_order_chunked, table, pst, block=16)

    keys = jax.random.split(jax.random.key(0), 4)
    states = jax.vmap(lambda k: init_chain(k, n, fn))(keys)

    # chain 2 misses twice -> cloned from the best chain with a fresh key
    progressed = np.array([True, True, False, True])
    missed = np.zeros(4, np.int64)
    states1, missed = rebalance_chains(jax.random.key(1), states,
                                       progressed, missed,
                                       StragglerPolicy(patience=2))
    assert missed[2] == 1           # first miss: no action yet
    np.testing.assert_array_equal(np.asarray(states1.pos),
                                  np.asarray(states.pos))

    states2, missed = rebalance_chains(jax.random.key(2), states1,
                                       progressed, missed,
                                       StragglerPolicy(patience=2))
    assert missed[2] == 0           # re-seeded
    best = int(np.argmax(np.asarray(states.best_score)))
    np.testing.assert_array_equal(np.asarray(states2.pos[2]),
                                  np.asarray(states.pos[best]))
    # fresh key: the clone diverges from its source immediately
    assert not np.array_equal(
        np.asarray(jax.random.key_data(states2.key[2])),
        np.asarray(jax.random.key_data(states2.key[best])))
    # cloned chain keeps sampling fine
    st, _ = mcmc_run(states2.key[2], n, fn, 10)
    assert np.isfinite(float(st.best_score))


# ------------------------------------------------------- full BN-path heal
def _bitmask_problem():
    """Padded dense problem with the full bitmask engine closures — the
    exact per-chain state layout bn_learn's supervised path heals through
    rebalance_chains (13 ChainState leaves incl. live mask_planes)."""
    from repro.core.combinatorics import build_pst, n_parent_sets
    from repro.core.order_scoring import (build_membership_planes,
                                          build_violation_planes,
                                          delta_window,
                                          score_order_blocked,
                                          score_order_delta_bitmask)

    n, s, block = 10, 2, 32
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(7)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    pad = (-S) % block
    table = jnp.pad(table, ((0, 0), (0, pad)), constant_values=-3e38)
    pst = jnp.pad(jnp.asarray(pst), ((0, pad), (0, 0)), constant_values=-1)
    score_fn = functools.partial(score_order_blocked, table, pst, block=block)
    planes_fn = functools.partial(build_violation_planes, pst)
    cm = build_membership_planes(pst, n)
    w = delta_window(n, 4)
    assert w

    def bitmask_fn(pos, lo, prev_ls, prev_idx, pos_old, planes):
        return score_order_delta_bitmask(table, cm, pos, prev_ls, prev_idx,
                                         lo, pos_old, planes, window=w,
                                         block=block)
    return n, score_fn, planes_fn, bitmask_fn, w


def _stacked_states(n, score_fn, planes_fn, bitmask_fn, w, chains=4,
                    steps=20):
    from repro.core.mcmc import BitmaskDelta, ChainState, init_chain, mcmc_step

    keys = jax.random.split(jax.random.key(3), chains)
    states = jax.vmap(
        lambda k: init_chain(k, n, score_fn, planes_fn=planes_fn))(keys)
    assert len(ChainState._fields) == 13 and len(tuple(states)) == 13
    # drive with the REAL bitmask engine so the planes leaf is live state
    # (patched in place per accepted move), not a stale init-time cache
    step = jax.jit(jax.vmap(
        lambda s: mcmc_step(s, score_fn, BitmaskDelta(bitmask_fn), w)))
    for _ in range(steps):                     # de-trivialise every leaf
        states = step(states)
    return states


def test_rebalance_full_chain_state_keeps_caches_consistent():
    n, score_fn, planes_fn, bitmask_fn, w = _bitmask_problem()
    states = _stacked_states(n, score_fn, planes_fn, bitmask_fn, w)

    best = int(np.argmax(np.asarray(states.best_score)))
    victim = (best + 1) % 4               # stall someone other than the donor
    progressed = np.ones(4, bool)
    progressed[victim] = False
    missed = np.zeros(4, np.int64)
    out, missed, healed = rebalance_chains(
        jax.random.key(9), states, progressed, missed,
        StragglerPolicy(patience=1), return_mask=True)
    assert healed.tolist() == [c == victim for c in range(4)]
    assert missed.tolist() == [0, 0, 0, 0]

    # every leaf of the healed slot is the donor's (except the PRNG key)
    for name in ("pos", "score", "cur_idx", "best_score", "best_idx",
                 "best_pos", "accepts", "cur_ls", "mask_planes", "win_idx",
                 "adapt_err", "step"):
        np.testing.assert_array_equal(
            np.asarray(getattr(out, name))[victim],
            np.asarray(getattr(states, name))[best], err_msg=name)
    assert not np.array_equal(
        np.asarray(jax.random.key_data(out.key[victim])),
        np.asarray(jax.random.key_data(out.key[best])))

    # clone-consistency invariant: the cloned slot's derived caches describe
    # its cloned order — (score, cur_ls, cur_idx) match a fresh rescore and
    # mask_planes match a fresh plane build from the cloned positions
    sc, bi, ls = score_fn(out.pos[victim])
    np.testing.assert_array_equal(np.asarray(sc),
                                  np.asarray(out.score[victim]))
    np.testing.assert_array_equal(np.asarray(ls),
                                  np.asarray(out.cur_ls[victim]))
    np.testing.assert_array_equal(np.asarray(bi),
                                  np.asarray(out.cur_idx[victim]))
    np.testing.assert_array_equal(np.asarray(planes_fn(out.pos[victim])),
                                  np.asarray(out.mask_planes[victim]))


def test_rebalance_never_clones_from_poisoned_donor():
    from repro.runtime.faults import poison_chain_state

    n, score_fn, planes_fn, bitmask_fn, w = _bitmask_problem()
    states = _stacked_states(n, score_fn, planes_fn, bitmask_fn, w)
    # poison the would-be donor; chain 1 needs healing
    top = int(np.argmax(np.asarray(states.best_score)))
    states = poison_chain_state(states, top, "nan")
    assert best_finite_chain(states.best_score) != top
    progressed = np.ones(4, bool)
    progressed[1] = False
    out, _, healed = rebalance_chains(
        jax.random.key(2), states, progressed, np.zeros(4, np.int64),
        StragglerPolicy(patience=1), return_mask=True)
    assert healed[1]
    assert np.isfinite(np.asarray(out.score)[1])
    assert np.isfinite(np.asarray(out.best_score)[1])
    donor = best_finite_chain(states.best_score)
    np.testing.assert_array_equal(np.asarray(out.pos)[1],
                                  np.asarray(states.pos)[donor])


def test_supervisor_trace_reseed_follows_heal():
    from repro.runtime.supervisor import _reseed_trace
    from repro.telemetry import init_trace

    trace = init_trace(4, 10, n_windows=2, cap=8)
    trace = trace._replace(
        scores=trace.scores + jnp.arange(4, dtype=jnp.float32)[:, None],
        edge_counts=trace.edge_counts
        + jnp.arange(4, dtype=jnp.int32)[:, None, None])
    healed = np.array([False, True, False, False])
    out = _reseed_trace(trace, healed, donor=2)
    np.testing.assert_array_equal(np.asarray(out.scores[1]),
                                  np.asarray(trace.scores[2]))
    np.testing.assert_array_equal(np.asarray(out.scores[0]),
                                  np.asarray(trace.scores[0]))
    np.testing.assert_array_equal(np.asarray(out.edge_counts[1]),
                                  np.asarray(trace.edge_counts[2]))
    assert np.asarray(out.reseeds).tolist() == [0, 1, 0, 0]


def test_remesh_then_reshard_roundtrips_chain_leaves():
    """remesh_plan -> reshard_tree on the live platform: chain-stacked
    leaves placed with a chains-over-'data' spec survive bitwise (the
    restart path: topology-free checkpoint -> new mesh)."""
    from jax.sharding import PartitionSpec as P
    from repro.runtime.jax_compat import make_auto_mesh

    ndev = jax.device_count()
    shape, names = remesh_plan(ndev, model_parallel=1)
    assert shape == (ndev, 1) and names == ("data", "model")
    mesh = make_auto_mesh(shape, names)
    C = 2 * ndev
    tree = {"pos": np.arange(C * 6).reshape(C, 6),
            "score": np.linspace(0, 1, C)}
    specs = {"pos": P("data"), "score": P("data")}
    placed = reshard_tree(tree, specs, mesh)
    np.testing.assert_array_equal(np.asarray(placed["pos"]), tree["pos"])
    np.testing.assert_array_equal(np.asarray(placed["score"]), tree["score"])
    assert placed["pos"].sharding.mesh.shape["data"] == ndev
