"""Runtime features: elastic re-meshing plans and straggler mitigation."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.elastic import (accum_steps_for_batch, remesh_plan,
                                   reshard_tree)
from repro.runtime.straggler import StragglerPolicy, rebalance_chains


def test_remesh_plan_shrink_grows_data_axis():
    # healthy 512-chip 2-pod job
    assert remesh_plan(512, model_parallel=16, prefer_pods=2) == \
        ((2, 16, 16), ("pod", "data", "model"))
    # a pod dies: restart on 256 chips, same model parallelism
    assert remesh_plan(256, model_parallel=16) == ((16, 16), ("data", "model"))
    # odd survivor counts still factor as long as TP divides
    assert remesh_plan(192, model_parallel=16) == ((12, 16), ("data", "model"))
    with pytest.raises(ValueError):
        remesh_plan(250, model_parallel=16)


def test_accum_steps_preserve_global_batch():
    assert accum_steps_for_batch(256, 256) == 1
    assert accum_steps_for_batch(256, 128) == 2   # half the chips -> 2 steps
    with pytest.raises(ValueError):
        accum_steps_for_batch(256, 96)


def test_straggler_chain_cloning():
    from repro.core.combinatorics import build_pst, n_parent_sets
    from repro.core.mcmc import init_chain, mcmc_run
    from repro.core.order_scoring import score_order_chunked

    n, s = 8, 2
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    pad = (-S) % 16
    table = jnp.pad(table, ((0, 0), (0, pad)), constant_values=-3e38)
    pst = jnp.pad(jnp.asarray(pst), ((0, pad), (0, 0)), constant_values=-1)
    fn = functools.partial(score_order_chunked, table, pst, block=16)

    keys = jax.random.split(jax.random.key(0), 4)
    states = jax.vmap(lambda k: init_chain(k, n, fn))(keys)

    # chain 2 misses twice -> cloned from the best chain with a fresh key
    progressed = np.array([True, True, False, True])
    missed = np.zeros(4, np.int64)
    states1, missed = rebalance_chains(jax.random.key(1), states,
                                       progressed, missed,
                                       StragglerPolicy(patience=2))
    assert missed[2] == 1           # first miss: no action yet
    np.testing.assert_array_equal(np.asarray(states1.pos),
                                  np.asarray(states.pos))

    states2, missed = rebalance_chains(jax.random.key(2), states1,
                                       progressed, missed,
                                       StragglerPolicy(patience=2))
    assert missed[2] == 0           # re-seeded
    best = int(np.argmax(np.asarray(states.best_score)))
    np.testing.assert_array_equal(np.asarray(states2.pos[2]),
                                  np.asarray(states.pos[best]))
    # fresh key: the clone diverges from its source immediately
    assert not np.array_equal(
        np.asarray(jax.random.key_data(states2.key[2])),
        np.asarray(jax.random.key_data(states2.key[best])))
    # cloned chain keeps sampling fine
    st, _ = mcmc_run(states2.key[2], n, fn, 10)
    assert np.isfinite(float(st.best_score))
