"""core/metrics hardening + the edge_posterior helper (ISSUE 7 satellite)."""
import numpy as np
import pytest

from repro.core import edge_posterior, roc_point, structural_hamming


def test_roc_point_basic():
    truth = np.zeros((3, 3), int)
    truth[0, 1] = truth[1, 2] = 1
    learned = np.zeros((3, 3), int)
    learned[0, 1] = 1               # one true edge
    learned[2, 0] = 1               # one spurious edge
    fp, tp = roc_point(learned, truth)
    assert tp == 0.5                # 1 of 2 true edges
    assert fp == 0.25               # 1 of 4 true non-edges


def test_roc_point_empty_inputs():
    fp, tp = roc_point(np.zeros((0, 0)), np.zeros((0, 0)))
    assert (fp, tp) == (0.0, 0.0)
    fp, tp = roc_point(np.zeros((4, 4)), np.zeros((4, 4)))   # edgeless truth
    assert (fp, tp) == (0.0, 0.0)


def test_roc_point_ignores_self_loops():
    truth = np.eye(4, dtype=int)          # only self-loops: no real edges
    learned = np.eye(4, dtype=int)
    assert roc_point(learned, truth) == (0.0, 0.0)
    # a self-loop on the learned side is not a false positive
    truth = np.zeros((3, 3), int)
    truth[0, 1] = 1
    learned = truth.copy()
    learned[2, 2] = 1
    fp, tp = roc_point(learned, truth)
    assert (fp, tp) == (0.0, 1.0)


def test_roc_point_rejects_bad_shapes():
    with pytest.raises(ValueError, match="square"):
        roc_point(np.zeros((2, 3)), np.zeros((3, 3)))
    with pytest.raises(ValueError, match="square"):
        roc_point(np.zeros(3), np.zeros((3, 3)))
    with pytest.raises(ValueError, match="differ"):
        roc_point(np.zeros((2, 2)), np.zeros((3, 3)))


def test_structural_hamming_hardened():
    assert structural_hamming(np.zeros((0, 0)), np.zeros((0, 0))) == 0
    a = np.zeros((3, 3), int)
    b = a.copy()
    b[1, 1] = 1                           # self-loop only: not a difference
    assert structural_hamming(a, b) == 0
    b[0, 2] = 1
    assert structural_hamming(a, b) == 1
    with pytest.raises(ValueError, match="differ"):
        structural_hamming(np.zeros((2, 2)), np.zeros((3, 3)))


def test_edge_posterior_hand_computed_3_nodes():
    # 4 thinned samples of a 3-node walk: edge 0->1 present in all four,
    # 1->2 in two, 2->0 in one; the diagonal picked up a stray count
    counts = np.array([[1, 4, 0],
                       [0, 0, 2],
                       [1, 0, 0]])
    p = edge_posterior(counts, 4)
    expect = np.array([[0.0, 1.0, 0.0],
                       [0.0, 0.0, 0.5],
                       [0.25, 0.0, 0.0]])
    np.testing.assert_allclose(p, expect)


def test_edge_posterior_pools_chains():
    counts = np.stack([np.full((3, 3), 2), np.full((3, 3), 4)])  # (C, n, n)
    p = edge_posterior(counts, 4)         # (2+4) / (2 chains * 4 samples)
    off = ~np.eye(3, dtype=bool)
    np.testing.assert_allclose(p[off], 0.75)
    np.testing.assert_allclose(np.diag(p), 0.0)


def test_edge_posterior_degenerate_and_invalid():
    np.testing.assert_array_equal(edge_posterior(np.zeros((3, 3)), 0),
                                  np.zeros((3, 3)))
    with pytest.raises(ValueError, match="square"):
        edge_posterior(np.zeros((2, 3)), 1)
    with pytest.raises(ValueError, match="shape"):
        edge_posterior(np.zeros(3), 1)
    with pytest.raises(ValueError, match="outside"):
        edge_posterior(np.full((2, 2), 9), 4)
    with pytest.raises(ValueError, match="outside"):
        edge_posterior(np.full((2, 2), -1), 4)
