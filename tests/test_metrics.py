"""core/metrics hardening + the edge_posterior helper (ISSUE 7 satellite)."""
import numpy as np
import pytest

from repro.core import edge_posterior, roc_point, structural_hamming


def test_roc_point_basic():
    truth = np.zeros((3, 3), int)
    truth[0, 1] = truth[1, 2] = 1
    learned = np.zeros((3, 3), int)
    learned[0, 1] = 1               # one true edge
    learned[2, 0] = 1               # one spurious edge
    fp, tp = roc_point(learned, truth)
    assert tp == 0.5                # 1 of 2 true edges
    assert fp == 0.25               # 1 of 4 true non-edges


def test_roc_point_empty_inputs():
    fp, tp = roc_point(np.zeros((0, 0)), np.zeros((0, 0)))
    assert (fp, tp) == (0.0, 0.0)
    fp, tp = roc_point(np.zeros((4, 4)), np.zeros((4, 4)))   # edgeless truth
    assert (fp, tp) == (0.0, 0.0)


def test_roc_point_ignores_self_loops():
    truth = np.eye(4, dtype=int)          # only self-loops: no real edges
    learned = np.eye(4, dtype=int)
    assert roc_point(learned, truth) == (0.0, 0.0)
    # a self-loop on the learned side is not a false positive
    truth = np.zeros((3, 3), int)
    truth[0, 1] = 1
    learned = truth.copy()
    learned[2, 2] = 1
    fp, tp = roc_point(learned, truth)
    assert (fp, tp) == (0.0, 1.0)


def test_roc_point_rejects_bad_shapes():
    with pytest.raises(ValueError, match="square"):
        roc_point(np.zeros((2, 3)), np.zeros((3, 3)))
    with pytest.raises(ValueError, match="square"):
        roc_point(np.zeros(3), np.zeros((3, 3)))
    with pytest.raises(ValueError, match="differ"):
        roc_point(np.zeros((2, 2)), np.zeros((3, 3)))


def test_structural_hamming_hardened():
    assert structural_hamming(np.zeros((0, 0)), np.zeros((0, 0))) == 0
    a = np.zeros((3, 3), int)
    b = a.copy()
    b[1, 1] = 1                           # self-loop only: not a difference
    assert structural_hamming(a, b) == 0
    b[0, 2] = 1
    assert structural_hamming(a, b) == 1
    with pytest.raises(ValueError, match="differ"):
        structural_hamming(np.zeros((2, 2)), np.zeros((3, 3)))


def test_edge_posterior_hand_computed_3_nodes():
    # 4 thinned samples of a 3-node walk: edge 0->1 present in all four,
    # 1->2 in two, 2->0 in one; the diagonal picked up a stray count
    counts = np.array([[1, 4, 0],
                       [0, 0, 2],
                       [1, 0, 0]])
    p = edge_posterior(counts, 4)
    expect = np.array([[0.0, 1.0, 0.0],
                       [0.0, 0.0, 0.5],
                       [0.25, 0.0, 0.0]])
    np.testing.assert_allclose(p, expect)


def test_edge_posterior_pools_chains():
    counts = np.stack([np.full((3, 3), 2), np.full((3, 3), 4)])  # (C, n, n)
    p = edge_posterior(counts, 4)         # (2+4) / (2 chains * 4 samples)
    off = ~np.eye(3, dtype=bool)
    np.testing.assert_allclose(p[off], 0.75)
    np.testing.assert_allclose(np.diag(p), 0.0)


def test_edge_posterior_degenerate_and_invalid():
    np.testing.assert_array_equal(edge_posterior(np.zeros((3, 3)), 0),
                                  np.zeros((3, 3)))
    with pytest.raises(ValueError, match="square"):
        edge_posterior(np.zeros((2, 3)), 1)
    with pytest.raises(ValueError, match="shape"):
        edge_posterior(np.zeros(3), 1)
    with pytest.raises(ValueError, match="outside"):
        edge_posterior(np.full((2, 2), 9), 4)
    with pytest.raises(ValueError, match="outside"):
        edge_posterior(np.full((2, 2), -1), 4)


# ---------------------------------------------------------------------------
# map_dag / consensus_graph (ISSUE 10 satellite: the service query layer's
# posterior artifacts), property-tested against a brute-force oracle
# ---------------------------------------------------------------------------
from _propcheck import given, hst, settings  # noqa: E402

from repro.core.combinatorics import (candidates_to_nodes,  # noqa: E402
                                      nodes_to_candidates, rank_parent_set,
                                      unrank_parent_set)
from repro.core.metrics import consensus_graph, map_dag  # noqa: E402
from repro.core.scores import build_score_table  # noqa: E402
from repro.preprocess.sparse import prune_table  # noqa: E402


def _oracle_map_dag(table, s, pos):
    """Brute force: per child, walk EVERY global PST rank in order, keep the
    first consistent argmax (strict > — ties resolve to the lowest rank,
    the contract map_dag and the jitted scorers share)."""
    n = len(pos)
    adj = np.zeros((n, n), np.int8)
    for i in range(n):
        best, best_parents = -np.inf, np.empty(0, np.int64)
        for r in range(table.shape[1]):
            parents = candidates_to_nodes(unrank_parent_set(n - 1, s, r), i)
            if all(pos[p] < pos[i] for p in parents) and table[i, r] > best:
                best, best_parents = table[i, r], parents
        adj[best_parents, i] = 1
    return adj


def _map_score(table, s, adj):
    """Total score of a decoded structure: sum of each child's chosen
    parent-set entry (tie-insensitive quality measure)."""
    n = adj.shape[0]
    return sum(table[i, rank_parent_set(
        n - 1, s, nodes_to_candidates(np.nonzero(adj[:, i])[0], i))]
        for i in range(n))


@settings(max_examples=10)
@given(hst.integers(0, 10_000))
def test_map_dag_matches_bruteforce_oracle(seed):
    rng = np.random.default_rng(seed)
    n, s = int(rng.integers(3, 7)), int(rng.integers(1, 3))
    data = rng.integers(0, 2, size=(50, n)).astype(np.int8)
    st = build_score_table(data, q=2, s=s)
    pos = np.argsort(rng.permutation(n))      # pos[v] = position of node v
    table = np.asarray(st.table)
    want = _oracle_map_dag(table, s, pos)
    got = map_dag(st, pos)
    np.testing.assert_array_equal(got, want)
    # every edge respects the order, and the decode is score-optimal
    pr, ch = np.nonzero(got)
    assert np.all(pos[pr] < pos[ch])
    assert np.isclose(_map_score(table, s, got), _map_score(table, s, want))


@settings(max_examples=10)
@given(hst.integers(0, 10_000))
def test_map_dag_pruned_matches_dense(seed):
    rng = np.random.default_rng(seed)
    n, s = int(rng.integers(3, 7)), int(rng.integers(1, 3))
    data = rng.integers(0, 2, size=(50, n)).astype(np.int8)
    st = build_score_table(data, q=2, s=s)
    pos = np.argsort(rng.permutation(n))
    # delta wide enough to keep everything: the pruned decode must agree
    # with the dense one exactly (kept_idx is rank-ascending, so even score
    # ties break identically)
    sp = prune_table(st, delta=1e9)
    np.testing.assert_array_equal(map_dag(sp, pos), map_dag(st, pos))
    # a tight delta still yields an order-consistent DAG
    tight = map_dag(prune_table(st, delta=1.0), pos)
    pr, ch = np.nonzero(tight)
    assert np.all(pos[pr] < pos[ch])


def test_map_dag_rejects_bad_pos():
    data = np.zeros((10, 3), np.int8)
    st = build_score_table(data, q=2, s=1)
    with pytest.raises(ValueError, match="flat"):
        map_dag(st, np.zeros((2, 3), int))


def test_consensus_graph_thresholds():
    p = np.array([[0.0, 0.9, 0.5],
                  [0.2, 0.0, 0.49],
                  [1.0, 0.5, 0.0]])
    got = consensus_graph(p, 0.5)
    want = np.array([[0, 1, 1],
                     [0, 0, 0],
                     [1, 1, 0]], np.int8)
    np.testing.assert_array_equal(got, want)
    assert consensus_graph(p, 1.0).sum() == 1          # only the certain edge
    # diagonal is dropped even when probabilities sneak onto it
    q = np.eye(3) * 0.9
    assert consensus_graph(q, 0.5).sum() == 0


def test_consensus_graph_validation():
    with pytest.raises(ValueError, match="square"):
        consensus_graph(np.zeros((2, 3)), 0.5)
    with pytest.raises(ValueError, match="outside"):
        consensus_graph(np.full((2, 2), 1.5), 0.5)
    with pytest.raises(ValueError, match="threshold"):
        consensus_graph(np.zeros((2, 2)), 0.0)
    with pytest.raises(ValueError, match="threshold"):
        consensus_graph(np.zeros((2, 2)), 1.1)
