"""The ISSUE 3 engine: cached consistency bitmasks ≡ recomputed masks
(bitwise, over move SEQUENCES), adaptive-window freeze, in-scan
exchange_best invariants, and restore of the extended ChainState from a
pre-tentpole checkpoint layout.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, hst, settings

from repro.core.combinatorics import build_pst, n_parent_sets
from repro.core.mcmc import (BitmaskDelta, ChainState, exchange_best,
                             exchange_step, init_chain, mcmc_run,
                             mcmc_run_adaptive, mcmc_run_chains, propose_move)
from repro.core.order_scoring import (NEG_INF, build_membership_planes,
                                      build_violation_planes, consistent_mask,
                                      pack_mask_words,
                                      planes_consistent_words,
                                      score_order_blocked,
                                      score_order_delta_bitmask,
                                      unpack_mask_words)


@functools.lru_cache(maxsize=None)
def _problem(n=12, s=3, block=64, seed=42):
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    pad = (-S) % block
    table = jnp.pad(table, ((0, 0), (0, pad)), constant_values=NEG_INF)
    pst = jnp.pad(jnp.asarray(pst), ((0, pad), (0, 0)), constant_values=-1)
    cm = build_membership_planes(pst, n)
    return table, pst, cm


def test_pack_unpack_roundtrip_and_init_planes_match_masks():
    """Packed word layout (LSB-first, rank 32j+b) roundtrips, and the
    freshly-built violation planes decode to exactly consistent_mask for
    every node."""
    table, pst, _ = _problem()
    n = table.shape[0]
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, 256).astype(bool)
    np.testing.assert_array_equal(
        np.asarray(unpack_mask_words(pack_mask_words(jnp.asarray(bits)))),
        bits)
    pos = jnp.asarray(rng.permutation(n).astype(np.int32))
    planes = build_violation_planes(pst, pos)
    for i in range(n):
        want = np.asarray(consistent_mask(pst, jnp.int32(i), pos))
        got = np.asarray(unpack_mask_words(planes_consistent_words(planes[i])))
        np.testing.assert_array_equal(got, want)


@given(hst.integers(0, 2**31 - 1))
@settings(max_examples=200, deadline=None)
def test_bitmask_cache_equals_recomputed_masks(seed):
    """≥200 randomized move SEQUENCES: the incrementally-patched planes stay
    bitwise-equal to planes rebuilt from scratch, and the bitmask delta
    rescore stays bitwise-equal to a full blocked rescore — total, argmax
    parent sets, per-node scores — across 4 chained moves."""
    block = 64
    table, pst, cm = _problem(block=block)
    n = table.shape[0]
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.permutation(n).astype(np.int32))
    planes = build_violation_planes(pst, pos)
    _, idx, ls = score_order_blocked(table, pst, pos, block=block)
    key = jax.random.key(seed)
    for _ in range(4):
        key, k_mv = jax.random.split(key)
        w = int(rng.integers(2, 7))
        new_pos, lo = propose_move(k_mv, pos, window=w)
        tot, gidx, gls, new_planes = score_order_delta_bitmask(
            table, cm, new_pos, ls, idx, lo, pos, planes, window=w,
            block=block)
        want = score_order_blocked(table, pst, new_pos, block=block)
        assert float(tot) == float(want[0])
        np.testing.assert_array_equal(np.asarray(gidx), np.asarray(want[1]))
        np.testing.assert_array_equal(np.asarray(gls), np.asarray(want[2]))
        np.testing.assert_array_equal(
            np.asarray(new_planes),
            np.asarray(build_violation_planes(pst, new_pos)))
        pos, planes, idx, ls = new_pos, new_planes, want[1], want[2]


def test_mcmc_bitmask_chain_is_bitwise_identical(padded_random_table):
    """Same key, same proposals: the bitmask-cached chain and the
    full-rescore chain traverse identical states, and the carried planes
    always describe the CURRENT order."""
    table, pst, block = padded_random_table
    n = table.shape[0]
    cm = build_membership_planes(pst, n)
    fn = functools.partial(score_order_blocked, table, pst, block=block)
    planes_fn = functools.partial(build_violation_planes, pst)

    def bfn(pos, lo, prev_ls, prev_idx, pos_old, planes):
        return score_order_delta_bitmask(table, cm, pos, prev_ls, prev_idx,
                                         lo, pos_old, planes, window=4,
                                         block=block)

    a, _ = mcmc_run(jax.random.key(3), n, fn, 300, window=4)
    b, _ = mcmc_run(jax.random.key(3), n, fn, 300,
                    delta_fn=BitmaskDelta(bfn), window=4,
                    planes_fn=planes_fn)
    assert float(a.score) == float(b.score)
    assert float(a.best_score) == float(b.best_score)
    assert int(a.accepts) == int(b.accepts)
    np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))
    np.testing.assert_array_equal(np.asarray(a.best_idx),
                                  np.asarray(b.best_idx))
    np.testing.assert_array_equal(np.asarray(a.cur_ls), np.asarray(b.cur_ls))
    np.testing.assert_array_equal(np.asarray(b.mask_planes),
                                  np.asarray(planes_fn(b.pos)))


def test_kernel_bitmask_variant_matches_core(padded_random_table):
    """The packed-word Pallas kernel (interpret mode) == the jnp bitmask
    scorer == the gather-path blocked scorer, bitwise."""
    from repro.kernels.order_score import order_score_delta_bitmask

    table, pst, block = padded_random_table
    n = table.shape[0]
    cm = build_membership_planes(pst, n)
    rng = np.random.default_rng(7)
    pos = jnp.asarray(rng.permutation(n).astype(np.int32))
    planes = build_violation_planes(pst, pos)
    _, idx, ls = score_order_blocked(table, pst, pos, block=block)
    for seed in range(3):
        new_pos, lo = propose_move(jax.random.key(seed), pos, window=3)
        want = score_order_blocked(table, pst, new_pos, block=block)
        for use_pallas in (True, False):
            got = order_score_delta_bitmask(
                table, cm, new_pos, ls, idx, lo, pos, planes, window=3,
                block_s=block, use_pallas=use_pallas, interpret=True)
            assert float(got[0]) == float(want[0])
            np.testing.assert_array_equal(np.asarray(got[1]),
                                          np.asarray(want[1]))
            np.testing.assert_array_equal(np.asarray(got[2]),
                                          np.asarray(want[2]))
            # the fused kernel's patched plane words == from-scratch build
            np.testing.assert_array_equal(
                np.asarray(got[3]),
                np.asarray(build_violation_planes(pst, new_pos)))
        pos, planes = new_pos, got[3]
        idx, ls = want[1], want[2]


# ------------------------------------------------- structural PST padding
def test_padded_pst_rows_are_structurally_inconsistent():
    """ISSUE 4 bugfix: pad_table/pad_for_kernel pad PST rows with the
    PAD_SET sentinel (-2), which every consistency path rejects — padded
    ranks can never reach best_idx even when the TABLE pad is 0.0 (which
    beats every real score here), where the old -1 pad (indistinguishable
    from the always-consistent empty set) handed best_idx to a padded
    rank."""
    from repro.core.order_scoring import PAD_SET, score_order_chunked
    from repro.core.sharded_scoring import pad_table
    from repro.kernels.order_score import order_score, pad_for_kernel

    from repro.core.combinatorics import build_pst, n_parent_sets

    n, s, block = 13, 3, 64
    S = n_parent_sets(n - 1, s)
    assert S % block != 0, "want a ragged pad for this test"
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(3)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    tpad, ppad = pad_table(table, jnp.asarray(pst), block)
    assert int(np.asarray(ppad)[S:].max(initial=PAD_SET)) == PAD_SET
    _, ppad_k = pad_for_kernel(table, jnp.asarray(pst), block)
    np.testing.assert_array_equal(np.asarray(ppad), np.asarray(ppad_k))
    # adversarial table pad: 0.0 beats every real entry
    tzero = jnp.pad(table, ((0, 0), (0, tpad.shape[1] - S)),
                    constant_values=0.0)
    pos = jnp.asarray(rng.permutation(n).astype(np.int32))
    for i in range(n):
        m = np.asarray(consistent_mask(ppad, jnp.int32(i), pos))
        assert not m[S:].any()
    planes = build_violation_planes(ppad, pos)
    for i in range(n):
        bits = np.asarray(unpack_mask_words(
            planes_consistent_words(planes[i])))
        assert not bits[S:].any()
    for scorer in (score_order_blocked, score_order_chunked):
        _, idx, _ = scorer(tzero, ppad, pos, block=block)
        assert int(np.max(np.asarray(idx))) < S, scorer.__name__
    _, idx, _ = order_score(tzero, ppad, pos, block_s=block, interpret=True)
    assert int(np.max(np.asarray(idx))) < S
    # bitmask delta on the adversarially-padded table also stays < S
    cm = build_membership_planes(ppad, n)
    _, idx0, ls0 = score_order_blocked(tzero, ppad, pos, block=block)
    new_pos, lo = propose_move(jax.random.key(0), pos, window=4)
    tot, gidx, _, _ = score_order_delta_bitmask(
        tzero, cm, new_pos, ls0, idx0, lo, pos, planes, window=4,
        block=block)
    assert int(np.max(np.asarray(gidx))) < S


# ------------------------------------------------- in-scan exchange_best
@pytest.fixture(scope="module")
def small_problem():
    table, pst, cm = _problem()
    block = 64
    fn = functools.partial(score_order_blocked, table, pst, block=block)
    return table, pst, cm, block, fn


def test_exchange_step_reseeds_worst_from_best(small_problem):
    """exchange_step: the worst chain inherits the best chain's position AND
    cache state together; everyone's best_score is monotone; keys stay
    per-slot."""
    _, _, _, _, fn = small_problem
    n = 12
    keys = jax.random.split(jax.random.key(0), 4)
    states = jax.vmap(lambda k: init_chain(k, n, fn))(keys)
    # make the ranking unambiguous
    states = states._replace(best_score=jnp.asarray([3., -9., 1., 2.],
                                                    jnp.float32))
    before = np.asarray(states.best_score)
    out = jax.jit(exchange_step)(states)
    b, w = int(np.argmax(before)), int(np.argmin(before))
    np.testing.assert_array_equal(np.asarray(out.pos[w]),
                                  np.asarray(states.pos[b]))
    assert float(out.score[w]) == float(states.score[b])
    np.testing.assert_array_equal(np.asarray(out.cur_idx[w]),
                                  np.asarray(states.cur_idx[b]))
    np.testing.assert_array_equal(np.asarray(out.cur_ls[w]),
                                  np.asarray(states.cur_ls[b]))
    assert float(out.best_score[w]) == float(before[b])
    # monotone: nobody's best got worse
    assert (np.asarray(out.best_score) >= before).all()
    # PRNG keys unchanged (clones diverge immediately)
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(out.key)),
        np.asarray(jax.random.key_data(states.key)))
    # untouched chains are bitwise-identical
    for c in range(4):
        if c != w:
            np.testing.assert_array_equal(np.asarray(out.pos[c]),
                                          np.asarray(states.pos[c]))


def test_mcmc_run_chains_in_scan_exchange_invariants(small_problem):
    """After a run WITH periodic exchange: every chain's (score, cur_idx,
    cur_ls, mask_planes) still describe its own pos — the re-seed copied
    caches consistently — and the final reduction returns a reproducible
    best triple."""
    table, pst, cm, block, fn = small_problem
    n = 12
    planes_fn = functools.partial(build_violation_planes, pst)

    def bfn(pos, lo, prev_ls, prev_idx, pos_old, planes):
        return score_order_delta_bitmask(table, cm, pos, prev_ls, prev_idx,
                                         lo, pos_old, planes, window=4,
                                         block=block)

    states = mcmc_run_chains(jax.random.key(5), 4, n, fn, 120,
                             delta_fn=BitmaskDelta(bfn), window=4,
                             exchange_every=25, planes_fn=planes_fn)
    for c in range(4):
        sc, idx, ls = fn(states.pos[c])
        assert float(sc) == float(states.score[c])
        np.testing.assert_array_equal(np.asarray(idx),
                                      np.asarray(states.cur_idx[c]))
        np.testing.assert_array_equal(np.asarray(ls),
                                      np.asarray(states.cur_ls[c]))
        np.testing.assert_array_equal(
            np.asarray(states.mask_planes[c]),
            np.asarray(planes_fn(states.pos[c])))
        assert float(states.best_score[c]) >= float(states.score[c]) - 1e-4
    bs, bi, bp = exchange_best(states)
    sc, idx, _ = fn(bp)
    assert float(sc) == float(bs)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(bi))


def test_exchange_step_degenerate_ranking_is_noop(small_problem):
    """ISSUE 4 bugfix: all-equal best_score makes argmax == argmin — the
    exchange must be a true NO-OP (guarded lax.cond), leaving EVERY leaf of
    every chain bitwise-untouched."""
    _, _, _, _, fn = small_problem
    n = 12
    keys = jax.random.split(jax.random.key(6), 4)
    states = jax.vmap(lambda k: init_chain(k, n, fn))(keys)
    states = states._replace(
        best_score=jnp.zeros(4, jnp.float32),
        win_idx=jnp.asarray([0, 1, 2, 3], jnp.int32),
        adapt_err=jnp.asarray([0.1, -0.2, 0.3, -0.4], jnp.float32))
    out = jax.jit(exchange_step)(states)
    for name in ChainState._fields:
        got, want = getattr(out, name), getattr(states, name)
        if name == "key":
            got, want = jax.random.key_data(got), jax.random.key_data(want)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want), name)


def test_exchange_step_keeps_adaptive_stats_per_slot(small_problem):
    """Non-degenerate exchange copies pos/caches/best_* — and ONLY those:
    win_idx, dual-averaging error, step, accept counts and PRNG keys stay
    strictly per-slot (a re-seeded chain keeps its own tuning)."""
    _, _, _, _, fn = small_problem
    n = 12
    keys = jax.random.split(jax.random.key(8), 4)
    states = jax.vmap(lambda k: init_chain(k, n, fn))(keys)
    states = states._replace(
        best_score=jnp.asarray([5., -2., 0., 1.], jnp.float32),
        win_idx=jnp.asarray([3, 1, 0, 2], jnp.int32),
        adapt_err=jnp.asarray([0.5, -0.1, 0.2, 0.9], jnp.float32),
        accepts=jnp.asarray([7, 3, 9, 1], jnp.int32),
        step=jnp.asarray([10, 10, 10, 10], jnp.int32))
    out = jax.jit(exchange_step)(states)
    # the worst slot really was re-seeded...
    np.testing.assert_array_equal(np.asarray(out.pos[1]),
                                  np.asarray(states.pos[0]))
    # ...but per-slot statistics never move
    for name in ("win_idx", "adapt_err", "accepts", "step"):
        np.testing.assert_array_equal(np.asarray(getattr(out, name)),
                                      np.asarray(getattr(states, name)), name)
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(out.key)),
        np.asarray(jax.random.key_data(states.key)))


def test_adaptive_chains_with_exchange_keep_per_slot_windows(small_problem):
    """mcmc_run_chains_adaptive + periodic in-scan exchange: the selection
    stays inside the static window set per chain, and on a FLAT table (all
    best_score equal, the degenerate ranking every round) the guarded
    exchange leaves the run bitwise-identical to exchange_every=0."""
    _, _, _, _, fn = small_problem
    n = 12
    from repro.core.mcmc import mcmc_run_chains_adaptive
    sts = mcmc_run_chains_adaptive(jax.random.key(3), 4, n, fn, 60,
                                   windows=(2, 4), delta_fns=(None, None),
                                   burn_in=20, exchange_every=15)
    assert set(np.asarray(sts.win_idx).tolist()) <= {0, 1}
    assert np.isfinite(np.asarray(sts.adapt_err)).all()

    flat = lambda pos: (jnp.float32(0.0), jnp.zeros(n, jnp.int32),
                        jnp.zeros(n, jnp.float32))
    a = mcmc_run_chains_adaptive(jax.random.key(4), 3, n, flat, 40,
                                 windows=(2, 4), delta_fns=(None, None),
                                 burn_in=10, exchange_every=10)
    b = mcmc_run_chains_adaptive(jax.random.key(4), 3, n, flat, 40,
                                 windows=(2, 4), delta_fns=(None, None),
                                 burn_in=10, exchange_every=0)
    np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))
    np.testing.assert_array_equal(np.asarray(a.win_idx),
                                  np.asarray(b.win_idx))
    np.testing.assert_array_equal(np.asarray(a.adapt_err),
                                  np.asarray(b.adapt_err))


def test_mcmc_run_chains_exchange_off_matches_legacy(small_problem):
    """exchange_every=0 keeps chains fully independent: identical to vmapped
    mcmc_run with the same keys."""
    _, _, _, _, fn = small_problem
    n = 12
    a = mcmc_run_chains(jax.random.key(2), 3, n, fn, 80, window=4)
    keys = jax.random.split(jax.random.key(2), 3)
    b, _ = jax.vmap(lambda k: mcmc_run(k, n, fn, 80, window=4))(keys)
    np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))
    np.testing.assert_array_equal(np.asarray(a.best_score),
                                  np.asarray(b.best_score))


# ------------------------------------------------- adaptive move windows
def test_adaptive_window_freezes_after_burn_in(small_problem):
    """win_idx stops moving once step >= burn_in (MCMC validity: post-warmup
    samples come from ONE fixed kernel), stays inside the static set, and
    the chain's caches remain consistent with its pos."""
    _, _, _, _, fn = small_problem
    n = 12
    st, (tr_sc, tr_w) = mcmc_run_adaptive(
        jax.random.key(7), n, fn, 150, windows=(2, 4, 6),
        delta_fns=(None, None, None), burn_in=60, trace=True)
    tw = np.asarray(tr_w)
    assert set(tw.tolist()) <= {0, 1, 2}
    assert len(set(tw[60:].tolist())) == 1, "window kept adapting past burn-in"
    assert 0 < int(st.accepts) <= 150
    sc, idx, ls = fn(st.pos)
    assert float(sc) == float(st.score)
    assert float(st.best_score) >= float(np.max(np.asarray(tr_sc))) - 1e-4


def test_adaptive_flat_table_accepts_everything(small_problem):
    """On a constant table every proposal is accepted regardless of which
    window branch fired — the adaptive mixture preserves move symmetry."""
    n = 12
    fn = lambda pos: (jnp.float32(0.0), jnp.zeros(n, jnp.int32),
                      jnp.zeros(n, jnp.float32))
    st, _ = mcmc_run_adaptive(jax.random.key(9), n, fn, 100,
                              windows=(2, 4), delta_fns=(None, None),
                              burn_in=30)
    assert int(st.accepts) == 100


# ------------------------------------------------- checkpoint compatibility
def test_restore_extended_chainstate_from_pre_tentpole_checkpoint(
        tmp_path, small_problem):
    """A checkpoint written with the OLD 9-leaf ChainState layout restores
    into the extended 13-leaf state: old leaves land bitwise, new leaves keep
    the caller's freshly-initialised values (allow_missing), and the planes
    rebuilt from the restored pos let the bitmask chain continue."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    table, pst, cm, block, fn = small_problem
    n = 12
    keys = jax.random.split(jax.random.key(1), 2)
    planes_fn = functools.partial(build_violation_planes, pst)
    states = jax.vmap(
        lambda k: init_chain(k, n, fn, planes_fn=planes_fn))(keys)
    pack = lambda st: jax.tree.map(
        np.asarray, st._replace(key=jax.random.key_data(st.key)))
    full = tuple(pack(states))

    # pre-tentpole snapshot: exactly the first 9 ChainState leaves
    old_layout = full[:9]
    save_checkpoint(str(tmp_path), 7, old_layout)

    # strict restore of the 13-leaf layout must fail loudly...
    with pytest.raises(ValueError, match="mismatch"):
        restore_checkpoint(str(tmp_path), full, step=7)
    # ...allow_missing backfills the new trailing leaves from the template
    restored, meta = restore_checkpoint(str(tmp_path), full, step=7,
                                        allow_missing=True)
    assert len(meta["missing_leaves"]) == 4
    for got, want in zip(restored[:9], full[:9]):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    st2 = ChainState(*[jnp.asarray(x) for x in restored])._replace(
        key=jax.random.wrap_key_data(jnp.asarray(restored[0])))
    # derived cache: rebuild planes from the restored positions and resume
    st2 = st2._replace(mask_planes=jax.vmap(planes_fn)(st2.pos))

    def bfn(pos, lo, prev_ls, prev_idx, pos_old, planes):
        return score_order_delta_bitmask(table, cm, pos, prev_ls, prev_idx,
                                         lo, pos_old, planes, window=4,
                                         block=block)

    from repro.core.mcmc import mcmc_step
    step = jax.jit(jax.vmap(
        lambda s: mcmc_step(s, fn, BitmaskDelta(bfn), 4)))
    for _ in range(5):
        st2 = step(st2)
    for c in range(2):
        sc, idx, ls = fn(st2.pos[c])
        assert float(sc) == float(st2.score[c])
        np.testing.assert_array_equal(np.asarray(ls),
                                      np.asarray(st2.cur_ls[c]))


def test_restore_across_engine_variants_reconciles_planes(tmp_path,
                                                          small_problem):
    """ISSUE 4 bugfix, both directions: a sharded-run snapshot (zero-size
    mask_planes placeholder) restored into the bitmask engine, and a
    full-planes snapshot restored into a placeholder engine, previously left
    a wrong-shaped planes leaf (allow_missing only backfills MISSING
    leaves). reconcile_mask_planes rebuilds the derived cache from the
    restored positions / resets the placeholder, and the chain continues
    bitwise-correctly."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.core.mcmc import mcmc_step
    from repro.launch.bn_learn import reconcile_mask_planes

    table, pst, cm, block, fn = small_problem
    n = 12
    planes_fn = functools.partial(build_violation_planes, pst)
    keys = jax.random.split(jax.random.key(12), 2)
    with_planes = jax.vmap(
        lambda k: init_chain(k, n, fn, planes_fn=planes_fn))(keys)
    placeholder = jax.vmap(lambda k: init_chain(k, n, fn))(keys)
    pack = lambda st: tuple(jax.tree.map(
        np.asarray, st._replace(key=jax.random.key_data(st.key))))
    unpack = lambda t: ChainState(*[jnp.asarray(x) for x in t])._replace(
        key=jax.random.wrap_key_data(jnp.asarray(t[0])))

    # direction 1: placeholder snapshot -> bitmask engine
    save_checkpoint(str(tmp_path / "a"), 1, pack(placeholder))
    restored, _ = restore_checkpoint(str(tmp_path / "a"), pack(with_planes),
                                     step=1, allow_missing=True)
    st = unpack(restored)
    assert st.mask_planes.shape == (2, 0)          # the wrong-shaped leaf
    st = reconcile_mask_planes(st, lambda p: jax.vmap(planes_fn)(p))
    assert st.mask_planes.shape == with_planes.mask_planes.shape
    np.testing.assert_array_equal(np.asarray(st.mask_planes),
                                  np.asarray(jax.vmap(planes_fn)(st.pos)))

    def bfn(pos, lo, prev_ls, prev_idx, pos_old, planes):
        return score_order_delta_bitmask(table, cm, pos, prev_ls, prev_idx,
                                         lo, pos_old, planes, window=4,
                                         block=block)

    step = jax.jit(jax.vmap(
        lambda s: mcmc_step(s, fn, BitmaskDelta(bfn), 4)))
    for _ in range(5):
        st = step(st)
    for c in range(2):
        sc, _, ls = fn(st.pos[c])
        assert float(sc) == float(st.score[c])
        np.testing.assert_array_equal(np.asarray(ls),
                                      np.asarray(st.cur_ls[c]))
        np.testing.assert_array_equal(np.asarray(st.mask_planes[c]),
                                      np.asarray(planes_fn(st.pos[c])))

    # direction 2: full-planes snapshot -> placeholder engine
    save_checkpoint(str(tmp_path / "b"), 1, pack(with_planes))
    restored, _ = restore_checkpoint(str(tmp_path / "b"), pack(placeholder),
                                     step=1, allow_missing=True)
    st = unpack(restored)
    assert st.mask_planes.ndim == 4                # the wrong-shaped leaf
    st = reconcile_mask_planes(st, None)
    assert st.mask_planes.shape == (2, 0)
    step = jax.jit(jax.vmap(lambda s: mcmc_step(s, fn, None, 4)))
    for _ in range(3):
        st = step(st)
    for c in range(2):
        sc, _, _ = fn(st.pos[c])
        assert float(sc) == float(st.score[c])


def test_new_leaves_roundtrip_through_checkpoint(tmp_path, small_problem):
    """Forward path: the 13-leaf layout saves and strict-restores bitwise."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    _, pst, _, _, fn = small_problem
    n = 12
    planes_fn = functools.partial(build_violation_planes, pst)
    st = init_chain(jax.random.key(4), n, fn, planes_fn=planes_fn)
    pack = tuple(jax.tree.map(
        np.asarray, st._replace(key=jax.random.key_data(st.key))))
    save_checkpoint(str(tmp_path), 1, pack)
    restored, meta = restore_checkpoint(str(tmp_path), pack, step=1)
    assert "missing_leaves" not in meta
    for got, want in zip(restored, pack):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
