import itertools
import math

import numpy as np
import pytest
from _propcheck import given, hst, settings

from repro.core.combinatorics import (build_pst, candidates_to_nodes,
                                      n_parent_sets, nodes_to_candidates,
                                      rank_combination,
                                      rank_combinations_batch,
                                      rank_parent_set, size_offsets,
                                      unrank_combination, unrank_parent_set)


@pytest.mark.parametrize("n,s", [(6, 3), (9, 2), (12, 4)])
def test_rank_combinations_batch_matches_scalar(n, s):
    """Vectorized hockey-stick ranking == the scalar rank_parent_set, i.e.
    the identity build_pst row t ranks back to t for every t."""
    pst, sizes = build_pst(n, s)
    got = rank_combinations_batch(n, s, pst, sizes)
    np.testing.assert_array_equal(got, np.arange(pst.shape[0]))
    # and on a shuffled batch with explicit scalar cross-check
    rng = np.random.default_rng(0)
    sel = rng.choice(pst.shape[0], size=min(50, pst.shape[0]), replace=False)
    got = rank_combinations_batch(n, s, pst[sel], sizes[sel])
    want = [rank_parent_set(n, s, row[row >= 0]) for row in pst[sel]]
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n,k", [(5, 2), (7, 3), (8, 4), (6, 1), (4, 4)])
def test_unrank_matches_itertools(n, k):
    combos = list(itertools.combinations(range(n), k))
    for l, c in enumerate(combos):
        assert tuple(unrank_combination(n, k, l)) == c


@given(hst.integers(2, 12), hst.integers(1, 4), hst.data())
@settings(max_examples=200, deadline=None)
def test_rank_unrank_roundtrip(n, k, data):
    k = min(k, n)
    l = data.draw(hst.integers(0, math.comb(n, k) - 1))
    c = unrank_combination(n, k, l)
    assert rank_combination(n, c) == l
    assert np.all(np.diff(c) > 0)  # strictly increasing
    assert 0 <= c[0] and c[-1] < n


def test_unrank_out_of_range():
    with pytest.raises(ValueError):
        unrank_combination(5, 2, math.comb(5, 2))


@pytest.mark.parametrize("nc,s", [(6, 4), (10, 3), (5, 2), (12, 4)])
def test_pst_complete_and_ordered(nc, s):
    pst, sizes = build_pst(nc, s)
    S = n_parent_sets(nc, s)
    assert pst.shape == (S, s)
    assert sizes.shape == (S,)
    # paper's example: n=6 candidates, s=4 -> S=57
    if (nc, s) == (6, 4):
        assert S == 57
    seen = set()
    off = size_offsets(nc, s)
    for i in range(S):
        row = tuple(pst[i][pst[i] >= 0].tolist())
        assert len(row) == sizes[i]
        assert row not in seen
        seen.add(row)
        # rank is the inverse of the table position
        assert rank_parent_set(nc, s, np.asarray(row, np.int64)) == i
    # block boundaries by size
    assert np.all(np.diff(sizes) >= 0)
    for k in range(s + 1):
        assert (sizes == k).sum() == math.comb(nc, k)
        assert off[k + 1] - off[k] == math.comb(nc, k)


@given(hst.integers(2, 20), hst.data())
@settings(max_examples=100, deadline=None)
def test_candidate_node_mapping_bijection(n, data):
    node = data.draw(hst.integers(0, n - 1))
    cands = np.arange(n - 1)
    nodes = candidates_to_nodes(cands, node)
    assert node not in set(nodes.tolist())
    assert len(set(nodes.tolist())) == n - 1
    back = nodes_to_candidates(nodes, node)
    np.testing.assert_array_equal(back, cands)


def test_pst_memory_matches_paper_figure():
    # Paper Fig. 6(b): 60-node graph, s=4 -> ~7.99 MB PST.
    S = n_parent_sets(59, 4)
    mb = S * 4 * 4 / 2**20  # S rows x 4 int32
    assert 7.0 < mb < 9.0


@pytest.mark.parametrize("nc,s", [(7, 3), (11, 2), (10, 4)])
def test_unrank_parent_set_inverts_pst_rows(nc, s):
    """unrank_parent_set decodes EVERY global rank back to its build_pst row
    — the no-PST adjacency path (ISSUE 3 satellite) cannot drift from the
    materialized table."""
    pst, sizes = build_pst(nc, s)
    for t in range(pst.shape[0]):
        cands = unrank_parent_set(nc, s, t)
        row = pst[t][pst[t] >= 0]
        np.testing.assert_array_equal(np.sort(cands), np.sort(row))
        assert len(cands) == sizes[t]
    with pytest.raises(ValueError):
        unrank_parent_set(nc, s, pst.shape[0])
    with pytest.raises(ValueError):
        unrank_parent_set(nc, s, -1)


def test_adjacency_from_ranks_matches_pst_lookup():
    """adjacency_from_ranks == adjacency_from_best on random winning ranks."""
    from repro.core.graph import adjacency_from_best, adjacency_from_ranks

    n, s = 9, 3
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(5)
    for _ in range(20):
        best_idx = rng.integers(0, pst.shape[0], size=n)
        want = adjacency_from_best(best_idx, pst)
        got = adjacency_from_ranks(best_idx, s=s)
        np.testing.assert_array_equal(got, want)
