"""System/integration tests: the full learning pipeline end to end —
recovery accuracy, priors, checkpoint/restart determinism, multi-chain
exchange, noise tolerance direction."""
import numpy as np
import pytest

from repro.core import random_cpts, random_dag, roc_point
from repro.core.priors import make_prior_matrix
from repro.data.bn_sampler import ancestral_sample, inject_noise
from repro.launch.bn_learn import LearnConfig, learn_structure


@pytest.fixture(scope="module")
def small_problem():
    rng = np.random.default_rng(0)
    n, q, m = 8, 2, 3000
    truth = random_dag(rng, n, max_parents=2)
    data = ancestral_sample(rng, truth, random_cpts(rng, truth, q), m, q)
    return truth, data, q


def _skeleton_tp(learned, truth):
    sk_l = (learned | learned.T).astype(bool)
    sk_t = (truth | truth.T).astype(bool)
    return (sk_l & sk_t).sum() / max(sk_t.sum(), 1)


def test_learns_structure_above_chance(small_problem):
    """Observational data identifies structure only up to Markov equivalence,
    so assert (a) the learned score beats the TRUE graph's score (the MCMC
    maximizes the right objective) and (b) skeleton recovery is high."""
    truth, data, q = small_problem
    out = learn_structure(data, LearnConfig(q=q, s=2, iters=1500, seed=0))

    from repro.core.combinatorics import nodes_to_candidates, rank_parent_set
    from repro.core.scores import build_score_table
    st = build_score_table(data, q=q, s=2)
    n = truth.shape[0]
    true_score = sum(
        float(st.table[i, rank_parent_set(
            n - 1, 2, nodes_to_candidates(np.nonzero(truth[:, i])[0], i))])
        for i in range(n))
    assert out["score"] >= true_score - 1e-3, \
        f"learned {out['score']} < true graph {true_score}"
    assert _skeleton_tp(out["adjacency"], truth) > 0.5
    fp, tp = roc_point(out["adjacency"], truth)
    assert fp < 0.2, f"FP {fp}"


def test_more_iterations_never_worse_score(small_problem):
    truth, data, q = small_problem
    s1 = learn_structure(data, LearnConfig(q=q, s=2, iters=100, seed=0))
    s2 = learn_structure(data, LearnConfig(q=q, s=2, iters=2000, seed=0))
    assert s2["score"] >= s1["score"] - 1e-4  # best-so-far is monotone


def test_chains_improve_best(small_problem):
    truth, data, q = small_problem
    one = learn_structure(data, LearnConfig(q=q, s=2, iters=300, seed=3))
    four = learn_structure(data, LearnConfig(q=q, s=2, iters=300, seed=3,
                                             chains=4))
    assert four["score"] >= one["score"] - 1e-4


def test_priors_steer_edges(small_problem):
    """A strong positive prior on an edge pulls it in; a strong negative
    prior on a true edge pushes it out (Eq. 9/10)."""
    truth, data, q = small_problem
    n = truth.shape[0]
    cfg = LearnConfig(q=q, s=2, iters=1500, seed=0)
    base = learn_structure(data, cfg)["adjacency"]

    edges = list(zip(*np.nonzero(truth)))
    target = edges[0]                      # (m, i): m -> i
    R_neg = make_prior_matrix(n, forbidden_edges=[target], confidence=0.999)
    out_neg = learn_structure(data, cfg, prior_matrix=R_neg)["adjacency"]
    assert out_neg[target[0], target[1]] == 0, "forbidden edge survived"

    if base[target[0], target[1]] == 1:
        R_pos = make_prior_matrix(n, known_edges=[target], confidence=0.999)
        out_pos = learn_structure(data, cfg, prior_matrix=R_pos)["adjacency"]
        assert out_pos[target[0], target[1]] == 1


def test_checkpoint_restart_resumes(tmp_path, small_problem):
    truth, data, q = small_problem
    cfg = LearnConfig(q=q, s=2, iters=400, seed=0, chains=2,
                      checkpoint_every=100, checkpoint_dir=str(tmp_path))
    full = learn_structure(data, cfg)
    # second invocation restores the final snapshot: no extra sampling, and
    # the recovered best graph/score agree with the uninterrupted run
    resumed = learn_structure(data, cfg)
    assert resumed["score"] == pytest.approx(full["score"], abs=1e-4)
    np.testing.assert_array_equal(resumed["adjacency"], full["adjacency"])


def test_noise_degrades_gracefully(small_problem):
    truth, data, q = small_problem
    cfg = LearnConfig(q=q, s=2, iters=800, seed=0)
    rng = np.random.default_rng(1)
    tp_clean = roc_point(learn_structure(data, cfg)["adjacency"], truth)[1]
    noisy = inject_noise(rng, data, 0.3, q)
    tp_noisy = roc_point(learn_structure(noisy, cfg)["adjacency"], truth)[1]
    assert tp_noisy <= tp_clean + 0.15, "noise should not help"


def test_deterministic_given_seed(small_problem):
    truth, data, q = small_problem
    cfg = LearnConfig(q=q, s=2, iters=200, seed=42)
    a = learn_structure(data, cfg)
    b = learn_structure(data, cfg)
    assert a["score"] == b["score"]
    np.testing.assert_array_equal(a["adjacency"], b["adjacency"])
