"""MCMC sampler behaviour (paper §III, Algorithm 1)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (adjacency_from_best, build_score_table, exchange_best,
                        init_chain, mcmc_run, mcmc_run_chains, random_cpts,
                        random_dag, roc_point, score_order_ref,
                        topological_order)
from repro.core.mcmc import _propose_swap
from repro.data import ancestral_sample


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(0)
    n, q, s, m = 7, 2, 3, 1500
    adj = random_dag(rng, n, s, 0.45)
    cpts = random_cpts(rng, adj, q, 0.3)
    data = ancestral_sample(rng, adj, cpts, m, q)
    st = build_score_table(data, q=q, s=s)
    sf = lambda pos: score_order_ref(st.table, st.pst, pos)
    return st, adj, sf


def test_propose_swap_is_a_transposition(problem):
    st, _, _ = problem
    pos = jnp.arange(st.n, dtype=jnp.int32)
    for i in range(20):
        new = _propose_swap(jax.random.key(i), pos)
        diff = np.nonzero(np.asarray(new) != np.asarray(pos))[0]
        assert len(diff) == 2  # exactly two nodes moved
        a, b = diff
        assert int(new[a]) == int(pos[b]) and int(new[b]) == int(pos[a])
        assert sorted(np.asarray(new).tolist()) == list(range(st.n))


def test_best_score_monotone_and_consistent(problem):
    st, _, sf = problem
    state, trace = mcmc_run(jax.random.key(0), st.n, sf, 300, trace=True)
    # best >= every visited score
    assert float(state.best_score) >= float(np.max(np.asarray(trace))) - 1e-4
    # recorded best order reproduces the recorded best score/graph
    sc, idx, _ = score_order_ref(st.table, st.pst, state.best_pos)
    np.testing.assert_allclose(float(sc), float(state.best_score), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(state.best_idx))
    assert 0 < int(state.accepts) <= 300


def test_chain_improves_over_init(problem):
    st, _, sf = problem
    st0 = init_chain(jax.random.key(42), st.n, sf)
    state, _ = mcmc_run(jax.random.key(42), st.n, sf, 1000)
    assert float(state.best_score) >= float(st0.score)


def test_learned_graph_is_dag_and_reasonable(problem):
    st, adj, sf = problem
    state, _ = mcmc_run(jax.random.key(1), st.n, sf, 2000)
    learned = adjacency_from_best(np.asarray(state.best_idx), np.asarray(st.pst))
    topological_order(learned)  # acyclic
    # MCMC best score must be >= score of the true topological order
    order = topological_order(adj)
    pos = np.empty(st.n, np.int32)
    pos[order] = np.arange(st.n)
    true_sc, _, _ = score_order_ref(st.table, st.pst, jnp.asarray(pos))
    assert float(state.best_score) >= float(true_sc) - 1e-3
    # skeleton accuracy: undirected recovery should be decent at m=1500
    sk_l = learned | learned.T
    sk_t = (adj | adj.T).astype(np.int8)
    fp, tp = roc_point(sk_l, sk_t)
    assert tp >= 0.5


def test_multichain_exchange_dominates_single(problem):
    st, _, sf = problem
    states = mcmc_run_chains(jax.random.key(2), 4, st.n, sf, 300)
    bs, bi, bp = exchange_best(states)
    assert float(bs) == pytest.approx(float(np.max(np.asarray(states.best_score))))
    sc, idx, _ = score_order_ref(st.table, st.pst, bp)
    np.testing.assert_allclose(float(sc), float(bs), rtol=1e-6)


def test_determinism_same_key(problem):
    st, _, sf = problem
    a, _ = mcmc_run(jax.random.key(9), st.n, sf, 200)
    b, _ = mcmc_run(jax.random.key(9), st.n, sf, 200)
    assert float(a.best_score) == float(b.best_score)
    np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))


# ------------------------------------------------- invariants (ISSUE 1)
def test_chain_score_monotone_consistent_with_best(problem):
    """best_score dominates every visited score AND the init score, on both
    the legacy and the bounded-window move sets; accepts ≤ iters."""
    st, _, sf = problem
    for window in (0, 3):
        state, trace = mcmc_run(jax.random.key(4), st.n, sf, 250, trace=True,
                                window=window)
        assert float(state.best_score) >= float(np.max(np.asarray(trace))) - 1e-4
        assert float(state.best_score) >= float(state.score) - 1e-4
        assert 0 <= int(state.accepts) <= 250


def test_detailed_balance_smoke_flat_table(problem):
    """Symmetric proposals ⇒ acceptance is the pure score ratio: on a
    CONSTANT table the ratio is always 1, so every proposal must be accepted
    (log u < 0 strictly, since u < 1). Holds for every move in the mixture."""
    st, _, _ = problem
    sf = lambda pos: (jnp.float32(0.0), jnp.zeros(st.n, jnp.int32),
                      jnp.zeros(st.n, jnp.float32))
    for window in (0, 4):
        state, _ = mcmc_run(jax.random.key(5), st.n, sf, 200, window=window)
        assert int(state.accepts) == 200


def test_current_state_cache_matches_rescore(problem):
    """(score, cur_idx, cur_ls) carried in ChainState always describe the
    CURRENT order — the invariant the delta path relies on."""
    st, _, sf = problem
    state, _ = mcmc_run(jax.random.key(6), st.n, sf, 150, window=3)
    sc, idx, ls = score_order_ref(st.table, st.pst, state.pos)
    np.testing.assert_allclose(float(sc), float(state.score), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(state.cur_idx))
    np.testing.assert_allclose(np.asarray(ls), np.asarray(state.cur_ls),
                               rtol=1e-6)


def test_exchange_best_returns_argmax_triple(problem):
    """exchange_best hands back the winning chain's OWN (score, idx, pos)."""
    st, _, sf = problem
    states = mcmc_run_chains(jax.random.key(7), 4, st.n, sf, 150)
    bs, bi, bp = exchange_best(states)
    w = int(np.argmax(np.asarray(states.best_score)))
    assert float(bs) == float(states.best_score[w])
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(states.best_idx[w]))
    np.testing.assert_array_equal(np.asarray(bp), np.asarray(states.best_pos[w]))
