"""Blockwise (flash-algorithm) attention path == naive path, end to end."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.models.attention import blockwise_attention


def test_blockwise_matches_naive_unit():
    rng = np.random.default_rng(0)
    B, T, Hkv, G, hd = 2, 2048, 2, 3, 16
    q = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, G, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, hd)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))

    scale = hd ** -0.5
    sc = jnp.einsum("btkgd,bskd->bkgts", q, k) * scale
    m = jnp.arange(T)[:, None] >= jnp.arange(T)[None, :]
    sc = jnp.where(m[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    want = jnp.einsum("bkgts,bskd->btkgd", p, v)

    got = blockwise_attention(q, k, v, pos, causal=True, block_q=256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)

    # windowed variant
    sc2 = jnp.einsum("btkgd,bskd->bkgts", q, k) * scale
    m2 = m & (jnp.arange(T)[:, None] - jnp.arange(T)[None, :] < 128)
    sc2 = jnp.where(m2[None, None, None], sc2, -1e30)
    want2 = jnp.einsum("bkgts,bskd->btkgd", jax.nn.softmax(sc2, -1), v)
    got2 = blockwise_attention(q, k, v, pos, causal=True, window=128,
                               block_q=256)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want2),
                               rtol=2e-4, atol=2e-4)


def test_flash_model_matches_naive_forward():
    cfg = dataclasses.replace(get_config("yi-34b").reduced(), remat=False)
    m0 = Model(cfg, tp=1)
    m1 = Model(cfg, tp=1, use_flash=True)
    params = m0.init(jax.random.key(0))
    tok = jax.random.randint(jax.random.key(1), (2, 1024), 0, cfg.vocab)
    a, _ = jax.jit(m0.forward)(params, tok)
    b, _ = jax.jit(m1.forward)(params, tok)
    np.testing.assert_allclose(np.asarray(a[..., :cfg.vocab], np.float32),
                               np.asarray(b[..., :cfg.vocab], np.float32),
                               rtol=3e-3, atol=3e-3)
    # prefill+decode continuation also agrees
    ca = m0.init_cache(2, 1026, dtype=jnp.float32)
    cb = m1.init_cache(2, 1026, dtype=jnp.float32)
    la, ca = jax.jit(m0.prefill)(params, tok, ca)
    lb, cb = jax.jit(m1.prefill)(params, tok, cb)
    np.testing.assert_allclose(
        np.asarray(la[:, -1, :cfg.vocab], np.float32),
        np.asarray(lb[:, -1, :cfg.vocab], np.float32), rtol=3e-3, atol=3e-3)
