"""Multi-device order scoring == single-device oracle (subprocess with 8
placeholder devices so the suite itself keeps seeing 1 CPU device)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.core.combinatorics import build_pst, n_parent_sets
    from repro.core.order_scoring import score_order_ref
    from repro.core.sharded_scoring import make_sharded_score_fn, pad_table
    from repro.core.mcmc import mcmc_run

    n, s = 14, 3
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    pst = jnp.asarray(pst)

    from repro.runtime.jax_compat import make_auto_mesh, mesh_context
    mesh = make_auto_mesh((2, 4), ("data", "model"))
    fn = make_sharded_score_fn(table, pst, mesh, block=64)

    for seed in range(5):
        pos = jnp.asarray(np.random.default_rng(seed).permutation(n)
                          .astype(np.int32))
        with mesh_context(mesh):
            sc, idx, ls = jax.jit(fn)(pos)
        sc_ref, idx_ref, ls_ref = score_order_ref(table, pst, pos)
        np.testing.assert_allclose(float(sc), float(sc_ref), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))

    # the full MCMC sampler runs on the sharded scorer unchanged
    with mesh_context(mesh):
        state, _ = mcmc_run(jax.random.key(0), n, fn, 50)
    assert np.isfinite(float(state.best_score))

    # delta path: sharded incremental rescore == sharded full rescore,
    # and the delta-path chain is step-for-step identical to the full one
    from repro.core.mcmc import propose_move
    from repro.core.sharded_scoring import make_sharded_delta_fn
    dfn = make_sharded_delta_fn(table, pst, mesh, window=4, block=64)
    for seed in range(5):
        pos = jnp.asarray(np.random.default_rng(100 + seed).permutation(n)
                          .astype(np.int32))
        with mesh_context(mesh):
            sc0, idx0, ls0 = jax.jit(fn)(pos)
        new_pos, lo = propose_move(jax.random.key(seed), pos, window=4)
        with mesh_context(mesh):
            got = jax.jit(dfn)(new_pos, lo, ls0, idx0)
            want = jax.jit(fn)(new_pos)
        np.testing.assert_allclose(float(got[0]), float(want[0]), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))

    with mesh_context(mesh):
        a, _ = mcmc_run(jax.random.key(1), n, fn, 40, window=4)
        b, _ = mcmc_run(jax.random.key(1), n, fn, 40, delta_fn=dfn, window=4)
    assert float(a.score) == float(b.score)
    np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))
    assert int(a.accepts) == int(b.accepts)

    # sharded_chain_step's fused delta path == per-chain full-rescore steps
    from repro.core.mcmc import init_chain, mcmc_step
    from repro.core.sharded_scoring import sharded_chain_step
    tpad, ppad = pad_table(table, pst, 4 * 64)
    keys = jax.random.split(jax.random.key(2), 8)
    with mesh_context(mesh):
        states = jax.vmap(lambda k: init_chain(k, n, fn))(keys)
        sd = sl = states
        for _ in range(3):
            sd = sharded_chain_step(sd, tpad, ppad, mesh, block=64, window=4)
            sl = jax.vmap(lambda s: mcmc_step(s, fn, None, 4))(sl)
    np.testing.assert_array_equal(np.asarray(sd.pos), np.asarray(sl.pos))
    np.testing.assert_allclose(np.asarray(sd.score), np.asarray(sl.score),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(sd.accepts),
                                  np.asarray(sl.accepts))
    print("OK")
""")


def test_sharded_scoring_matches_oracle():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
