"""Multi-device order scoring == single-device oracle (subprocess with 8
placeholder devices so the suite itself keeps seeing 1 CPU device)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.core.combinatorics import build_pst, n_parent_sets
    from repro.core.order_scoring import score_order_ref
    from repro.core.sharded_scoring import make_sharded_score_fn, pad_table
    from repro.core.mcmc import mcmc_run

    n, s = 14, 3
    S = n_parent_sets(n - 1, s)
    pst, _ = build_pst(n - 1, s)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(-40, 8, (n, S)).astype(np.float32))
    pst = jnp.asarray(pst)

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    fn = make_sharded_score_fn(table, pst, mesh, block=64)

    for seed in range(5):
        pos = jnp.asarray(np.random.default_rng(seed).permutation(n)
                          .astype(np.int32))
        with jax.set_mesh(mesh):
            sc, idx, ls = jax.jit(fn)(pos)
        sc_ref, idx_ref, ls_ref = score_order_ref(table, pst, pos)
        np.testing.assert_allclose(float(sc), float(sc_ref), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))

    # the full MCMC sampler runs on the sharded scorer unchanged
    with jax.set_mesh(mesh):
        state, _ = mcmc_run(jax.random.key(0), n, fn, 50)
    assert np.isfinite(float(state.best_score))
    print("OK")
""")


def test_sharded_scoring_matches_oracle():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
