"""Property-check shim: real `hypothesis` when installed, otherwise a
seeded-random fallback, so the tier-1 suite collects and runs on a bare
interpreter.

Fallback semantics: ``@given(...)`` reruns the test body `max_examples` times
(``settings`` records it; default 20) with values drawn from a deterministic
per-test PRNG; ``hst.integers/floats/data`` cover the strategies the suite
uses. Shrinking and statistics are hypothesis luxuries the fallback skips —
on failure the example index and seed are printed so a case is reproducible.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import random
    import zlib
    from types import SimpleNamespace

    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return rng.randint(self.lo, self.hi)

    class _Floats:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            r = rng.random()
            if r < 0.05:            # endpoints are the usual bug nests
                return self.lo
            if r > 0.95:
                return self.hi
            return self.lo + (self.hi - self.lo) * rng.random()

    class _DataProxy:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example(self._rng)

    class _Data:
        def example(self, rng):
            return _DataProxy(rng)

    hst = SimpleNamespace(
        integers=lambda lo, hi: _Integers(lo, hi),
        floats=lambda lo, hi: _Floats(lo, hi),
        data=lambda: _Data(),
    )

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._pc_max_examples = max_examples
            return fn
        return deco

    def given(*strats, **kwstrats):
        def deco(fn):
            # zero-arg wrapper: pytest must not mistake drawn args for
            # fixtures (all @given tests here take drawn values only)
            def wrapper():
                n_ex = getattr(wrapper, "_pc_max_examples",
                               getattr(fn, "_pc_max_examples", 20))
                base = zlib.crc32(fn.__qualname__.encode())
                for ex in range(n_ex):
                    rng = random.Random(base + ex)
                    try:
                        fn(*[s.example(rng) for s in strats],
                           **{k: s.example(rng) for k, s in kwstrats.items()})
                    except BaseException:
                        print(f"[_propcheck] falsified on example {ex} "
                              f"(rng seed {base + ex})")
                        raise

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._pc_max_examples = getattr(fn, "_pc_max_examples", 20)
            return wrapper
        return deco
