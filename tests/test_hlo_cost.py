"""Validate the trip-count-aware HLO cost analyzer (launch/hlo_cost.py).

The critical property: a scanned loop must cost the same as its unrolled
equivalent (XLA's own cost_analysis fails this — it counts while bodies once).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo

L, M, K = 10, 64, 64


def _cost(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(c.as_text(), n_devices=1)


@pytest.fixture(scope="module")
def wx():
    w = jnp.zeros((L, M, K), jnp.float32)
    x = jnp.zeros((8, M), jnp.float32)
    return w, x


def test_scan_matches_unrolled_flops(wx):
    w, x = wx

    def scanned(w, x):
        x, _ = jax.lax.scan(lambda x, wl: (jnp.tanh(x @ wl), None), x, w)
        return x

    def unrolled(w, x):
        for i in range(L):
            x = jnp.tanh(x @ w[i])
        return x

    cs, cu = _cost(scanned, w, x), _cost(unrolled, w, x)
    assert cs.loops and cs.loops[0][1] == L
    assert not cs.unknown_loops
    # dominant dot flops must agree within the elementwise noise (~1%)
    assert cs.flops == pytest.approx(cu.flops, rel=0.05)


def test_dot_flops_analytic():
    a = jnp.zeros((32, 128), jnp.float32)
    b = jnp.zeros((128, 16), jnp.float32)
    c = _cost(lambda a, b: a @ b, a, b)
    assert c.flops == pytest.approx(2 * 32 * 128 * 16, rel=0.01)


def test_batched_dot_flops():
    a = jnp.zeros((4, 32, 128), jnp.float32)
    b = jnp.zeros((4, 128, 16), jnp.float32)
    c = _cost(lambda a, b: jnp.einsum("bmk,bkn->bmn", a, b), a, b)
    assert c.flops == pytest.approx(2 * 4 * 32 * 128 * 16, rel=0.01)


def test_nested_scan_multiplies():
    def fn(w, x):
        def outer(x, wl):
            def inner(x, _):
                return jnp.tanh(x @ wl), None
            x, _ = jax.lax.scan(inner, x, None, length=7)
            return x, None
        x, _ = jax.lax.scan(outer, x, w)
        return x

    w = jnp.zeros((5, M, M), jnp.float32)
    x = jnp.zeros((8, M), jnp.float32)
    c = _cost(fn, w, x)
    assert c.flops == pytest.approx(5 * 7 * 2 * 8 * M * M, rel=0.05)


def test_scan_with_nested_tuple_carry():
    """KV-cache-like carries give the while op a nested-tuple type; the
    parser must still find the loop (regression: silently skipped)."""
    def fn(w, x):
        def body(carry, wl):
            x, (a, b) = carry
            x = jnp.tanh(x @ wl)
            return (x, (a + 1, b * 2.0)), None
        carry, _ = jax.lax.scan(body, (x, (jnp.int32(0), jnp.float32(1))), w)
        return carry[0]

    w = jnp.zeros((L, M, M), jnp.float32)
    x = jnp.zeros((8, M), jnp.float32)
    c = _cost(fn, w, x)
    assert c.loops and c.loops[0][1] == L
    assert c.flops == pytest.approx(L * 2 * 8 * M * M, rel=0.05)


def test_bytes_nonzero_and_scale_with_trip(wx):
    w, x = wx

    def scanned(w, x):
        x, _ = jax.lax.scan(lambda x, wl: (jnp.tanh(x @ wl), None), x, w)
        return x

    c = _cost(scanned, w, x)
    # each iteration reads at least one (M, K) weight slice
    assert c.bytes >= L * M * K * 4
