"""Posterior-service invariants: admission/dedup, multi-job scheduling
determinism, slot reclamation, elastic expansion, and the response schema.

The load-bearing property is bitwise parity: a job advanced segment-by-
segment inside a multi-job FleetScheduler pack must produce artifacts
identical to a standalone ``learn_structure`` run of the same
(data, config, seed) — interleaving may only change WHEN segments run.
"""
import json
import os

import numpy as np
import pytest

from repro.launch.bn_learn import learn_structure
from repro.service import (DatasetSpec, FleetScheduler, JobManager,
                           admission_key, error_response, job_response,
                           load_dataset, materialize, service_config,
                           validate_response)
from repro.service.scheduler import expand_fleet


def _cfg(**kw):
    base = dict(iters=240, chains=3, seed=5, check_every=80, trace_every=10,
                window=6, stop_on_converge=False)
    base.update(kw)
    return service_config(base)


@pytest.fixture(scope="module")
def dataset():
    cfg = _cfg()
    return load_dataset(DatasetSpec(network="synth", n=7, m=120, seed=2),
                        cfg.q)


# --------------------------------------------------------------- admission
def test_service_config_invariants():
    cfg = _cfg()
    assert cfg.telemetry and cfg.emit_consensus
    with pytest.raises(ValueError, match="unknown config field"):
        service_config({"not_a_field": 1})


def test_admission_key_separates_run_config(dataset):
    a = admission_key(dataset, _cfg())
    assert a == admission_key(dataset, _cfg())
    assert a != admission_key(dataset, _cfg(seed=6))
    assert a != admission_key(dataset, _cfg(iters=241))
    assert a != admission_key(dataset[:100], _cfg())
    # presentation-only fields must NOT split dedup
    assert a == admission_key(dataset, _cfg(run_name="other",
                                            trace_dir="/elsewhere"))


def test_dedup_attaches_to_same_job(dataset, tmp_path):
    man = JobManager(run_dir=str(tmp_path))
    j1, d1 = man.submit(dataset, _cfg())
    j2, d2 = man.submit(dataset, _cfg())
    j3, d3 = man.submit(dataset, _cfg(seed=9))
    assert (d1, d2, d3) == (False, True, False)
    assert j1 is j2 and j1.attached == 2
    assert j3.id != j1.id


def test_oversized_job_fails_admission(dataset, tmp_path):
    sched = FleetScheduler(JobManager(run_dir=str(tmp_path)), slots=2)
    job, deduped = sched.submit(dataset, _cfg(chains=3))
    assert not deduped and job.state == "failed"
    assert "chain slots" in job.error
    assert not sched.pending and not sched.active


# ------------------------------------------------------------- determinism
def test_concurrent_jobs_bitwise_equal_standalone(dataset, tmp_path):
    """Two jobs interleaved through the scheduler == each run alone."""
    cfgs = [_cfg(seed=5), _cfg(seed=9, iters=160)]
    sched = FleetScheduler(JobManager(run_dir=str(tmp_path)), slots=6)
    handles = [sched.submit(dataset, c)[0] for c in cfgs]
    sched.run()
    for job, cfg in zip(handles, cfgs):
        assert job.state == "done", job.error
        ref = learn_structure(dataset, cfg)
        np.testing.assert_array_equal(np.asarray(job.result["edge_posterior"]),
                                      np.asarray(ref["edge_posterior"]))
        np.testing.assert_array_equal(np.asarray(job.result["map_dag"]),
                                      np.asarray(ref["map_dag"]))
        np.testing.assert_array_equal(np.asarray(job.result["consensus"]),
                                      np.asarray(ref["consensus"]))
        assert float(job.result["score"]) == float(ref["score"])


# ------------------------------------------------------------- scheduling
def test_finished_job_slots_reclaimed(dataset, tmp_path):
    """A short job retires early; its slots admit the queued third job."""
    sched = FleetScheduler(JobManager(run_dir=str(tmp_path)), slots=6)
    short, _ = sched.submit(dataset, _cfg(seed=5, iters=160))
    long_, _ = sched.submit(dataset, _cfg(seed=9, iters=400))
    queued, _ = sched.submit(dataset, _cfg(seed=13, iters=80))
    sched.step()
    assert queued.state == "queued" and sched.slots_used == 6
    admitted = False
    for _ in range(100):
        alive = sched.step()
        if not admitted and queued.state != "queued":
            # a single-segment job can start AND finish inside one tick, so
            # observe the admission via the state leaving "queued"
            admitted = True
            assert short.state == "done", \
                "queued job admitted before any slots were freed"
        if not alive:
            break
    assert admitted, "queued job never admitted into reclaimed slots"
    assert {short.state, long_.state, queued.state} == {"done"}


def test_converged_job_stops_early(dataset, tmp_path):
    sched = FleetScheduler(JobManager(run_dir=str(tmp_path)), slots=4)
    job, _ = sched.submit(dataset, _cfg(
        iters=4000, chains=4, check_every=100, stop_on_converge=True,
        patience=1, rhat_threshold=1.5))
    sched.run()
    assert job.state == "done"
    assert job.result["iters_run"] < 4000, "never converged in 4000 iters"
    assert sched.slots_used == 0 and not sched.active


def test_elastic_expansion_completes(dataset, tmp_path):
    sched = FleetScheduler(JobManager(run_dir=str(tmp_path)), slots=4,
                           elastic=True)
    short, _ = sched.submit(dataset, _cfg(seed=5, iters=80, chains=2))
    grown, _ = sched.submit(dataset, _cfg(seed=9, iters=400, chains=2))
    sched.run()
    assert short.state == "done" and grown.state == "done"
    assert grown.extra_chains > 0, "idle slots were never cloned into"
    C = grown.cfg.chains + grown.extra_chains
    tele = grown.result["telemetry"]
    assert len(tele["reseeds"]) == C
    assert np.asarray(grown.result["edge_posterior"]).shape == (7, 7)


def test_expand_fleet_noop_when_not_running(dataset, tmp_path):
    job, _ = JobManager(run_dir=str(tmp_path)).submit(dataset, _cfg())
    assert expand_fleet(job, 2) == 0 and job.extra_chains == 0


# ------------------------------------------------------------------ query
def test_responses_validate_and_persist(dataset, tmp_path):
    man = JobManager(run_dir=str(tmp_path))
    sched = FleetScheduler(man, slots=4)
    job, _ = sched.submit(dataset, _cfg())
    validate_response(job_response(job))          # queued is a valid state
    with pytest.raises(LookupError):
        materialize(job)                          # artifacts gated on done
    sched.run()
    arts = materialize(job)
    for resp in arts.values():
        validate_response(resp)
        assert resp["job_id"] == job.id
    n = job.data.shape[1]
    assert np.asarray(arts["posterior"]["edge_probs"]).shape == (n, n)
    persisted = os.path.join(str(tmp_path), "jobs", job.id, "result.json")
    with open(persisted) as f:
        doc = json.load(f)
    assert doc["posterior"]["edge_probs"] == arts["posterior"]["edge_probs"]
    with pytest.raises(ValueError, match="missing required field"):
        validate_response({"schema": "bn-service/v1", "kind": "job"})
    validate_response(error_response("nope"))
