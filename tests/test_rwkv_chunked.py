"""RWKV6 chunked parallel recurrence == per-token scan (exact log-space
decays), across chunk sizes and with a warm incoming state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.rwkv6 import _wkv_chunked, _wkv_scan

B, T, H, N = 2, 32, 3, 8


def _inputs(seed, warm_state=False):
    rng = np.random.default_rng(seed)
    f = lambda *s: jnp.asarray(rng.normal(0, 1, s).astype(np.float32))
    r, k, v = f(B, T, H, N), f(B, T, H, N), f(B, T, H, N)
    # decay parameterization: w in (0, 1), well away from underflow
    w = jnp.exp(-jnp.exp(f(B, T, H, N) * 0.5))
    u = f(H, N)
    s0 = (f(B, H, N, N) * 0.3 if warm_state
          else jnp.zeros((B, H, N, N), jnp.float32))
    return r, k, v, w, u, s0


@pytest.mark.parametrize("chunk", [1, 4, 8, 16, 32])
@pytest.mark.parametrize("warm", [False, True])
def test_chunked_matches_scan(chunk, warm):
    r, k, v, w, u, s0 = _inputs(0, warm)
    y_ref, s_ref = _wkv_scan(r, k, v, w, u, s0)
    y_chk, s_chk = _wkv_chunked(r, k, v, w, u, s0, chunk)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_chk),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_chk),
                               rtol=2e-4, atol=2e-4)


def test_time_mix_chunk_flag_equivalent():
    """rwkv_time_mix(chunk=0) == rwkv_time_mix(chunk=8) end to end."""
    from repro.configs import get_config
    from repro.models import Model

    cfg = get_config("rwkv6-7b").reduced()
    m0 = Model(cfg, tp=1, rwkv_chunk=0)
    m8 = Model(cfg, tp=1, rwkv_chunk=8)
    params = m0.init(jax.random.key(0))
    tok = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    a, _ = jax.jit(m0.forward)(params, tok)
    b, _ = jax.jit(m8.forward)(params, tok)
    np.testing.assert_allclose(np.asarray(a[..., :cfg.vocab]),
                               np.asarray(b[..., :cfg.vocab]),
                               rtol=2e-3, atol=2e-3)
