"""Pairwise prior (paper §IV): PPF requirements and effect on learning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, hst, settings

from repro.core import (adjacency_from_best, build_score_table,
                        make_prior_matrix, mcmc_run, ppf, prior_table,
                        roc_point, score_order_ref)
from repro.core.priors import LN10, ppf_ln, prior_chunk
from repro.data import ancestral_sample
from repro.core.graph import random_cpts, random_dag


def test_ppf_paper_requirements():
    # PPF(i,m) = 0 iff R = 0.5; sign follows R - 0.5; ±10 at the extremes
    assert float(ppf(jnp.float32(0.5))) == 0.0
    assert float(ppf(jnp.float32(1.0))) == pytest.approx(12.5)   # 100*(0.5)^3
    assert float(ppf(jnp.float32(0.9))) == pytest.approx(6.4)
    assert float(ppf(jnp.float32(0.0))) == pytest.approx(-12.5)
    assert abs(float(ppf(jnp.float32(0.97)))) == pytest.approx(10.38, abs=0.05)


@given(hst.floats(0.0, 1.0))
@settings(max_examples=100, deadline=None)
def test_ppf_monotone_and_sign(r):
    v = float(ppf(jnp.float32(r)))
    if r > 0.5:
        assert v > 0
    elif r < 0.5:
        assert v < 0
    # natural-log version is exactly ln(10) times the log10 version
    assert float(ppf_ln(jnp.float32(r))) == pytest.approx(v * LN10, rel=1e-5)


def test_prior_chunk_sums_over_members():
    n = 5
    R = np.full((n, n), 0.5, np.float32)
    R[0, 1] = 0.9   # edge 1 -> 0 favored
    R[0, 3] = 0.2   # edge 3 -> 0 disfavored
    # candidate indices for node 0: cand c -> node c+1
    pst = jnp.asarray([[0, 2, -1], [1, -1, -1], [-1, -1, -1]], jnp.int32)
    out = np.asarray(prior_chunk(jnp.asarray(R), 0, pst))
    want0 = float(ppf_ln(jnp.float32(0.9)) + ppf_ln(jnp.float32(0.2)))
    np.testing.assert_allclose(out[0], want0, rtol=1e-5)
    np.testing.assert_allclose(out[1], 0.0, atol=1e-6)  # R[0,2]=0.5
    np.testing.assert_allclose(out[2], 0.0, atol=1e-6)  # empty set


def test_prior_shifts_argmax_toward_known_edge():
    """A strong prior on a missing edge makes the scorer pick parent sets
    containing it (paper Figs. 9-10 mechanism)."""
    rng = np.random.default_rng(0)
    n, q, s, m = 6, 2, 2, 60  # few samples => weak likelihood, priors can win
    adj = random_dag(rng, n, s, 0.5)
    cpts = random_cpts(rng, adj, q)
    data = ancestral_sample(rng, adj, cpts, m, q)

    st_plain = build_score_table(data, q=q, s=s)
    # favor every true edge strongly
    edges = [(int(a), int(b)) for a, b in zip(*np.nonzero(adj))]
    R = make_prior_matrix(n, known_edges=edges, confidence=0.99)
    st_prior = build_score_table(data, q=q, s=s, prior_matrix=R)

    # prior table is exactly the difference (priors fold additively, Eq. 9)
    diff = np.asarray(st_prior.table - st_plain.table)
    want = np.asarray(prior_table(jnp.asarray(R), st_plain.pst, n))
    np.testing.assert_allclose(diff, want, atol=3e-3)

    from repro.core.graph import topological_order
    order = topological_order(adj)
    pos = np.empty(n, np.int32)
    pos[order] = np.arange(n)
    _, idx_plain, _ = score_order_ref(st_plain.table, st_plain.pst, jnp.asarray(pos))
    _, idx_prior, _ = score_order_ref(st_prior.table, st_prior.pst, jnp.asarray(pos))
    roc_plain = roc_point(adjacency_from_best(np.asarray(idx_plain), np.asarray(st_plain.pst)), adj)
    roc_prior = roc_point(adjacency_from_best(np.asarray(idx_prior), np.asarray(st_prior.pst)), adj)
    assert roc_prior[1] >= roc_plain[1]   # TP rate cannot drop
    assert roc_prior[1] > 0.9             # strong prior nearly pins the truth
