"""GQA/MQA attention with TP head padding, sliding windows, KV cache.

Head layout (DESIGN.md §5): query heads are organized as (Hkv, G) groups with
G padded to G_pad so that Hkv·G_pad is divisible by the model-axis size; a
static head mask zeroes the padded slots, making padding mathematically inert
(output AND gradients of padded slots vanish — the mask is applied to the
attention output before the out-projection). K/V heads are replicated over
`model` and the attention einsum runs grouped, so GQA needs no kv gather or
repeat.

Decode uses a sequence-sharded KV cache (seq on `model`): softmax partial
reductions over the sharded axis are inserted by the SPMD partitioner.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .layers import ParamDef, rope, constrain

__all__ = ["attn_defs", "attention", "AttnDims", "init_kv_cache", "KVCache"]

NEG = -1.0e30


class AttnDims(NamedTuple):
    hkv: int
    g: int        # real groups (Hq // Hkv)
    g_pad: int    # padded groups (Hkv*g_pad divisible by tp)
    hd: int

    @property
    def hq_pad(self) -> int:
        return self.hkv * self.g_pad


def attn_dims(cfg: ModelConfig, tp: int) -> AttnDims:
    hkv, hq, hd = cfg.n_kv_heads, cfg.n_heads, cfg.hd
    g = hq // hkv
    g_pad = g
    while (hkv * g_pad) % tp:
        g_pad += 1
    return AttnDims(hkv, g, g_pad, hd)


def attn_defs(cfg: ModelConfig, tp: int, dtype) -> dict:
    d = cfg.d_model
    dims = attn_dims(cfg, tp)
    return {
        "wq": ParamDef((d, dims.hq_pad * dims.hd), P("data", "model"), dtype),
        "wk": ParamDef((d, dims.hkv * dims.hd), P("data", None), dtype),
        "wv": ParamDef((d, dims.hkv * dims.hd), P("data", None), dtype),
        "wo": ParamDef((dims.hq_pad * dims.hd, d), P("model", "data"), dtype),
    }


class KVCache(NamedTuple):
    k: jnp.ndarray       # (B, S, Hkv, hd)
    v: jnp.ndarray
    index: jnp.ndarray   # scalar int32 — number of valid positions


def init_kv_cache(batch: int, seq: int, cfg: ModelConfig, dtype) -> KVCache:
    dims = attn_dims(cfg, 1)
    shape = (batch, seq, dims.hkv, dims.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def _head_mask(dims: AttnDims, dtype) -> jnp.ndarray:
    """(Hkv, G_pad) 1.0 for real query heads, 0.0 for padded slots."""
    return (jnp.arange(dims.g_pad) < dims.g).astype(dtype)[None, :].repeat(
        dims.hkv, axis=0)


def attention(params: dict, x: jnp.ndarray, *, cfg: ModelConfig,
              dims: AttnDims, positions: jnp.ndarray,
              cache: KVCache | None = None,
              kv_x: jnp.ndarray | None = None,
              static_kv: KVCache | None = None,
              causal: bool = True, window: int = 0,
              batch_axes=("data",),
              use_flash: bool = False) -> tuple[jnp.ndarray, KVCache | None]:
    """x: (B, T, d). kv_x: cross-attention source (B, Tk, d) (causal=False).
    cache: decode mode (T == 1 expected, appends then attends).
    static_kv: cross-attention K/V cache — at prefill (T > 1) K/V are
    computed from kv_x and STORED; at decode (T == 1) they are READ, so the
    encoder projections are never recomputed per step (§Roofline: seamless
    decode useful-ratio fix)."""
    B, T, d = x.shape
    hkv, gp, hd = dims.hkv, dims.g_pad, dims.hd
    # TP axis for heads; None under fsdp_only (batch occupies every axis)
    tp_ax = None if "model" in batch_axes else "model"

    q = jnp.einsum("btd,dh->bth", x, params["wq"])
    q = constrain(q, P(batch_axes, None, tp_ax))
    q = q.reshape(B, T, hkv, gp, hd)
    if static_kv is not None and T == 1:
        # decode with precomputed cross-K/V
        k = static_kv.k.astype(x.dtype)
        v = static_kv.v.astype(x.dtype)
    else:
        src = x if kv_x is None else kv_x
        k = jnp.einsum("btd,dh->bth", src, params["wk"]).reshape(
            B, -1, hkv, hd)
        v = jnp.einsum("btd,dh->bth", src, params["wv"]).reshape(
            B, -1, hkv, hd)
    if static_kv is not None and T > 1:
        static_kv = KVCache(k.astype(static_kv.k.dtype),
                            v.astype(static_kv.v.dtype),
                            jnp.asarray(k.shape[1], jnp.int32))

    if kv_x is None:  # self-attention: rotary embedding
        kv_pos = positions if cache is None else positions
        q = rope(q.reshape(B, T, hkv * gp, hd), positions,
                 cfg.rope_theta).reshape(B, T, hkv, gp, hd)
        k = rope(k, kv_pos, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # decode: write this step's k/v at cache.index, attend over the cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), cache.index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), cache.index, axis=1)
        spec = P(batch_axes, tp_ax, None, None)
        k_cache = constrain(k_cache, spec)
        v_cache = constrain(v_cache, spec)
        new_cache = KVCache(k_cache, v_cache, cache.index + T)
        k, v = k_cache.astype(x.dtype), v_cache.astype(x.dtype)

    if use_flash and T > 1 and T % 1024 == 0:
        # flash-algorithm path: query-block scan, no (T, S) materialization
        out = blockwise_attention(q, k, v, positions,
                                  causal=causal or cache is not None,
                                  window=window)
        out = out * _head_mask(dims, out.dtype)[None, None, :, :, None]
        out = out.astype(x.dtype).reshape(B, T, hkv * gp * hd)
        out = constrain(out, P(batch_axes, None, tp_ax))
        y = jnp.einsum("bth,hd->btd", out, params["wo"])
        if static_kv is not None:
            return y, static_kv
        return y, new_cache

    scale = hd ** -0.5
    scores = jnp.einsum("btkgd,bskd->bkgts", q, k).astype(jnp.float32) * scale

    S = k.shape[1]
    spos = jnp.arange(S) if cache is not None else positions
    qpos = positions
    if cache is not None:
        valid = spos[None, None, None, None, :] <= (cache.index + jnp.arange(T))[None, None, None, :, None]
        scores = jnp.where(valid, scores, NEG)
        if window:
            near = spos[None, None, None, None, :] > (cache.index + jnp.arange(T))[None, None, None, :, None] - window
            scores = jnp.where(near, scores, NEG)
    elif causal:
        m = qpos[..., :, None] >= spos[..., None, :]
        if window:
            m = m & (qpos[..., :, None] - spos[..., None, :] < window)
        scores = jnp.where(m[:, None, None, :, :] if m.ndim == 3 else m, scores, NEG)

    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    out = out * _head_mask(dims, out.dtype)[None, None, :, :, None]
    out = out.reshape(B, T, hkv * gp * hd)
    out = constrain(out, P(batch_axes, None, tp_ax))
    y = jnp.einsum("bth,hd->btd", out, params["wo"])
    if static_kv is not None:
        return y, static_kv
    return y, new_cache


def blockwise_attention(q, k, v, positions, *, causal=True, window=0,
                        block_q: int = 1024):
    """Flash-algorithm attention, jnp edition: lax.scan over QUERY blocks
    with online softmax — never materializes the full (T, S) score matrix
    (peak memory O(T·block) instead of O(T²)). Exact (tested vs the naive
    path). On TPU the Pallas kernel (kernels/flash_attention) is the fast
    path; this is the portable algorithm with the same memory shape.

    q: (B, T, Hkv, G, hd); k, v: (B, S, Hkv, hd); positions: (B, T).
    Returns (B, T, Hkv, G, hd) float32.
    """
    B, T, Hkv, G, hd = q.shape
    S = k.shape[1]
    nb = T // block_q
    scale = hd ** -0.5
    qf = q.astype(jnp.float32).reshape(B, nb, block_q, Hkv, G, hd)
    pf = positions.reshape(B, nb, block_q)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    spos = jnp.arange(S)

    def per_block(args):
        qb, pb = args                                  # (B,blk,Hkv,G,hd), (B,blk)
        sc = jnp.einsum("btkgd,bskd->bkgts", qb, kf) * scale
        m = pb[:, None, None, :, None] >= spos[None, None, None, None, :]             if causal else jnp.ones((), bool)
        if window:
            m = m & (pb[:, None, None, :, None]
                     - spos[None, None, None, None, :] < window)
        sc = jnp.where(m, sc, NEG)
        mx = sc.max(axis=-1, keepdims=True)
        p = jnp.exp(sc - mx)
        o = jnp.einsum("bkgts,bskd->btkgd", p, vf)
        return o / p.sum(axis=-1).transpose(0, 3, 1, 2)[..., None]

    out = jax.lax.map(per_block, (qf.transpose(1, 0, 2, 3, 4, 5),
                                  pf.transpose(1, 0, 2)))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, T, Hkv, G, hd)
