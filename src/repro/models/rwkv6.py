"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time mixing with
data-dependent per-channel decay, plus squared-ReLU channel mixing.

Time mixing (per head, head size N = cfg.rwkv_head_dim):
    S_t = diag(w_t) S_{t−1} + k_t v_tᵀ            state (N_k × N_v)
    y_t = (S_{t−1} + diag(u) k_t v_tᵀ)ᵀ r_t
with w_t = exp(−exp(w0 + LoRA(x̃_t))) data-dependent decay, and token-shift
interpolation x̃ = lerp(x_t, x_{t−1}, μ + LoRA) on every projection input.

Two evaluation paths, equal to each other (tested):
* `lax.scan` over time — the reference, O(T) sequential;
* chunked parallel form — intra-chunk matmuls (MXU) + inter-chunk scan,
  the performance path for train/prefill (§Perf hillclimb).

The recurrence state is O(1) in sequence length ⇒ long_500k decode works.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .layers import ParamDef, constrain

__all__ = ["rwkv_defs", "rwkv_time_mix", "rwkv_channel_mix", "RWKVState",
           "init_rwkv_state"]

LORA_R = 32


class RWKVState(NamedTuple):
    wkv: jnp.ndarray       # (B, H, N, N) recurrent state
    shift_t: jnp.ndarray   # (B, d) last token (time-mix input)
    shift_c: jnp.ndarray   # (B, d) last token (channel-mix input)


def rwkv_defs(cfg: ModelConfig, tp: int, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    r = LORA_R
    return {
        "time": {
            "mu": ParamDef((5, d), P(None, "model"), jnp.float32, "zeros"),
            "w_r": ParamDef((d, d), P("data", "model"), dtype),
            "w_k": ParamDef((d, d), P("data", "model"), dtype),
            "w_v": ParamDef((d, d), P("data", "model"), dtype),
            "w_g": ParamDef((d, d), P("data", "model"), dtype),
            "w_o": ParamDef((d, d), P("model", "data"), dtype),
            "decay0": ParamDef((d,), P("model"), jnp.float32, "zeros"),
            "decay_a": ParamDef((d, r), P("data", None), dtype),
            "decay_b": ParamDef((r, d), P(None, "model"), dtype),
            "bonus": ParamDef((d,), P("model"), jnp.float32, "zeros"),
        },
        "channel": {
            "mu": ParamDef((2, d), P(None, "model"), jnp.float32, "zeros"),
            "w_k": ParamDef((d, ff), P("data", "model"), dtype),
            "w_v": ParamDef((ff, d), P("model", "data"), dtype),
            "w_r": ParamDef((d, d), P("data", "model"), dtype),
        },
    }


def init_rwkv_state(batch: int, cfg: ModelConfig, dtype) -> RWKVState:
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    h = d // n
    return RWKVState(jnp.zeros((batch, h, n, n), jnp.float32),
                     jnp.zeros((batch, d), dtype),
                     jnp.zeros((batch, d), dtype))


def _token_shift(x: jnp.ndarray, last: jnp.ndarray | None) -> jnp.ndarray:
    """x_{t-1} sequence (first element from `last` or zeros)."""
    first = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _wkv_scan(r, k, v, w, u, s0):
    """Reference recurrence. r,k,v,w: (B, T, H, N); s0: (B, H, N, N)."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)
        y = jnp.einsum("bhij,bhi->bhj", s + u[None, :, :, None] * kv, r_t)
        s = w_t[..., None] * s + kv
        return s, y

    xs = jax.tree.map(lambda t: t.transpose(1, 0, 2, 3), (r, k, v, w))
    s, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), s


def _wkv_chunked(r, k, v, w, u, s0, chunk: int):
    """Chunked parallel form; exact (log-space cumulative decays)."""
    B, T, H, N = r.shape
    assert T % chunk == 0
    nc = T // chunk
    rs = r.reshape(B, nc, chunk, H, N)
    ks = k.reshape(B, nc, chunk, H, N)
    vs = v.reshape(B, nc, chunk, H, N)
    logw = jnp.log(jnp.clip(w, 1e-38)).reshape(B, nc, chunk, H, N)
    # cumulative decay within chunk: W_t = prod_{τ<=t} w_τ  (inclusive)
    cum = jnp.cumsum(logw, axis=2)                       # (B,nc,L,H,N)
    total = cum[:, :, -1]                                # (B,nc,H,N)

    # Factored-exponential stability. The pairwise intra-chunk decay
    # exp(excl_t − cum_τ) (≤ 1 always) is factored into two exponentials for
    # the MXU matmul; each factor is re-centred by m0 = total/2 so its
    # exponent stays within ±range/2, and clamped asymmetrically
    # (UP=+30, LO=−80): whenever the true pair weight is representable the
    # factorization is exact, and clamped outliers always round TOWARD ZERO
    # (a pair with a factor beyond e^30 has partner ≤ e^{−range/2}, so the
    # product lands below e^{30−range/2} ≪ its true ≤ 1 value — never above).
    UP, LO = 30.0, -80.0

    def chunk_step(s, inp):
        rc, kc, vc, cumc, totc = inp                      # (B,L,H,N)...
        # exclusive cumulative decay (decay applied to state before step t)
        excl = jnp.concatenate([jnp.zeros_like(cumc[:, :1]), cumc[:, :-1]],
                               axis=1)                    # (B,L,H,N)
        m0 = 0.5 * totc[:, None]                          # (B,1,H,N)
        # inter-chunk: y_inter_t = (r_t ⊙ exp(excl_t)) · S   (excl ≤ 0)
        y_inter = jnp.einsum("blhi,bhij->blhj",
                             rc * jnp.exp(jnp.clip(excl, LO, 0.0)), s)
        # intra-chunk: pairs τ < t with decay exp(excl_t − cum_τ)
        r_dec = rc * jnp.exp(jnp.clip(excl - m0, LO, UP))
        k_dec = kc * jnp.exp(jnp.clip(m0 - cumc, LO, UP))
        att = jnp.einsum("blhi,bmhi->bhlm", r_dec, k_dec)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        y_intra = jnp.einsum("bhlm,bmhj->blhj", att, vc)
        # bonus diagonal term: u ⊙ k_t
        y_diag = jnp.einsum("blhi,blhi,blhj->blhj", rc,
                            u[None, None] * kc, vc)
        # state update: S' = diag(exp(total)) S + Σ_τ exp(total − cum_τ) k_τ v_τᵀ
        k_carry = kc * jnp.exp(jnp.clip(totc[:, None] - cumc, LO, 0.0))
        s_new = jnp.exp(totc)[..., None] * s + jnp.einsum(
            "blhi,blhj->bhij", k_carry, vc)
        return s_new, y_inter + y_intra + y_diag

    xs = (rs.transpose(1, 0, 2, 3, 4), ks.transpose(1, 0, 2, 3, 4),
          vs.transpose(1, 0, 2, 3, 4), cum.transpose(1, 0, 2, 3, 4),
          total.transpose(1, 0, 2, 3))
    s, ys = jax.lax.scan(chunk_step, s0, xs)
    return ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, N), s


def rwkv_time_mix(params: dict, x: jnp.ndarray, *, cfg: ModelConfig,
                  state: RWKVState | None = None, chunk: int = 0,
                  batch_axes=("data",)):
    """x: (B, T, d). chunk > 0 selects the chunked parallel path (T % chunk == 0)."""
    p = params["time"]
    B, T, d = x.shape
    n = cfg.rwkv_head_dim
    h = d // n
    dt = x.dtype

    prev = _token_shift(x, None if state is None else state.shift_t)
    mu = p["mu"].astype(dt)                               # (5, d)
    xr, xk, xv, xg, xw = (x + (prev - x) * mu[i] for i in range(5))

    r = jnp.einsum("btd,de->bte", xr, p["w_r"]).reshape(B, T, h, n)
    k = jnp.einsum("btd,de->bte", xk, p["w_k"]).reshape(B, T, h, n)
    v = jnp.einsum("btd,de->bte", xv, p["w_v"]).reshape(B, T, h, n)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p["w_g"]))

    lora = jnp.einsum("btd,dr,re->bte", jnp.tanh(xw.astype(jnp.float32)),
                      p["decay_a"].astype(jnp.float32),
                      p["decay_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(p["decay0"] + lora)).reshape(B, T, h, n)
    u = p["bonus"].reshape(h, n)

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    s0 = jnp.zeros((B, h, n, n), jnp.float32) if state is None else state.wkv
    if chunk and T % chunk == 0 and T > 1:
        y, s = _wkv_chunked(rf, kf, vf, w, u, s0, chunk)
    else:
        y, s = _wkv_scan(rf, kf, vf, w, u, s0)

    y = (y.reshape(B, T, d).astype(dt)) * g
    out = jnp.einsum("btd,de->bte", y, p["w_o"])
    out = constrain(out, P(batch_axes, None, None))
    new_state = None
    if state is not None:
        new_state = state._replace(wkv=s, shift_t=x[:, -1, :])
    return out, new_state


def rwkv_channel_mix(params: dict, x: jnp.ndarray, *, cfg: ModelConfig,
                     state: RWKVState | None = None,
                     batch_axes=("data",)):
    p = params["channel"]
    prev = _token_shift(x, None if state is None else state.shift_c)
    mu = p["mu"].astype(x.dtype)
    xk = x + (prev - x) * mu[0]
    xr = x + (prev - x) * mu[1]
    k = jnp.einsum("btd,df->btf", xk, p["w_k"])
    kk = jnp.square(jax.nn.relu(k))
    v = jnp.einsum("btf,fd->btd", kk, p["w_v"])
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["w_r"]))
    out = r * v
    new_state = None
    if state is not None:
        new_state = state._replace(shift_c=x[:, -1, :])
    return constrain(out, P(batch_axes, None, None)), new_state
