"""Shared model machinery: parameter definitions (shape+sharding+init in one
place, so init / specs / abstract views can never drift), norms, RoPE, MLP.

Sharding convention (DESIGN.md §5): PartitionSpecs mention the logical axes
"data" (FSDP/batch) and "model" (TP). The launcher maps batch specs to
("pod","data") on the multi-pod mesh; params stay pod-replicated (pure DP over
pods) unless pipeline parallelism is enabled.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["ParamDef", "init_params", "param_specs", "abstract_params", "get_mesh",
           "rms_norm", "rope", "swiglu", "DTYPES", "set_mesh", "constrain"]

# Active mesh for sharding constraints. None (default) = single-process smoke
# mode: constraints become no-ops so models run on bare CPU without a mesh.
_MESH = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


def constrain(x: jnp.ndarray, spec: P) -> jnp.ndarray:
    """Sharding constraint that degrades gracefully: axes that do not divide
    the corresponding dim are dropped (e.g. batch-1 serving cells)."""
    if _MESH is None:
        return x
    from jax.sharding import NamedSharding

    def ok(dim: int, entry) -> bool:
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= dict(zip(_MESH.axis_names, _MESH.devices.shape))[a]
        return dim % n == 0

    fixed = tuple(
        (e if e is None or ok(d, e) else None)
        for d, e in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))))
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, P(*fixed)))

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16}


@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    spec: P = P()
    dtype: Any = jnp.bfloat16
    init: str = "normal"          # normal | zeros | ones
    scale: float | None = None    # None -> 1/sqrt(fan_in)


def _tree_map_defs(f: Callable[[ParamDef], Any], defs):
    return jax.tree.map(f, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def init_params(key: jax.Array, defs) -> Any:
    leaves = [d for d in jax.tree.leaves(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))]
    keys = list(jax.random.split(key, max(len(leaves), 1)))
    it = iter(keys)

    def make(d: ParamDef):
        k = next(it)
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[-1], 1)
        scale = d.scale if d.scale is not None else fan_in ** -0.5
        return (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(d.dtype)

    return _tree_map_defs(make, defs)


def param_specs(defs) -> Any:
    return _tree_map_defs(lambda d: d.spec, defs)


def abstract_params(defs) -> Any:
    return _tree_map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs)


# ------------------------------------------------------------------ layers
def rms_norm(w: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, D) rotary over D; positions: (..., T)."""
    D = x.shape[-1]
    half = D // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs       # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]                             # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(w_gate: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray,
           x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)
