"""Block assembly: per-family residual blocks, stacked-and-scanned layers.

Scan-over-layers keeps compile time and HLO size O(1) in depth (essential for
the 126-layer dry-runs); hybrid patterns scan over the repeating superblock
(recurrentgemma: (rglru, rglru, attn) × 12 + 2 tail rglru blocks).
Remat (full activation checkpointing) wraps the scanned body for train mode.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .attention import KVCache, attention, attn_defs, attn_dims, init_kv_cache
from .layers import ParamDef, swiglu
from .moe import moe_apply, moe_defs
from .rglru import RGLRUState, init_rglru_state, rglru_apply, rglru_defs
from .rwkv6 import (RWKVState, init_rwkv_state, rwkv_channel_mix, rwkv_defs,
                    rwkv_time_mix)

__all__ = ["block_defs", "block_apply", "stack_defs", "scan_blocks",
           "init_block_cache"]


def _norm_def(d: int) -> ParamDef:
    return ParamDef((d,), P(None), jnp.float32, "ones")


def mlp_defs(cfg: ModelConfig, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamDef((d, ff), P("data", "model"), dtype),
        "w_up": ParamDef((d, ff), P("data", "model"), dtype),
        "w_down": ParamDef((ff, d), P("model", "data"), dtype),
    }


def block_defs(cfg: ModelConfig, kind: str, tp: int, dtype,
               cross: bool = False) -> dict:
    """kind: attn | moe | rglru | rwkv. cross adds encoder cross-attention."""
    d = cfg.d_model
    if kind == "rwkv":
        return {"ln1": _norm_def(d), "ln2": _norm_def(d),
                **rwkv_defs(cfg, tp, dtype)}
    defs: dict[str, Any] = {"ln1": _norm_def(d), "ln2": _norm_def(d)}
    if kind == "attn":
        defs["attn"] = attn_defs(cfg, tp, dtype)
        defs["mlp"] = mlp_defs(cfg, dtype)
    elif kind == "moe":
        defs["attn"] = attn_defs(cfg, tp, dtype)
        defs["moe"] = moe_defs(cfg, tp, dtype)
    elif kind == "rglru":
        defs["rglru"] = rglru_defs(cfg, tp, dtype)
        defs["mlp"] = mlp_defs(cfg, dtype)
    else:
        raise ValueError(kind)
    if cross:
        defs["ln_x"] = _norm_def(d)
        defs["xattn"] = attn_defs(cfg, tp, dtype)
    return defs


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, seq: int,
                     dtype, cross_seq: int = 0) -> Any:
    if kind in ("attn", "moe"):
        self_c = init_kv_cache(batch, seq, cfg, dtype)
        if cross_seq:
            # cross-attention K/V: computed once at prefill, read at decode
            return {"self": self_c,
                    "x": init_kv_cache(batch, cross_seq, cfg, dtype)}
        return self_c
    if kind == "rglru":
        return init_rglru_state(batch, cfg, dtype)
    if kind == "rwkv":
        return init_rwkv_state(batch, cfg, dtype)
    raise ValueError(kind)


def block_apply(params: dict, x: jnp.ndarray, *, cfg: ModelConfig, kind: str,
                tp: int, positions: jnp.ndarray, cache: Any = None,
                enc_out: jnp.ndarray | None = None, causal: bool = True,
                rwkv_chunk: int = 0, batch_axes=("data",),
                moe_gathered: bool = False,
                moe_ep: bool = False,
                use_flash: bool = False) -> tuple[jnp.ndarray, Any]:
    """One residual block. Returns (x, new_cache)."""
    from .layers import rms_norm
    dims = attn_dims(cfg, tp) if kind != "rwkv" else None
    new_cache = cache

    if kind == "rwkv":
        h, new_cache = rwkv_time_mix(params, rms_norm(params["ln1"], x, cfg.norm_eps),
                                     cfg=cfg, state=cache, chunk=rwkv_chunk,
                                     batch_axes=batch_axes)
        x = x + h
        h, new_cache = rwkv_channel_mix(params, rms_norm(params["ln2"], x, cfg.norm_eps),
                                        cfg=cfg, state=new_cache,
                                        batch_axes=batch_axes)
        return x + h, new_cache

    if kind in ("attn", "moe"):
        window = cfg.window if (cfg.family == "hybrid") else 0
        self_cache = cache["self"] if isinstance(cache, dict) else cache
        h, new_self = attention(params["attn"], rms_norm(params["ln1"], x, cfg.norm_eps),
                                cfg=cfg, dims=dims, positions=positions,
                                cache=self_cache, causal=causal, window=window,
                                batch_axes=batch_axes, use_flash=use_flash)
        new_cache = ({"self": new_self, "x": cache["x"]}
                     if isinstance(cache, dict) else new_self)
        x = x + h
    elif kind == "rglru":
        h, new_cache = rglru_apply(params["rglru"], rms_norm(params["ln1"], x, cfg.norm_eps),
                                   cfg=cfg, state=cache, batch_axes=batch_axes)
        x = x + h

    if enc_out is not None and "xattn" in params:
        x_kv = cache.get("x") if isinstance(cache, dict) else None
        h, new_x = attention(params["xattn"], rms_norm(params["ln_x"], x, cfg.norm_eps),
                             cfg=cfg, dims=dims, positions=positions,
                             kv_x=enc_out, static_kv=x_kv, causal=False,
                             batch_axes=batch_axes)
        if isinstance(new_cache, dict) and x_kv is not None:
            new_cache = {**new_cache, "x": new_x}
        x = x + h

    xn = rms_norm(params["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        if moe_ep and x.shape[1] > 1:        # train & prefill (not decode)
            # expert-parallel a2a path (§Perf): experts resident, tokens move
            from .layers import get_mesh
            from .moe import moe_apply_ep
            h = moe_apply_ep(params["moe"], xn, cfg=cfg,
                             mesh=get_mesh(), batch_axes=batch_axes)
        elif moe_gathered and x.shape[1] > 1:
            # gathered-experts path (§Perf): local dispatch, FSDP weights
            from .layers import get_mesh
            from .moe import moe_apply_gathered
            h = moe_apply_gathered(params["moe"], xn, cfg=cfg,
                                   mesh=get_mesh(), batch_axes=batch_axes)
        else:
            h = moe_apply(params["moe"], xn, cfg=cfg, tp=tp,
                          batch_axes=batch_axes)
    else:
        h = swiglu(params["mlp"]["w_gate"], params["mlp"]["w_up"],
                   params["mlp"]["w_down"], xn)
    return x + h, new_cache


# ------------------------------------------------------------ layer stacking
def stack_defs(n: int, defs) -> Any:
    """Prepend a layer axis to every ParamDef (unsharded, scanned)."""
    def f(d: ParamDef):
        return ParamDef((n,) + d.shape, P(*((None,) + tuple(d.spec))),
                        d.dtype, d.init, d.scale)
    return jax.tree.map(f, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def scan_blocks(params_stacked, x: jnp.ndarray, apply_fn, cache_stacked=None,
                remat: bool = True):
    """Run a stack of identical blocks with lax.scan.

    apply_fn(layer_params, x, layer_cache) -> (x, new_layer_cache).
    """
    has_cache = cache_stacked is not None

    def body(carry, layer):
        p, c = layer if has_cache else (layer, None)
        y, c2 = apply_fn(p, carry, c)
        return y, (c2 if has_cache else None)

    if remat:
        body = jax.checkpoint(body)
    xs = (params_stacked, cache_stacked) if has_cache else params_stacked
    x, new_cache = jax.lax.scan(body, x, xs)
    return x, (new_cache if has_cache else None)
