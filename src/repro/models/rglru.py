"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block: x -> [branch1: linear -> causal depthwise conv(4) -> RG-LRU]
           ⊙ gelu(branch2: linear) -> out-projection.

RG-LRU (diagonal gated linear recurrence):
    r_t = σ(W_a x_t + b_a)            recurrence gate
    i_t = σ(W_i x_t + b_i)            input gate
    a_t = exp(c · softplus(Λ) · (−r_t))   per-channel decay in (0,1)
    h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

The recurrence is diagonal ⇒ channel-shardable on `model` and evaluated with
`jax.lax.associative_scan` (O(log T) depth — this is what makes long_500k
prefill tractable, and the recurrence state is O(1) for decode).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .layers import ParamDef, constrain

__all__ = ["rglru_defs", "rglru_apply", "RGLRUState", "init_rglru_state"]

C_FACTOR = 8.0


class RGLRUState(NamedTuple):
    h: jnp.ndarray          # (B, W) recurrent state
    conv: jnp.ndarray       # (B, taps-1, W) conv lookback


def rglru_defs(cfg: ModelConfig, tp: int, dtype) -> dict:
    d = cfg.d_model
    w = cfg.lru_dim or d
    return {
        "w_in": ParamDef((d, w), P("data", "model"), dtype),
        "w_gate": ParamDef((d, w), P("data", "model"), dtype),
        "conv": ParamDef((cfg.conv_width, w), P(None, "model"), dtype),
        "w_a": ParamDef((w, w), P("data", "model"), dtype),
        "w_i": ParamDef((w, w), P("data", "model"), dtype),
        "b_a": ParamDef((w,), P("model"), jnp.float32, "zeros"),
        "b_i": ParamDef((w,), P("model"), jnp.float32, "zeros"),
        "lam": ParamDef((w,), P("model"), jnp.float32, "ones"),
        "w_out": ParamDef((w, d), P("model", "data"), dtype),
    }


def init_rglru_state(batch: int, cfg: ModelConfig, dtype) -> RGLRUState:
    w = cfg.lru_dim or cfg.d_model
    return RGLRUState(jnp.zeros((batch, w), jnp.float32),
                      jnp.zeros((batch, cfg.conv_width - 1, w), dtype))


def _causal_conv(xw: jnp.ndarray, kernel: jnp.ndarray,
                 lookback: jnp.ndarray | None):
    """xw: (B, T, W); kernel: (taps, W) depthwise. Returns (y, new_lookback)."""
    taps = kernel.shape[0]
    if lookback is None:
        lookback = jnp.zeros((xw.shape[0], taps - 1, xw.shape[2]), xw.dtype)
    ext = jnp.concatenate([lookback, xw], axis=1)          # (B, T+taps-1, W)
    y = sum(ext[:, i:i + xw.shape[1], :] * kernel[i] for i in range(taps))
    return y, ext[:, -(taps - 1):, :]


def _lru_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + b_t via associative scan. a, b: (B, T, W)."""
    a0 = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
    b0 = jnp.concatenate([h0[:, None, :], b], axis=1)

    def op(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay + by

    _, h = jax.lax.associative_scan(op, (a0, b0), axis=1)
    return h[:, 1:, :]


def rglru_apply(params: dict, x: jnp.ndarray, *, cfg: ModelConfig,
                state: RGLRUState | None = None,
                batch_axes=("data",)) -> tuple[jnp.ndarray, RGLRUState | None]:
    """x: (B, T, d) -> (B, T, d); state threaded for decode."""
    B, T, d = x.shape
    xw = jnp.einsum("btd,dw->btw", x, params["w_in"])
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, params["w_gate"]))
    tp_ax = None if "model" in batch_axes else "model"
    xw = constrain(xw, P(batch_axes, None, tp_ax))

    conv_in = None if state is None else state.conv
    xc, new_conv = _causal_conv(xw, params["conv"], conv_in)

    xf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", xf, params["w_a"].astype(jnp.float32)) + params["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", xf, params["w_i"].astype(jnp.float32)) + params["b_i"])
    log_a = -C_FACTOR * jax.nn.softplus(params["lam"]) * r     # (B, T, W) < 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-9)) * (i * xf)

    h0 = jnp.zeros((B, a.shape[-1]), jnp.float32) if state is None else state.h
    if T == 1 and state is not None:          # decode fast path
        h = (a[:, 0] * h0 + b[:, 0])[:, None, :]
    else:
        h = _lru_scan(a, b, h0)

    new_state = None
    if state is not None:
        new_state = RGLRUState(h[:, -1, :], new_conv)

    y = (h.astype(x.dtype) * gate)
    y = jnp.einsum("btw,wd->btd", y, params["w_out"])
    return constrain(y, P(batch_axes, None, None)), new_state
