"""Model: config -> params/specs, train forward, prefill, decode.

One class serves all 10 assigned architectures; the family decides the block
layout (DESIGN.md §5). The modality frontends of [audio]/[vlm] archs are
stubs: seamless's encoder consumes precomputed frame embeddings; chameleon's
VQ image tokens are ordinary vocab ids.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .attention import KVCache
from .layers import (DTYPES, ParamDef, abstract_params, constrain,
                     init_params, param_specs, rms_norm)
from .transformer import (block_apply, block_defs, init_block_cache,
                          scan_blocks, stack_defs)

__all__ = ["Model"]

NEG = -1.0e30


class Model:
    def __init__(self, cfg: ModelConfig, *, tp: int = 1,
                 batch_axes: tuple[str, ...] = ("data",),
                 rwkv_chunk: int = 0, rwkv_sp: bool = False,
                 moe_gathered: bool = False, moe_ep: bool = False,
                 use_flash: bool = False):
        self.cfg = cfg
        self.tp = tp
        self.batch_axes = batch_axes
        self.rwkv_chunk = rwkv_chunk
        self.rwkv_sp = rwkv_sp     # sequence-parallel RWKV stack (T > 1)
        self.moe_gathered = moe_gathered   # gathered-experts MoE dispatch
        self.moe_ep = moe_ep               # expert-parallel a2a dispatch
        self.use_flash = use_flash         # blockwise/flash attention (T>1)
        self.dtype = DTYPES[cfg.param_dtype]
        self.v_pad = cfg.padded_vocab(tp)
        self._defs = self._build_defs()

    # ------------------------------------------------------------ params
    def _kind(self) -> str:
        return {"dense": "attn", "moe": "moe", "ssm": "rwkv",
                "encdec": "attn", "hybrid": None}[self.cfg.family]

    def _hybrid_layout(self):
        pat = self.cfg.layer_pattern()
        n_rep = self.cfg.n_layers // len(pat)
        tail = self.cfg.n_layers - n_rep * len(pat)
        return pat, n_rep, tail

    def _build_defs(self) -> dict:
        cfg, tp, dt = self.cfg, self.tp, self.dtype
        d = cfg.d_model
        defs: dict[str, Any] = {
            "embed": ParamDef((self.v_pad, d), P("model", "data"), dt,
                              scale=1.0),
            "ln_f": ParamDef((d,), P(None), jnp.float32, "ones"),
        }
        if not cfg.tie_embeddings:
            defs["unembed"] = ParamDef((d, self.v_pad), P("data", "model"), dt)
        if cfg.family == "hybrid":
            pat, n_rep, tail = self._hybrid_layout()
            super_defs = {f"b{i}": block_defs(cfg, k, tp, dt)
                          for i, k in enumerate(pat)}
            defs["layers"] = stack_defs(n_rep, super_defs)
            defs["tail"] = {f"t{i}": block_defs(cfg, pat[i % len(pat)], tp, dt)
                            for i in range(tail)}
        elif cfg.family == "encdec":
            defs["enc_layers"] = stack_defs(
                cfg.enc_layers, block_defs(cfg, "attn", tp, dt))
            defs["enc_ln"] = ParamDef((d,), P(None), jnp.float32, "ones")
            defs["layers"] = stack_defs(
                cfg.n_layers, block_defs(cfg, "attn", tp, dt, cross=True))
        else:
            defs["layers"] = stack_defs(
                cfg.n_layers, block_defs(cfg, self._kind(), tp, dt))
        return defs

    def init(self, key: jax.Array):
        return init_params(key, self._defs)

    def specs(self):
        return param_specs(self._defs)

    def abstract(self):
        return abstract_params(self._defs)

    # ------------------------------------------------------------ caches
    def init_cache(self, batch: int, seq: int, dtype=None) -> dict:
        cfg = self.cfg
        dtype = dtype or self.dtype
        stack = lambda n, c: jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape), c)
        if cfg.family == "hybrid":
            pat, n_rep, tail = self._hybrid_layout()
            sup = {f"b{i}": init_block_cache(cfg, k, batch, seq, dtype)
                   for i, k in enumerate(pat)}
            cache = {"layers": stack(n_rep, sup),
                     "tail": {f"t{i}": init_block_cache(
                         cfg, pat[i % len(pat)], batch, seq, dtype)
                         for i in range(tail)}}
        elif cfg.family == "encdec":
            enc_t = seq // cfg.enc_seq_divisor
            cache = {"layers": stack(cfg.n_layers, init_block_cache(
                cfg, "attn", batch, seq, dtype, cross_seq=enc_t)),
                "enc_out": jnp.zeros((batch, enc_t, cfg.d_model), dtype)}
        else:
            cache = {"layers": stack(cfg.n_layers, init_block_cache(
                cfg, self._kind(), batch, seq, dtype))}
        cache["index"] = jnp.zeros((), jnp.int32)
        return cache

    # ----------------------------------------------------------- forward
    def _embed(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        return constrain(
            x, P(self.batch_axes, None, None))

    def _logits(self, params, x):
        x = rms_norm(params["ln_f"], x, self.cfg.norm_eps)
        w = (params["embed"].T if self.cfg.tie_embeddings
             else params["unembed"])
        logits = jnp.einsum("btd,dv->btv", x, w).astype(jnp.float32)
        logits = jnp.where(jnp.arange(self.v_pad) < self.cfg.vocab,
                           logits, NEG)
        tp_ax = None if "model" in self.batch_axes else "model"
        return constrain(
            logits, P(self.batch_axes, None, tp_ax))

    def _apply_fn(self, kind: str, positions, enc_out=None, causal=True):
        def f(p, x, c):
            return block_apply(p, x, cfg=self.cfg, kind=kind, tp=self.tp,
                               positions=positions, cache=c, enc_out=enc_out,
                               causal=causal, rwkv_chunk=self.rwkv_chunk,
                               batch_axes=self.batch_axes,
                               moe_gathered=self.moe_gathered,
                               moe_ep=self.moe_ep, use_flash=self.use_flash)
        return f

    def _encode(self, params, enc_feats, remat):
        B, Te, _ = enc_feats.shape
        pos = jnp.broadcast_to(jnp.arange(Te), (B, Te))
        x, _ = scan_blocks(params["enc_layers"], enc_feats.astype(self.dtype),
                           self._apply_fn("attn", pos, causal=False),
                           remat=remat)
        return rms_norm(params["enc_ln"], x, self.cfg.norm_eps)

    def forward(self, params, tokens, *, enc_feats=None, cache=None):
        """tokens: (B, T). cache=None -> pure causal forward (train);
        cache given -> fill-and-attend (prefill T>1 / decode T==1).
        Returns (logits, new_cache)."""
        cfg = self.cfg
        B, T = tokens.shape
        remat = cfg.remat and cache is None
        index = cache["index"] if cache is not None else jnp.int32(0)
        positions = index + jnp.broadcast_to(jnp.arange(T), (B, T))
        x = self._embed(params, tokens)

        enc_out = None
        if cfg.family == "encdec":
            if cache is not None and enc_feats is None:
                enc_out = cache["enc_out"].astype(self.dtype)
            else:
                enc_out = self._encode(params, enc_feats, remat)

        new_cache = dict(cache) if cache is not None else None
        if cfg.family == "hybrid":
            pat, n_rep, tail = self._hybrid_layout()

            def sup_apply(p, x, c):
                cs = {}
                for i, k in enumerate(pat):
                    x, c2 = block_apply(
                        p[f"b{i}"], x, cfg=cfg, kind=k, tp=self.tp,
                        positions=positions,
                        cache=None if c is None else c[f"b{i}"],
                        rwkv_chunk=self.rwkv_chunk,
                        batch_axes=self.batch_axes)
                    cs[f"b{i}"] = c2
                return x, (cs if c is not None else None)

            x, nc = scan_blocks(params["layers"], x, sup_apply,
                                None if cache is None else cache["layers"],
                                remat=remat)
            if cache is not None:
                new_cache["layers"] = nc
            for i in range(tail):
                k = pat[i % len(pat)]
                c_i = None if cache is None else cache["tail"][f"t{i}"]
                x, c2 = block_apply(params["tail"][f"t{i}"], x, cfg=cfg,
                                    kind=k, tp=self.tp, positions=positions,
                                    cache=c_i, batch_axes=self.batch_axes)
                if cache is not None:
                    new_cache["tail"][f"t{i}"] = c2
        elif cfg.family == "ssm" and self.rwkv_sp and T > 1:
            # sequence-parallel RWKV stack (models/rwkv_sp.py): T sharded
            # over `model`, weights FSDP-gathered, state via prefix scan.
            # Fresh-state only: train, or prefill into a zero cache.
            from .layers import get_mesh
            from .rwkv_sp import rwkv_stack_sp
            from .transformer import block_defs, stack_defs
            from .layers import param_specs
            specs = param_specs(self._defs)["layers"]
            out = rwkv_stack_sp(params["layers"], specs, x, cfg=cfg,
                                mesh=get_mesh(), chunk=self.rwkv_chunk or 256,
                                batch_axes=self.batch_axes, remat=remat,
                                want_cache=cache is not None)
            if cache is not None:
                x, new_cache["layers"] = out
            else:
                x = out
        else:
            kind = self._kind()
            x, nc = scan_blocks(
                params["layers"], x,
                self._apply_fn(kind, positions, enc_out=enc_out),
                None if cache is None else cache["layers"], remat=remat)
            if cache is not None:
                new_cache["layers"] = nc

        logits = self._logits(params, x)
        if cache is not None:
            new_cache["index"] = index + T
            if cfg.family == "encdec" and enc_feats is not None:
                new_cache["enc_out"] = enc_out.astype(
                    cache["enc_out"].dtype)
        return logits, new_cache

    # ------------------------------------------------------------- steps
    def loss(self, params, batch):
        """batch: {"tokens": (B,T), "labels": (B,T)} (+ "enc_feats")."""
        logits, _ = self.forward(params, batch["tokens"],
                                 enc_feats=batch.get("enc_feats"))
        labels = batch["labels"]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, labels[..., None],
                                     axis=-1)[..., 0]
        return jnp.mean(lse - picked)

    def prefill(self, params, tokens, cache, *, enc_feats=None):
        return self.forward(params, tokens, enc_feats=enc_feats, cache=cache)

    def decode_step(self, params, cache, tokens):
        """tokens: (B, 1). Returns (logits (B,1,V), new_cache)."""
        return self.forward(params, tokens, cache=cache)
