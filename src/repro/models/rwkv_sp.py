"""Sequence-parallel RWKV-6 layer stack (§Perf hillclimb — beyond paper).

Motivation: with the stock layout (batch on `data`, d_model on `model`) the
partitioner re-gathers the (B, T, d) residual stream for every token-shift
projection — ~6 × 1 GiB per layer at prefill_32k. Linear-attention recurrence
makes a better decomposition possible: shard the TIME axis over `model`.
Then every projection, norm, lerp and the intra-shard WKV recurrence is
device-local, and the only cross-device traffic per layer is

  * FSDP-style weight all-gathers (the weights are small: ~450 MB/layer),
  * a 1-token boundary exchange for token-shift (ppermute),
  * a log2(tp)-round associative PREFIX SCAN of the (decay, state) pair —
    the WKV recurrence `S' = diag(D)·S + K` is an affine map, and affine
    maps compose associatively: (D2,K2)∘(D1,K1) = (D2·D1, D2·K1+K2).
    This is the linear-attention analogue of flash-decoding's split-K.

Used for train/prefill (T > 1, fresh state); decode keeps the stock path.
Exactness vs the sequential stack is tested in tests/test_rwkv_sp.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..runtime.jax_compat import axis_size
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .layers import rms_norm
from .rwkv6 import LORA_R, RWKVState, _wkv_chunked

__all__ = ["rwkv_stack_sp", "sp_param_specs"]


def sp_param_specs(specs_tree):
    """in_specs for the stacked layer params: exactly their storage specs."""
    return specs_tree


def _gather_full(x, spec):
    """Reassemble a full parameter from its shard inside shard_map."""
    for dim, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            x = jax.lax.all_gather(x, a, axis=dim, tiled=True)
    return x


def _shift_from_left(x_l, axis_name: str):
    """prev-token sequence for a T-sharded (B, T_l, d) block: within-shard
    shift + the previous rank's last token via ppermute (rank 0 gets zeros,
    which is the sequence-start convention)."""
    tp = axis_size(axis_name)
    boundary = jax.lax.ppermute(x_l[:, -1:], axis_name,
                                perm=[(i, i + 1) for i in range(tp - 1)])
    return jnp.concatenate([boundary, x_l[:, :-1]], axis=1)


def _state_prefix_scan(D, K, axis_name: str):
    """Exclusive prefix scan of affine maps (D, K) over the sequence shards.
    D: (B, H, N) total decay of the shard; K: (B, H, N, N) state injected by
    the shard. Returns each rank's incoming state (zeros at rank 0).
    Hillis–Steele doubling: log2(tp) ppermute rounds."""
    tp = axis_size(axis_name)
    rank = jax.lax.axis_index(axis_name)
    step = 1
    while step < tp:
        perm = [(i, i + step) for i in range(tp - step)]
        Dr = jax.lax.ppermute(D, axis_name, perm=perm)
        Kr = jax.lax.ppermute(K, axis_name, perm=perm)
        has = rank >= step           # ranks with an incoming partner
        # compose: earlier (Dr, Kr) then current (D, K)
        D, K = (jnp.where(has, D * Dr, D),
                jnp.where(has, D[..., None] * Kr + K, K))
        step *= 2
    # exclusive: shift the inclusive scan right by one rank
    s_in = jax.lax.ppermute(K, axis_name,
                            perm=[(i, i + 1) for i in range(tp - 1)])
    return s_in


def _time_mix_sp(p, x_l, *, cfg: ModelConfig, chunk: int, axis_name: str):
    B, Tl, d = x_l.shape
    n = cfg.rwkv_head_dim
    h = d // n
    dt = x_l.dtype

    prev = _shift_from_left(x_l, axis_name)
    mu = p["mu"].astype(dt)
    xr, xk, xv, xg, xw = (x_l + (prev - x_l) * mu[i] for i in range(5))

    r = jnp.einsum("btd,de->bte", xr, p["w_r"]).reshape(B, Tl, h, n)
    k = jnp.einsum("btd,de->bte", xk, p["w_k"]).reshape(B, Tl, h, n)
    v = jnp.einsum("btd,de->bte", xv, p["w_v"]).reshape(B, Tl, h, n)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p["w_g"]))

    lora = jnp.einsum("btd,dr,re->bte", jnp.tanh(xw.astype(jnp.float32)),
                      p["decay_a"].astype(jnp.float32),
                      p["decay_b"].astype(jnp.float32))
    logw = -jnp.exp(p["decay0"] + lora).reshape(B, Tl, h, n)   # log decay ≤ 0
    w = jnp.exp(logw)
    u = p["bonus"].reshape(h, n)

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    s0 = jnp.zeros((B, h, n, n), jnp.float32)
    y0, s_loc = _wkv_chunked(rf, kf, vf, w, u, s0, min(chunk, Tl))

    # cross-shard recurrence: affine-map prefix scan
    cum = jnp.cumsum(logw, axis=1)                       # (B,Tl,h,n)
    D_tot = jnp.exp(cum[:, -1])                          # (B,h,n)
    s_in = _state_prefix_scan(D_tot, s_loc, axis_name)
    excl = cum - logw                                    # exclusive cumsum
    r_dec = rf * jnp.exp(jnp.clip(excl, -80.0, 0.0))
    y = y0 + jnp.einsum("blhi,bhij->blhj", r_dec, s_in)

    y = (y.reshape(B, Tl, d).astype(dt)) * g
    out = jnp.einsum("btd,de->bte", y, p["w_o"])
    # global final state (for the prefill cache): lives on the last rank
    tp = axis_size(axis_name)
    rank = jax.lax.axis_index(axis_name)
    s_fin = D_tot[..., None] * s_in + s_loc
    s_fin = jax.lax.psum(jnp.where(rank == tp - 1, s_fin, 0.0), axis_name)
    return out, s_fin


def _channel_mix_sp(p, x_l, *, axis_name: str):
    prev = _shift_from_left(x_l, axis_name)
    mu = p["mu"].astype(x_l.dtype)
    xk = x_l + (prev - x_l) * mu[0]
    xr = x_l + (prev - x_l) * mu[1]
    k = jnp.einsum("btd,df->btf", xk, p["w_k"])
    kk = jnp.square(jax.nn.relu(k))
    v = jnp.einsum("btf,fd->btd", kk, p["w_v"])
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["w_r"]))
    return r * v


def rwkv_stack_sp(params_stacked, specs_stacked, x, *, cfg: ModelConfig,
                  mesh, chunk: int, batch_axes=("data",), remat: bool = True,
                  seq_axis: str = "model", want_cache: bool = False):
    """Run the whole RWKV layer stack sequence-parallel.

    x: (B, T, d) global, batch sharded over `batch_axes`; T must divide by
    the `seq_axis` extent. Fresh state only (train / first prefill).
    Returns (x_out, per-layer RWKVState stacked or None).
    """
    from jax.experimental.shard_map import shard_map

    tp = mesh.shape[seq_axis]
    layer_specs = jax.tree.map(
        lambda s: P(*s), specs_stacked,
        is_leaf=lambda s: isinstance(s, P))
    x_spec = P(batch_axes, seq_axis, None)
    out_state_spec = RWKVState(P(None, batch_axes, None, None, None),
                               P(None, batch_axes, None),
                               P(None, batch_axes, None))

    # per-layer specs with the leading (scanned) layer dim dropped
    spec_leaves = [tuple(s)[1:] for s in jax.tree.leaves(
        specs_stacked, is_leaf=lambda s: isinstance(s, P))]

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(layer_specs, x_spec),
        out_specs=(x_spec, out_state_spec) if want_cache else x_spec,
        check_rep=False)
    def run(params_l, x_l):
        rank = jax.lax.axis_index(seq_axis)

        def layer(x_l, p_shard):
            leaves, tdef = jax.tree.flatten(p_shard)
            p = jax.tree.unflatten(tdef, [
                _gather_full(a, s) for a, s in zip(leaves, spec_leaves)])
            xn1 = rms_norm(p["ln1"], x_l, cfg.norm_eps)
            h, s_fin = _time_mix_sp(p["time"], xn1, cfg=cfg, chunk=chunk,
                                    axis_name=seq_axis)
            x1 = x_l + h
            xn2 = rms_norm(p["ln2"], x1, cfg.norm_eps)
            x2 = x1 + _channel_mix_sp(p["channel"], xn2, axis_name=seq_axis)
            if want_cache:
                # cache stores the NORMED last token of each mix input
                last = jax.lax.psum(
                    jnp.where(rank == tp - 1, xn1[:, -1], 0.0), seq_axis)
                last2 = jax.lax.psum(
                    jnp.where(rank == tp - 1, xn2[:, -1], 0.0), seq_axis)
                st = RWKVState(s_fin, last.astype(x_l.dtype),
                               last2.astype(x_l.dtype))
            else:
                st = 0.0
            return x2, st

        body = jax.checkpoint(layer) if remat else layer
        x_l, states = jax.lax.scan(body, x_l, params_l)
        return (x_l, states) if want_cache else x_l

    return run(params_stacked, x)
