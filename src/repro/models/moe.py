"""Mixture-of-Experts FFN: top-k softmax router, dropless-style scatter
dispatch into per-expert capacity buffers, expert-parallel (experts sharded
over `model`). Expert count is padded up to a multiple of the model-axis size;
padded experts' router logits are −inf (zero traffic, mathematically inert).

Arctic-style dense residual: an ordinary SwiGLU MLP runs in parallel with the
MoE FFN and its output is added (cfg.moe_dense_residual).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .layers import ParamDef, constrain

__all__ = ["moe_defs", "moe_apply", "moe_apply_gathered", "moe_apply_ep"]


def moe_defs(cfg: ModelConfig, tp: int, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    e = cfg.padded_experts(tp)
    defs = {
        "router": ParamDef((d, e), P("data", None), jnp.float32),
        "w_gate": ParamDef((e, d, ff), P("model", "data", None), dtype),
        "w_up": ParamDef((e, d, ff), P("model", "data", None), dtype),
        "w_down": ParamDef((e, ff, d), P("model", None, "data"), dtype),
    }
    if cfg.moe_dense_residual:
        defs["dense"] = {
            "w_gate": ParamDef((d, ff), P("data", "model"), dtype),
            "w_up": ParamDef((d, ff), P("data", "model"), dtype),
            "w_down": ParamDef((ff, d), P("model", "data"), dtype),
        }
    return defs


def moe_apply(params: dict, x: jnp.ndarray, *, cfg: ModelConfig, tp: int,
              batch_axes=("data",)) -> jnp.ndarray:
    """x: (B, T, d) -> (B, T, d)."""
    B, T, d = x.shape
    e_pad = cfg.padded_experts(tp)
    e_real, k = cfg.n_experts, cfg.experts_top_k
    n_tok = B * T
    xt = x.reshape(n_tok, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    logits = jnp.where(jnp.arange(e_pad) < e_real, logits, -jnp.inf)
    top_vals, top_idx = jax.lax.top_k(logits, k)              # (n_tok, k)
    gates = jax.nn.softmax(top_vals, axis=-1)                 # renormalized

    # flatten (token, k) assignments and sort by expert
    expert_id = top_idx.reshape(-1)                           # (n_tok*k,)
    token_id = jnp.repeat(jnp.arange(n_tok), k)
    gate_flat = gates.reshape(-1)
    order = jnp.argsort(expert_id)
    expert_s, token_s, gate_s = expert_id[order], token_id[order], gate_flat[order]

    # capacity buffers: position within expert via exclusive segment offsets
    capacity = max(int(n_tok * k / max(e_real, 1) * cfg.capacity_factor), 8)
    counts = jnp.bincount(expert_id, length=e_pad)
    offsets = jnp.concatenate([jnp.zeros(1, counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(n_tok * k) - offsets[expert_s]
    keep = pos < capacity
    slot = jnp.where(keep, expert_s * capacity + pos, e_pad * capacity)

    buf = jnp.zeros((e_pad * capacity + 1, d), x.dtype)
    buf = buf.at[slot].set(xt[token_s] * keep[:, None].astype(x.dtype))
    buf = buf[:-1].reshape(e_pad, capacity, d)
    buf = constrain(buf, P("model", None, None))

    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_buf = constrain(out_buf, P("model", None, None))

    # combine: gather each assignment's output back to its token, weighted
    flat = out_buf.reshape(e_pad * capacity, d)
    contrib = flat[jnp.clip(slot, 0, e_pad * capacity - 1)]
    contrib = contrib * (gate_s * keep)[:, None].astype(x.dtype)
    y = jnp.zeros((n_tok, d), x.dtype).at[token_s].add(contrib)
    y = y.reshape(B, T, d)

    if cfg.moe_dense_residual:
        from .layers import swiglu
        dp = params["dense"]
        y = y + swiglu(dp["w_gate"], dp["w_up"], dp["w_down"], x)
    return constrain(y, P(batch_axes, None, None))


def _dispatch_local(xt, logits, *, e_pad, e_real, k, capacity, dtype):
    """Capacity-buffer dispatch for a LOCAL token shard (runs inside
    shard_map — no cross-device traffic). Returns (buf, combine closure)."""
    n_tok, d = xt.shape
    top_vals, top_idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(top_vals, axis=-1)
    expert_id = top_idx.reshape(-1)
    token_id = jnp.repeat(jnp.arange(n_tok), k)
    gate_flat = gates.reshape(-1)
    order = jnp.argsort(expert_id)
    expert_s, token_s, gate_s = (expert_id[order], token_id[order],
                                 gate_flat[order])
    counts = jnp.bincount(expert_id, length=e_pad)
    offsets = jnp.concatenate([jnp.zeros(1, counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(n_tok * k) - offsets[expert_s]
    keep = pos < capacity
    slot = jnp.where(keep, expert_s * capacity + pos, e_pad * capacity)
    buf = jnp.zeros((e_pad * capacity + 1, d), dtype)
    buf = buf.at[slot].set(xt[token_s] * keep[:, None].astype(dtype))
    buf = buf[:-1].reshape(e_pad, capacity, d)

    def combine(out_buf):
        flat = out_buf.reshape(e_pad * capacity, d)
        contrib = flat[jnp.clip(slot, 0, e_pad * capacity - 1)]
        contrib = contrib * (gate_s * keep)[:, None].astype(dtype)
        return jnp.zeros((n_tok, d), dtype).at[token_s].add(contrib)

    return buf, combine


def moe_apply_gathered(params, x, *, cfg: ModelConfig, mesh,
                       batch_axes=("data",), seq_axis: str = "model"):
    """Gathered-experts MoE (§Perf hillclimb — beyond paper).

    The scatter-dispatch path (moe_apply) makes the partitioner all-gather
    the FULL token buffer per layer (~439 s of collective per train step on
    granite-moe). When the per-layer expert weights are small (granite-moe:
    226 MB), the cheaper decomposition is the transpose: shard TOKENS over
    every mesh axis, all-gather the WEIGHTS (FSDP-style), and dispatch
    entirely device-locally — per-layer traffic drops from O(tokens·d) to
    O(expert_weights).

    x: (B, T, d) with batch over `batch_axes`; T divisible by the seq_axis
    extent. Capacity is enforced per token shard (more balanced than global).
    """
    from jax.experimental.shard_map import shard_map

    B, T, d = x.shape
    e_pad = cfg.padded_experts(mesh.shape[seq_axis])
    e_real, k = cfg.n_experts, cfg.experts_top_k
    # Under fsdp_only the batch already occupies every axis: tokens are
    # fully local with full d and no transpose is needed. Otherwise x stays
    # in its native (batch, None, d-sharded) layout and the tokens<->features
    # transpose is an EXPLICIT all_to_all inside the region (the partitioner
    # otherwise lowers the boundary reshard as a full all-gather).
    fsdp_only = seq_axis in (batch_axes if isinstance(batch_axes, tuple)
                             else (batch_axes,))
    if fsdp_only:
        # tokens fully sharded with full d: batch over the data axes, T over
        # the model axis (works for any B; needs T % tp == 0)
        data_axes = tuple(a for a in batch_axes if a != seq_axis)
        x_spec = P(data_axes, seq_axis, None)
    else:
        x_spec = P(batch_axes, None, seq_axis)

    w_specs = {
        "router": P("data", None),
        "w_gate": P(seq_axis, "data", None),
        "w_up": P(seq_axis, "data", None),
        "w_down": P(seq_axis, None, "data"),
    }
    if cfg.moe_dense_residual:
        w_specs["dense"] = {"w_gate": P("data", seq_axis),
                            "w_up": P("data", seq_axis),
                            "w_down": P(seq_axis, "data")}
    p_in = {kk: params[kk] for kk in w_specs if kk in params}

    tok_shards = 1
    for a in dict.fromkeys((*batch_axes, seq_axis)):   # de-dup, keep order
        tok_shards *= mesh.shape[a]
    local_tok = (B * T) // tok_shards
    capacity = max(int(local_tok * k / max(e_real, 1) * cfg.capacity_factor),
                   8)

    @functools.partial(shard_map, mesh=mesh, in_specs=(w_specs, x_spec),
                       out_specs=x_spec, check_rep=False)
    def run(p, x_l):
        full = {}
        for name, spec in w_specs.items():
            if name == "dense":
                continue
            wv = p[name]
            for dim, entry in enumerate(spec):
                if entry is not None:
                    wv = jax.lax.all_gather(wv, entry, axis=dim, tiled=True)
            full[name] = wv
        if not fsdp_only:
            # (B_l, T, d/tp) -> (B_l, T/tp, d): token/feature transpose
            x_l = jax.lax.all_to_all(x_l, seq_axis, split_axis=1,
                                     concat_axis=2, tiled=True)
        Bl, Tl, _ = x_l.shape
        xt = x_l.reshape(Bl * Tl, d)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            full["router"])
        logits = jnp.where(jnp.arange(e_pad) < e_real, logits, -jnp.inf)
        buf, combine = _dispatch_local(xt, logits, e_pad=e_pad,
                                       e_real=e_real, k=k,
                                       capacity=capacity, dtype=x_l.dtype)
        g = jnp.einsum("ecd,edf->ecf", buf, full["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, full["w_up"])
        out_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                             full["w_down"])
        y = combine(out_buf).reshape(Bl, Tl, d)
        if cfg.moe_dense_residual:
            dp = p["dense"]
            wg = jax.lax.all_gather(jax.lax.all_gather(
                dp["w_gate"], "data", axis=0, tiled=True),
                seq_axis, axis=1, tiled=True)
            wu = jax.lax.all_gather(jax.lax.all_gather(
                dp["w_up"], "data", axis=0, tiled=True),
                seq_axis, axis=1, tiled=True)
            wd = jax.lax.all_gather(jax.lax.all_gather(
                dp["w_down"], seq_axis, axis=0, tiled=True),
                "data", axis=1, tiled=True)
            from .layers import swiglu
            y = y + swiglu(wg, wu, wd, x_l)
        if fsdp_only:
            return y
        # back to (B_l, T, d/tp)
        return jax.lax.all_to_all(y, seq_axis, split_axis=2, concat_axis=1,
                                  tiled=True)

    return run(p_in, x)


def moe_apply_ep(params, x, *, cfg: ModelConfig, mesh,
                 batch_axes=("data",), seq_axis: str = "model"):
    """True expert-parallel MoE dispatch (§Perf — beyond paper): experts stay
    RESIDENT (sharded over `seq_axis`), tokens travel.

    For big-expert models (arctic: 26.8 GB of expert weights per layer) the
    gathered-experts path still moves the weights every layer; the cheaper
    direction is the classic EP all-to-all: each device top-k routes its
    local tokens, buckets them by destination rank (expert // e_per_rank),
    exchanges fixed-capacity buffers with one `lax.all_to_all`, runs its OWN
    experts on what arrives, and reverses the exchange. Per-layer traffic is
    O(local_tokens · k · d), independent of expert-weight size.

    x: (B, T, d), batch over `batch_axes`, T divisible by the seq_axis
    extent. Router replicated (gathered once — it is (d, e), tiny).
    """
    from jax.experimental.shard_map import shard_map

    B, T, d = x.shape
    tp = mesh.shape[seq_axis]
    e_pad = cfg.padded_experts(tp)
    e_real, k = cfg.n_experts, cfg.experts_top_k
    e_loc = e_pad // tp                      # experts resident per rank
    data_axes = tuple(a for a in batch_axes if a != seq_axis)
    x_spec = P(data_axes, seq_axis, None)

    w_specs = {
        "router": P("data", None),
        "w_gate": P(seq_axis, "data", None),
        "w_up": P(seq_axis, "data", None),
        "w_down": P(seq_axis, None, "data"),
    }
    if cfg.moe_dense_residual:
        w_specs["dense"] = {"w_gate": P("data", seq_axis),
                            "w_up": P("data", seq_axis),
                            "w_down": P(seq_axis, "data")}
    p_in = {kk: params[kk] for kk in w_specs if kk in params}

    tok_shards = 1
    for a in dict.fromkeys((*batch_axes, seq_axis)):
        tok_shards *= mesh.shape[a]
    local_tok = (B * T) // tok_shards
    # per-destination-rank send capacity and per-expert compute capacity
    cap_send = max(int(local_tok * k / tp * cfg.capacity_factor), 8)
    cap_exp = max(int(local_tok * k * tp / max(e_real, 1)
                      * cfg.capacity_factor), 8)

    @functools.partial(shard_map, mesh=mesh, in_specs=(w_specs, x_spec),
                       out_specs=x_spec, check_rep=False)
    def run(p, x_l):
        # weights: experts resident (dim 0 already sharded over seq_axis);
        # only their FSDP ('data') dim is gathered — e_loc × that slice
        def fsdp(w, dim):
            return jax.lax.all_gather(w, "data", axis=dim, tiled=True)
        w_gate = fsdp(p["w_gate"], 1)
        w_up = fsdp(p["w_up"], 1)
        w_down = fsdp(p["w_down"], 2)
        router = fsdp(p["router"], 0)

        Bl, Tl, _ = x_l.shape
        xt = x_l.reshape(Bl * Tl, d)
        n_tok = xt.shape[0]
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        logits = jnp.where(jnp.arange(e_pad) < e_real, logits, -jnp.inf)
        top_vals, top_idx = jax.lax.top_k(logits, k)          # (n_tok, k)
        gates = jax.nn.softmax(top_vals, axis=-1)

        # ---- bucket assignments by destination rank
        expert_id = top_idx.reshape(-1)                        # (n_tok*k,)
        dest = expert_id // e_loc                              # (n_tok*k,)
        token_id = jnp.repeat(jnp.arange(n_tok), k)
        gate_flat = gates.reshape(-1)
        order = jnp.argsort(dest)
        dest_s, tok_s, gate_s, exp_s = (dest[order], token_id[order],
                                        gate_flat[order], expert_id[order])
        counts = jnp.bincount(dest, length=tp)
        offsets = jnp.concatenate([jnp.zeros(1, counts.dtype),
                                   jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(n_tok * k) - offsets[dest_s]
        keep = pos < cap_send
        slot = jnp.where(keep, dest_s * cap_send + pos, tp * cap_send)

        send_x = jnp.zeros((tp * cap_send + 1, d), x_l.dtype)
        send_x = send_x.at[slot].set(xt[tok_s] * keep[:, None]
                                     .astype(x_l.dtype))[:-1]
        # metadata: local expert id (+1, 0 = invalid) rides along
        send_m = jnp.zeros((tp * cap_send + 1,), jnp.int32)
        send_m = send_m.at[slot].set(
            jnp.where(keep, exp_s % e_loc + 1, 0))[:-1]

        # ---- exchange: (tp, cap, ...) -> rows now indexed by SOURCE rank
        recv_x = jax.lax.all_to_all(send_x.reshape(tp, cap_send, d),
                                    seq_axis, 0, 0, tiled=False)
        recv_m = jax.lax.all_to_all(send_m.reshape(tp, cap_send),
                                    seq_axis, 0, 0, tiled=False)
        rx = recv_x.reshape(tp * cap_send, d)
        rm = recv_m.reshape(tp * cap_send)

        # ---- local dispatch into my experts' capacity buffers
        valid = rm > 0
        my_exp = jnp.where(valid, rm - 1, e_loc)               # e_loc = drop
        order2 = jnp.argsort(my_exp)
        exp2, src2 = my_exp[order2], jnp.arange(tp * cap_send)[order2]
        counts2 = jnp.bincount(my_exp, length=e_loc + 1)
        off2 = jnp.concatenate([jnp.zeros(1, counts2.dtype),
                                jnp.cumsum(counts2)[:-1]])
        pos2 = jnp.arange(tp * cap_send) - off2[exp2]
        keep2 = (pos2 < cap_exp) & (exp2 < e_loc)
        slot2 = jnp.where(keep2, exp2 * cap_exp + pos2, e_loc * cap_exp)

        buf = jnp.zeros((e_loc * cap_exp + 1, d), x_l.dtype)
        buf = buf.at[slot2].set(rx[src2] * keep2[:, None]
                                .astype(x_l.dtype))[:-1]
        buf = buf.reshape(e_loc, cap_exp, d)

        g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        u = jnp.einsum("ecd,edf->ecf", buf, w_up)
        out_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down)

        # ---- un-dispatch to recv slots, reverse exchange, combine
        flat = out_buf.reshape(e_loc * cap_exp, d)
        back = jnp.zeros((tp * cap_send, d), x_l.dtype)
        back = back.at[src2].set(
            flat[jnp.clip(slot2, 0, e_loc * cap_exp - 1)]
            * keep2[:, None].astype(x_l.dtype))
        ret = jax.lax.all_to_all(back.reshape(tp, cap_send, d),
                                 seq_axis, 0, 0, tiled=False)
        ret = ret.reshape(tp * cap_send, d)

        contrib = ret[jnp.clip(slot, 0, tp * cap_send - 1)]
        contrib = contrib * (gate_s * keep)[:, None].astype(x_l.dtype)
        y = jnp.zeros((n_tok, d), x_l.dtype).at[tok_s].add(contrib)
        y = y.reshape(Bl, Tl, d)
        if cfg.moe_dense_residual:
            dp = p["dense"]
            wg = jax.lax.all_gather(jax.lax.all_gather(
                dp["w_gate"], "data", axis=0, tiled=True),
                seq_axis, axis=1, tiled=True)
            wu = jax.lax.all_gather(jax.lax.all_gather(
                dp["w_up"], "data", axis=0, tiled=True),
                seq_axis, axis=1, tiled=True)
            wd = jax.lax.all_gather(jax.lax.all_gather(
                dp["w_down"], seq_axis, axis=0, tiled=True),
                "data", axis=1, tiled=True)
            from .layers import swiglu
            y = y + swiglu(wg, wu, wd, x_l)
        return y

    return run(p_in, x)
