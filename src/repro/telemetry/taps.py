"""Accelerator-resident telemetry taps (the in-scan half of the subsystem).

Everything in this module runs INSIDE the jitted iteration scan, so it must
be (a) a pytree the scan can carry, (b) O(small) per iteration, and (c) free
of host sync. The host-side collector (collector.py) drains the state
between segments — the tap/collector split mirrors the engine's own
device/host split: per-iteration work stays resident, per-segment analysis
(R̂, spike detection, JSONL) runs on host where branching is free.

:class:`TraceState` is carried NEXT TO the sampler's ``ChainState`` (leaves
stacked over chains, like every ChainState leaf), never inside it — the
sampler's checkpoint layout is unchanged, and pre-telemetry snapshots
restore through the checkpointer's ``allow_missing`` backfill exactly like
the pre-bitmask 9-leaf snapshots did (the trace leaves are appended AFTER
the 13 ChainState leaves in the checkpoint tuple).

Per-iteration cost (why the ≤ 5% overhead gate holds): one (C, W) histogram
scatter-add every iteration, plus — only on tap iterations, every
``trace_every``-th — two (C,) ring writes and one (C, n, n) adjacency
accumulation whose parent sets are unranked ARITHMETICALLY on device
(:func:`adjacency_bits_from_ranks`, paper Algorithm 2 as fixed-depth jax
ops). Nothing is gathered from the (n, S) table and nothing crosses ICI:
every tapped quantity (score, accepts, cur_idx, win_idx) is already
per-chain and replicated after the engine's own pmax/pmin reduction, so on
the sharded path the taps add ZERO collective traffic over the
``model``/chain axes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.combinatorics import binom_table, size_offsets
from ..core.mcmc import ChainState, exchange_step

__all__ = ["TraceState", "init_trace", "make_tap", "exchange_step_traced",
           "unrank_parent_sets_jax", "adjacency_bits_from_ranks", "drain",
           "DEFAULT_TRACE_CAP"]

# ring capacity: enough taps for a stable split-R̂ (128 half-length 64 per
# split half) while keeping the trace leaves tiny (C · 128 · 8 bytes)
DEFAULT_TRACE_CAP = 128


class TraceState(NamedTuple):
    """Per-chain telemetry accumulators, one scan-carried pytree.

    scores/accepts are RING buffers written every ``trace_every`` iterations
    at slot ``taps % cap`` (strided + bounded: a long run overwrites the
    oldest taps, so R̂ always sees the most recent window — old history is
    exactly what a convergence check must forget). edge_counts accumulates
    the thinned per-order argmax adjacency (the graph the max-scorer walk
    reports), the posterior edge-count accumulator behind
    ``core.metrics.edge_posterior`` and the cross-chain edge-R̂."""
    scores: jax.Array       # (C, cap) f32 — ring of tapped chain scores
    accepts: jax.Array      # (C, cap) i32 — cumulative accept count at tap
    taps: jax.Array         # i32 — total taps written (ring head = taps % cap)
    win_hist: jax.Array     # (C, W) i32 — iterations spent per window index
    edge_counts: jax.Array  # (C, n, n) i32 — adj[parent, child] sample counts
    edge_taps: jax.Array    # i32 — thinned adjacency samples accumulated
    reseeds: jax.Array      # (C,) i32 — times slot was re-seeded by exchange


def init_trace(n_chains: int, n: int, n_windows: int = 1,
               cap: int = DEFAULT_TRACE_CAP) -> TraceState:
    return TraceState(
        scores=jnp.zeros((n_chains, cap), jnp.float32),
        accepts=jnp.zeros((n_chains, cap), jnp.int32),
        taps=jnp.int32(0),
        win_hist=jnp.zeros((n_chains, max(n_windows, 1)), jnp.int32),
        edge_counts=jnp.zeros((n_chains, n, n), jnp.int32),
        edge_taps=jnp.int32(0),
        reseeds=jnp.zeros((n_chains,), jnp.int32),
    )


def unrank_parent_sets_jax(ranks: jax.Array, off: jax.Array, B: jax.Array,
                           s: int) -> jax.Array:
    """(n,) global PST ranks -> (n, s) sorted candidate indices, -1 padded.

    The jax twin of core.combinatorics.unrank_parent_set (paper Algorithm 2):
    locate the size-k block from the offsets, then pick each element with the
    hockey-stick prefix sum g(t) = C(n_rest, r) − C(n_rest − t, r) — the
    first t with g(t) > l is the paper's inner while loop collapsed into one
    vectorized compare+argmax, so the whole decode is s fixed-depth steps of
    O(m) table lookups: jit/vmap-safe, no host round-trip, exact in int32
    for every S < 2^31 (n = 100, s = 4 is S ≈ 3.9M).

    off: (s+2,) int32 size_offsets; B: (m+1, s+2) int32 binom_table over the
    m = n−1 candidates.
    """
    m = B.shape[0] - 1
    t_vec = jnp.arange(1, m + 1, dtype=jnp.int32)

    def one(rank):
        rank = rank.astype(jnp.int32)
        k = jnp.searchsorted(off, rank, side="right").astype(jnp.int32) - 1
        l0 = rank - off[k]

        def body(pos, carry):
            low, l, out = carry
            active = pos < k
            r = jnp.clip(k - pos, 0, B.shape[1] - 1)
            n_rest = m - (low + 1)
            top = B[jnp.clip(n_rest, 0, m), r]
            g = top - B[jnp.clip(n_rest - t_vec, 0, m), r]       # g(t), t>=1
            t = jnp.int32(1) + jnp.argmax(g > l).astype(jnp.int32)
            elem = low + t
            l_new = l - (top - B[jnp.clip(n_rest - (t - 1), 0, m), r])
            out = out.at[pos].set(jnp.where(active, elem, -1))
            return (jnp.where(active, elem, low),
                    jnp.where(active, l_new, l), out)

        init = (jnp.int32(-1), l0, jnp.full((s,), -1, jnp.int32))
        _, _, out = jax.lax.fori_loop(0, s, body, init)
        return out

    return jax.vmap(one)(ranks)


def adjacency_bits_from_ranks(ranks: jax.Array, off: jax.Array, B: jax.Array,
                              s: int) -> jax.Array:
    """(n,) per-node winning PST ranks -> (n, n) int32 adjacency
    adj[parent, child] — core.graph.adjacency_from_ranks as pure jax ops
    (bit-identical; pinned by tests/test_telemetry.py)."""
    n = ranks.shape[0]
    cands = unrank_parent_sets_jax(ranks, off, B, s)              # (n, s)
    child = jnp.arange(n, dtype=jnp.int32)[:, None]
    parents = jnp.where(cands >= 0, cands + (cands >= child), -1)  # node ids
    onehot = (parents[:, :, None] == jnp.arange(n, dtype=jnp.int32)) \
        & (parents[:, :, None] >= 0)                               # (n, s, n)
    return onehot.any(axis=1).T.astype(jnp.int32)    # (parent, child)


def make_tap(n: int, s: int, trace_every: int):
    """Build the in-scan tap closure: (trace, states, it) -> trace.

    ``it`` is the GLOBAL 1-based iteration index (start + i + 1 inside a
    segment scan), so the tap cadence survives segment and checkpoint-restart
    boundaries exactly like the exchange cadence does. The unranking tables
    (off, binom) are baked into the closure as constants — a few KB,
    replicated everywhere."""
    off = jnp.asarray(size_offsets(n - 1, s), jnp.int32)
    B = jnp.asarray(binom_table(n - 1, s + 1), jnp.int32)
    every = max(int(trace_every), 1)

    def tap(trace: TraceState, states: ChainState, it) -> TraceState:
        C = trace.win_hist.shape[0]
        wi = jnp.clip(states.win_idx, 0, trace.win_hist.shape[1] - 1)
        trace = trace._replace(
            win_hist=trace.win_hist.at[jnp.arange(C), wi].add(1))

        def do_tap(tr: TraceState) -> TraceState:
            slot = tr.taps % tr.scores.shape[1]
            adj = jax.vmap(
                lambda r: adjacency_bits_from_ranks(r, off, B, s))(
                    states.cur_idx)
            # graceful degradation: a poisoned chain (non-finite cached
            # score) keeps tapping its ring — diagnostics must SEE the NaN
            # to flag it — but contributes nothing to the posterior edge
            # accumulator until the supervisor heals it
            ok = jnp.isfinite(states.score).astype(adj.dtype)
            return tr._replace(
                scores=tr.scores.at[:, slot].set(states.score),
                accepts=tr.accepts.at[:, slot].set(states.accepts),
                taps=tr.taps + 1,
                edge_counts=tr.edge_counts + adj * ok[:, None, None],
                edge_taps=tr.edge_taps + 1,
            )

        return jax.lax.cond(it % every == 0, do_tap, lambda tr: tr, trace)

    return tap


def exchange_step_traced(states: ChainState,
                         trace: TraceState) -> tuple[ChainState, TraceState]:
    """core.mcmc.exchange_step + a re-seed count on the recipient slot (the
    degenerate all-equal ranking is a no-op there and counts nothing here).
    Mirrors exchange_step's NaN/inf-safe masked rank so the counted
    recipient slot matches the slot the exchange actually re-seeds."""
    rank = jnp.where(jnp.isfinite(states.best_score), states.best_score,
                     -jnp.inf)
    b = jnp.argmax(rank)
    w = jnp.argmin(rank)
    trace = trace._replace(
        reseeds=trace.reseeds.at[w].add((b != w).astype(jnp.int32)))
    return exchange_step(states), trace


def drain(trace: TraceState) -> dict:
    """Host-side snapshot: fetch every leaf as numpy, and linearise the
    score/accept rings oldest-first (valid entries only) so the collector
    sees plain (C, L) time series."""
    tr = jax.tree.map(np.asarray, trace)
    cap = tr.scores.shape[1]
    T = int(tr.taps)
    L = min(T, cap)
    idx = (np.arange(T - L, T) % cap) if L else np.empty(0, np.int64)
    return {
        "scores": tr.scores[:, idx],
        "accepts": tr.accepts[:, idx],
        "taps": T,
        "win_hist": tr.win_hist,
        "edge_counts": tr.edge_counts,
        "edge_taps": int(tr.edge_taps),
        "reseeds": tr.reseeds,
    }
