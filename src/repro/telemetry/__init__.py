"""Convergence telemetry: accelerator-resident taps + host-side collector.

Long MCMC runs used to terminate on a fixed iteration count with no
visibility into whether the posterior had mixed (the paper's §V convergence
caveat). This package splits observability the same way the engine splits
work:

* **In-scan taps** (:mod:`taps`, device): a :class:`~taps.TraceState` pytree
  carried beside ``ChainState`` through every run loop — downsampled
  per-chain score/accept ring buffers, a per-iteration window histogram, a
  thinned posterior edge-count accumulator (parent sets unranked
  arithmetically on device), and per-slot exchange re-seed counts. O(small)
  per iteration, no host sync, no extra collectives on the sharded path.
* **Host collector** (:mod:`collector`): drains the taps between jitted
  segments, computes split-R̂ on score traces and max-R̂ over cross-chain
  edge marginals (:mod:`rhat`, the Kuipers–Moffa concordance criterion),
  flags stuck/diverged chains with rolling-median/MAD spike detection, and
  appends schema-versioned JSONL rows (:mod:`schema`) under
  ``experiments/runs/``.

The R̂ stopping rule (``bn_learn --stop-on-converge``): both R̂ statistics
below ``--rhat-threshold`` for ``--patience`` consecutive checks stops the
run early — convergence, not the iteration cap, decides run length.
``python -m repro.telemetry.validate`` re-validates emitted trace files
(CI runs it after an end-to-end telemetry smoke).
"""
from .collector import Collector, host_meta
from .rhat import edge_rhat, median_outliers, split_rhat
from .schema import SCHEMA, read_rows, validate_row, write_rows
from .taps import (DEFAULT_TRACE_CAP, TraceState, adjacency_bits_from_ranks,
                   drain, exchange_step_traced, init_trace, make_tap,
                   unrank_parent_sets_jax)

__all__ = [
    "Collector", "host_meta", "edge_rhat", "median_outliers", "split_rhat",
    "SCHEMA", "read_rows", "validate_row", "write_rows", "DEFAULT_TRACE_CAP",
    "TraceState", "adjacency_bits_from_ranks", "drain",
    "exchange_step_traced", "init_trace", "make_tap",
    "unrank_parent_sets_jax",
]
