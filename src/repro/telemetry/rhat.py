"""Cross-chain convergence diagnostics (host side).

Two R̂ statistics drive the ``--stop-on-converge`` rule:

* :func:`split_rhat` — the Gelman–Rubin potential scale reduction factor on
  the per-chain SCORE traces, with each chain split in half (Vehtari et al.
  2021's split-R̂: halving catches within-chain drift that whole-chain R̂
  hides). Scores are the one scalar the sampler already computes every
  iteration, so this costs nothing on device.
* :func:`edge_rhat` — max-R̂ over POSTERIOR EDGE MARGINALS: per-chain edge
  frequencies from the thinned adjacency accumulator, compared across
  chains. This is the Kuipers & Moffa (1803.07859) criterion — judge the
  sampler by concordance of edge posteriors across independent chains, not
  by score alone: two chains can sit at the same score in different basins,
  which score-R̂ misses and edge-R̂ catches.

Both return inf for frozen-apart chains (zero within-variance, nonzero
between-variance) and 1.0 for bit-identical chains; the stopping rule only
fires when BOTH drop below the threshold for ``patience`` consecutive
checks.

Rolling-median spike detection (:func:`median_outliers`) follows the
HomebrewNLP WandbLog pattern: compare each value against the median of its
peer set and flag deviations beyond a MAD multiple — robust to the one
stuck/diverged chain it is trying to find.
"""
from __future__ import annotations

import numpy as np

__all__ = ["split_rhat", "edge_rhat", "median_outliers"]

_EPS = 1e-12


def _psrf(means: np.ndarray, wvars: np.ndarray, length: float) -> float:
    """Potential scale reduction factor from per-chain (mean, within-var)
    summaries of `length` draws each. Degenerate cases: no spread anywhere
    -> 1.0 (converged and frozen together); between-spread with ZERO
    within-variance -> inf (frozen apart — never report converged)."""
    w = float(np.mean(wvars))
    b = float(np.var(means, ddof=1)) * length    # between-chain variance * L
    if b <= _EPS and w <= _EPS:
        return 1.0
    if w <= _EPS:
        return float("inf")
    var_plus = (length - 1.0) / length * w + b / length
    return float(np.sqrt(var_plus / w))


def split_rhat(traces: np.ndarray) -> float:
    """Split-R̂ over (C, L) per-chain scalar traces.

    Each chain is halved -> 2C sequences of length L//2; R̂ is the PSRF over
    those. Returns nan when there is too little data (L < 4) and inf when
    chains are frozen at different values.
    """
    traces = np.asarray(traces, dtype=np.float64)
    if traces.ndim != 2:
        raise ValueError(f"traces must be (chains, length), got {traces.shape}")
    C, L = traces.shape
    half = L // 2
    if half < 2:
        return float("nan")
    halves = np.concatenate([traces[:, :half], traces[:, L - half:]], axis=0)
    return _psrf(halves.mean(axis=1), halves.var(axis=1, ddof=1), float(half))


def edge_rhat(edge_counts: np.ndarray, n_samples: int,
              min_disagreement: float = 0.0) -> tuple[float, np.ndarray]:
    """(max R̂, per-edge R̂ matrix) over per-chain edge marginals.

    edge_counts: (C, n, n) — per-chain counts of edge (parent, child) over
    ``n_samples`` thinned samples. Within-chain variance of an edge
    indicator stream with frequency p is the Bernoulli sample variance
    p(1-p)·N/(N-1); the between term is the cross-chain variance of the
    per-chain frequencies — exactly the PSRF recipe with the indicator
    series summarised by its sufficient statistic, which is all the
    accumulator keeps (O(n²) per chain instead of O(n²·samples)).

    Unanimous-in-every-chain edges (all frequencies exactly 0 or exactly 1,
    and equal) have zero within- AND between-variance: R̂ = 1 by the
    degenerate rule — a hard edge every chain agrees on is converged.
    Chains unanimous at DIFFERENT values (one says always, another never)
    get R̂ = inf. ``min_disagreement`` optionally ignores edges whose
    cross-chain frequency range is below it (measurement noise floor).

    Returns (nan, empty) when n_samples < 2 or there is a single chain.
    """
    counts = np.asarray(edge_counts, dtype=np.float64)
    if counts.ndim != 3 or counts.shape[1] != counts.shape[2]:
        raise ValueError(f"edge_counts must be (C, n, n), got {counts.shape}")
    C, n, _ = counts.shape
    if C < 2 or n_samples < 2:
        return float("nan"), np.full((n, n), np.nan)
    N = float(n_samples)
    p = counts / N                                       # (C, n, n)
    off = ~np.eye(n, dtype=bool)
    w = (p * (1.0 - p) * N / (N - 1.0)).mean(axis=0)     # (n, n)
    b = p.var(axis=0, ddof=1) * N
    var_plus = (N - 1.0) / N * w + b / N
    with np.errstate(divide="ignore", invalid="ignore"):
        rhat = np.sqrt(var_plus / w)
    rhat = np.where((b <= _EPS) & (w <= _EPS), 1.0, rhat)
    rhat = np.where((w <= _EPS) & (b > _EPS), np.inf, rhat)
    spread = p.max(axis=0) - p.min(axis=0)
    rhat = np.where(off & (spread >= min_disagreement), rhat, 1.0)
    return float(rhat.max(initial=1.0)), rhat


def median_outliers(values: np.ndarray, threshold: float = 4.0,
                    floor: float = 0.0) -> np.ndarray:
    """Boolean mask of entries deviating > threshold MADs from the median.

    The WandbLog-style robust spike detector, applied across the CHAIN axis:
    the median/MAD of the healthy majority defines normal, so one stuck or
    diverged chain cannot drag the baseline toward itself (a mean/std
    detector would). ``floor`` bounds the MAD from below so a near-constant
    healthy population doesn't flag harmless jitter."""
    values = np.asarray(values, dtype=np.float64)
    if values.size < 3:                       # no robust majority to speak of
        return np.zeros(values.shape, dtype=bool)
    med = np.median(values)
    mad = np.median(np.abs(values - med))
    scale = max(1.4826 * mad, floor, _EPS)    # 1.4826: MAD -> sigma, normal
    return np.abs(values - med) > threshold * scale
