"""Versioned JSONL trace schema for convergence telemetry.

Every run with ``--telemetry`` appends one JSON object per line to a trace
file under ``--trace-dir`` (default ``experiments/runs/``). The file is a
TRAJECTORY, like the repo-root ``BENCH_*.json`` files: rows are append-only,
self-describing (every row carries ``schema`` + ``kind``), and validated
both at write time (the collector refuses to emit a malformed row) and in CI
(``python -m repro.telemetry.validate <file...>`` re-validates the emitted
file after an end-to-end ``bn_learn --telemetry`` run).

Row kinds
---------

* ``meta``    — one per run, first row: run id, config echo, host metadata.
* ``stage``   — one per timed pipeline stage (preprocess plan/score/assemble,
  MCMC compile, ...): {stage, seconds}.
* ``segment`` — one per collector check (every ``--check-every`` iterations):
  per-chain score/accept stats, split-R̂ on the score traces, max-R̂ over
  edge marginals, stuck/diverged chain flags, convergence-vote state.
* ``heal``    — one per chain-healing event under ``bn_learn --supervise``:
  the run supervisor re-seeded {chain} as a clone of {donor} at global
  iteration {iter} because of {reason} (nonfinite / stalled / stuck /
  diverged / lagging).
* ``final``   — one per run, last row: outcome summary (stopped_early,
  iters_run, final R̂s, best score).

Schema evolution: bump :data:`SCHEMA` when a required field changes meaning
or disappears; ADDING optional fields is allowed within a version (readers
must ignore unknown keys — the same contract as the bench trajectories).
"""
from __future__ import annotations

import json
import os

__all__ = ["SCHEMA", "REQUIRED", "validate_row", "write_rows", "read_rows"]

SCHEMA = "bn-telemetry/v1"

# required fields (and their types) per row kind; every row additionally
# needs schema == SCHEMA and a known kind
_NUM = (int, float)
REQUIRED: dict[str, dict[str, type | tuple]] = {
    "meta": {"run": str, "config": dict, "host": dict},
    "stage": {"run": str, "stage": str, "seconds": _NUM},
    "segment": {"run": str, "iter": int, "taps": int,
                "score_mean": _NUM, "score_rhat": _NUM,
                "edge_rhat": _NUM, "accept_rates": list,
                "stuck_chains": list, "diverged_chains": list,
                "converge_hits": int, "converged": bool},
    "heal": {"run": str, "iter": int, "chain": int, "donor": int,
             "reason": str},
    "final": {"run": str, "iters_run": int, "stopped_early": bool,
              "score_rhat": _NUM, "edge_rhat": _NUM},
}


def validate_row(row) -> None:
    """Raise ValueError unless ``row`` is a valid row of the CURRENT schema.

    NaN/inf are valid numeric values (R̂ is inf for frozen disjoint chains,
    nan before enough taps exist) — the JSON writer emits them as
    ``NaN``/``Infinity`` (Python's json dialect), and :func:`read_rows`
    parses them back.
    """
    if not isinstance(row, dict):
        raise ValueError(f"telemetry row must be a dict, got {type(row)}")
    if row.get("schema") != SCHEMA:
        raise ValueError(f"row schema {row.get('schema')!r} != {SCHEMA!r}")
    kind = row.get("kind")
    if kind not in REQUIRED:
        raise ValueError(f"unknown row kind {kind!r} "
                         f"(expected one of {sorted(REQUIRED)})")
    for field, typ in REQUIRED[kind].items():
        if field not in row:
            raise ValueError(f"{kind} row missing required field {field!r}")
        if not isinstance(row[field], typ):
            raise ValueError(
                f"{kind} row field {field!r} has type "
                f"{type(row[field]).__name__}, expected {typ}")


def write_rows(path: str, rows: list[dict]) -> None:
    """Validate and append rows to a JSONL trace file (creates parents)."""
    for row in rows:
        validate_row(row)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "a") as f:
        for row in rows:
            f.write(json.dumps(row, default=float) + "\n")


def read_rows(path: str) -> list[dict]:
    """Parse a JSONL trace file (no validation — pair with validate_row)."""
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows
