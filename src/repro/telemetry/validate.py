"""Validate emitted JSONL trace files against the current schema.

CLI (used by the CI telemetry smoke step after an end-to-end
``bn_learn --telemetry`` run):

    python -m repro.telemetry.validate experiments/runs/run_*.jsonl

Exits non-zero on the first malformed row; prints a per-file row-count
summary otherwise. Also enforces the file-level shape: the first row must
be ``meta``, at most one ``final`` row, and every row must belong to the
same run id.
"""
from __future__ import annotations

import sys

from .schema import read_rows, validate_row

__all__ = ["validate_file", "main"]


def validate_file(path: str) -> dict:
    """Validate one trace file; returns {kinds: {kind: count}, run}."""
    rows = read_rows(path)
    if not rows:
        raise ValueError(f"{path}: empty trace file")
    kinds: dict[str, int] = {}
    run = None
    for i, row in enumerate(rows):
        try:
            validate_row(row)
        except ValueError as e:
            raise ValueError(f"{path}:{i + 1}: {e}") from e
        kinds[row["kind"]] = kinds.get(row["kind"], 0) + 1
        if run is None:
            run = row.get("run")
        elif row.get("run") != run:
            raise ValueError(f"{path}:{i + 1}: run id {row.get('run')!r} "
                             f"differs from the file's {run!r}")
    if rows[0]["kind"] != "meta":
        raise ValueError(f"{path}: first row must be 'meta', "
                         f"got {rows[0]['kind']!r}")
    if kinds.get("final", 0) > 1:
        raise ValueError(f"{path}: {kinds['final']} 'final' rows (max 1)")
    return {"run": run, "kinds": kinds, "rows": len(rows)}


def main(argv=None) -> int:
    paths = list(argv if argv is not None else sys.argv[1:])
    if not paths:
        print("usage: python -m repro.telemetry.validate <trace.jsonl> ...",
              file=sys.stderr)
        return 2
    for path in paths:
        try:
            info = validate_file(path)
        except (OSError, ValueError) as e:
            print(f"INVALID: {e}", file=sys.stderr)
            return 1
        kinds = ", ".join(f"{k}={v}" for k, v in sorted(info["kinds"].items()))
        print(f"ok: {path} run={info['run']} rows={info['rows']} ({kinds})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
