"""Host-side telemetry collector: drains the in-scan taps between segments,
computes convergence diagnostics, emits schema-valid JSONL, and votes on
early stopping.

The collector is deliberately dumb about devices: it only ever sees the
numpy snapshot from :func:`taps.drain`, so it works identically for the
single-device, checkpointed and sharded run loops — the run loop decides
WHEN to check (every ``--check-every`` iterations, between jitted
segments), the collector decides WHAT it means.

Stopping rule (``--stop-on-converge``): a check PASSES when both split-R̂
on the per-chain score traces and max-R̂ over the cross-chain edge
marginals are below ``rhat_threshold`` (and enough taps exist for either to
be meaningful). ``patience`` consecutive passes are required before
``converged`` flips — one lucky segment is not mixing; R̂ dipping under the
bar and climbing back out resets the vote. Runs then stop on convergence,
not on the iteration cap (the cap stays as the upper bound).

Stuck/diverged flags reuse the WandbLog rolling-median idea across the
chain axis: a chain whose segment accept rate or score sits many MADs from
the chain-population median is flagged (stuck chains are also flagged
absolutely at ~zero acceptance). Flags are reports here — the in-scan
``exchange_step`` re-seeds the worst chain on its own cadence, and the
reseeds-per-slot counter makes that observable — but under ``bn_learn
--supervise`` the run supervisor (runtime/supervisor.py) ACTS on them:
flagged chains are healed via straggler cloning between segments, and each
action lands back in this trace as a ``heal`` row.
"""
from __future__ import annotations

import json
import os
import time
import uuid

import numpy as np

from .rhat import edge_rhat, median_outliers, split_rhat
from .schema import SCHEMA, validate_row, write_rows

__all__ = ["Collector", "host_meta"]

_STUCK_ACCEPT = 1e-3      # absolute floor: a chain accepting ~nothing is stuck


def host_meta() -> dict:
    """Machine identity recorded in the meta row (and, via
    benchmarks/common.py, in every bench row): enough to tell a 1-vCPU CI
    smoke from a multi-core gate box when reading trajectories later."""
    import jax
    devs = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "unknown",
        "n_devices": len(devs),
        "cpu_count": os.cpu_count() or 1,
    }


class Collector:
    """One instance per run; owns the trace file and the convergence vote."""

    def __init__(self, trace_dir: str, *, run_name: str = "",
                 rhat_threshold: float = 1.05, patience: int = 3,
                 trace_every: int = 8, min_taps: int = 16,
                 spike_mad: float = 4.0):
        self.run = run_name or time.strftime("run_%Y%m%d_%H%M%S_") \
            + uuid.uuid4().hex[:6]
        self.path = os.path.join(trace_dir, f"{self.run}.jsonl")
        self.rhat_threshold = float(rhat_threshold)
        self.patience = max(int(patience), 1)
        self.trace_every = max(int(trace_every), 1)
        self.min_taps = max(int(min_taps), 4)
        self.spike_mad = float(spike_mad)
        self.hits = 0
        self.last: dict = {}
        self._prev_accepts: np.ndarray | None = None
        self._prev_iter = 0

    # ------------------------------------------------------------- emission
    def _emit(self, row: dict) -> None:
        row = {"schema": SCHEMA, "ts": time.time(), **row}
        validate_row(row)
        write_rows(self.path, [row])

    def start(self, config: dict) -> None:
        # A run name OWNS its trace file: starting a run truncates any stale
        # trace from an earlier run that reused the name (e.g. a re-run CI
        # smoke, or a retried acceptance run). Without this the appended
        # second meta/final pair fails the single-run validation contract.
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        open(self.path, "w").close()
        self._emit({"kind": "meta", "run": self.run,
                    "config": _jsonable(config), "host": host_meta()})

    def stage(self, stage: str, seconds: float, **extra) -> None:
        """One timed pipeline stage (preprocess plan/score/assemble, compile,
        ...) — the run's flame graph, one row per stage."""
        self._emit({"kind": "stage", "run": self.run, "stage": stage,
                    "seconds": float(seconds), **_jsonable(extra)})

    # ---------------------------------------------------------------- check
    def check(self, snap: dict, it: int) -> dict:
        """Analyse one drained trace snapshot at global iteration ``it``.

        Returns the segment record (also appended to the JSONL trace), with
        ``converged`` reflecting the patience-gated vote."""
        scores = np.asarray(snap["scores"], np.float64)       # (C, L) ordered
        C, L = scores.shape

        # --- per-chain accept rate over THIS segment (cumulative diff)
        acc_now = (np.asarray(snap["accepts"][:, -1], np.float64)
                   if L else np.zeros(C))
        prev = (self._prev_accepts if self._prev_accepts is not None
                else np.zeros(C))
        d_iter = max(it - self._prev_iter, 1)
        seg_accept = (acc_now - prev) / d_iter
        self._prev_accepts, self._prev_iter = acc_now, it

        # --- diagnostics
        score_rhat = split_rhat(scores) if L >= 4 else float("nan")
        e_rhat, _ = edge_rhat(snap["edge_counts"], snap["edge_taps"])
        # score jump per chain over the segment window (for divergence flags)
        jumps = (scores[:, -1] - scores[:, 0]) if L >= 2 else np.zeros(C)

        stuck = median_outliers(seg_accept, self.spike_mad, floor=0.02) \
            & (seg_accept < np.median(seg_accept))
        stuck |= seg_accept < _STUCK_ACCEPT
        diverged = median_outliers(jumps, self.spike_mad,
                                   floor=max(np.abs(jumps).max(initial=0.0)
                                             * 0.05, 1e-6)) \
            & (jumps < np.median(jumps))

        # --- patience-gated convergence vote
        enough = snap["taps"] >= self.min_taps
        ok = (enough and np.isfinite(score_rhat)
              and score_rhat < self.rhat_threshold
              and (C < 2 or (np.isfinite(e_rhat)
                             and e_rhat < self.rhat_threshold)))
        self.hits = self.hits + 1 if ok else 0
        converged = self.hits >= self.patience

        rec = {
            "kind": "segment", "run": self.run, "iter": int(it),
            "taps": int(snap["taps"]),
            "score_mean": float(scores.mean()) if L else float("nan"),
            "score_last": [float(x) for x in (scores[:, -1] if L
                                              else np.zeros(C))],
            "score_rhat": float(score_rhat),
            "edge_rhat": float(e_rhat),
            "edge_samples": int(snap["edge_taps"]),
            "accept_rates": [float(x) for x in seg_accept],
            "win_hist": np.asarray(snap["win_hist"]).tolist(),
            "reseeds": np.asarray(snap["reseeds"]).tolist(),
            "stuck_chains": [int(i) for i in np.nonzero(stuck)[0]],
            "diverged_chains": [int(i) for i in np.nonzero(diverged)[0]],
            "converge_hits": int(self.hits),
            "converged": bool(converged),
        }
        self._emit(rec)
        self.last = rec
        return rec

    def heal(self, *, iter: int, chain: int, donor: int,
             reason: str) -> dict:
        """One chain-healing event from the run supervisor: ``chain`` was
        re-seeded as a clone of ``donor`` at global iteration ``iter``."""
        rec = {"kind": "heal", "run": self.run, "iter": int(iter),
               "chain": int(chain), "donor": int(donor),
               "reason": str(reason)}
        self._emit(rec)
        return rec

    def grow(self, extra: int) -> None:
        """The fleet gained ``extra`` chain slots mid-run (elastic cloning).
        Pad the cumulative-accept baseline with zeros so the new chains'
        first segment accept-rate diff is measured from zero, like any
        freshly started chain."""
        if extra > 0 and self._prev_accepts is not None:
            self._prev_accepts = np.concatenate(
                [self._prev_accepts, np.zeros(extra, np.float64)])

    # ------------------------------------------------------ resume support
    def state_dict(self) -> dict:
        """The collector's tiny vote state, persisted in checkpoint metadata
        by the run supervisor so a crash-resumed run casts bitwise-identical
        convergence votes to one that never died."""
        return {"hits": int(self.hits), "prev_iter": int(self._prev_iter),
                "prev_accepts": (None if self._prev_accepts is None
                                 else [float(x) for x in self._prev_accepts])}

    def load_state(self, state: dict) -> None:
        self.hits = int(state.get("hits", 0))
        self._prev_iter = int(state.get("prev_iter", 0))
        pa = state.get("prev_accepts")
        self._prev_accepts = None if pa is None else np.asarray(pa,
                                                                np.float64)

    def finalize(self, *, iters_run: int, stopped_early: bool,
                 **extra) -> dict:
        rec = {"kind": "final", "run": self.run, "iters_run": int(iters_run),
               "stopped_early": bool(stopped_early),
               "score_rhat": float(self.last.get("score_rhat", float("nan"))),
               "edge_rhat": float(self.last.get("edge_rhat", float("nan"))),
               **_jsonable(extra)}
        self._emit(rec)
        return rec


def _jsonable(obj):
    """Round-trip through json-compatible types (numpy scalars/arrays ->
    python), dropping anything that still refuses to serialise."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (np.floating, np.integer, np.bool_)):
        return obj.item()
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    try:
        json.dumps(obj)
        return obj
    except TypeError:
        return str(obj)
