"""AdamW with decoupled weight decay, sharded moments (same PartitionSpec as
the parameter), optional bf16 moment storage (halves optimizer HBM — the
memory-roofline lever for the 100B+ configs), and global-norm clipping.

Pure-pytree implementation (no optax dependency in this offline container).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "opt_state_specs"]


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32    # jnp.bfloat16 halves optimizer memory


class OptState(NamedTuple):
    mu: Any        # first moment (pytree like params)
    nu: Any        # second moment
    step: jnp.ndarray


def adamw_init(params, cfg: AdamWConfig) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return OptState(jax.tree.map(zeros, params),
                    jax.tree.map(zeros, params),
                    jnp.zeros((), jnp.int32))


def opt_state_specs(param_specs) -> OptState:
    """Moments shard exactly like their parameters (ZeRO-style)."""
    from jax.sharding import PartitionSpec as P
    return OptState(param_specs, param_specs, P())


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: OptState, params, cfg: AdamWConfig,
                 lr_scale: jnp.ndarray | float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/biases exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype)

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    p_new = treedef.unflatten([t[0] for t in flat])
    mu_new = treedef.unflatten([t[1] for t in flat])
    nu_new = treedef.unflatten([t[2] for t in flat])
    return p_new, OptState(mu_new, nu_new, step), {"grad_norm": gnorm}
