from .adamw import (AdamWConfig, OptState, adamw_init, adamw_update,
                    opt_state_specs)
from .grad_compress import CompressState, compress_grads, compress_init
from .schedule import warmup_cosine

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "opt_state_specs", "CompressState", "compress_grads",
           "compress_init", "warmup_cosine"]
