"""Gradient compression for cross-pod all-reduce: int8 block quantization with
error feedback (the residual is carried to the next step, preserving
convergence). Used on the `pod` axis where ICI bandwidth is scarcest.

compress -> (all-reduce int8 payload) -> decompress. In the single-program
SPMD setting the all-reduce is implicit (psum of the dequantized values under
shard_map, or the SPMD partitioner's reduction); what this module guarantees
is the 4× payload shrink and the error-feedback correctness, both unit-tested.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CompressState", "compress_init", "quantize", "dequantize",
           "compress_grads", "BLOCK"]

BLOCK = 256


class CompressState(NamedTuple):
    residual: Any     # error-feedback pytree (like grads)


def compress_init(grads_like) -> CompressState:
    return CompressState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def quantize(x: jnp.ndarray):
    """Per-block symmetric int8. Returns (q int8, scales f32)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_grads(grads, state: CompressState):
    """Error-feedback quantize/dequantize round trip.

    Returns (decompressed grads to feed the optimizer, new state). The int8
    payload (q, scale) is what crosses the wire — 4× smaller than f32.
    """
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize(x)
        dq = dequantize(q, s, g.shape)
        return dq, x - dq

    out = jax.tree.map(one, grads, state.residual)
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda t: isinstance(t, tuple))
    deq = treedef.unflatten([t[0] for t in flat])
    res = treedef.unflatten([t[1] for t in flat])
    return deq, CompressState(res)
