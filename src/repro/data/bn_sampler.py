"""Synthetic experimental data from a ground-truth Bayesian network.

Ancestral (forward) sampling from Dirichlet CPTs — the paper assumes complete
multinomial data (§II). Noise injection (paper §VI, Fig. 11): each entry flips
state with probability p (for q=2 a bit flip; for q>2 a uniform re-draw among
the other states).
"""
from __future__ import annotations

import numpy as np

from ..core.graph import parents_list_from_adjacency, topological_order

__all__ = ["ancestral_sample", "inject_noise"]


def ancestral_sample(rng: np.random.Generator, adj: np.ndarray,
                     cpts: list[np.ndarray], m: int, q: int) -> np.ndarray:
    """m samples (m, n) int32 from the network (adj[m, i] = 1 ⇔ m → i)."""
    n = adj.shape[0]
    order = topological_order(adj)
    parents = parents_list_from_adjacency(adj)
    data = np.zeros((m, n), dtype=np.int32)
    for i in order:
        ps = parents[i]
        if len(ps) == 0:
            probs = np.broadcast_to(cpts[i][0], (m, q))
        else:
            code = np.zeros(m, dtype=np.int64)
            for j, p in enumerate(ps):
                code += data[:, p].astype(np.int64) * q ** j
            probs = cpts[i][code]
        u = rng.random((m, 1))
        data[:, i] = (probs.cumsum(axis=1) < u).sum(axis=1).clip(0, q - 1)
    return data


def inject_noise(rng: np.random.Generator, data: np.ndarray, p: float,
                 q: int) -> np.ndarray:
    """Flip each entry with probability p (paper §VI fault-injection study)."""
    flip = rng.random(data.shape) < p
    if q == 2:
        return np.where(flip, 1 - data, data).astype(data.dtype)
    shift = rng.integers(1, q, size=data.shape)
    return np.where(flip, (data + shift) % q, data).astype(data.dtype)
