"""LM data pipeline: deterministic, stateless-resumable synthetic token
stream (step-indexed PRNG — a restarted worker regenerates exactly its shard
of any step, which is what makes checkpoint/restart and elastic re-sharding
deterministic), with host-side prefetch and per-device placement.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["synthetic_batch", "batch_iterator", "Prefetcher"]


def synthetic_batch(step: int, *, global_batch: int, seq_len: int, vocab: int,
                    seed: int = 0, enc_feats_shape=None) -> dict:
    """Batch for `step`, independent of worker count (step-indexed PRNG)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # Zipfian-ish marginals so the loss surface is non-degenerate
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(vocab, size=(global_batch, seq_len + 1), p=probs)
    batch = {"tokens": toks[:, :-1].astype(np.int32),
             "labels": toks[:, 1:].astype(np.int32)}
    if enc_feats_shape is not None:
        batch["enc_feats"] = rng.standard_normal(
            enc_feats_shape, dtype=np.float32)
    return batch


def batch_iterator(start_step: int, **kw) -> Iterator[dict]:
    step = start_step
    while True:
        yield synthetic_batch(step, **kw)
        step += 1


class Prefetcher:
    """Host-side double-buffering: overlaps batch synthesis/placement with the
    device step (the CPU analogue of an input pipeline's prefetch-to-device)."""

    def __init__(self, it: Iterator[dict], depth: int = 2, shardings=None):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._shardings = shardings
        self._done = False
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        try:
            for batch in self._it:
                if self._shardings is not None:
                    batch = {k: jax.device_put(v, self._shardings.get(k))
                             for k, v in batch.items()}
                self._q.put(batch)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item
