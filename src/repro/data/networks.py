"""Reference networks used in the paper's experiments (§VI):

* STN — the 11-node signaling transduction network from human T-cells
  (Sachs et al., Science 2005; paper ref [10]); consensus edge set.
* ALARM — the 37-node monitoring network (paper ref [17]); standard 46 edges.
* synthetic — random sparse DAGs at arbitrary n for the paper's n > 60 scale
  claim (§VI uses networks the benchmark suite ships; past ALARM size we
  generate ALARM-like ground truth instead).
"""
from __future__ import annotations

import numpy as np

from ..core.graph import random_dag

STN_NODES = ["Raf", "Mek", "Plcg", "PIP2", "PIP3", "Erk", "Akt", "PKA",
             "PKC", "P38", "Jnk"]

STN_EDGES = [
    ("Erk", "Akt"), ("Mek", "Erk"), ("PIP3", "PIP2"), ("PKA", "Akt"),
    ("PKA", "Erk"), ("PKA", "Jnk"), ("PKA", "Mek"), ("PKA", "P38"),
    ("PKA", "Raf"), ("PKC", "Jnk"), ("PKC", "Mek"), ("PKC", "P38"),
    ("PKC", "PKA"), ("PKC", "Raf"), ("Plcg", "PIP2"), ("Plcg", "PIP3"),
    ("Raf", "Mek"),
]

ALARM_NODES = [
    "HISTORY", "CVP", "PCWP", "HYPOVOLEMIA", "LVEDVOLUME", "LVFAILURE",
    "STROKEVOLUME", "ERRLOWOUTPUT", "HRBP", "HREKG", "ERRCAUTER", "HRSAT",
    "INSUFFANESTH", "ANAPHYLAXIS", "TPR", "EXPCO2", "KINKEDTUBE", "MINVOL",
    "FIO2", "PVSAT", "SAO2", "PAP", "PULMEMBOLUS", "SHUNT", "INTUBATION",
    "PRESS", "DISCONNECT", "MINVOLSET", "VENTMACH", "VENTTUBE", "VENTLUNG",
    "VENTALV", "ARTCO2", "CATECHOL", "HR", "CO", "BP",
]

ALARM_EDGES = [
    ("LVFAILURE", "HISTORY"), ("LVEDVOLUME", "CVP"), ("LVEDVOLUME", "PCWP"),
    ("HYPOVOLEMIA", "LVEDVOLUME"), ("LVFAILURE", "LVEDVOLUME"),
    ("HYPOVOLEMIA", "STROKEVOLUME"), ("LVFAILURE", "STROKEVOLUME"),
    ("ERRLOWOUTPUT", "HRBP"), ("HR", "HRBP"), ("ERRCAUTER", "HREKG"),
    ("HR", "HREKG"), ("ERRCAUTER", "HRSAT"), ("HR", "HRSAT"),
    ("ANAPHYLAXIS", "TPR"), ("ARTCO2", "EXPCO2"), ("VENTLUNG", "EXPCO2"),
    ("INTUBATION", "MINVOL"), ("VENTLUNG", "MINVOL"), ("FIO2", "PVSAT"),
    ("VENTALV", "PVSAT"), ("PVSAT", "SAO2"), ("SHUNT", "SAO2"),
    ("PULMEMBOLUS", "PAP"), ("INTUBATION", "SHUNT"), ("PULMEMBOLUS", "SHUNT"),
    ("INTUBATION", "PRESS"), ("KINKEDTUBE", "PRESS"), ("VENTTUBE", "PRESS"),
    ("MINVOLSET", "VENTMACH"), ("DISCONNECT", "VENTTUBE"),
    ("VENTMACH", "VENTTUBE"), ("INTUBATION", "VENTLUNG"),
    ("KINKEDTUBE", "VENTLUNG"), ("VENTTUBE", "VENTLUNG"),
    ("INTUBATION", "VENTALV"), ("VENTLUNG", "VENTALV"),
    ("VENTALV", "ARTCO2"), ("ARTCO2", "CATECHOL"), ("INSUFFANESTH", "CATECHOL"),
    ("SAO2", "CATECHOL"), ("TPR", "CATECHOL"), ("CATECHOL", "HR"),
    ("HR", "CO"), ("STROKEVOLUME", "CO"), ("CO", "BP"), ("TPR", "BP"),
]


def _adjacency(nodes: list[str], edges: list[tuple[str, str]]) -> np.ndarray:
    idx = {v: i for i, v in enumerate(nodes)}
    adj = np.zeros((len(nodes), len(nodes)), dtype=np.int8)
    for a, b in edges:
        adj[idx[a], idx[b]] = 1
    return adj


def stn_adjacency() -> np.ndarray:
    return _adjacency(STN_NODES, STN_EDGES)


def alarm_adjacency() -> np.ndarray:
    return _adjacency(ALARM_NODES, ALARM_EDGES)


def synthetic_adjacency(rng: np.random.Generator, n: int = 64, *,
                        max_parents: int = 3,
                        edge_prob: float = 0.45) -> np.ndarray:
    """ALARM-like synthetic ground truth at scale n (~1.2 parents/node at the
    defaults — the n = 64 scale-benchmark network of bn_learn/preprocess)."""
    return random_dag(rng, n, max_parents, edge_prob)
