"""Discretization of continuous observations (paper §II: gene-expression
data is discretized to {under, normal, over} before learning; the paper cites
MDL [7] and CAIM/CACC/Ameva [8]).

Unsupervised methods here (the BN learner has no class variable):

* quantile  — equal-frequency bins (robust default for expression data);
* width     — equal-width bins;
* mdl_merge — bottom-up pairwise bin merging that stops when merging would
  cost more description length than it saves (an unsupervised MDL variant of
  Fayyad–Irani: model cost log2(bins) per sample vs data cost of the merged
  histogram).
"""
from __future__ import annotations

import numpy as np

__all__ = ["discretize", "quantile_bins", "width_bins", "mdl_merge_bins"]


def quantile_bins(col: np.ndarray, q: int) -> np.ndarray:
    edges = np.quantile(col, np.linspace(0, 1, q + 1)[1:-1])
    return np.searchsorted(edges, col, side="right").astype(np.int32)


def width_bins(col: np.ndarray, q: int) -> np.ndarray:
    lo, hi = float(col.min()), float(col.max())
    if hi <= lo:
        return np.zeros(col.shape, np.int32)
    edges = np.linspace(lo, hi, q + 1)[1:-1]
    return np.searchsorted(edges, col, side="right").astype(np.int32)


def _entropy(counts: np.ndarray) -> float:
    p = counts[counts > 0] / counts.sum()
    return float(-(p * np.log2(p)).sum())


def mdl_merge_bins(col: np.ndarray, q: int, start_bins: int = 16) -> np.ndarray:
    """Start from `start_bins` quantile bins, greedily merge the adjacent
    pair whose merge reduces total description length (data bits at the
    histogram entropy + log2(bins) model bits per cut), never below q bins."""
    m = len(col)
    codes = quantile_bins(col, start_bins)
    counts = np.bincount(codes, minlength=start_bins).astype(np.float64)
    counts = counts[counts > 0]          # collapse empty bins
    while len(counts) > q:
        base = m * _entropy(counts) + np.log2(max(len(counts), 2)) * m / 64
        best, best_cost = None, base
        for j in range(len(counts) - 1):
            merged = np.concatenate([counts[:j], [counts[j] + counts[j + 1]],
                                     counts[j + 2:]])
            cost = m * _entropy(merged) + np.log2(max(len(merged), 2)) * m / 64
            if cost <= best_cost:
                best, best_cost = j, cost
        if best is None and len(counts) > q:
            best = int(np.argmin(counts[:-1] + counts[1:]))  # force progress
        counts = np.concatenate([counts[:best],
                                 [counts[best] + counts[best + 1]],
                                 counts[best + 2:]])
    # map original codes onto the merged bins via cumulative boundaries
    bounds = np.cumsum(counts)[:-1]
    order = np.argsort(col, kind="stable")
    ranks = np.empty(m, np.int64)
    ranks[order] = np.arange(m)
    return np.searchsorted(bounds, ranks, side="right").astype(np.int32)


def discretize(data: np.ndarray, q: int, method: str = "quantile") -> np.ndarray:
    """(m, n) continuous -> (m, n) int32 states in [0, q)."""
    fn = {"quantile": quantile_bins, "width": width_bins,
          "mdl": mdl_merge_bins}[method]
    out = np.stack([fn(np.asarray(data[:, i], np.float64), q)
                    for i in range(data.shape[1])], axis=1)
    assert out.min() >= 0 and out.max() < q
    return out
