from .bn_sampler import ancestral_sample, inject_noise
from .networks import (ALARM_EDGES, STN_EDGES, alarm_adjacency,
                       stn_adjacency, synthetic_adjacency)

__all__ = ["ancestral_sample", "inject_noise", "ALARM_EDGES", "STN_EDGES",
           "alarm_adjacency", "stn_adjacency", "synthetic_adjacency"]
