"""Pallas TPU kernels for the compute hot spots.

* order_score — the paper's GPU scoring kernel (§V): masked max+argmax over
  parent-set-table blocks, grid-accumulated (the Fig. 7 reduction tree mapped
  to VPU lanes + sequential grid revisiting).
* count — preprocessing N_ijk contingency counting as one-hot × one-hot MXU
  matmuls (the paper's "future work: accelerate preprocessing on GPU").
* flash_attention — blockwise causal attention with online softmax for the LM
  substrate's prefill path.

Each kernel directory has kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper), ref.py (pure-jnp oracle). Kernels run in interpret
mode off-TPU; wrappers select automatically.
"""
from .count.ops import count_contingency
from .flash_attention.ops import flash_attention
from .order_score.ops import order_score

__all__ = ["order_score", "count_contingency", "flash_attention"]
