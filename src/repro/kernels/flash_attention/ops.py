"""Public wrapper with GQA support (kv heads repeated to q heads)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention"]


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "use_pallas", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 512,
                    block_k: int = 512, use_pallas: bool = True,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q: (B, T, Hq, D); k, v: (B, T, Hkv, D), Hq % Hkv == 0. Returns like q."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Tq, Hq, D = q.shape
    Hkv = k.shape[2]
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Tq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hq, -1, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hq, -1, D)
    if use_pallas:
        of = flash_attention_pallas(qf, kf, vf, causal=causal,
                                    block_q=min(block_q, Tq),
                                    block_k=min(block_k, kf.shape[1]),
                                    interpret=interpret)
    else:
        of = attention_ref(qf, kf, vf, causal=causal)
    return of.reshape(B, Hq, Tq, D).transpose(0, 2, 1, 3)
