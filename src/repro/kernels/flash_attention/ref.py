"""Pure-jnp oracle: exact softmax attention."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True) -> jnp.ndarray:
    """q, k, v: (BH, T, D)."""
    D = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    if causal:
        Tq, Tk = s.shape[-2:]
        mask = jnp.arange(Tq)[:, None] >= jnp.arange(Tk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
