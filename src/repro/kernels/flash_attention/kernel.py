"""Blockwise causal attention with online softmax (flash attention) for TPU.

Grid (batch·heads, q-blocks, kv-blocks), kv fastest ⇒ sequential accumulation
into VMEM scratch (running max m, normalizer l, accumulator acc). Causal
blocks strictly above the diagonal are skipped; the output is finalized at the
last *visited* kv block of each q row. Blocks are MXU-aligned (multiples of
128 on the contracting/lane dims recommended).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, scale: float, causal: bool,
                  nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    last_ik = ((iq + 1) * block_q - 1) // block_k if causal else nk - 1
    run = (ik * block_k <= (iq + 1) * block_q - 1) if causal else True

    @pl.when(run)
    def _body():
        q = q_ref[0, :, :]                         # (BQ, D)
        k = k_ref[0, :, :]                         # (BK, D)
        v = v_ref[0, :, :]                         # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_scr[...]                        # (BQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                     # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)            # (BQ, 1)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == last_ik)
    def _finalize():
        o_ref[0, :, :] = (acc_scr[...] /
                          jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, block_q: int = 512,
                           block_k: int = 512, interpret: bool = False):
    """q, k, v: (BH, T, D) — already head-flattened; T divisible by blocks."""
    BH, Tq, D = q.shape
    Tk = k.shape[1]
    assert Tq % block_q == 0 and Tk % block_k == 0
    nq, nk = Tq // block_q, Tk // block_k
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(_flash_kernel, block_q=block_q,
                               block_k=block_k, scale=scale, causal=causal,
                               nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
