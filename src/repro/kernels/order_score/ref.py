"""Pure-jnp oracle for the order-scoring kernel (same contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.order_scoring import PAD_SET
from .kernel import NEG_INF


def order_score_ref(table: jnp.ndarray, pst: jnp.ndarray, pos: jnp.ndarray):
    """(n, S), (S, s), (n,) -> (best_val (n,), best_idx (n,))."""
    n, S = table.shape

    def per_node(i, row):
        pnode = pst + (pst >= i).astype(jnp.int32)
        ppos = pos[jnp.clip(pnode, 0)]
        ok = jnp.where(pst < 0, pst > PAD_SET, ppos < pos[i])  # pad row
        masked = jnp.where(jnp.all(ok, axis=-1), row, NEG_INF)
        a = jnp.argmax(masked)
        return masked[a], a.astype(jnp.int32)

    return jax.vmap(per_node)(jnp.arange(n), table)
