"""Pallas TPU kernel for order scoring (paper §V-B/§V, Eq. 6).

Grid (S/BLK, n), parent-set block OUTER: one PST tile is fetched into VMEM
once and all n nodes consume it while it is hot. Consistency is evaluated
lane-parallel on the VPU; each step folds the block max+argmax into a
persistent (n, 1) accumulator block — the paper's thread →
shared-memory-tree → global reduction (Fig. 7) becomes lane-reduction →
sequential-grid accumulation. The cross-device level (pmax/pmin over the
`model` axis) lives in core/sharded_scoring.py.

Two §Perf tricks mirrored from the winning jnp scorer (EXPERIMENTS.md §Perf
cell 1):

* select-instead-of-gather: candidate c maps to node c + (c ≥ i), so a
  parent's position is either pos[c] or pos[c+1]; BOTH are materialized
  node-independently ONCE per block (gather-free one-hot contraction over
  the small node axis — TPU vector memory dislikes dynamic gathers) into
  VMEM scratch, and each node then needs only an elementwise select.
* the per-node work is a (BLK, s) compare/select + (BLK,) max — exactly the
  compare/assign-only inner loop the paper argues for (§III-B).

Bitmask variants and the fused plane-patch kernel (ISSUE 4)
-----------------------------------------------------------

`_order_score_window_bitmask_kernel` consumes PACKED consistency words
(core/order_scoring §Cached consistency bitmasks) instead of recomputing the
mask from PST gathers. `_order_score_window_bitmask_fused_kernel` goes one
step further and is the production bitmask path: the cached violation-plane
words are read into VMEM, the membership/ripple-carry patch for the ≤ w
moved window nodes is applied, the packed consistency mask derived, and the
masked max+argmax folded — ONE kernel, one VMEM pass, with the patched words
emitted as an output for adoption on accept. Contract:

    (rows (w, S), node_ids (w,), pos_old (n,), pos_new (n,),
     planes_win (w, P, S/32), cm_lo (w, S/32), cm_hi (w, S/32))
        -> (best_val (w,), best_idx (w,), patched_planes (w, P, S/32))

cm_lo/cm_hi are the two possible membership rows of each window node
(candidate x vs x−1, selected per (child, parent) pair in-kernel — the same
select-instead-of-gather trick as the position kernel). Grid (S/BLK, w):
ALL w window rows ride one invocation, same accumulator fold and first-wins
tie-break as every other window kernel, so the three variants are
bitwise-interchangeable.

Plane-sharding layout (core/sharded_scoring): on a mesh, the plane word
axis is S-sharded over `model` right alongside the table — word j of a
device's (n, P, shard/32) slice covers global ranks 32·(shard_start/32 + j)
…, so each device patches and scores only its own words (this kernel runs
per shard inside shard_map) and only the (w,) pmax/pmin pair crosses ICI.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.order_scoring import PAD_SET

NEG_INF = -3.0e38


def _order_score_window_kernel(pos_ref, nid_ref, table_ref, pst_ref, val_ref,
                               idx_ref, lo_ref, hi_ref, *, block_s: int,
                               n: int, w: int, s: int):
    """The one scoring kernel body: grid dim 1 runs over w ROW SLOTS whose
    actual node ids come from nid_ref (the candidate→node shift and the
    node's own position are resolved per slot). The full path is the special
    case nid_ref = arange(n) with w = n; the delta path passes the w moved
    window nodes — identical tile order, accumulator fold, and tie-break by
    construction."""
    b = pl.program_id(0)          # parent-set block (outer)
    i = pl.program_id(1)          # window slot (inner — PST tile stays hot)

    @pl.when(jnp.logical_and(b == 0, i == 0))
    def _init():
        val_ref[...] = jnp.full(val_ref.shape, NEG_INF, val_ref.dtype)
        idx_ref[...] = jnp.zeros(idx_ref.shape, idx_ref.dtype)

    pst = pst_ref[...]                            # (BLK, s)
    pos = pos_ref[...]                            # (n,)

    @pl.when(i == 0)
    def _prep():
        safe = jnp.maximum(pst, 0)
        iota = jax.lax.broadcasted_iota(jnp.int32, (block_s, s, n), 2)
        oh_lo = safe[..., None] == iota
        lo_ref[...] = jnp.sum(jnp.where(oh_lo, pos[None, None, :], 0),
                              axis=-1).astype(jnp.int32)
        hi = jnp.minimum(safe + 1, n - 1)
        oh_hi = hi[..., None] == iota
        hi_ref[...] = jnp.sum(jnp.where(oh_hi, pos[None, None, :], 0),
                              axis=-1).astype(jnp.int32)

    scores = table_ref[0, :]                      # (BLK,)
    nid = jnp.sum(jnp.where(jnp.arange(w) == i, nid_ref[...], 0))
    my_pos = jnp.sum(jnp.where(jnp.arange(n) == nid, pos, 0))

    ppos = jnp.where(pst >= nid, hi_ref[...], lo_ref[...])
    ok = jnp.where(pst < 0, pst > PAD_SET, ppos < my_pos)  # pad row sentinel
    consistent = jnp.all(ok, axis=-1)

    masked = jnp.where(consistent, scores, NEG_INF)
    larg = jnp.argmax(masked).astype(jnp.int32)
    lmax = jnp.max(masked)

    # accumulator column index as a jnp scalar, not a python int: interpret-
    # mode state discharge on jax 0.4.x rejects raw-int indices
    _Z = jnp.int32(0)
    cur = pl.load(val_ref, (i, _Z))
    better = lmax > cur
    pl.store(val_ref, (i, _Z), jnp.where(better, lmax, cur))
    pl.store(idx_ref, (i, _Z),
             jnp.where(better, larg + b * block_s, pl.load(idx_ref, (i, _Z))))


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def order_score_window_pallas(rows: jnp.ndarray, node_ids: jnp.ndarray,
                              pst: jnp.ndarray, pos: jnp.ndarray, *,
                              block_s: int = 2048, interpret: bool = False):
    """(w, S) gathered rows, (w,) node ids, (S, s) pst, (n,) pos ->
    (best_val (w,), best_idx (w,)). S must be a multiple of block_s."""
    w, S = rows.shape
    n = pos.shape[0]
    s = pst.shape[1]
    assert S % block_s == 0, "pad S to a multiple of block_s"
    grid = (S // block_s, w)

    kernel = functools.partial(_order_score_window_kernel, block_s=block_s,
                               n=n, w=w, s=s)
    val, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda b, i: (0,)),              # pos
            pl.BlockSpec((w,), lambda b, i: (0,)),              # node ids
            pl.BlockSpec((1, block_s), lambda b, i: (i, b)),    # row tile
            pl.BlockSpec((block_s, s), lambda b, i: (b, 0)),    # PST tile (hot)
        ],
        out_specs=[
            pl.BlockSpec((w, 1), lambda b, i: (0, 0)),          # running max
            pl.BlockSpec((w, 1), lambda b, i: (0, 0)),          # running argmax
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w, 1), jnp.float32),
            jax.ShapeDtypeStruct((w, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_s, s), jnp.int32),                # ppos_lo
            pltpu.VMEM((block_s, s), jnp.int32),                # ppos_hi
        ],
        interpret=interpret,
    )(pos, node_ids, rows, pst)
    return val[:, 0], idx[:, 0]


def _order_score_window_bitmask_kernel(mask_ref, table_ref, val_ref,
                                       idx_ref, *, block_s: int, w: int):
    """Bitmask-consuming variant of the window kernel: consistency arrives as
    PACKED uint32 words (core/order_scoring §Cached consistency bitmasks)
    streamed through VMEM alongside the score tile — (BLK/32) words per tile
    instead of the (BLK, s) PST tile plus two (BLK, s) position scratch
    buffers. The per-slot work collapses to unpack + select + fold: no
    gathers, no per-node compares — the paper's compare/assign-only inner
    loop (§III-B) taken one step further. Same grid walk, same accumulator
    fold, same first-wins tie-break as `_order_score_window_kernel`, so the
    two paths are bitwise-interchangeable given an identical mask."""
    b = pl.program_id(0)          # parent-set block (outer)
    i = pl.program_id(1)          # window slot (inner)

    @pl.when(jnp.logical_and(b == 0, i == 0))
    def _init():
        val_ref[...] = jnp.full(val_ref.shape, NEG_INF, val_ref.dtype)
        idx_ref[...] = jnp.zeros(idx_ref.shape, idx_ref.dtype)

    bw = block_s // 32
    words = mask_ref[0, :]                        # (BLK/32,) uint32
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (bw, 32), 1)
    bits = jnp.right_shift(words[:, None], shifts) & jnp.uint32(1)
    consistent = (bits != 0).reshape(block_s)     # LSB-first, rank 32j+b

    scores = table_ref[0, :]                      # (BLK,)
    masked = jnp.where(consistent, scores, NEG_INF)
    larg = jnp.argmax(masked).astype(jnp.int32)
    lmax = jnp.max(masked)

    _Z = jnp.int32(0)
    cur = pl.load(val_ref, (i, _Z))
    better = lmax > cur
    pl.store(val_ref, (i, _Z), jnp.where(better, lmax, cur))
    pl.store(idx_ref, (i, _Z),
             jnp.where(better, larg + b * block_s, pl.load(idx_ref, (i, _Z))))


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def order_score_window_bitmask_pallas(rows: jnp.ndarray,
                                      mask_words: jnp.ndarray, *,
                                      block_s: int = 2048,
                                      interpret: bool = False):
    """(w, S) gathered rows + (w, S/32) packed consistency words ->
    (best_val (w,), best_idx (w,)). S must be a multiple of block_s and
    block_s a multiple of 32. The PST never enters the kernel — masks were
    patched on the host side of the cache (update_window_planes)."""
    w, S = rows.shape
    assert S % block_s == 0, "pad S to a multiple of block_s"
    assert block_s % 32 == 0, "packed words need block_s % 32 == 0"
    bw = block_s // 32
    grid = (S // block_s, w)

    kernel = functools.partial(_order_score_window_bitmask_kernel,
                               block_s=block_s, w=w)
    val, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bw), lambda b, i: (i, b)),         # mask words
            pl.BlockSpec((1, block_s), lambda b, i: (i, b)),    # row tile
        ],
        out_specs=[
            pl.BlockSpec((w, 1), lambda b, i: (0, 0)),          # running max
            pl.BlockSpec((w, 1), lambda b, i: (0, 0)),          # running argmax
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w, 1), jnp.float32),
            jax.ShapeDtypeStruct((w, 1), jnp.int32),
        ],
        interpret=interpret,
    )(mask_words, rows)
    return val[:, 0], idx[:, 0]


def _order_score_window_bitmask_fused_kernel(
        pos_old_ref, pos_new_ref, nid_ref, planes_ref, cmlo_ref, cmhi_ref,
        table_ref, val_ref, idx_ref, new_planes_ref, *, block_s: int, n: int,
        w: int, n_planes: int):
    """ONE kernel for the whole bitmask-cached proposal rescore: read the
    OLD violation-plane words, apply the membership/ripple-carry patch for
    the ≤ w moved window nodes, derive the packed consistency mask, and fold
    the masked max+argmax — all in the same VMEM pass over the (BLK) tile.
    The patched words are emitted as a third output so the sampler can adopt
    them on accept. Replaces the XLA word-op patch (`update_window_planes`)
    + separate scoring kernel (`_order_score_window_bitmask_kernel`) pair:
    the plane words are read ONCE instead of written to HBM and re-read.

    Per grid cell (b, i): slot i's (P, BLK/32) plane tile for block b is
    patched against the other w slots' membership rows (cmlo/cmhi are the
    candidate rows for x < i / x > i — the same select-instead-of-gather
    trick as the position kernel, one select per (i, x) pair), then scored.
    Same grid walk, accumulator fold and first-wins tie-break as the other
    window kernels, so all three are bitwise-interchangeable."""
    b = pl.program_id(0)          # parent-set block (outer)
    i = pl.program_id(1)          # window slot (inner)

    @pl.when(jnp.logical_and(b == 0, i == 0))
    def _init():
        val_ref[...] = jnp.full(val_ref.shape, NEG_INF, val_ref.dtype)
        idx_ref[...] = jnp.zeros(idx_ref.shape, idx_ref.dtype)

    nid = nid_ref[...]                            # (w,)
    pos_old = pos_old_ref[...]                    # (n,)
    pos_new = pos_new_ref[...]                    # (n,)
    nid_i = jnp.sum(jnp.where(jnp.arange(w) == i, nid, 0))
    po_i = jnp.sum(jnp.where(jnp.arange(n) == nid_i, pos_old, 0))
    pn_i = jnp.sum(jnp.where(jnp.arange(n) == nid_i, pos_new, 0))

    planes = planes_ref[0]                        # (P, BLK/32) uint32
    for x in range(w):                            # static unroll: w is small
        nx = nid[x]
        po_x = jnp.sum(jnp.where(jnp.arange(n) == nx, pos_old, 0))
        pn_x = jnp.sum(jnp.where(jnp.arange(n) == nx, pos_new, 0))
        was = po_x > po_i
        now = pn_x > pn_i
        # candidate row of x as seen by child i: cm[x - (x > i)] — both
        # gathers were done once outside; select per (i, x) pair here
        row = jnp.where(nx > nid_i, cmhi_ref[x, :], cmlo_ref[x, :])
        zero = jnp.zeros_like(row)
        add = jnp.where(now & jnp.logical_not(was), row, zero)
        sub = jnp.where(was & jnp.logical_not(now), row, zero)
        out, carry = [], add                      # ripple-carry +1
        for p in range(n_planes):
            v = planes[p]
            out.append(v ^ carry)
            carry = v & carry
        planes = jnp.stack(out)
        out, borrow = [], sub                     # ripple-borrow -1
        for p in range(n_planes):
            v = planes[p]
            out.append(v ^ borrow)
            borrow = (~v) & borrow
        planes = jnp.stack(out)
    new_planes_ref[0] = planes

    acc = planes[0]                               # violation-count != 0 OR
    for p in range(1, n_planes):
        acc = acc | planes[p]
    words = ~acc
    bw = block_s // 32
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (bw, 32), 1)
    bits = jnp.right_shift(words[:, None], shifts) & jnp.uint32(1)
    consistent = (bits != 0).reshape(block_s)     # LSB-first, rank 32j+b

    scores = table_ref[0, :]                      # (BLK,)
    masked = jnp.where(consistent, scores, NEG_INF)
    larg = jnp.argmax(masked).astype(jnp.int32)
    lmax = jnp.max(masked)

    _Z = jnp.int32(0)
    cur = pl.load(val_ref, (i, _Z))
    better = lmax > cur
    pl.store(val_ref, (i, _Z), jnp.where(better, lmax, cur))
    pl.store(idx_ref, (i, _Z),
             jnp.where(better, larg + b * block_s, pl.load(idx_ref, (i, _Z))))


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def order_score_window_bitmask_fused_pallas(
        rows: jnp.ndarray, node_ids: jnp.ndarray, pos_old: jnp.ndarray,
        pos_new: jnp.ndarray, planes_win: jnp.ndarray, cm_lo: jnp.ndarray,
        cm_hi: jnp.ndarray, *, block_s: int = 2048,
        interpret: bool = False):
    """Fused plane-patch + masked-argmax (see the fused kernel docstring).

    rows: (w, S) gathered table rows for the window nodes; node_ids: (w,);
    pos_old/pos_new: (n,) previous/proposed orders; planes_win: (w, P, S/32)
    the CACHED plane rows under pos_old; cm_lo/cm_hi: (w, S/32) membership
    rows cm[clip(node)] / cm[clip(node-1)] (the two possible candidate rows
    of each window node). Returns (best_val (w,), best_idx (w,),
    patched_planes (w, P, S/32)). S must be a multiple of block_s, block_s a
    multiple of 32. Grid (S/BLK, w): ALL w window rows ride one kernel
    invocation, exactly like the gather-window kernel."""
    w, S = rows.shape
    n = pos_old.shape[0]
    n_planes, W = planes_win.shape[1], planes_win.shape[2]
    assert S % block_s == 0, "pad S to a multiple of block_s"
    assert block_s % 32 == 0, "packed words need block_s % 32 == 0"
    assert W * 32 == S, "planes words must cover S"
    bw = block_s // 32
    grid = (S // block_s, w)

    kernel = functools.partial(_order_score_window_bitmask_fused_kernel,
                               block_s=block_s, n=n, w=w, n_planes=n_planes)
    val, idx, new_planes = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda b, i: (0,)),              # pos_old
            pl.BlockSpec((n,), lambda b, i: (0,)),              # pos_new
            pl.BlockSpec((w,), lambda b, i: (0,)),              # node ids
            pl.BlockSpec((1, n_planes, bw), lambda b, i: (i, 0, b)),  # planes
            pl.BlockSpec((w, bw), lambda b, i: (0, b)),         # cm (x < i)
            pl.BlockSpec((w, bw), lambda b, i: (0, b)),         # cm (x > i)
            pl.BlockSpec((1, block_s), lambda b, i: (i, b)),    # row tile
        ],
        out_specs=[
            pl.BlockSpec((w, 1), lambda b, i: (0, 0)),          # running max
            pl.BlockSpec((w, 1), lambda b, i: (0, 0)),          # running argmax
            pl.BlockSpec((1, n_planes, bw), lambda b, i: (i, 0, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w, 1), jnp.float32),
            jax.ShapeDtypeStruct((w, 1), jnp.int32),
            jax.ShapeDtypeStruct((w, n_planes, W), jnp.uint32),
        ],
        interpret=interpret,
    )(pos_old, pos_new, node_ids, planes_win, cm_lo, cm_hi, rows)
    return val[:, 0], idx[:, 0], new_planes


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def order_score_pallas(table: jnp.ndarray, pst: jnp.ndarray, pos: jnp.ndarray,
                       *, block_s: int = 2048, interpret: bool = False):
    """(n, S) table, (S, s) pst, (n,) pos -> (best_val (n,), best_idx (n,)).

    S must be a multiple of block_s (pad table with NEG_INF, pst with -1).
    The full score IS the windowed kernel with node_ids = arange(n) — one
    kernel body, so full and delta can never diverge on masking/tie-break.
    """
    n = table.shape[0]
    return order_score_window_pallas(table, jnp.arange(n, dtype=jnp.int32),
                                     pst, pos, block_s=block_s,
                                     interpret=interpret)
