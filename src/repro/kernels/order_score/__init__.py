from .ops import order_score, pad_for_kernel
from .ref import order_score_ref

__all__ = ["order_score", "pad_for_kernel", "order_score_ref"]
