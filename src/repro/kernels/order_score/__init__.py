from .ops import (order_score, order_score_delta, order_score_delta_bitmask,
                  pad_for_kernel)
from .ref import order_score_ref

__all__ = ["order_score", "order_score_delta", "order_score_delta_bitmask",
           "pad_for_kernel", "order_score_ref"]
