"""Public jit'd wrapper: pads, dispatches kernel vs oracle, returns the
(score, best_idx, best_ls) contract used by core.mcmc."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import (NEG_INF, order_score_pallas,
                     order_score_window_bitmask_fused_pallas,
                     order_score_window_bitmask_pallas,
                     order_score_window_pallas)
from .ref import order_score_ref

__all__ = ["order_score", "order_score_delta", "order_score_delta_bitmask",
           "pad_for_kernel"]


def pad_for_kernel(table: jnp.ndarray, pst: jnp.ndarray, block_s: int):
    """Pad S to a multiple of block_s: scores with NEG_INF (never win) AND
    parent sets with the PAD_SET row sentinel (-2, structurally inconsistent
    in every consistency check) — padded ranks can't reach best_idx even if a
    caller pads the table with something other than NEG_INF."""
    from ...core.order_scoring import PAD_SET

    S = table.shape[1]
    pad = (-S) % block_s
    if pad:
        table = jnp.pad(table, ((0, 0), (0, pad)), constant_values=NEG_INF)
        pst = jnp.pad(pst, ((0, pad), (0, 0)), constant_values=PAD_SET)
    return table, pst


@functools.partial(jax.jit,
                   static_argnames=("block_s", "use_pallas", "interpret"))
def order_score(table: jnp.ndarray, pst: jnp.ndarray, pos: jnp.ndarray, *,
                block_s: int = 2048, use_pallas: bool = True,
                interpret: bool | None = None):
    """Score an order (paper Eq. 6). Returns (score, best_idx (n,), best_ls (n,))."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_pallas:
        tbl, ps = pad_for_kernel(table, pst, block_s)
        val, idx = order_score_pallas(tbl, ps, pos, block_s=block_s,
                                      interpret=interpret)
    else:
        val, idx = order_score_ref(table, pst, pos)
    return val.sum(), idx, val


@functools.partial(jax.jit, static_argnames=("window", "block_s", "use_pallas",
                                             "interpret"))
def order_score_delta(table: jnp.ndarray, pst: jnp.ndarray, pos: jnp.ndarray,
                      prev_ls: jnp.ndarray, prev_idx: jnp.ndarray,
                      lo: jnp.ndarray, *, window: int, block_s: int = 2048,
                      use_pallas: bool = True, interpret: bool | None = None):
    """Kernel-path incremental rescore (core/order_scoring.py docstring):
    recomputes only the `window` nodes at positions [lo, lo+window-1] of the
    proposed order via the windowed Pallas kernel, splices them into the
    cached (prev_ls, prev_idx). Same (score, best_idx, best_ls) contract —
    bitwise-consistent with the full `order_score` path (same tiles, same
    fold, same tie-break)."""
    from ...core.order_scoring import splice_window, window_nodes

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = table.shape[0]
    w = min(window, n)
    tbl, ps = pad_for_kernel(table, pst, block_s)
    win = window_nodes(pos, lo, w)
    rows = tbl[win]
    if use_pallas:
        val, idx = order_score_window_pallas(rows, win, ps, pos,
                                             block_s=block_s,
                                             interpret=interpret)
    else:
        from ...core.order_scoring import _score_nodes_blocked
        val, idx = _score_nodes_blocked(rows, win, ps, pos,
                                        block=min(block_s, tbl.shape[1]))
    return splice_window(prev_ls, prev_idx, win, val, idx)


@functools.partial(jax.jit, static_argnames=("window", "block_s", "use_pallas",
                                             "interpret"))
def order_score_delta_bitmask(table: jnp.ndarray, cm: jnp.ndarray,
                              pos: jnp.ndarray, prev_ls: jnp.ndarray,
                              prev_idx: jnp.ndarray, lo: jnp.ndarray,
                              pos_old: jnp.ndarray, planes: jnp.ndarray, *,
                              window: int, block_s: int = 2048,
                              use_pallas: bool = True,
                              interpret: bool | None = None):
    """Kernel-path bitmask-cached rescore, now ONE fused Pallas kernel
    (order_score_window_bitmask_fused_pallas): the cached violation-plane
    words are read into VMEM once, patched with the membership/ripple-carry
    word ops, and the masked max+argmax folds in the same pass — the XLA
    word-op patch + separate scoring-kernel round trip through HBM is gone,
    and the PST leaves the per-iteration hot path entirely. table must
    already be padded to a block_s multiple (pad_for_kernel), with cm/planes
    built on the padded shape. Same extended contract as core's
    score_order_delta_bitmask: (total, best_idx, best_ls, patched_planes)."""
    from ...core.order_scoring import (_score_nodes_blocked_bitmask,
                                      planes_consistent_words, splice_window,
                                      update_window_planes, window_nodes)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, S = table.shape
    assert S % block_s == 0, "pad table with pad_for_kernel first"
    w = min(window, n)
    win = window_nodes(pos, lo, w)
    rows = table[win]
    if use_pallas:
        n_cand = cm.shape[0]
        cm_lo = cm[jnp.clip(win, 0, n_cand - 1)]        # row when x < i
        cm_hi = cm[jnp.clip(win - 1, 0, n_cand - 1)]    # row when x > i
        val, idx, new_planes_win = order_score_window_bitmask_fused_pallas(
            rows, win, pos_old, pos, planes[win], cm_lo, cm_hi,
            block_s=block_s, interpret=interpret)
    else:
        new_planes_win = update_window_planes(cm, pos_old, pos, win,
                                              planes[win])
        words = planes_consistent_words(new_planes_win)
        val, idx = _score_nodes_blocked_bitmask(rows, words,
                                                block=min(block_s, S))
    tot, best_idx, best_ls = splice_window(prev_ls, prev_idx, win, val, idx)
    return tot, best_idx, best_ls, planes.at[win].set(new_planes_win)
