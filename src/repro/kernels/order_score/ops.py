"""Public jit'd wrapper: pads, dispatches kernel vs oracle, returns the
(score, best_idx, best_ls) contract used by core.mcmc."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import NEG_INF, order_score_pallas, order_score_window_pallas
from .ref import order_score_ref

__all__ = ["order_score", "order_score_delta", "pad_for_kernel"]


def pad_for_kernel(table: jnp.ndarray, pst: jnp.ndarray, block_s: int):
    """Pad S to a multiple of block_s: scores with NEG_INF (never win),
    parent sets with -1 (vacuously consistent, but unreachable)."""
    S = table.shape[1]
    pad = (-S) % block_s
    if pad:
        table = jnp.pad(table, ((0, 0), (0, pad)), constant_values=NEG_INF)
        pst = jnp.pad(pst, ((0, pad), (0, 0)), constant_values=-1)
    return table, pst


@functools.partial(jax.jit,
                   static_argnames=("block_s", "use_pallas", "interpret"))
def order_score(table: jnp.ndarray, pst: jnp.ndarray, pos: jnp.ndarray, *,
                block_s: int = 2048, use_pallas: bool = True,
                interpret: bool | None = None):
    """Score an order (paper Eq. 6). Returns (score, best_idx (n,), best_ls (n,))."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_pallas:
        tbl, ps = pad_for_kernel(table, pst, block_s)
        val, idx = order_score_pallas(tbl, ps, pos, block_s=block_s,
                                      interpret=interpret)
    else:
        val, idx = order_score_ref(table, pst, pos)
    return val.sum(), idx, val


@functools.partial(jax.jit, static_argnames=("window", "block_s", "use_pallas",
                                             "interpret"))
def order_score_delta(table: jnp.ndarray, pst: jnp.ndarray, pos: jnp.ndarray,
                      prev_ls: jnp.ndarray, prev_idx: jnp.ndarray,
                      lo: jnp.ndarray, *, window: int, block_s: int = 2048,
                      use_pallas: bool = True, interpret: bool | None = None):
    """Kernel-path incremental rescore (core/order_scoring.py docstring):
    recomputes only the `window` nodes at positions [lo, lo+window-1] of the
    proposed order via the windowed Pallas kernel, splices them into the
    cached (prev_ls, prev_idx). Same (score, best_idx, best_ls) contract —
    bitwise-consistent with the full `order_score` path (same tiles, same
    fold, same tie-break)."""
    from ...core.order_scoring import splice_window, window_nodes

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = table.shape[0]
    w = min(window, n)
    tbl, ps = pad_for_kernel(table, pst, block_s)
    win = window_nodes(pos, lo, w)
    rows = tbl[win]
    if use_pallas:
        val, idx = order_score_window_pallas(rows, win, ps, pos,
                                             block_s=block_s,
                                             interpret=interpret)
    else:
        from ...core.order_scoring import _score_nodes_blocked
        val, idx = _score_nodes_blocked(rows, win, ps, pos,
                                        block=min(block_s, tbl.shape[1]))
    return splice_window(prev_ls, prev_idx, win, val, idx)
