"""Pallas TPU kernel for preprocessing contingency counting (beyond-paper:
the paper leaves preprocessing acceleration as future work, §VII).

N[c, k, j] = #{samples: parent-config-code == k and child-state == j} for a
batch of parent sets c. Formulated as a one-hot × one-hot matmul so the MXU
does the counting: counts_c = onehot(code_c)^T @ onehot(child), a
(Q × m) · (m × q) product per parent set. Grid streams parent sets; the
sample axis m is tiled into VMEM blocks and accumulated in the revisited
output block (sequential grid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _count_kernel(codes_ref, child_oh_ref, out_ref, *, Q: int, block_m: int):
    mb = pl.program_id(1)

    @pl.when(mb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[0, :]                      # (BM,) int32, -1 = padding
    # mask padded rows out of the child one-hot BEFORE the contraction: the
    # code side is all-zero there, but correctness must not hinge on the
    # caller having zero-padded child_oh (a one-hot built from a 0-padded
    # child array has VALID-looking rows in the pad region and would
    # otherwise corrupt counts whenever m % block_m != 0)
    valid = codes >= 0
    child = jnp.where(valid[:, None], child_oh_ref[...], 0.0)   # (BM, q) f32
    bins = jax.lax.broadcasted_iota(jnp.int32, (block_m, Q), 1)
    oh = (codes[:, None] == bins).astype(jnp.float32)   # (BM, Q); pad rows all-0
    # MXU contraction over samples: (Q, BM) @ (BM, q)
    out_ref[0, :, :] += jax.lax.dot_general(
        oh, child, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("Q", "block_m", "interpret"))
def count_pallas(codes: jnp.ndarray, child_oh: jnp.ndarray, *, Q: int,
                 block_m: int = 512, interpret: bool = False) -> jnp.ndarray:
    """codes: (C, m) int32 mixed-radix parent configs (-1 = padded sample);
    child_oh: (m, q) one-hot child states. Returns (C, Q, q) f32 counts.
    m must be a multiple of block_m (pad codes with -1, child_oh with 0)."""
    C, m = codes.shape
    q = child_oh.shape[1]
    assert m % block_m == 0, "pad m to a multiple of block_m"
    grid = (C, m // block_m)
    kernel = functools.partial(_count_kernel, Q=Q, block_m=block_m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m), lambda c, mb: (c, mb)),
            pl.BlockSpec((block_m, q), lambda c, mb: (mb, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, q), lambda c, mb: (c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((C, Q, q), jnp.float32),
        interpret=interpret,
    )(codes, child_oh)
