"""Public wrapper: mixed-radix encode + kernel/oracle dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import count_pallas
from .ref import count_ref

__all__ = ["count_contingency", "encode_parent_configs"]


def encode_parent_configs(data_ext: jnp.ndarray, parent_cols: jnp.ndarray,
                          q: int) -> jnp.ndarray:
    """(m, n+1) data (zeros col appended), (C, s) columns -> (C, m) codes."""
    cols = data_ext[:, parent_cols]                     # (m, C, s)
    pw = q ** jnp.arange(parent_cols.shape[1], dtype=jnp.int32)
    return jnp.sum(cols * pw, axis=-1).T.astype(jnp.int32)   # (C, m)


@functools.partial(jax.jit,
                   static_argnames=("q", "s", "block_m", "use_pallas", "interpret"))
def count_contingency(data_ext: jnp.ndarray, child: jnp.ndarray,
                      parent_cols: jnp.ndarray, *, q: int, s: int,
                      block_m: int = 512, use_pallas: bool = True,
                      interpret: bool | None = None) -> jnp.ndarray:
    """N_ijk counts (C, q**s, q) for a chunk of parent sets of one node."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    Q = q ** s
    codes = encode_parent_configs(data_ext, parent_cols, q)   # (C, m)
    child_oh = jax.nn.one_hot(child, q, dtype=jnp.float32)    # (m, q)
    if not use_pallas:
        return count_ref(codes, child_oh, Q=Q)
    m = codes.shape[1]
    pad = (-m) % block_m
    if pad:
        # codes pad with -1 marks the rows as invalid; the kernel masks the
        # child one-hot by that marker, so the child_oh pad VALUE is
        # irrelevant (zeros here only for cleanliness)
        codes = jnp.pad(codes, ((0, 0), (0, pad)), constant_values=-1)
        child_oh = jnp.pad(child_oh, ((0, pad), (0, 0)))
    return count_pallas(codes, child_oh, Q=Q, block_m=block_m,
                        interpret=interpret)
