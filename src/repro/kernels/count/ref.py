"""Pure-jnp oracle for the counting kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def count_ref(codes: jnp.ndarray, child_oh: jnp.ndarray, *, Q: int) -> jnp.ndarray:
    """codes (C, m) int32 (-1 padding), child_oh (m, q) -> (C, Q, q) counts."""
    oh = jax.nn.one_hot(codes, Q, dtype=jnp.float32)          # -1 -> all-zero row
    return jnp.einsum("cmQ,mj->cQj", oh, child_oh)
