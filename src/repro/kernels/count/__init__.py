from .ops import count_contingency, encode_parent_configs
from .ref import count_ref

__all__ = ["count_contingency", "encode_parent_configs", "count_ref"]
