"""GPU-resident preprocessing subsystem (paper §III-A / §III-B).

The paper splits structure learning into a *preprocessing* stage — compute
every local score ls(i, pi) for |pi| <= s and store it in a hash table
(§III-A) — and an MCMC stage that only reads the table (§III-B). After PR 1
made the MCMC iteration O(window*S), preprocessing became the end-to-end
wall-clock bottleneck (the paper's own future work, §VII: move counting onto
the accelerator). This package is that stage, organised by paper section:

==================  =========================================================
module              paper mapping
==================  =========================================================
fused.py            §III-A counting + Eq. 4 scoring fused into one pass:
                    each column subset is counted ONCE against all n children
                    (one matmul) and scored in-register via gammaln lookup
                    tables / in-VMEM gammaln (Pallas kernel), so the
                    (C, q^s, q) contingency tensor never reaches HBM.
planner.py          §III-B task assignment: work units weighted by the
                    paper's q^{|pi|}*m cost estimate and LPT-balanced across
                    devices (the GPU-block task table, promoted to a mesh).
sparse.py           §III-A memory-saving strategy: per-node score lists
                    pruned to a delta of the node's best, stored in an
                    open-addressing hash table (the paper's chained hash
                    buckets, TPU-vectorized) + packed lists for the
                    order-scoring hot path, with an exact dense fallback.
streaming.py        §III-A taken at its word: fused chunks rank-gathered
                    chunk-locally and merged straight into the pruned
                    SparseScoreTable — peak memory O(n·K + chunk·n), no
                    (n, S) dense table or rank map ever materialised
                    (bitwise-equal to dense+prune). The engine behind
                    prune_delta runs; reaches n = 100, s = 4.
cache.py            preprocessing disk cache keyed on (data, q, s, ess,
                    gamma, prior [+ prune_delta/max_keep for pruned
                    entries]); manifests verified on restore: repeated
                    bn_learn runs skip the stage, never get a wrong table.
pipeline.py         the driver: cache -> plan -> fused pass -> dense
                    rank-gather assembly (the rank IS the hash address) or
                    streaming-pruned assembly -> cache store.
==================  =========================================================

core/scores.build_score_table remains the oracle; tests/test_preprocess.py
pins fused == oracle to <= 1e-4 absolute (bitwise on CPU) and
benchmarks/preprocess_bench.py tracks the >= 3x n = 64 speedup gate.
"""
from .fused import fused_scores_pallas, fused_scores_ref, score_luts
from .pipeline import assemble_table, build_score_table_fused
from .planner import PreprocessPlan, assign_chunks, chunk_costs, plan_preprocess
from .sparse import SparseScoreTable, prune_table
from .streaming import build_sparse_table_streaming

__all__ = [
    "build_score_table_fused", "assemble_table",
    "build_sparse_table_streaming",
    "fused_scores_ref", "fused_scores_pallas", "score_luts",
    "PreprocessPlan", "plan_preprocess", "assign_chunks", "chunk_costs",
    "SparseScoreTable", "prune_table",
]
