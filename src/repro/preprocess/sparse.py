"""Hash-compressed score table (the paper's §III-A memory-saving strategy).

The paper stores (node, parent-set) scores in a hash table because the dense
(n, S) table outgrows GPU memory. We keep the dense rank-indexed layout as
the oracle but add :class:`SparseScoreTable`: per node, only the parent sets
scoring within ``delta`` of that node's best are retained (Kuipers et al.
1803.07859's pruned per-node score lists), stored twice:

* an **open-addressing hash table** (multiplicative hashing + linear probe,
  the TPU-friendly replacement for the paper's chained buckets): O(1) point
  lookups of ls(i, pi) by PST rank, fully vectorized/jittable — usable from
  inside the order-scoring hot path;
* a **packed candidate list** (kept_ls / kept_parents / kept_idx): the
  representation core/order_scoring.score_order_pruned consumes, turning the
  per-iteration cost from O(n*S) into O(n*K) for K kept entries.

Pruning guarantee (exactness)
-----------------------------
The empty parent set is always kept, so every order has a consistent entry
per node and the pruned order score is well-defined and is always a LOWER
bound on the dense score. It is *exactly* equal whenever, for every node i,
the dense-optimal consistent parent set scores within ``delta`` of node i's
global best — in particular for delta = +inf the two scorers agree on every
order (tests/test_preprocess.py pins both properties). `to_dense()` is the
exact dense fallback: NEG_INF outside the kept set, bitwise-equal on it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.order_scoring import NEG_INF

__all__ = ["SparseScoreTable", "prune_table"]

_HASH_MULT = np.uint32(0x9E3779B1)       # Fibonacci / golden-ratio hashing


def _hash(idx: np.ndarray, log2_cap: int) -> np.ndarray:
    h = (idx.astype(np.uint32) * _HASH_MULT)
    return (h >> np.uint32(32 - log2_cap)).astype(np.int64)


class SparseScoreTable:
    """Per-node pruned score lists with open-addressing lookup.

    Duck-types the parts of core.scores.ScoreTable the driver uses (`n`, `S`,
    `q`, `s`, and a `table` property materialising the exact dense fallback),
    so core/order_scoring, core/mcmc and launch/bn_learn accept either
    representation. Deliberately does NOT keep the (S, s) PST or (S,) psizes:
    every stored array is O(n·K) — adjacency recovery decodes the winning
    ranks arithmetically instead (core.graph.adjacency_from_ranks), which is
    the paper's Algorithm 2 run in reverse and was the last O(S·s)
    hanger-on in the pruned path's memory footprint.
    """

    def __init__(self, *, keys, vals, kept_idx, kept_ls, kept_parents,
                 max_probe, q, s, delta, S, pst=None, psizes=None):
        # pst/psizes accepted (and ignored) for builder-signature stability
        del pst, psizes
        self.keys = jnp.asarray(keys)                # (n, cap) int32, -1 empty
        self.vals = jnp.asarray(vals)                # (n, cap) f32
        self.kept_idx = jnp.asarray(kept_idx)        # (n, K) int32, -1 pad
        self.kept_ls = jnp.asarray(kept_ls)          # (n, K) f32, NEG_INF pad
        self.kept_parents = jnp.asarray(kept_parents)  # (n, K, s) node ids
        self.max_probe = int(max_probe)
        self.q = q
        self.s = s
        self.delta = float(delta)
        self._S = int(S)
        self._dense = None

    # ------------------------------------------------------------ metadata
    @property
    def n(self) -> int:
        return self.keys.shape[0]

    @property
    def S(self) -> int:
        return self._S

    @property
    def K(self) -> int:
        """Packed width: max kept entries over nodes."""
        return self.kept_idx.shape[1]

    @property
    def nbytes_compressed(self) -> int:
        """Hash storage footprint (the memory the compression is about)."""
        return int(self.keys.nbytes + self.vals.nbytes)

    @property
    def compression_ratio(self) -> float:
        """Dense (n, S) f32 bytes over compressed bytes."""
        return (self.n * self.S * 4) / max(self.nbytes_compressed, 1)

    # ------------------------------------------------------------- lookups
    def lookup(self, node, idx):
        """ls(node, PST rank idx) if kept, else NEG_INF. Vectorized over
        leading dims of (node, idx); jit/vmap-safe (bounded probe window)."""
        return _hash_lookup(self.keys, self.vals, jnp.asarray(node),
                            jnp.asarray(idx), self.max_probe)

    @property
    def table(self):
        """Exact dense fallback: (n, S) f32 with NEG_INF at pruned entries.
        Materialised lazily and cached (this is the bridge that lets every
        dense-table scorer run unchanged on the compressed representation)."""
        if self._dense is None:
            dense = jnp.full((self.n, self.S), NEG_INF, jnp.float32)
            rows = jnp.arange(self.n, dtype=jnp.int32)[:, None]
            rows = jnp.broadcast_to(rows, self.kept_idx.shape)
            # pad entries (-1) are pushed out of range so mode="drop" skips
            # them (clipping could clobber rank 0 with a pad's NEG_INF)
            tgt = jnp.where(self.kept_idx >= 0, self.kept_idx, self.S)
            self._dense = dense.at[rows, tgt].set(self.kept_ls, mode="drop")
        return self._dense

    to_dense = table.fget

    # ------------------------------------------------------------- builders
    @classmethod
    def from_kept(cls, kept_idx: np.ndarray, kept_ls: np.ndarray,
                  kept_parents: np.ndarray, *, q: int, s: int, delta: float,
                  S: int):
        """Build the table from already-pruned per-node lists.

        kept_idx: (n, K) PST ranks, ASCENDING per node, -1 padded (rank 0 —
        the empty set — must be present for every node); kept_ls: (n, K) f32
        scores (NEG_INF pad); kept_parents: (n, K, s) parent NODE ids (-1
        pad). This is the single hash-construction path shared by
        :meth:`from_dense` and the streaming assembly
        (preprocess/streaming.py), so both produce bit-identical keys/vals/
        max_probe for identical kept lists — the property the
        streaming == dense+prune tests pin."""
        kept_idx = np.asarray(kept_idx, np.int32)
        kept_ls = np.asarray(kept_ls, np.float32)
        kept_parents = np.asarray(kept_parents, np.int32)
        n, K = kept_idx.shape
        cap = 1 << max(3, int(np.ceil(np.log2(2 * max(K, 1)))))
        log2_cap = int(np.log2(cap))
        keys = np.full((n, cap), -1, np.int32)
        vals = np.full((n, cap), np.float32(NEG_INF), np.float32)
        max_probe = 1
        for i in range(n):
            idxs = kept_idx[i][kept_idx[i] >= 0].astype(np.int64)
            slots = _hash(idxs, log2_cap)
            for k, (t, h) in enumerate(zip(idxs, slots)):
                probe = 1
                while keys[i, h] != -1:
                    h = (h + 1) % cap
                    probe += 1
                keys[i, h] = t
                vals[i, h] = kept_ls[i, k]
                max_probe = max(max_probe, probe)
        return cls(keys=keys, vals=vals, kept_idx=kept_idx, kept_ls=kept_ls,
                   kept_parents=kept_parents, max_probe=max_probe,
                   q=q, s=s, delta=delta, S=S)

    @classmethod
    def from_dense(cls, table, pst, psizes, *, q: int, s: int, delta: float):
        """Prune a dense (n, S) table: keep {t : ls[i,t] >= best_i - delta}
        (plus the empty set, rank 0) per node, hash the survivors."""
        del psizes                                   # layout-compat signature
        tbl = np.asarray(table)
        pst_np = np.asarray(pst)
        n, S = tbl.shape
        best = tbl.max(axis=1)
        keep = tbl >= (best[:, None] - float(delta))
        keep[:, 0] = True                            # empty set: always valid
        counts = keep.sum(axis=1)
        K = int(counts.max())
        kept_idx = np.full((n, K), -1, np.int32)
        kept_ls = np.full((n, K), np.float32(NEG_INF), np.float32)
        kept_parents = np.full((n, K, pst_np.shape[1]), -1, np.int32)
        for i in range(n):
            idxs = np.nonzero(keep[i])[0].astype(np.int64)
            kept_idx[i, :len(idxs)] = idxs
            kept_ls[i, :len(idxs)] = tbl[i, idxs]
            cands = pst_np[idxs]                     # (k, s) candidate space
            pn = cands + (cands >= i)                # -> node ids
            kept_parents[i, :len(idxs)] = np.where(cands < 0, -1, pn)
        return cls.from_kept(kept_idx, kept_ls, kept_parents, q=q, s=s,
                             delta=delta, S=S)


@functools.partial(jax.jit, static_argnames=("max_probe",))
def _hash_lookup(keys, vals, node, idx, max_probe: int):
    cap = keys.shape[1]
    log2_cap = int(np.log2(cap))
    h0 = ((idx.astype(jnp.uint32) * jnp.uint32(0x9E3779B1))
          >> jnp.uint32(32 - log2_cap)).astype(jnp.int32)
    probes = (h0[..., None] + jnp.arange(max_probe, dtype=jnp.int32)) % cap
    k = keys[node[..., None], probes]                # (..., P)
    hit = k == idx[..., None]
    v = vals[node[..., None], probes]
    return jnp.max(jnp.where(hit, v, NEG_INF), axis=-1)


def prune_table(st, delta: float) -> SparseScoreTable:
    """Compress a core.scores.ScoreTable (paper's memory-saving switch)."""
    return SparseScoreTable.from_dense(st.table, st.pst, st.psizes,
                                       q=st.q, s=st.s, delta=delta)
