"""Preprocessing disk cache: skip score-table construction on repeat runs.

Keyed on everything the table depends on — a SHA-256 over the data bytes and
the scoring hyperparameters (q, s, ess, gamma, prior matrix) — so a second
`bn_learn` invocation with identical inputs restores the table instead of
recomputing it. Storage rides checkpoint/checkpointer: atomic publish
(write-to-temp + rename) means a killed run can never leave a
readable-but-corrupt cache entry, and entries are plain .npy + manifest.

Always caches the DENSE table: pruning (sparse.prune_table) is cheap and
delta-dependent, so one cache entry serves every --prune-delta setting.
"""
from __future__ import annotations

import hashlib
import os

import numpy as np

from ..checkpoint import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["cache_key", "load_cached_table", "store_cached_table"]

_FORMAT = "preprocess-v1"     # bump to invalidate every cached table


def cache_key(data: np.ndarray, *, q: int, s: int, gamma: float, ess: float,
              prior_matrix: np.ndarray | None = None) -> str:
    """Hex digest identifying one preprocessing problem instance."""
    h = hashlib.sha256()
    h.update(_FORMAT.encode())
    arr = np.ascontiguousarray(np.asarray(data, np.int32))
    h.update(repr((arr.shape, q, s, float(gamma), float(ess))).encode())
    h.update(arr.tobytes())
    if prior_matrix is not None:
        R = np.ascontiguousarray(np.asarray(prior_matrix, np.float32))
        h.update(R.tobytes())
    return h.hexdigest()[:24]


def _entry_dir(cache_dir: str, key: str) -> str:
    return os.path.join(cache_dir, key)


def load_cached_table(cache_dir: str, key: str):
    """(table, pst, psizes) numpy arrays, or None on miss."""
    entry = _entry_dir(cache_dir, key)
    if latest_step(entry) is None:
        return None
    tree_like = (np.zeros(0, np.float32), np.zeros(0, np.int32),
                 np.zeros(0, np.int32))
    (table, pst, psizes), _ = restore_checkpoint(entry, tree_like, step=0)
    return np.asarray(table), np.asarray(pst), np.asarray(psizes)


def store_cached_table(cache_dir: str, key: str, table, pst, psizes,
                       metadata: dict | None = None) -> str:
    tree = (np.asarray(table, np.float32), np.asarray(pst, np.int32),
            np.asarray(psizes, np.int32))
    return save_checkpoint(_entry_dir(cache_dir, key), 0, tree,
                           metadata=metadata or {})
