"""Preprocessing disk cache: skip score-table construction on repeat runs.

Keyed on everything the table depends on — a SHA-256 over the data bytes and
the scoring hyperparameters (q, s, ess, gamma, prior matrix INCLUDING its
shape/dtype) — so a second `bn_learn` invocation with identical inputs
restores the table instead of recomputing it. Storage rides
checkpoint/checkpointer: atomic publish (write-to-temp + rename) means a
killed run can never leave a readable-but-corrupt cache entry, and entries
are plain .npy + manifest.

Two entry kinds now coexist (the "always caches the DENSE table" contract
died with the streaming assembly — at n = 100, s = 4 the dense table is the
1.6 GB intermediate the streaming path exists to avoid):

* **dense** entries (``cache_key`` without ``prune_delta``): the (n, S)
  table + PST. One entry serves every --prune-delta setting, since pruning
  from dense is cheap. Written only by the dense pipeline path.
* **sparse** entries (``cache_key`` with ``prune_delta``): the pruned
  SparseScoreTable arrays (kept_idx / kept_ls / kept_parents), O(n·K) on
  disk. Written by the streaming path; ``prune_delta`` (and the optional
  ``max_keep`` cap) is part of the digest because the kept set depends on
  it. The pipeline's lookup order is sparse -> dense (prune on the fly) ->
  build.

Restores are **verified against the request**: every entry stores a manifest
(q, s, m, n, gamma, ess, kind, ...) and ``load_cached_*`` takes an
``expect`` mapping — any mismatch (stale format, hand-mixed cache dirs,
truncated copies) is treated as a logged miss instead of being served as a
silently wrong-shape table. The checkpointer additionally digests every
array at write time (sha256 in the manifest) and re-verifies on restore, so
a truncated or bit-flipped cached .npy degrades to the same logged
miss-and-rebuild instead of feeding garbage scores into the walk — which is
exactly what the supervisor's ``cache@K`` chaos fault exercises.
"""
from __future__ import annotations

import hashlib
import logging
import os

import numpy as np

from ..checkpoint import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["cache_key", "load_cached_table", "store_cached_table",
           "load_cached_sparse", "store_cached_sparse"]

_FORMAT = "preprocess-v2"     # bump to invalidate every cached table

logger = logging.getLogger(__name__)


def cache_key(data: np.ndarray, *, q: int, s: int, gamma: float, ess: float,
              prior_matrix: np.ndarray | None = None,
              prune_delta: float | None = None,
              max_keep: int | None = None) -> str:
    """Hex digest identifying one preprocessing problem instance.

    ``prune_delta``/``max_keep`` enter the digest only when set — they key
    the PRUNED (sparse) entries, whose kept set depends on both; dense
    entries are delta-independent and keep the delta-free key."""
    h = hashlib.sha256()
    h.update(_FORMAT.encode())
    arr = np.ascontiguousarray(np.asarray(data, np.int32))
    h.update(repr((arr.shape, q, s, float(gamma), float(ess))).encode())
    h.update(arr.tobytes())
    if prior_matrix is not None:
        R = np.ascontiguousarray(np.asarray(prior_matrix, np.float32))
        # shape/dtype in the digest: R.tobytes() alone collides e.g. a
        # transposed or reshaped prior with the original (satellite bugfix)
        h.update(repr((R.shape, str(R.dtype))).encode())
        h.update(R.tobytes())
    if prune_delta is not None:
        h.update(repr(("pruned", float(prune_delta), max_keep)).encode())
    return h.hexdigest()[:24]


def _entry_dir(cache_dir: str, key: str) -> str:
    return os.path.join(cache_dir, key)


def _manifest_ok(meta: dict, expect: dict | None, entry: str) -> bool:
    """True iff every expected manifest field matches. A missing or
    mismatching field means the entry was written by an older format or a
    different problem — log and treat as a miss (never serve it)."""
    if not expect:
        return True
    for field, want in expect.items():
        got = meta.get(field, None)
        if got != want:
            logger.warning(
                "preprocess cache: manifest mismatch at %s (%s: stored %r, "
                "requested %r) — ignoring entry", entry, field, got, want)
            return False
    return True


def load_cached_table(cache_dir: str, key: str,
                      expect: dict | None = None):
    """(table, pst, psizes) numpy arrays, or None on miss.

    ``expect`` maps manifest fields (q, s, m, n, gamma, ess, ...) to the
    values the caller is requesting; a stored manifest that disagrees is a
    logged miss (satellite bugfix: never serve a wrong-shape table)."""
    entry = _entry_dir(cache_dir, key)
    if latest_step(entry) is None:
        return None
    tree_like = (np.zeros(0, np.float32), np.zeros(0, np.int32),
                 np.zeros(0, np.int32))
    try:
        (table, pst, psizes), meta = restore_checkpoint(entry, tree_like,
                                                        step=0)
    except Exception as exc:                      # corrupt / truncated entry
        logger.warning("preprocess cache: unreadable entry at %s (%s) — "
                       "ignoring", entry, exc)
        return None
    if not _manifest_ok(dict(meta or {}), expect, entry):
        return None
    return np.asarray(table), np.asarray(pst), np.asarray(psizes)


def store_cached_table(cache_dir: str, key: str, table, pst, psizes,
                       metadata: dict | None = None) -> str:
    meta = dict(metadata or {})
    meta.setdefault("kind", "dense")
    tree = (np.asarray(table, np.float32), np.asarray(pst, np.int32),
            np.asarray(psizes, np.int32))
    return save_checkpoint(_entry_dir(cache_dir, key), 0, tree,
                           metadata=meta)


def load_cached_sparse(cache_dir: str, key: str,
                       expect: dict | None = None):
    """(kept_idx, kept_ls, kept_parents, meta) or None on miss. The same
    manifest verification as :func:`load_cached_table` applies."""
    entry = _entry_dir(cache_dir, key)
    if latest_step(entry) is None:
        return None
    tree_like = (np.zeros(0, np.int32), np.zeros(0, np.float32),
                 np.zeros(0, np.int32))
    try:
        (kept_idx, kept_ls, kept_parents), meta = restore_checkpoint(
            entry, tree_like, step=0)
    except Exception as exc:
        logger.warning("preprocess cache: unreadable entry at %s (%s) — "
                       "ignoring", entry, exc)
        return None
    meta = dict(meta or {})
    if meta.get("kind") != "sparse" or not _manifest_ok(meta, expect, entry):
        return None
    return (np.asarray(kept_idx), np.asarray(kept_ls),
            np.asarray(kept_parents), meta)


def store_cached_sparse(cache_dir: str, key: str, kept_idx, kept_ls,
                        kept_parents, metadata: dict | None = None) -> str:
    meta = dict(metadata or {})
    meta["kind"] = "sparse"
    tree = (np.asarray(kept_idx, np.int32),
            np.asarray(kept_ls, np.float32),
            np.asarray(kept_parents, np.int32))
    return save_checkpoint(_entry_dir(cache_dir, key), 0, tree,
                           metadata=meta)
