"""Streaming pruned assembly: fused count+score chunks -> SparseScoreTable
with NO dense (n, S) intermediate (paper §III-A taken at its word).

The dense assembly (pipeline.assemble_table) materialises the full (n, S)
score table plus an (n, S) host-side rank map before pruning — at n = 100,
s = 4 (S ≈ 3.9M) that is ~1.6 GB apiece, the memory wall that blocked the
"n >= 100 in bounded memory" gate. This module inverts the dataflow: as each
device finishes a column-subset chunk, its (chunk, n) fused scores are

1. **rank-gathered per chunk**: for every node i NOT in column subset σ, the
   candidate-space PST rank of σ is computed arithmetically
   (core/combinatorics.rank_combinations_batch on the chunk only — the
   per-chunk replacement for the (n, S) ``_rank_map``), and the full local
   score ``|σ|·ln γ + TI[σ, i] (+ prior)`` is formed with the SAME f32 ops
   as the dense assembly, so kept scores are bitwise the dense path's;
2. **merged into per-device partial candidate lists** under a GLOBAL running
   best-per-node threshold: an entry is dropped only once it falls more than
   ``delta`` below the running best, and the running best only rises, so the
   final kept set is EXACTLY ``{t : ls[i,t] >= best_i - delta} ∪ {rank 0}``
   — the same rule ``SparseScoreTable.from_dense`` applies (Scutari et al.
   1804.08137's prune-without-loss argument; Kuipers & Moffa 1803.07859's
   per-node score lists);
3. **finalised once**: the per-device partials are merged, re-thresholded
   against the final best, packed per node in ascending-rank order and
   hashed through ``SparseScoreTable.from_kept`` — the construction path
   shared with the dense oracle, so streaming == dense+prune bitwise.

Chunks are cost-sharded over devices with the existing LPT planner
(planner.py); each device's dispatches stay async with a bounded in-flight
window, so peak memory is O(n·K) merge state + O(chunk·n) per-chunk
temporaries instead of O(n·S). ``peak_assembly_bytes`` in the returned info
self-reports the high-water mark of every host allocation the assembly makes
(the tests assert it — and independently, tracemalloc — stays under 25% of
the dense table's n·S·4 bytes).

``max_keep`` optionally caps each node's list at the top-``max_keep`` scores
(ties broken toward smaller rank). The cap composes exactly with the delta
rule — an entry outside a node's running top-``max_keep`` can never re-enter
it — but the result then equals dense+prune only when no node's within-delta
set exceeds ``max_keep``.
"""
from __future__ import annotations

import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from ..core.combinatorics import (build_pst, n_parent_sets,
                                  rank_combinations_batch)
from ..core.order_scoring import NEG_INF
from .planner import plan_preprocess
from .sparse import SparseScoreTable

__all__ = ["build_sparse_table_streaming"]

_COMPACT_EVERY = 16      # chunks merged into a device partial between sweeps
_INFLIGHT_PER_DEV = 2    # bounded dispatch window (results buffer on device)
_RANK_BATCH = 2048       # survivors ranked per call: bounds the int64
                         # temporaries of rank_combinations_batch (~8 arrays
                         # of (_RANK_BATCH, s) each) independent of how many
                         # survivors an early, pre-threshold chunk produces


def _rank_batched(n_cand: int, s: int, rows: np.ndarray,
                  sizes: np.ndarray) -> np.ndarray:
    out = np.empty(rows.shape[0], np.int64)
    for b0 in range(0, rows.shape[0], _RANK_BATCH):
        b1 = min(b0 + _RANK_BATCH, rows.shape[0])
        out[b0:b1] = rank_combinations_batch(n_cand, s, rows[b0:b1],
                                             sizes[b0:b1])
    return out


class _DevicePartial:
    """One device's running candidate lists: flat (node, rank, ls, parents)
    triples appended per chunk, periodically compacted against the global
    running threshold. Everything is O(kept) — no per-node padding until
    finalisation."""

    def __init__(self, s: int):
        self.node: list[np.ndarray] = []       # (L,) int32
        self.rank: list[np.ndarray] = []       # (L,) int64 PST ranks
        self.ls: list[np.ndarray] = []         # (L,) f32
        self.par: list[np.ndarray] = []        # (L, s) int32 parent node ids
        self.s = s
        self.since_compact = 0

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes for lst in (self.node, self.rank, self.ls,
                                        self.par) for a in lst)

    def append(self, node, rank, ls, par) -> None:
        if len(node):
            self.node.append(node)
            self.rank.append(rank)
            self.ls.append(ls)
            self.par.append(par)
        self.since_compact += 1

    def _concat(self):
        if not self.node:
            return (np.empty(0, np.int32), np.empty(0, np.int64),
                    np.empty(0, np.float32), np.empty((0, self.s), np.int32))
        return (np.concatenate(self.node), np.concatenate(self.rank),
                np.concatenate(self.ls), np.concatenate(self.par))

    def compact(self, best: np.ndarray, delta: float,
                max_keep: int | None) -> None:
        """Re-filter against the CURRENT threshold (the running best only
        rises, so this drops only entries the final rule would drop too)."""
        node, rank, ls, par = self._concat()
        keep = ls >= (best - float(delta))[node]
        node, rank, ls, par = node[keep], rank[keep], ls[keep], par[keep]
        if max_keep is not None and len(node):
            node, rank, ls, par = _cap_per_node(node, rank, ls, par,
                                                best.shape[0], max_keep)
        self.node, self.rank = [node], [rank]
        self.ls, self.par = [ls], [par]
        self.since_compact = 0


def _cap_per_node(node, rank, ls, par, n: int, max_keep: int):
    """Keep each node's top-``max_keep`` entries by score, ties toward the
    smaller rank (deterministic, so the cap composes exactly across
    compactions)."""
    order = np.lexsort((rank, -ls.astype(np.float64), node))
    node_s = node[order]
    starts = np.zeros(n + 1, np.int64)
    starts[1:] = np.cumsum(np.bincount(node_s, minlength=n))
    pos = np.arange(len(node_s)) - starts[node_s]
    keep = order[pos < max_keep]
    keep.sort()                          # restore append order (stability)
    return node[keep], rank[keep], ls[keep], par[keep]


@jax.jit
def _prior_all_jit(R: jnp.ndarray, sub_c: jnp.ndarray) -> jnp.ndarray:
    """(C, n) additive prior for a chunk of column subsets — the streaming
    counterpart of core/priors.prior_chunk, evaluated for every child node at
    once (σ already holds parent NODE ids, so no candidate shift needed)."""
    from ..core.priors import ppf_ln
    vals = ppf_ln(R[:, jnp.clip(sub_c, 0)])              # (n, C, s)
    vals = jnp.where((sub_c < 0)[None, :, :], 0.0, vals)
    return vals.sum(-1).T                                # (C, n)


def build_sparse_table_streaming(
        data: np.ndarray, *, q: int, s: int, gamma: float = 0.1,
        ess: float = 1.0, chunk: int = 1024, delta: float,
        prior_matrix: np.ndarray | None = None, max_keep: int | None = None,
        devices=None, use_pallas: bool = False, block_m: int = 512,
        interpret: bool | None = None):
    """(SparseScoreTable, stream_info): the fused pipeline streamed straight
    into the pruned representation. Bitwise-equal to
    ``prune_table(build_score_table_fused(...), delta)`` (kept sets, packed
    lists AND hash arrays) while never allocating an (n, S)-sized array.

    stream_info: {"peak_assembly_bytes", "n_chunks", "n_devices",
    "imbalance", "kept_entries", "K", "stages"} — ``stages`` breaks the
    wall-clock into {plan_s, stream_s, finalize_s} for the telemetry
    collector's stage rows.
    """
    from .fused import score_luts
    from .pipeline import _run_device

    t_plan = time.time()
    data = np.asarray(data, dtype=np.int32)
    m, n = data.shape
    S = n_parent_sets(n - 1, s)
    log_gamma = float(np.log(gamma))

    # ---- plan: identical chunking + LPT sharding to the dense pipeline
    sub, ssz = build_pst(n, s)                  # subsets of ALL n columns
    Csub = sub.shape[0]
    chunk = min(chunk, Csub)
    pad = (-Csub) % chunk
    sub_p = np.pad(sub, ((0, pad), (0, 0)), constant_values=-1)
    ssz_p = np.pad(ssz, (0, pad))
    del sub, ssz                  # keep only the padded copy on the host
    nch = sub_p.shape[0] // chunk
    if devices is None:
        devices = [jax.devices()[0]]
    plan = plan_preprocess(ssz_p, chunk, m, q, len(devices))

    subs3 = sub_p.reshape(nch, chunk, s)
    sszs2 = ssz_p.reshape(nch, chunk)
    lut_k, lut_j = score_luts(q, s, m, ess)
    data_ext = np.concatenate([data, np.zeros((m, 1), np.int32)], axis=1)
    R = (jnp.asarray(prior_matrix, jnp.float32)
         if prior_matrix is not None else None)

    dev_in = []
    for d, dev in enumerate(devices[:plan.n_devices]):
        dev_in.append((jax.device_put(jnp.asarray(data_ext), dev),
                       jax.device_put(jnp.asarray(subs3), dev),
                       jax.device_put(jnp.asarray(sszs2), dev),
                       jax.device_put(lut_k, dev),
                       jax.device_put(lut_j, dev)))

    # ---- streaming merge state
    best = np.full(n, np.float32(NEG_INF), np.float32)   # global running best
    ls0 = np.full(n, np.float32(NEG_INF), np.float32)    # empty-set scores
    partials = [_DevicePartial(s) for _ in range(plan.n_devices)]
    peak = 0

    def note_peak(tmp_bytes: int) -> None:
        nonlocal peak
        peak = max(peak, sum(p.nbytes for p in partials) + tmp_bytes)

    arange_n = np.arange(n, dtype=np.int32)

    def merge_chunk(d: int, ci: int, ti_c: np.ndarray) -> None:
        nonlocal best
        sub_c = sub_p[ci * chunk:(ci + 1) * chunk]       # (C, s) node ids
        ssz_c = ssz_p[ci * chunk:(ci + 1) * chunk]
        n_valid = int(np.clip(Csub - ci * chunk, 0, chunk))
        # same f32 composition as assemble_table: |σ|·ln γ + TI (+ prior)
        sc = ssz_c.astype(np.float32) * np.float32(log_gamma)
        sc = sc[:, None] + ti_c                           # (C, n)
        if R is not None:
            sc = sc + np.asarray(_prior_all_jit(R, jnp.asarray(sub_c)))
        member = (sub_c[:, :, None] == arange_n[None, None, :]).any(1)
        valid = np.zeros((chunk, 1), bool)
        valid[:n_valid] = True
        dom = valid & ~member                             # (C, n) child ok
        chunk_best = np.where(dom, sc, np.float32(NEG_INF)).max(0)
        best = np.maximum(best, chunk_best)
        if ci * chunk == 0:                               # σ = ∅ lives here
            ls0[:] = sc[0]
        keep = dom & (sc >= (best - float(delta))[None, :])
        if ci * chunk == 0:
            keep[0] = False          # rank 0 re-inserted at finalisation
        cc, ii = np.nonzero(keep)
        if len(cc):
            rows = sub_c[cc]                              # (L, s) node ids
            cand = rows - (rows > ii[:, None])
            cand = np.where(rows < 0, -1, cand)
            ranks = _rank_batched(n - 1, s, cand, ssz_c[cc])
            partials[d].append(ii.astype(np.int32), ranks,
                               sc[cc, ii], rows.astype(np.int32))
        note_peak(ti_c.nbytes + sc.nbytes + member.nbytes + keep.nbytes
                  + 2 * len(cc) * (4 + 8 + 4 + 4 * s))
        if partials[d].since_compact >= _COMPACT_EVERY:
            partials[d].compact(best, delta, max_keep)

    # ---- dispatch: round-robin over the LPT buckets, bounded in-flight
    t_stream = time.time()
    plan_s = t_stream - t_plan
    schedule = []
    width = max(len(b) for b in plan.device_chunks)
    for r in range(width):
        for d, bucket in enumerate(plan.device_chunks):
            if r < len(bucket):
                schedule.append((d, bucket[r]))
    pending: deque = deque()
    for d, ci in schedule:
        de, su, sz, lk, lj = dev_in[d]
        ids = jax.device_put(jnp.asarray([ci], jnp.int32), devices[d])
        out = _run_device(de, su, sz, lk, lj, ids, q=q, s=s, n=n, ess=ess,
                          use_pallas=use_pallas, block_m=block_m,
                          interpret=interpret)            # async dispatch
        pending.append((d, ci, out))
        if len(pending) >= _INFLIGHT_PER_DEV * plan.n_devices:
            dd, cc_, fut = pending.popleft()
            merge_chunk(dd, cc_, np.asarray(fut)[0])
    while pending:
        dd, cc_, fut = pending.popleft()
        merge_chunk(dd, cc_, np.asarray(fut)[0])

    # ---- one merge at the end: final threshold, pack, hash
    t_final = time.time()
    stream_s = t_final - t_stream
    node = np.concatenate([np.concatenate(p.node) if p.node else
                           np.empty(0, np.int32) for p in partials])
    rank = np.concatenate([np.concatenate(p.rank) if p.rank else
                           np.empty(0, np.int64) for p in partials])
    ls = np.concatenate([np.concatenate(p.ls) if p.ls else
                         np.empty(0, np.float32) for p in partials])
    par = np.concatenate([np.concatenate(p.par) if p.par else
                          np.empty((0, s), np.int32) for p in partials])
    keep = ls >= (best - float(delta))[node]
    node, rank, ls, par = node[keep], rank[keep], ls[keep], par[keep]
    if max_keep is not None and len(node):
        node, rank, ls, par = _cap_per_node(node, rank, ls, par, n, max_keep)
    note_peak(node.nbytes + rank.nbytes + ls.nbytes + par.nbytes)

    order = np.lexsort((rank, node))          # per node, ascending rank
    node, rank, ls, par = node[order], rank[order], ls[order], par[order]
    counts = np.bincount(node, minlength=n)
    K = int(counts.max()) + 1 if len(node) else 1        # +1: forced rank 0
    kept_idx = np.full((n, K), -1, np.int32)
    kept_ls = np.full((n, K), np.float32(NEG_INF), np.float32)
    kept_parents = np.full((n, K, s), -1, np.int32)
    kept_idx[:, 0] = 0                                   # empty set first
    kept_ls[:, 0] = ls0
    starts = np.zeros(n + 1, np.int64)
    starts[1:] = np.cumsum(counts)
    pos = np.arange(len(node)) - starts[node] + 1
    kept_idx[node, pos] = rank.astype(np.int32)
    kept_ls[node, pos] = ls
    kept_parents[node, pos] = par
    note_peak(kept_idx.nbytes + kept_ls.nbytes + kept_parents.nbytes)

    sp = SparseScoreTable.from_kept(kept_idx, kept_ls, kept_parents,
                                    q=q, s=s, delta=delta, S=S)
    info = {"peak_assembly_bytes": int(peak), "n_chunks": plan.n_chunks,
            "n_devices": plan.n_devices, "imbalance": plan.imbalance,
            "kept_entries": int(counts.sum()) + n, "K": K,
            "stages": {"plan_s": plan_s, "stream_s": stream_s,
                       "finalize_s": time.time() - t_final}}
    return sp, info
