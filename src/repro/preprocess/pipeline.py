"""The fused preprocessing pipeline: cache -> plan -> fused count+score ->
assemble (dense OR streaming-pruned) -> cache store.

Replaces core/scores.build_score_table's host-side double loop (n nodes x
S/chunk chunks, one device round-trip each) with:

1. one fused count+score pass per column-subset chunk (fused.py) — all n
   children of a chunk are scored by a single contraction;
2. cost-balanced chunk sharding across devices (planner.py, paper §III-B);
3. one of two assemblies:

   * **dense** (``prune_delta=None``, or ``streaming=False``): a single
     jitted scan per device, then a gather
     ls(i, pi) = |pi|*ln(gamma) + TI[rank(columns(pi, i)), i] using the
     vectorized combination ranking (core/combinatorics) — the rank IS the
     hash (paper §III-A). Materialises the (n, S) table (plus an (n, S)
     host-side rank map), which is the memory wall at n >= 100;
   * **streaming** (``prune_delta`` set — the default engine for pruned
     tables, streaming.py): per-chunk dispatch whose (chunk, n) output is
     rank-gathered chunk-locally and merged into per-node within-delta
     candidate lists under a global running best, going straight into the
     pruned SparseScoreTable. Peak memory O(n·K + chunk·n); NO dense (n, S)
     table or rank map ever exists. Bitwise-equal to dense+prune
     (tests/test_streaming.py pins it).

4. a disk cache (cache.py) keyed on (data, q, s, ess, gamma, prior). Dense
   runs cache the dense table (one entry serves every delta); streaming runs
   cache the pruned representation under a key that additionally includes
   (prune_delta, max_keep) — "always cache the DENSE table" is no longer
   possible at streaming scale. Pruned lookups try sparse first, then fall
   back to pruning a dense entry, then build. Every restore is
   manifest-verified (wrong q/s/m/n/... is a logged miss, never a
   wrong-shape table).

The dense result is bitwise-compatible with build_score_table on CPU (the
oracle's reduction order is reproduced deliberately; see fused.py) at a
fraction of the wall clock — benchmarks/preprocess_bench.py measures >= 3x
at n = 64 and ~10x at ALARM size, which is what makes n > 60 end-to-end
practical; the streaming path extends reach to n = 100, s = 4 (S ~ 3.9M)
where the dense intermediate alone is ~1.6 GB.

With ``return_info=True`` the info dict has the SAME schema on cache hit and
miss: {cache_hit, n, S, plan, preprocess_s, streaming,
peak_assembly_bytes, stages}. ``plan`` is None on a cache hit (no sharding
was planned), a {n_chunks, n_devices, imbalance} dict otherwise;
``peak_assembly_bytes`` is None unless the streaming assembly ran.
``stages`` breaks ``preprocess_s`` into per-stage wall-clock seconds
(plan_s/score_s/assemble_s on the dense path, plan_s/stream_s/finalize_s
streaming, cache_load_s/cache_store_s around the disk cache) — the
telemetry collector (launch/bn_learn --telemetry) emits them as stage rows.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.combinatorics import build_pst, n_parent_sets, rank_combinations_batch
from ..core.scores import ScoreTable, validate_prior_matrix
from .cache import (cache_key, load_cached_sparse, load_cached_table,
                    store_cached_sparse, store_cached_table)
from .fused import (encode_subset_codes, fused_scores_pallas,
                    fused_scores_ref, score_luts)
from .planner import plan_preprocess
from .sparse import SparseScoreTable, prune_table

__all__ = ["build_score_table_fused", "assemble_table"]


def _rank_map(n: int, s: int, pst: np.ndarray, psizes: np.ndarray) -> np.ndarray:
    """(n, S) int32: rank_map[i, t] = rank (in the size-ascending subset
    enumeration over the n columns) of the column set of PST row t for node i.
    Candidate->column mapping is monotone, so digit order is preserved and
    the subset's config bins line up with the PST entry's.

    Built one node at a time: the batch ranking's int64 temporaries are
    (S, s)-sized, so peak host memory stays ~S*s*8 bytes regardless of n
    (an (n, S, s) broadcast would peak at ~12 GB for n=64, s=4).

    Dense-assembly only — the streaming path computes the INVERSE map chunk
    by chunk (streaming.py) and never materialises this array."""
    out = np.empty((n, pst.shape[0]), np.int32)
    for i in range(n):
        cols = pst + (pst >= i)
        cols = np.where(pst < 0, -1, cols)
        out[i] = rank_combinations_batch(n, s, cols, psizes)
    return out


def assemble_table(TI: jnp.ndarray, rank_map: np.ndarray, psizes: np.ndarray,
                   log_gamma: float) -> jnp.ndarray:
    """(n, S) table from the fused per-subset output: a pure gather."""
    n = TI.shape[1]
    kfac = jnp.asarray(np.asarray(psizes, np.float32)) * jnp.float32(log_gamma)
    rm = jnp.asarray(rank_map)
    return kfac[None, :] + TI[rm, jnp.arange(n, dtype=jnp.int32)[:, None]]


@functools.partial(jax.jit, static_argnames=("q", "s", "n", "ess",
                                             "use_pallas", "block_m",
                                             "interpret"))
def _run_device(data_ext, subs, sszs, lut_k, lut_j, chunk_ids, *, q, s, n,
                ess, use_pallas, block_m, interpret):
    """One device's share: a single jitted scan over its chunk ids ->
    stacked (U, C, n) TI. Module-level so the trace is compiled once per
    problem shape, not once per build call. The streaming assembly reuses it
    with (1,)-shaped chunk_ids (one trace serves all chunks)."""
    m = data_ext.shape[0]
    child_oh = jax.nn.one_hot(data_ext[:, :n].reshape(-1), q,
                              dtype=jnp.float32).reshape(m, n * q)
    if use_pallas:
        child_p = jnp.pad(child_oh, ((0, (-m) % block_m), (0, 0)))

    def body(_, ci):
        sub_c = subs[ci]
        ssz_c = sszs[ci]
        if use_pallas:
            codes = encode_subset_codes(data_ext, sub_c, q).T       # (C, m)
            codes = jnp.pad(codes, ((0, 0), (0, (-m) % block_m)),
                            constant_values=-1)
            ti = fused_scores_pallas(codes, child_p, ssz_c, q=q, s=s,
                                     n=n, ess=ess, block_m=block_m,
                                     interpret=interpret)
        else:
            ti = fused_scores_ref(data_ext, child_oh, sub_c, ssz_c,
                                  lut_k, lut_j, q=q, s=s, n=n)
        return None, ti

    _, TI = jax.lax.scan(body, None, chunk_ids)
    return TI


def build_score_table_fused(data: np.ndarray, *, q: int, s: int,
                            gamma: float = 0.1, ess: float = 1.0,
                            chunk: int = 1024,
                            prior_matrix: np.ndarray | None = None,
                            prune_delta: float | None = None,
                            max_keep: int | None = None,
                            streaming: bool | None = None,
                            cache_dir: str | None = None,
                            mesh=None, devices=None,
                            use_pallas: bool | None = None,
                            block_m: int = 512,
                            interpret: bool | None = None,
                            return_info: bool = False):
    """Drop-in replacement for core/scores.build_score_table (same table, same
    PST ordering) via the fused pipeline. Returns a ScoreTable — or a
    SparseScoreTable when ``prune_delta`` is set — and, with
    ``return_info=True``, an info dict with a schema that is IDENTICAL on
    cache hit and miss (see module docstring).

    ``streaming`` selects the assembly when ``prune_delta`` is set: None
    (default) and True stream chunks straight into the pruned table with no
    dense (n, S) intermediate; False forces the dense build-then-prune path
    (the oracle the streaming tests compare against). ``max_keep``
    optionally caps each node's kept list at its top-``max_keep`` scores
    (streaming path only).

    ``mesh``/``devices`` pick the accelerators to shard chunks over
    (launch/mesh meshes work directly); default is the first local device.
    ``use_pallas`` defaults to True on TPU, False elsewhere (the jnp fused
    path is the fast CPU path; the kernel is the fast TPU path).
    """
    t0 = time.time()
    data = np.asarray(data, dtype=np.int32)
    m, n = data.shape
    if np.any(data < 0) or np.any(data >= q):
        raise ValueError(f"data states must lie in [0, {q})")
    validate_prior_matrix(prior_matrix, n)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if streaming is None:
        streaming = prune_delta is not None
    streaming = bool(streaming) and prune_delta is not None

    S = n_parent_sets(n - 1, s)
    # "stages" is the per-stage wall-clock breakdown of preprocess_s — the
    # telemetry collector's stage rows (launch/bn_learn) read it verbatim
    info: dict = {"cache_hit": False, "n": n, "S": S, "plan": None,
                  "preprocess_s": None, "streaming": streaming,
                  "peak_assembly_bytes": None, "stages": {}}
    log_gamma = float(np.log(gamma))
    expect = {"q": q, "s": s, "m": m, "n": n,
              "gamma": float(gamma), "ess": float(ess)}
    if devices is None:
        devices = (list(np.asarray(mesh.devices).flat) if mesh is not None
                   else [jax.devices()[0]])

    # ---- cache lookups: sparse (exact delta/max_keep) first, then dense
    key = skey = None
    if cache_dir:
        key = cache_key(data, q=q, s=s, gamma=gamma, ess=ess,
                        prior_matrix=prior_matrix)
        if prune_delta is not None:
            skey = cache_key(data, q=q, s=s, gamma=gamma, ess=ess,
                             prior_matrix=prior_matrix,
                             prune_delta=prune_delta, max_keep=max_keep)
            hit = load_cached_sparse(cache_dir, skey, expect=expect)
            if hit is not None:
                kept_idx, kept_ls, kept_parents, _ = hit
                sp = SparseScoreTable.from_kept(
                    kept_idx, kept_ls, kept_parents,
                    q=q, s=s, delta=prune_delta, S=S)
                info.update(cache_hit=True, preprocess_s=time.time() - t0)
                info["stages"]["cache_load_s"] = info["preprocess_s"]
                return (sp, info) if return_info else sp
        cached = load_cached_table(cache_dir, key, expect=expect)
        if cached is not None:
            table_np, pst_c, psz_c = cached
            info.update(cache_hit=True, streaming=False,
                        preprocess_s=time.time() - t0)
            info["stages"]["cache_load_s"] = info["preprocess_s"]
            st = ScoreTable(jnp.asarray(table_np), np.asarray(pst_c),
                            np.asarray(psz_c), q, s)
            if prune_delta is not None:
                st = prune_table(st, prune_delta)
            return (st, info) if return_info else st

    # ---- streaming assembly: chunks -> pruned table, no dense intermediate
    if streaming:
        from .streaming import build_sparse_table_streaming
        sp, sinfo = build_sparse_table_streaming(
            data, q=q, s=s, gamma=gamma, ess=ess, chunk=chunk,
            delta=prune_delta, prior_matrix=prior_matrix, max_keep=max_keep,
            devices=devices, use_pallas=use_pallas, block_m=block_m,
            interpret=interpret)
        info["plan"] = {k: sinfo[k] for k in
                        ("n_chunks", "n_devices", "imbalance")}
        info["peak_assembly_bytes"] = sinfo["peak_assembly_bytes"]
        info["stages"].update(sinfo.get("stages", {}))
        info["preprocess_s"] = time.time() - t0
        if cache_dir:
            t_store = time.time()
            store_cached_sparse(
                cache_dir, skey or cache_key(
                    data, q=q, s=s, gamma=gamma, ess=ess,
                    prior_matrix=prior_matrix, prune_delta=prune_delta,
                    max_keep=max_keep),
                np.asarray(sp.kept_idx), np.asarray(sp.kept_ls),
                np.asarray(sp.kept_parents),
                metadata={**expect, "prune_delta": float(prune_delta),
                          "max_keep": max_keep, "S": S})
            info["stages"]["cache_store_s"] = time.time() - t_store
        return (sp, info) if return_info else sp

    # ---- dense assembly -------------------------------------------------
    t_plan = time.time()
    pst, psizes = build_pst(n - 1, s)

    # plan: column subsets, chunked + cost-sharded (paper §III-B)
    sub, ssz = build_pst(n, s)                   # subsets of ALL n columns
    Csub = sub.shape[0]
    chunk = min(chunk, Csub)
    pad = (-Csub) % chunk
    sub_p = np.pad(sub, ((0, pad), (0, 0)), constant_values=-1)
    ssz_p = np.pad(ssz, (0, pad))
    nch = sub_p.shape[0] // chunk
    plan = plan_preprocess(ssz_p, chunk, m, q, len(devices))
    info["plan"] = {"n_chunks": plan.n_chunks, "n_devices": plan.n_devices,
                    "imbalance": plan.imbalance}
    info["stages"]["plan_s"] = time.time() - t_plan
    t_score = time.time()

    # execute: one jitted scan per device over its chunks
    data_ext = np.concatenate([data, np.zeros((m, 1), np.int32)], axis=1)
    subs3 = sub_p.reshape(nch, chunk, s)
    sszs2 = ssz_p.reshape(nch, chunk)
    lut_k, lut_j = score_luts(q, s, m, ess)
    per_dev = []
    for d, dev in enumerate(devices[:plan.n_devices]):
        de = jax.device_put(jnp.asarray(data_ext), dev)
        su = jax.device_put(jnp.asarray(subs3), dev)
        sz = jax.device_put(jnp.asarray(sszs2), dev)
        lk = jax.device_put(lut_k, dev)
        lj = jax.device_put(lut_j, dev)
        ids = jax.device_put(jnp.asarray(plan.padded_chunks[d]), dev)
        out = _run_device(de, su, sz, lk, lj, ids, q=q, s=s, n=n, ess=ess,
                          use_pallas=use_pallas, block_m=block_m,
                          interpret=interpret)                # async dispatch
        per_dev.append((plan.padded_chunks[d], out))

    TI = np.zeros((nch * chunk, n), np.float32)
    for ids, out in per_dev:
        out = np.asarray(out)                              # (U, C, n) sync
        for u, ci in enumerate(ids):                       # dupes: same data
            TI[ci * chunk:(ci + 1) * chunk] = out[u]
    TI = jnp.asarray(TI[:Csub])
    info["stages"]["score_s"] = time.time() - t_score
    t_asm = time.time()

    # assemble: rank-gather + structure penalty (+ prior)
    rmap = _rank_map(n, s, pst, psizes)
    table = assemble_table(TI, rmap, psizes, log_gamma)
    if prior_matrix is not None:
        from ..core.priors import prior_table
        table = table + prior_table(jnp.asarray(prior_matrix, jnp.float32),
                                    jnp.asarray(pst), n)
    info["stages"]["assemble_s"] = time.time() - t_asm
    info["preprocess_s"] = time.time() - t0

    if cache_dir:
        t_store = time.time()
        store_cached_table(cache_dir, key, np.asarray(table), pst, psizes,
                           metadata={**expect, "kind": "dense"})
        info["stages"]["cache_store_s"] = time.time() - t_store

    st = ScoreTable(table, pst, psizes, q, s)
    if prune_delta is not None:
        t_prune = time.time()
        st = prune_table(st, prune_delta)
        info["stages"]["prune_s"] = time.time() - t_prune
    return (st, info) if return_info else st
