"""Fused count+score for one column-subset chunk (paper §III-A on-device).

The reference preprocessing (core/scores.build_score_table) materialises a
(C, q^s, q) contingency tensor per (node, chunk) unit and re-builds the
parent-config one-hot for every node. The fused formulation exploits two
identities:

* **Count once per column subset, against every child at once.** The
  contingency counts for parent set pi of node i depend only on the *column
  set* sigma = columns(pi, i) and the child column i. Counting sigma jointly
  against the one-hot of ALL n columns — one (Q x m) @ (m x n*q) matmul —
  amortises the (m, C, Q) one-hot build over all n children, an ~n-fold cut
  in the memory traffic that dominates preprocessing.

* **Scores depend on counts only through small integer marginals.** With a
  uniform arity q, Eq. 4's gammaln terms take only (s+1) x (m+1) distinct
  values: gammaln(N + alpha) for integer N in [0, m] and alpha determined by
  |pi|. The ref path replaces gammaln evaluation with two precomputed lookup
  tables (:func:`score_luts`), turning the transcendental bulk of scoring into
  gathers; the Pallas kernel evaluates gammaln directly on the (Q, n*q) counts
  block it just produced in VMEM — either way the (C, q^s, q) tensor never
  reaches HBM, only the (C, n) fused output does.

The per-subset output is ``TI[c, i] = sum_{k active} (term_k + term_jk)`` —
everything of ls(i, pi) except the |pi|*ln(gamma) structure penalty, which the
assembly (pipeline.py) adds per PST entry. The bin reduction is an explicitly
SEQUENTIAL accumulation over the q^s bins so it reproduces the oracle's
row-sum order: fused tables match `local_scores_chunk` bitwise on CPU (the
property tests in tests/test_preprocess.py pin this to <= 1e-4 absolute).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.scipy.special import gammaln

__all__ = ["score_luts", "fused_scores_ref", "fused_scores_pallas",
           "encode_subset_codes"]


def score_luts(q: int, s: int, m: int, ess: float):
    """(lut_k, lut_j), each (s+1, m+1) f32: the two gammaln families of Eq. 4
    tabulated over parent-set size k (rows) and integer count N (cols).

    lut_k[k, N] = gammaln(a_k) - gammaln(a_k + N),   a_k  = ess / q^k
    lut_j[k, N] = gammaln(N + a_jk) - gammaln(a_jk), a_jk = ess / (q^k * q)

    Built with the same f32 ops as the oracle (jnp.power, jax gammaln) so the
    tabulated values are bitwise the oracle's.
    """
    ks = jnp.arange(s + 1, dtype=jnp.float32)
    r = jnp.power(float(q), ks)
    a_k = (ess / r)[:, None]
    a_jk = (ess / (r * q))[:, None]
    counts = jnp.arange(m + 1, dtype=jnp.float32)[None, :]
    lut_k = gammaln(a_k) - gammaln(a_k + counts)
    lut_j = gammaln(counts + a_jk) - gammaln(a_jk)
    return lut_k, lut_j


def encode_subset_codes(data_ext: jnp.ndarray, sub_chunk: jnp.ndarray,
                        q: int) -> jnp.ndarray:
    """Mixed-radix configuration codes for a chunk of column subsets.

    data_ext: (m, n+1) with an appended all-zeros column; sub_chunk: (C, s)
    sorted column indices, -1 padded (padding maps to the zeros column, so
    padded digit positions are the HIGH digits and contribute 0 — which is
    what makes `code < q^{|subset|}` the exact active-bin test).
    Returns (m, C) int32.
    """
    n = data_ext.shape[1] - 1
    cols = jnp.where(sub_chunk < 0, n, sub_chunk)        # (C, s)
    dcols = data_ext[:, cols]                            # (m, C, s)
    pw = q ** jnp.arange(sub_chunk.shape[1], dtype=jnp.int32)
    return jnp.sum(dcols * pw, axis=-1).astype(jnp.int32)


def _sequential_bin_sum(masked: jnp.ndarray) -> jnp.ndarray:
    """(C, Q, n) -> (C, n), accumulating the Q bins strictly in order — the
    same association order as the oracle's (C, Q) row sum, which is what keeps
    fused == reference at the ulp level."""
    C, _, n = masked.shape

    def step(acc, x):
        return acc + x, None

    acc, _ = jax.lax.scan(step, jnp.zeros((C, n), jnp.float32),
                          jnp.moveaxis(masked, 1, 0))
    return acc


@functools.partial(jax.jit, static_argnames=("q", "s", "n"))
def fused_scores_ref(data_ext: jnp.ndarray, child_oh: jnp.ndarray,
                     sub_chunk: jnp.ndarray, ssz_chunk: jnp.ndarray,
                     lut_k: jnp.ndarray, lut_j: jnp.ndarray, *,
                     q: int, s: int, n: int) -> jnp.ndarray:
    """Pure-jnp fused chunk: (C, n) TI for one chunk of column subsets.

    child_oh: (m, n*q) one-hot of every column (built once per table).
    Counts are produced by one MXU-shaped contraction, immediately consumed
    by LUT gathers, and discarded — the only chunk output is (C, n).
    """
    C = sub_chunk.shape[0]
    Q = q ** s
    code = encode_subset_codes(data_ext, sub_chunk, q)               # (m, C)
    oh = jax.nn.one_hot(code, Q, dtype=jnp.float32)                  # (m, C, Q)
    counts = jnp.round(jnp.einsum("mcQ,mJ->cQJ", oh, child_oh)
                       ).astype(jnp.int32)                           # (C, Q, n*q)
    sz = ssz_chunk
    Nk = counts[:, :, 0:q].sum(-1)                                   # (C, Q)
    bins = jnp.arange(Q, dtype=jnp.float32)[None, :]
    active = bins + 0.5 < jnp.power(float(q), sz.astype(jnp.float32))[:, None]
    term_k = lut_k[sz[:, None], Nk]                                  # (C, Q)
    term_j = lut_j[sz[:, None, None], counts]                        # (C, Q, n*q)
    tj = term_j.reshape(C, Q, n, q).sum(-1)                          # (C, Q, n)
    masked = active[:, :, None] * (tj + term_k[:, :, None])
    return _sequential_bin_sum(masked)                               # (C, n)


def _fused_kernel(sizes_ref, codes_ref, child_oh_ref, out_ref, counts_ref, *,
                  Q: int, q: int, n: int, block_m: int, ess: float):
    """Per (subset, m-block) program: accumulate the (Q, n*q) counts block in
    VMEM, and on the last m-block collapse it straight to the (n,) fused
    scores — the counts never leave VMEM (the fusion the paper leaves as
    future work, §VII)."""
    mb = pl.program_id(1)
    nmb = pl.num_programs(1)

    @pl.when(mb == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    codes = codes_ref[0, :]                              # (BM,) int32, -1 pad
    valid = codes >= 0
    bins = jax.lax.broadcasted_iota(jnp.int32, (block_m, Q), 1)
    oh = (codes[:, None] == bins).astype(jnp.float32)    # pad rows all-zero
    # mask padded samples out of the child one-hot too: correctness must not
    # depend on the caller having zero-padded it (see kernels/count bugfix)
    child = jnp.where(valid[:, None], child_oh_ref[...], 0.0)   # (BM, n*q)
    counts_ref[...] += jax.lax.dot_general(
        oh, child, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (Q, n*q)

    @pl.when(mb == nmb - 1)
    def _score():
        counts = counts_ref[...]
        szf = sizes_ref[0, 0].astype(jnp.float32)
        r = jnp.power(float(q), szf)
        a_k = ess / r
        a_jk = ess / (r * q)
        Nk = jnp.sum(counts[:, 0:q], axis=-1)                        # (Q,)
        term_k = gammaln(a_k) - gammaln(a_k + Nk)                    # (Q,)
        gl = gammaln(counts + a_jk) - gammaln(a_jk)                  # (Q, n*q)
        # per-child j-sum as an MXU matmul with a block-diagonal 0/1 matrix
        # (avoids an in-kernel reshape, which Mosaic restricts)
        col = jax.lax.broadcasted_iota(jnp.int32, (n * q, n), 0) // q
        tgt = jax.lax.broadcasted_iota(jnp.int32, (n * q, n), 1)
        sum_mat = (col == tgt).astype(jnp.float32)                   # (n*q, n)
        tj = jax.lax.dot_general(gl, sum_mat, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q, n)
        kbins = jax.lax.broadcasted_iota(jnp.float32, (Q,), 0)
        active = (kbins + 0.5 < r).astype(jnp.float32)               # (Q,)
        masked = active[:, None] * (tj + term_k[:, None])            # (Q, n)

        def body(k, acc):
            return acc + masked[k, :]

        out_ref[0, :] = jax.lax.fori_loop(0, Q, body,
                                          jnp.zeros((n,), jnp.float32))


@functools.partial(jax.jit, static_argnames=("q", "s", "n", "ess", "block_m",
                                             "interpret"))
def fused_scores_pallas(codes: jnp.ndarray, child_oh: jnp.ndarray,
                        ssz_chunk: jnp.ndarray, *, q: int, s: int, n: int,
                        ess: float = 1.0, block_m: int = 512,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Pallas fused count+score. codes: (C, m) int32 subset config codes with
    -1 sample padding; child_oh: (m, n*q) one-hot of all columns (padded rows
    are masked in-kernel); ssz_chunk: (C,) subset sizes. Returns (C, n) TI.
    m must already be padded to a multiple of block_m."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    C, m = codes.shape
    Q = q ** s
    assert m % block_m == 0, "pad m to a multiple of block_m (codes with -1)"
    grid = (C, m // block_m)
    kernel = functools.partial(_fused_kernel, Q=Q, q=q, n=n,
                               block_m=block_m, ess=ess)
    sizes2d = ssz_chunk.astype(jnp.int32)[:, None]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda c, mb: (c, 0)),
            pl.BlockSpec((1, block_m), lambda c, mb: (c, mb)),
            pl.BlockSpec((block_m, n * q), lambda c, mb: (mb, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda c, mb: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((C, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((Q, n * q), jnp.float32)],
        interpret=interpret,
    )(sizes2d, codes, child_oh)
