"""Task-assignment planner (paper §III-B) for the preprocessing pipeline.

The paper assigns score-computation work to GPU blocks by *estimated cost*,
not by unit count: a parent set pi costs ~ q^{|pi|} * m (bins x samples).
We shard at the granularity of column-subset chunks (fused.py) and balance
chunks across devices with LPT (longest-processing-time-first) greedy
scheduling — the classic 4/3-approximation to makespan, which is exactly the
imbalance the paper's Fig. 6 task table addresses.

The planner is pure (no device state): it maps a cost vector to per-device
chunk lists, so it is unit-testable at any simulated device count and is
reused by launch/bn_learn through pipeline.build_score_table_fused with the
devices of a launch/mesh mesh.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["chunk_costs", "assign_chunks", "PreprocessPlan", "plan_preprocess"]


def chunk_costs(sub_sizes: np.ndarray, chunk: int, m: int, q: int) -> np.ndarray:
    """(n_chunks,) float64 estimated cost of each subset chunk:
    sum over its rows of q^{size} * m (paper §III-B's per-set estimate).

    This is the paper's cost model, an upper envelope on the active-bin
    scoring work. The fused matmul itself is near-uniform per chunk (its
    width is always q^s), so over uniform chunks LPT degrades gracefully
    toward chunk-count balance — the model matters most for the padded tail
    chunk and for mixed-size chunks at small S."""
    sub_sizes = np.asarray(sub_sizes)
    assert sub_sizes.shape[0] % chunk == 0, "pad subsets to a chunk multiple"
    per_row = (float(q) ** sub_sizes.astype(np.float64)) * float(m)
    return per_row.reshape(-1, chunk).sum(axis=1)


def assign_chunks(costs: np.ndarray, n_devices: int) -> list[list[int]]:
    """LPT assignment: chunks sorted by descending cost, each placed on the
    currently least-loaded device. Returns per-device chunk-id lists (each
    list ascending, for deterministic execution order)."""
    costs = np.asarray(costs, dtype=np.float64)
    loads = np.zeros(n_devices)
    buckets: list[list[int]] = [[] for _ in range(n_devices)]
    for c in np.argsort(-costs, kind="stable"):
        d = int(np.argmin(loads))
        buckets[d].append(int(c))
        loads[d] += costs[c]
    return [sorted(b) for b in buckets]


@dataclass
class PreprocessPlan:
    """Sharding decision for one preprocessing run."""
    chunk: int
    n_chunks: int
    costs: np.ndarray                       # (n_chunks,) estimated unit costs
    device_chunks: list[list[int]]          # per-device ascending chunk ids
    padded_chunks: list[np.ndarray] = field(default_factory=list)
    # per-device ids padded (by repeating the last id) to a common length so
    # every device runs the same static-shape scan; duplicate results are
    # overwritten with identical values at assembly.

    @property
    def n_devices(self) -> int:
        return len(self.device_chunks)

    @property
    def device_loads(self) -> np.ndarray:
        return np.asarray([sum(self.costs[c] for c in b) if b else 0.0
                           for b in self.device_chunks])

    @property
    def imbalance(self) -> float:
        """max/mean device load (1.0 = perfectly balanced)."""
        loads = self.device_loads
        mean = loads.mean()
        return float(loads.max() / mean) if mean > 0 else 1.0


def plan_preprocess(sub_sizes: np.ndarray, chunk: int, m: int, q: int,
                    n_devices: int) -> PreprocessPlan:
    """Full plan: cost model + LPT + static-shape padding.

    Every chunk id appears on exactly one device (before padding); padding
    repeats each device's last id so all scans share one trace.
    """
    costs = chunk_costs(sub_sizes, chunk, m, q)
    n_chunks = costs.shape[0]
    device_chunks = assign_chunks(costs, max(1, n_devices))
    # drop devices with no work (more devices than chunks); n_chunks >= 1
    # always (the PST includes the empty set), so at least one bucket remains
    device_chunks = [b for b in device_chunks if b]
    width = max((len(b) for b in device_chunks), default=0)
    padded = [np.asarray(b + [b[-1]] * (width - len(b)), dtype=np.int32)
              for b in device_chunks]
    return PreprocessPlan(chunk=chunk, n_chunks=n_chunks, costs=costs,
                          device_chunks=device_chunks, padded_chunks=padded)
