"""Golden pytree-leaf registry for checkpointed NamedTuple state.

Every leaf-count migration in this repo's history (9 → 13 ChainState
leaves across the bitmask/adaptive engine, +7 TraceState leaves for
telemetry) had to be hand-backfilled in every checkpoint path via the
checkpointer's ``allow_missing`` restore. This registry makes the layout a
DECLARED contract: the ``pytree-unregistered-field`` bnlint rule compares
the real class definitions against it, so a field added or reordered
without (a) bumping the registry version, (b) updating the expected field
tuple here, and (c) keeping an ``allow_missing=True`` backfill path in the
restore code, fails ``make lint`` before it can strand old checkpoints.

Field ORDER is part of the contract, not just the count: checkpoint leaves
are restored positionally-by-name (``leaf_<index>``), and new fields must be
appended LAST so pre-migration snapshots keep their alignment (see the
ChainState docstring in core/mcmc.py).
"""
from __future__ import annotations

__all__ = ["PYTREE_REGISTRY", "registered_fields", "registered_leaves"]

PYTREE_REGISTRY: dict[str, dict] = {
    "ChainState": {
        "module": "src/repro/core/mcmc.py",
        "version": 3,        # v1: 8 leaves; v2: +cur_ls (9); v3: +bitmask/adaptive (13)
        "fields": ("key", "pos", "score", "cur_idx", "best_score",
                   "best_idx", "best_pos", "accepts", "cur_ls",
                   "mask_planes", "win_idx", "adapt_err", "step"),
    },
    "TraceState": {
        "module": "src/repro/telemetry/taps.py",
        "version": 1,        # v1: 7 leaves, appended after ChainState's 13
        "fields": ("scores", "accepts", "taps", "win_hist",
                   "edge_counts", "edge_taps", "reseeds"),
    },
}


def registered_fields(name: str) -> tuple[str, ...]:
    return tuple(PYTREE_REGISTRY[name]["fields"])


def registered_leaves(name: str) -> int:
    """Leaf count of a registered state type (every field is one array
    leaf — NamedTuples of arrays flatten 1:1)."""
    return len(PYTREE_REGISTRY[name]["fields"])
