"""bnlint CLI.

    python -m repro.analysis [paths...] [--fail-on-findings] [--json]
                             [--baseline PATH | --no-baseline]
                             [--write-baseline] [--expect rule,rule,...]
                             [--emit-vmem]

Exit codes: 0 clean (or all --expect rules fired), 1 internal/usage error,
2 unbaselined findings under --fail-on-findings (or missing --expect rule).
"""
from __future__ import annotations

import argparse
import json
import sys

from .engine import DEFAULT_BASELINE, BaselineError, lint, write_baseline
from .rules import RULES


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="bnlint: static analysis for the JAX/Pallas repro repo")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to scan (default: src)")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 2 if any unbaselined finding remains")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline JSON path (default: the package baseline)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline entirely")
    ap.add_argument("--write-baseline", action="store_true",
                    help="record current unbaselined findings into the "
                         "baseline (reasons start as TODO and must be "
                         "filled in)")
    ap.add_argument("--expect", default="",
                    help="comma-separated rule ids that MUST fire "
                         "(fixture self-test mode): exit 0 iff all do")
    ap.add_argument("--emit-vmem", action="store_true",
                    help="emit static per-kernel VMEM rows into the BENCH "
                         "trajectories via benchmarks/common.save")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable findings on stdout")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, desc in sorted(RULES.items()):
            print(f"{rid:28s} {desc}")
        return 0

    paths = args.paths or ["src"]
    baseline = None if args.no_baseline else args.baseline

    if args.emit_vmem:
        from .vmem import emit_vmem_rows
        rows = emit_vmem_rows(paths)
        for row in rows:
            print(f"[vmem] {row['variant']:44s} "
                  f"{row['vmem_mib']:9.4f} MiB "
                  f"({row['vmem_frac_of_budget']:.1%} of budget)"
                  + (f"  assumed {row['assumed_dims']}"
                     if row["assumed_dims"] else ""))
        print(f"[vmem] {len(rows)} kernel estimate(s) merged into BENCH "
              "trajectories")
        return 0

    try:
        result = lint(paths, baseline_path=baseline)
    except (BaselineError, FileNotFoundError, SyntaxError) as exc:
        print(f"bnlint: error: {exc}", file=sys.stderr)
        return 1

    if args.write_baseline:
        path = args.baseline
        write_baseline(path, result.all_findings)
        print(f"bnlint: wrote {len(result.all_findings)} entrie(s) to "
              f"{path} — fill in every TODO reason before committing")
        return 0

    if args.json:
        print(json.dumps({
            "new": [f.as_dict() for f in result.new],
            "baselined": [f.as_dict() for f in result.baselined],
            "suppressed": [f.as_dict() for f in result.suppressed],
            "stale_baseline": sorted(result.stale_baseline),
        }, indent=2, sort_keys=True))
    else:
        for f in result.new:
            print(f.render())
        if result.baselined:
            print(f"bnlint: {len(result.baselined)} baselined finding(s) "
                  "(see baseline.json for reasons)")
        if result.suppressed:
            print(f"bnlint: {len(result.suppressed)} inline-suppressed "
                  "finding(s)")
        for key in sorted(result.stale_baseline):
            print(f"bnlint: warning: stale baseline entry (no longer "
                  f"fires): {key}")

    if args.expect:
        want = {r.strip() for r in args.expect.split(",") if r.strip()}
        unknown = want - set(RULES)
        if unknown:
            print(f"bnlint: error: unknown rule id(s) in --expect: "
                  f"{sorted(unknown)}", file=sys.stderr)
            return 1
        fired = {f.rule for f in result.all_findings}
        missing = want - fired
        if missing:
            print(f"bnlint: expected rule(s) did not fire: "
                  f"{sorted(missing)}", file=sys.stderr)
            return 2
        print(f"bnlint: all {len(want)} expected rule(s) fired")
        return 0

    if result.new:
        n = len(result.new)
        print(f"bnlint: {n} finding(s)" + (
            "" if not args.fail_on_findings else
            " — fix them or baseline with a reason"))
        if args.fail_on_findings:
            return 2
    else:
        print("bnlint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
