"""bnlint — static analysis for the JAX/Pallas reproduction codebase.

A pure-AST pass (analyzed code is parsed, never imported) with five rule
families tuned to this repo's failure history:

1. retrace hazards   — undeclared static args, eager switch/cond closures
                       (the PR-5 propose_move segfault pattern)
2. host-sync         — .item()/np.asarray/float() in code reachable from
                       jit, scan bodies, shard_map or the segment runner
3. pallas contracts  — grid/BlockSpec arithmetic, interpret= plumbing,
                       static VMEM-footprint estimates (vmem.py)
4. pytree drift      — checkpointed NamedTuples vs the golden leaf
                       registry (registry.py)
5. emit sites        — telemetry kinds vs schema.py, bench row keys vs
                       benchmarks/common.CONFIG_KEYS

Run it with ``python -m repro.analysis src benchmarks --fail-on-findings``
(the ``make lint`` target). Findings are suppressed inline with
``# bnlint: disable=<rule-id>`` or recorded in baseline.json with a
mandatory reason string.
"""
from __future__ import annotations

from .engine import (BaselineError, Finding, LintResult, lint, load_baseline,
                     load_project, write_baseline)
from .registry import PYTREE_REGISTRY, registered_fields, registered_leaves
from .rules import CHECKERS, RULES

__all__ = [
    "BaselineError", "Finding", "LintResult", "lint", "load_baseline",
    "load_project", "write_baseline", "PYTREE_REGISTRY",
    "registered_fields", "registered_leaves", "CHECKERS", "RULES",
]
