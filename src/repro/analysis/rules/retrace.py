"""Retrace-hazard rules (family 1).

The seed-era bug these encode: ``propose_move`` built fresh ``lax.switch``
branch closures on every eager Python call, so each call re-traced and
re-compiled the switch; ~800 property-test calls exhausted the LLVM JIT
code-mapping budget and segfaulted the suite (fixed in PR 5 by jitting the
public entry point with ``static_argnames=("window",)``).

* ``retrace-eager-switch`` — a module-level function that builds
  ``lax.switch``/``lax.cond`` branches from locally-created closures and has
  NO jitted entry point (neither decorated nor wrapped by a module-level
  ``partial(jax.jit, ...)`` assignment). Every eager call re-traces the
  branches. In-scan step helpers that are only ever called from inside a
  jitted run loop belong in the baseline with that reason.
* ``retrace-undeclared-static`` — a jitted function using a parameter in a
  Python-level static context (``if``/``while`` test, ``range``, ``assert``,
  shape argument) without declaring it in ``static_argnames``: either a
  trace-time TypeError, or — worse — silent retrace-per-value.
* ``retrace-loop-varying-static`` — a call to a known-jitted function inside
  a Python loop passing a loop-varying value for a STATIC parameter: one
  full recompile per iteration.
"""
from __future__ import annotations

import ast

from ..astutil import (call_name, jitted_functions, names_in, own_body_nodes,
                       qualname)
from ..engine import Finding, Project

RULE_EAGER = "retrace-eager-switch"
RULE_STATIC = "retrace-undeclared-static"
RULE_LOOP = "retrace-loop-varying-static"

_SWITCH_NAMES = {"jax.lax.switch", "lax.switch", "jax.lax.cond", "lax.cond"}

# attribute accesses that yield trace-STATIC Python values even on tracers:
# `n = pos.shape[0]; jnp.arange(n)` retraces only when the shape does, which
# is exactly when jit would retrace anyway — not an undeclared-static hazard
_SAFE_ATTRS = {"shape", "ndim", "size", "dtype", "itemsize"}


def _dynamic_names(expr: ast.AST) -> set[str]:
    """names_in(expr) minus names reached only through a trace-static
    attribute chain (x.shape[0], x.ndim, ...)."""
    safe_ids: set[int] = set()
    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and node.attr in _SAFE_ATTRS:
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Name):
                    safe_ids.add(id(sub))
    return {n.id for n in ast.walk(expr)
            if isinstance(n, ast.Name) and id(n) not in safe_ids}

# shape-position argument indices per callee suffix (None = every arg)
_SHAPE_ARGS: dict[str, tuple | None] = {
    "zeros": (0,), "ones": (0,), "full": (0,), "empty": (0,),
    "arange": None, "ShapeDtypeStruct": (0,), "broadcasted_iota": (1,),
    "reshape": None, "iota": (1,),
}


def _branch_exprs(call: ast.Call,
                  fn: ast.AST | None = None) -> list[ast.AST]:
    name = call_name(call)
    if name and name.endswith("switch") and len(call.args) >= 2:
        b = call.args[1]
        if isinstance(b, ast.Name) and fn is not None:
            # follow one local assignment: branches = [swap, insert, ...]
            for node in own_body_nodes(fn):
                if isinstance(node, ast.Assign) and any(
                        isinstance(t, ast.Name) and t.id == b.id
                        for t in node.targets):
                    b = node.value
                    break
        return list(b.elts) if isinstance(b, (ast.List, ast.Tuple)) else [b]
    if name and name.endswith("cond"):
        return list(call.args[1:3])
    return []


def _fresh_closures(branches: list[ast.AST], local_names: set[str]) -> bool:
    for b in branches:
        if isinstance(b, (ast.Lambda, ast.ListComp, ast.GeneratorExp)):
            return True
        if isinstance(b, ast.Name) and b.id in local_names:
            return True
        if isinstance(b, ast.Call):          # branch(j)-style factory calls
            return True
    return False


def check_eager_switch(project: Project) -> list[Finding]:
    findings = []
    for mod in project.modules:
        jitted = jitted_functions(mod.tree)
        for fn in mod.tree.body:
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name in jitted:
                continue                     # has a jitted entry point
            local = {n.name for n in ast.walk(fn)
                     if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                     and n is not fn}
            for node in own_body_nodes(fn):
                if not isinstance(node, ast.Call):
                    continue
                if call_name(node) not in _SWITCH_NAMES:
                    continue
                if _fresh_closures(_branch_exprs(node, fn), local):
                    findings.append(Finding(
                        RULE_EAGER, mod.relpath, fn.lineno, fn.name,
                        f"'{fn.name}' builds {call_name(node)} branches from "
                        "fresh closures but has no jitted entry point: every "
                        "eager call re-traces and re-compiles the branches "
                        "(the PR-5 propose_move segfault pattern). Wrap it "
                        "with jax.jit (static_argnames for config args) or "
                        "baseline it with the reason it is only ever called "
                        "inside a traced scan."))
                    break
    return findings


def _param_names(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]


def _tainted_locals(fn: ast.FunctionDef, seeds: set[str]) -> dict[str, str]:
    """name -> originating parameter, via one round of simple assignments."""
    origin = {s: s for s in seeds}
    for _ in range(2):                       # two rounds: a = f(p); b = g(a)
        for node in own_body_nodes(fn):
            if not isinstance(node, ast.Assign):
                continue
            used = _dynamic_names(node.value) & set(origin)
            if not used:
                continue
            src = origin[sorted(used)[0]]
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    origin.setdefault(tgt.id, src)
    return origin


def check_undeclared_static(project: Project) -> list[Finding]:
    findings = []
    for mod in project.modules:
        for name, (fn, statics) in jitted_functions(mod.tree).items():
            if fn is None or name != fn.name:
                continue                     # report once, on the impl
            params = set(_param_names(fn))
            undeclared = params - set(statics)
            if not undeclared:
                continue
            origin = _tainted_locals(fn, undeclared)
            hits: dict[str, tuple[int, str]] = {}

            def note(expr: ast.AST, why: str) -> None:
                for nm in _dynamic_names(expr) & set(origin):
                    hits.setdefault(origin[nm],
                                    (getattr(expr, "lineno", fn.lineno), why))

            for node in own_body_nodes(fn):
                if isinstance(node, (ast.If, ast.While)):
                    note(node.test, "Python control flow on its value")
                elif isinstance(node, ast.Assert):
                    note(node.test, "assert on its value")
                elif isinstance(node, ast.Call):
                    cn = call_name(node) or ""
                    if cn == "range":
                        for a in node.args:
                            note(a, "range() bound")
                    else:
                        idxs = _SHAPE_ARGS.get(cn.rsplit(".", 1)[-1])
                        if cn.rsplit(".", 1)[-1] in _SHAPE_ARGS:
                            args = (node.args if idxs is None
                                    else [node.args[i] for i in idxs
                                          if i < len(node.args)])
                            for a in args:
                                note(a, f"shape argument of {cn}")
            for pname, (line, why) in sorted(hits.items()):
                findings.append(Finding(
                    RULE_STATIC, mod.relpath, line, f"{fn.name}#{pname}",
                    f"jitted '{fn.name}' uses parameter '{pname}' in a "
                    f"static context ({why}) but does not declare it in "
                    "static_argnames: tracing either fails or silently "
                    "re-traces per value."))
    return findings


def check_loop_varying_static(project: Project) -> list[Finding]:
    # project-wide map: simple callable name -> (funcdef|None, statics)
    jit_map: dict[str, tuple] = {}
    for mod in project.modules:
        for name, info in jitted_functions(mod.tree).items():
            if info[1]:
                jit_map.setdefault(name, info)

    findings = []
    for mod in project.modules:
        for loop in ast.walk(mod.tree):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            loop_vars = (names_in(loop.target)
                         if isinstance(loop, ast.For) else set())
            if not loop_vars:
                continue
            for node in ast.walk(loop):
                if not isinstance(node, ast.Call):
                    continue
                cn = (call_name(node) or "").rsplit(".", 1)[-1]
                info = jit_map.get(cn)
                if info is None:
                    continue
                fn, statics = info
                static_args: list[tuple[str, ast.AST]] = [
                    (kw.arg, kw.value) for kw in node.keywords
                    if kw.arg in statics]
                if fn is not None:
                    pnames = _param_names(fn)
                    static_args += [
                        (pnames[i], a) for i, a in enumerate(node.args)
                        if i < len(pnames) and pnames[i] in statics]
                for pname, val in static_args:
                    if names_in(val) & loop_vars:
                        findings.append(Finding(
                            RULE_LOOP, mod.relpath, node.lineno,
                            f"{qualname(node)}#{cn}.{pname}",
                            f"static argument '{pname}' of jitted '{cn}' "
                            "varies with the enclosing Python loop: one "
                            "full recompile per iteration. Hoist the "
                            "compile out of the loop or make the argument "
                            "traced."))
    return findings


CHECKERS = [check_eager_switch, check_undeclared_static,
            check_loop_varying_static]
