"""bnlint rule families. Each module exposes CHECKERS: list of
``checker(project) -> list[Finding]``; RULES maps every rule id to a
one-line description (used by the CLI listing and docs)."""
from __future__ import annotations

from . import emitsites, hostsync, pallas, pytree, retrace

RULES: dict[str, str] = {
    retrace.RULE_EAGER:
        "eager lax.switch/cond with fresh branch closures and no jitted "
        "entry point (re-traces per call — the PR-5 segfault pattern)",
    retrace.RULE_STATIC:
        "jitted function uses a parameter statically without declaring it "
        "in static_argnames",
    retrace.RULE_LOOP:
        "static argument of a jitted function varies with an enclosing "
        "Python loop (recompile per iteration)",
    hostsync.RULE:
        ".item()/np.asarray/float()/int()/bool()/device_get inside code "
        "reachable from jit, scan bodies, shard_map or the segment runner",
    pallas.RULE_SPEC:
        "pallas_call grid/BlockSpec/out_shape arithmetic inconsistency",
    pallas.RULE_INTERPRET:
        "pallas_call interpret= missing or hardcoded instead of plumbed",
    pytree.RULE_FIELD:
        "checkpointed NamedTuple fields drifted from the golden registry "
        "without a version bump",
    pytree.RULE_STALE:
        "pytree registry entry points at a class that no longer exists",
    pytree.RULE_BACKFILL:
        "no allow_missing checkpoint-restore backfill path left under src/",
    emitsites.RULE_KIND:
        "telemetry row kind not declared in telemetry/schema.py REQUIRED",
    emitsites.RULE_CONFIG:
        "bench row key is an undeclared near-miss of a CONFIG_KEYS entry",
    emitsites.RULE_NO_CONFIG:
        "bench row has no CONFIG_KEYS field (merges by full-JSON identity)",
}

CHECKERS = (retrace.CHECKERS + hostsync.CHECKERS + pallas.CHECKERS
            + pytree.CHECKERS + emitsites.CHECKERS)

__all__ = ["RULES", "CHECKERS", "retrace", "hostsync", "pallas", "pytree",
           "emitsites"]
