"""Host-sync-in-hot-path rule (family 2).

A ``.item()``, ``np.asarray``, ``float()``/``int()``/``bool()`` coercion or
``jax.device_get`` on a tracer inside traced code either fails at trace
time or — when it sneaks through on a concrete value — forces a blocking
device→host transfer per iteration. The hot set is computed by
reachability, mirroring how code actually becomes traced in this repo:

roots
  * jit-covered functions (decorator or module-level wrapper assignment);
  * functions passed to ``lax.scan`` / ``fori_loop`` / ``while_loop`` /
    ``cond`` / ``switch`` / ``lax.map`` / ``vmap`` / ``pmap`` /
    ``shard_map`` (through one level of ``functools.partial``);
  * kernel bodies passed to ``pl.pallas_call`` (again through partial);
  * closures handed to ``core.mcmc.make_traced_segment_runner`` (``step``,
    ``tap``, ``exchange``) at any call site;
  * closures RETURNED by ``make_*`` factory functions — the repo's
    convention for building traced callables (make_tap, make_score_fn,
    make_delta_fn, ...).

edges
  direct calls by (possibly imported) name, so helpers called from scan
  bodies are hot transitively.

Host-side boundary code (the collector, the run supervisor between
segments, checkpoint I/O) is deliberately NOT reachable from these roots —
``np.asarray`` there is the designed device→host drain, not a bug.
"""
from __future__ import annotations

import ast

from ..astutil import (call_name, import_map, jit_static_names,
                       jitted_functions, is_jit_expr, partial_aliases,
                       qualname)
from ..engine import Finding, Project

RULE = "hostsync-in-hot-path"

_TRACING_WRAPPERS = {
    "jax.lax.scan", "lax.scan", "jax.lax.fori_loop", "lax.fori_loop",
    "jax.lax.while_loop", "lax.while_loop", "jax.lax.cond", "lax.cond",
    "jax.lax.switch", "lax.switch", "jax.lax.map", "lax.map",
    "jax.vmap", "vmap", "jax.pmap", "pmap", "shard_map",
    "jax.experimental.shard_map.shard_map",
}
_PALLAS = {"pl.pallas_call", "pallas_call", "jax.experimental.pallas.pallas_call"}
_SEGMENT_RUNNER = "make_traced_segment_runner"

_SYNC_CALLS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
               "jax.device_get", "onp.asarray", "onp.array"}
_SAFE_ATTR_TOKENS = {"shape", "ndim", "size", "dtype", "itemsize"}


class _Fn:
    """One call-graph node: a function def plus its name-resolution scope."""

    def __init__(self, mod, node: ast.AST, qual: str):
        self.mod = mod
        self.node = node
        self.qual = qual
        self.hot = False
        self.hot_via = ""


def _walk_own(node: ast.AST):
    """Walk a function's own body, excluding nested def/lambda subtrees."""
    stack = (list(node.body) if hasattr(node, "body")
             and not isinstance(node, ast.Lambda) else [node.body])
    while stack:
        cur = stack.pop()
        yield cur
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda, ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(cur))


def _collect_graph(project: Project):
    """Build nodes, name scopes, and the static-ish parameter sets."""
    nodes: dict[tuple[str, str], _Fn] = {}
    by_simple: dict[str, list[_Fn]] = {}
    mod_funcs: dict[str, dict[str, _Fn]] = {}

    for mod in project.modules:
        funcs: dict[str, _Fn] = {}
        for n in ast.walk(mod.tree):
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fn = _Fn(mod, n, qualname(n))
                nodes[(mod.relpath, fn.qual)] = fn
                by_simple.setdefault(n.name, []).append(fn)
                funcs.setdefault(n.name, fn)
        mod_funcs[mod.relpath] = funcs
    return nodes, by_simple, mod_funcs


def _callee_names(call: ast.Call, aliases: dict) -> list[str]:
    """Candidate function names referenced by a call argument position:
    plain Names, partial(...) wrappers, and partial-alias Names."""
    out = []
    for arg in list(call.args) + [kw.value for kw in call.keywords]:
        if isinstance(arg, ast.Name):
            tgt = aliases.get(arg.id, (arg.id, set()))[0]
            out.append(tgt)
        elif isinstance(arg, ast.Call) and \
                (call_name(arg) or "").endswith("partial") and arg.args \
                and isinstance(arg.args[0], ast.Name):
            out.append(arg.args[0].id)
    return out


def _mark(fn: _Fn, via: str, queue: list) -> None:
    if not fn.hot:
        fn.hot, fn.hot_via = True, via
        queue.append(fn)


def _static_params(fn_node: ast.AST, mod, aliases: dict) -> set[str]:
    """Parameters of ``fn_node`` that are bound as Python values at trace
    time: jit static_argnames, or keywords bound via functools.partial."""
    statics: set[str] = set()
    if isinstance(fn_node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        a = fn_node.args
        for p in (*a.posonlyargs, *a.args, *a.kwonlyargs):
            ann = p.annotation
            if isinstance(ann, ast.Name) and ann.id in {"int", "float",
                                                        "bool", "str"}:
                statics.add(p.arg)       # scalar-annotated: static by contract
        for dec in fn_node.decorator_list:
            if is_jit_expr(dec):
                statics |= set(jit_static_names(dec))
        for name, (wrapped, bound) in aliases.items():
            if wrapped == fn_node.name:
                statics |= bound
        jm = jitted_functions(mod.tree)
        if fn_node.name in jm:
            statics |= set(jm[fn_node.name][1])
    return statics


def check_hostsync(project: Project) -> list[Finding]:
    nodes, by_simple, mod_funcs = _collect_graph(project)
    queue: list[_Fn] = []

    # --- roots
    for mod in project.modules:
        funcs = mod_funcs[mod.relpath]
        aliases = partial_aliases(mod.tree)
        jm = jitted_functions(mod.tree)
        for name, (fn_node, _) in jm.items():
            if fn_node is not None and fn_node.name in funcs:
                _mark(funcs[fn_node.name], "jit", queue)
        for n in ast.walk(mod.tree):
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in n.decorator_list:
                    if is_jit_expr(dec):
                        key = (mod.relpath, qualname(n))
                        if key in nodes:
                            _mark(nodes[key], "jit", queue)
            if not isinstance(n, ast.Call):
                continue
            cn = call_name(n) or ""
            if cn in _TRACING_WRAPPERS or cn in _PALLAS \
                    or cn.rsplit(".", 1)[-1] == _SEGMENT_RUNNER:
                via = cn.rsplit(".", 1)[-1]
                for callee in _callee_names(n, aliases):
                    for cand in _resolve(callee, n, mod, by_simple,
                                         mod_funcs, project):
                        _mark(cand, via, queue)
        # closures returned by make_* factories run traced by convention
        for n in mod.tree.body:
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and n.name.startswith("make_"):
                inner = {f.name: f for f in ast.walk(n)
                         if isinstance(f, (ast.FunctionDef,
                                           ast.AsyncFunctionDef))
                         and f is not n}
                for ret in ast.walk(n):
                    if isinstance(ret, ast.Return) \
                            and isinstance(ret.value, ast.Name) \
                            and ret.value.id in inner:
                        key = (mod.relpath, qualname(inner[ret.value.id]))
                        if key in nodes:
                            _mark(nodes[key], f"{n.name} factory", queue)

    # --- propagate over direct-call edges
    while queue:
        fn = queue.pop()
        aliases = partial_aliases(fn.node)
        for n in _walk_own(fn.node):
            if not isinstance(n, ast.Call):
                continue
            cn = call_name(n)
            if not cn:
                continue
            for cand in _resolve(cn, n, fn.mod, by_simple, mod_funcs,
                                 project):
                _mark(cand, f"called from {fn.qual}", queue)
            for callee in _callee_names(n, aliases):
                if cn in _TRACING_WRAPPERS or cn in _PALLAS:
                    for cand in _resolve(callee, n, fn.mod, by_simple,
                                         mod_funcs, project):
                        _mark(cand, f"traced arg in {fn.qual}", queue)

    # --- violations inside hot bodies
    findings = []
    for fn in nodes.values():
        if not fn.hot:
            continue
        mod_aliases = partial_aliases(fn.mod.tree)
        statics = _static_params(fn.node, fn.mod, mod_aliases)
        for _ in range(2):               # locals derived from static values
            for n in _walk_own(fn.node):
                if not isinstance(n, ast.Assign):
                    continue
                if not _safe_cast_arg(n.value, statics):
                    continue
                for tgt in n.targets:
                    elts = (tgt.elts if isinstance(tgt, (ast.Tuple, ast.List))
                            else [tgt])
                    for e in elts:
                        if isinstance(e, ast.Name):
                            statics.add(e.id)
        for n in _walk_own(fn.node):
            if not isinstance(n, ast.Call):
                continue
            cn = call_name(n) or ""
            bad = None
            if isinstance(n.func, ast.Attribute) and n.func.attr == "item" \
                    and not n.args:
                bad = ".item() host sync"
            elif cn in _SYNC_CALLS:
                bad = f"{cn}() device->host transfer"
            elif cn in {"float", "int", "bool"} and len(n.args) == 1 \
                    and not _safe_cast_arg(n.args[0], statics):
                bad = f"{cn}() coercion of a possibly-traced value"
            if bad:
                findings.append(Finding(
                    RULE, fn.mod.relpath, n.lineno,
                    f"{fn.qual}#{cn or 'item'}",
                    f"{bad} inside hot path '{fn.qual}' (hot via "
                    f"{fn.hot_via}): traced code must stay on device — "
                    "move the coercion to the host side of the segment "
                    "boundary or use jnp ops."))
    return findings


def _resolve(name: str, call: ast.Call, mod, by_simple, mod_funcs, project):
    """Resolve a (possibly dotted) callee name to candidate graph nodes."""
    out = []
    simple = name.rsplit(".", 1)[-1]
    if "." not in name:
        if name in mod_funcs.get(mod.relpath, {}):
            return [mod_funcs[mod.relpath][name]]
        imports = import_map(mod.tree, mod.package)
        target = imports.get(name)
        if target:
            rel = "src/" + target.replace(".", "/") + ".py"
            other = project.find(rel)
            if other is not None and other.relpath in mod_funcs:
                f = mod_funcs[other.relpath].get(simple)
                return [f] if f else []
        return []
    # dotted: alias.func — resolve the alias to a project module
    base = name.split(".")[0]
    imports = import_map(mod.tree, mod.package)
    target = imports.get(base)
    if target:
        rel = "src/" + target.replace(".", "/") + ".py"
        other = project.find(rel)
        if other is not None and other.relpath in mod_funcs:
            f = mod_funcs[other.relpath].get(simple)
            if f:
                out.append(f)
    return out


def _safe_cast_arg(arg: ast.AST, statics: set[str]) -> bool:
    """float()/int()/bool() args that are knowably NOT tracers: literals,
    len()/shape/dtype lookups, and trace-time-static parameters."""
    if isinstance(arg, ast.Constant):
        return True
    if isinstance(arg, ast.Name):
        return arg.id in statics
    if isinstance(arg, ast.Attribute):
        toks = set()
        cur: ast.AST = arg
        while isinstance(cur, ast.Attribute):
            toks.add(cur.attr)
            cur = cur.value
        return bool(toks & _SAFE_ATTR_TOKENS)
    if isinstance(arg, ast.Subscript):
        return _safe_cast_arg(arg.value, statics)
    if isinstance(arg, ast.Call):
        full = call_name(arg) or ""
        cn = full.rsplit(".", 1)[-1]
        if cn in {"len", "ord", "round", "abs", "min", "max"}:
            return all(_safe_cast_arg(a, statics) for a in arg.args)
        # host math on knowably-static values: np.log2(cap) where
        # cap = keys.shape[1] — pure Python/numpy arithmetic, no tracer
        if full.split(".")[0] in {"np", "numpy", "onp", "math"}:
            return bool(arg.args) and all(
                _safe_cast_arg(a, statics) for a in arg.args)
        return False
    if isinstance(arg, ast.BinOp):
        return _safe_cast_arg(arg.left, statics) \
            and _safe_cast_arg(arg.right, statics)
    return False


CHECKERS = [check_hostsync]
