"""Pytree leaf-registry rules (family 4).

The checkpoint layout is positional: ChainState's 13 leaves come first,
TraceState's 7 ride after them, and every past layout migration (8 → 9 →
13 → +7) relied on the checkpointer's ``allow_missing`` backfill to keep
old snapshots restorable. These rules pin that contract to the golden
registry (analysis/registry.py):

* ``pytree-unregistered-field`` — a registered NamedTuple's real field
  tuple (names AND order) differs from the registry: the author must bump
  the registry version, append (never insert) the new fields, and keep the
  ``allow_missing`` backfill path working before lint passes.
* ``pytree-registry-stale`` — the registry points at a class/file that no
  longer exists (the registry itself rotted).
* ``pytree-no-backfill-restore`` — no ``allow_missing=True`` restore call
  remains anywhere under src/: the schema-evolution path old checkpoints
  depend on has been dropped. Only checked when the real state modules are
  part of the scan (fixture corpora skip it).
"""
from __future__ import annotations

import ast

from ..astutil import call_name, dotted
from ..engine import Finding, Project
from ..registry import PYTREE_REGISTRY

RULE_FIELD = "pytree-unregistered-field"
RULE_STALE = "pytree-registry-stale"
RULE_BACKFILL = "pytree-no-backfill-restore"

_RESTORE_CALLS = {"restore_checkpoint", "restore_latest_verified"}


def _namedtuple_fields(cls: ast.ClassDef) -> tuple[str, ...] | None:
    is_nt = any((dotted(b) or "").rsplit(".", 1)[-1] == "NamedTuple"
                for b in cls.bases)
    if not is_nt:
        return None
    fields = []
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                          ast.Name):
            fields.append(node.target.id)
    return tuple(fields)


def check_pytree_registry(project: Project) -> list[Finding]:
    findings = []
    seen: set[str] = set()
    for mod in project.modules:
        for node in mod.tree.body:
            if not isinstance(node, ast.ClassDef) \
                    or node.name not in PYTREE_REGISTRY:
                continue
            fields = _namedtuple_fields(node)
            if fields is None:
                continue
            seen.add(node.name)
            entry = PYTREE_REGISTRY[node.name]
            expected = tuple(entry["fields"])
            if fields == expected:
                continue
            added = [f for f in fields if f not in expected]
            removed = [f for f in expected if f not in fields]
            detail = []
            if added:
                detail.append(f"added {added}")
            if removed:
                detail.append(f"removed {removed}")
            if not detail:
                detail.append("reordered fields")
            findings.append(Finding(
                RULE_FIELD, mod.relpath, node.lineno, node.name,
                f"'{node.name}' has {len(fields)} leaves but the golden "
                f"registry v{entry['version']} declares {len(expected)} "
                f"({'; '.join(detail)}). Checkpoint layout is positional: "
                "append new fields LAST, bump the registry version and "
                "field tuple in repro/analysis/registry.py, and verify the "
                "allow_missing backfill path restores pre-migration "
                "snapshots."))

    # registry-stale + backfill checks only make sense against the real
    # tree, signalled by the registered module being part of the scan
    for name, entry in PYTREE_REGISTRY.items():
        home = entry["module"]
        in_scan = any(m.relpath.endswith(home.split("/")[-1])
                      and home in m.relpath for m in project.modules)
        if in_scan and name not in seen:
            findings.append(Finding(
                RULE_STALE, home, 1, name,
                f"registry declares '{name}' in {home} but no such "
                "NamedTuple was found there — update or remove the "
                "registry entry."))

    chain_home = PYTREE_REGISTRY["ChainState"]["module"]
    scans_real_tree = any(m.relpath == chain_home for m in project.modules)
    if scans_real_tree:
        has_backfill = False
        for mod in project.modules:
            if not mod.relpath.startswith("src/"):
                continue
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Call) and \
                        (call_name(node) or "").rsplit(".", 1)[-1] \
                        in _RESTORE_CALLS:
                    for kw in node.keywords:
                        if kw.arg == "allow_missing" and not (
                                isinstance(kw.value, ast.Constant)
                                and kw.value.value is False):
                            has_backfill = True
        if not has_backfill:
            findings.append(Finding(
                RULE_BACKFILL, chain_home, 1, "allow_missing",
                "no checkpoint restore call under src/ passes "
                "allow_missing: the schema-evolution backfill path that "
                "keeps pre-migration snapshots restorable has been "
                "dropped."))
    return findings


CHECKERS = [check_pytree_registry]
