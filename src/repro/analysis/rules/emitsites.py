"""Schema / bench emit-site rules (family 5).

Both trajectory formats in this repo are append-only JSON with a declared
row identity, and both have a history of silent-drift bugs (the PAD_SET
sentinel, the pre-merge ``save`` clobbering gate rows). These rules check
the two declarations at every emit site, reading the source of truth from
the AST (never importing it):

* ``telemetry-unknown-kind`` — a row literal carrying ``kind`` (alongside
  ``schema`` or ``run``, the schema-row signature) whose kind is not
  declared in any REQUIRED table (``telemetry/schema.py`` for trace rows,
  ``service/schema.py`` for bn-service responses): the validator would
  refuse it at runtime, deep into a run.
* ``bench-unknown-config-key`` — a row passed to ``benchmarks/common.save``
  / ``emit`` with a key that is a near-miss of a CONFIG_KEYS entry
  (case/underscore variant or one edit away): the row would silently stop
  merging by that field and clobber or duplicate trajectory rows.
* ``bench-row-no-config`` — an emitted row with NO CONFIG_KEYS field at
  all: it merges by full-JSON identity, so re-measuring appends a
  duplicate instead of replacing the stale measurement.
"""
from __future__ import annotations

import ast

from ..astutil import call_name, qualname, str_keys
from ..engine import Finding, Project

RULE_KIND = "telemetry-unknown-kind"
RULE_CONFIG = "bench-unknown-config-key"
RULE_NO_CONFIG = "bench-row-no-config"

# fallbacks if the source-of-truth files are missing from the tree
_DEFAULT_KINDS = ("meta", "stage", "segment", "heal", "final")
_DEFAULT_CONFIG_KEYS = ("n", "q", "s", "m", "S", "iters", "chains", "window",
                        "devices", "n_devices", "tp", "dp", "chunk", "block",
                        "mode", "variant", "scorer", "delta", "prune_delta",
                        "max_keep", "backend", "flip_p")


# every schema module declaring a REQUIRED kind table; rows anywhere in the
# tree may carry any declared kind (both schemas validate at emit time)
_SCHEMA_PATHS = ("src/repro/telemetry/schema.py",
                 "src/repro/service/schema.py")


def declared_kinds(project: Project) -> tuple[str, ...]:
    """Row kinds declared in the REQUIRED dict literal of every schema
    module (telemetry rows and bn-service responses share the
    ``schema`` + ``kind`` envelope)."""
    kinds: list[str] = []
    for path in _SCHEMA_PATHS:
        mod = project.find(path)
        if mod is None:
            continue
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.Assign, ast.AnnAssign)):
                tgts = (node.targets if isinstance(node, ast.Assign)
                        else [node.target])
                if any(isinstance(t, ast.Name) and t.id == "REQUIRED"
                       for t in tgts) and isinstance(node.value, ast.Dict):
                    kinds.extend(k.value for k in node.value.keys
                                 if isinstance(k, ast.Constant)
                                 and isinstance(k.value, str))
    return tuple(kinds) if kinds else _DEFAULT_KINDS


def declared_config_keys(project: Project) -> tuple[str, ...]:
    mod = project.find("benchmarks/common.py")
    if mod is None:
        return _DEFAULT_CONFIG_KEYS
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign):
            if any(isinstance(t, ast.Name) and t.id == "CONFIG_KEYS"
                   for t in node.targets) \
                    and isinstance(node.value, (ast.Tuple, ast.List)):
                return tuple(e.value for e in node.value.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, str))
    return _DEFAULT_CONFIG_KEYS


def check_telemetry_kinds(project: Project) -> list[Finding]:
    kinds = set(declared_kinds(project))
    findings = []
    for mod in project.modules:
        if any(mod.relpath.endswith(p.split("/", 1)[-1])
               for p in _SCHEMA_PATHS):
            continue                     # the declaration sites themselves
        for node in ast.walk(mod.tree):
            keys = str_keys(node)
            if "kind" not in keys:
                continue
            if not ({"schema", "run"} & set(keys)):
                continue                 # not a telemetry row literal
            kv = keys["kind"]
            if isinstance(kv, ast.Constant) and isinstance(kv.value, str) \
                    and kv.value not in kinds:
                findings.append(Finding(
                    RULE_KIND, mod.relpath, node.lineno,
                    f"{qualname(node)}#kind={kv.value}",
                    f"schema row kind '{kv.value}' is not declared in any "
                    f"REQUIRED table ({sorted(kinds)}; telemetry/schema.py "
                    "for trace rows, service/schema.py for bn-service "
                    "responses): the validator will reject this row at "
                    "runtime. Declare the kind (with its required fields) "
                    "in the right schema first."))
    return findings


def _edit_distance_leq1(a: str, b: str) -> bool:
    if a == b:
        return True
    la, lb = len(a), len(b)
    if abs(la - lb) > 1:
        return False
    if la == lb:                         # one substitution
        return sum(x != y for x, y in zip(a, b)) <= 1
    if la > lb:
        a, b, la, lb = b, a, lb, la
    i = j = diff = 0                     # one insertion
    while i < la and j < lb:
        if a[i] == b[j]:
            i += 1
        else:
            diff += 1
            if diff > 1:
                return False
        j += 1
    return True


def _norm(key: str) -> str:
    return key.replace("_", "").lower()


def _row_dicts(rows_arg: ast.AST, fn: ast.AST | None) -> list[ast.AST]:
    """Dict literals flowing into a save/emit rows argument: inline dict,
    inline list of dicts, or a local name assigned/appended to in ``fn``."""
    out = []

    def collect(node: ast.AST) -> None:
        if isinstance(node, ast.Dict) or (
                isinstance(node, ast.Call) and call_name(node) == "dict"):
            out.append(node)
        elif isinstance(node, (ast.List, ast.Tuple)):
            for e in node.elts:
                collect(e)
        elif isinstance(node, (ast.ListComp, ast.GeneratorExp)):
            collect(node.elt)

    collect(rows_arg)
    if isinstance(rows_arg, ast.Name) and fn is not None:
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == rows_arg.id
                    for t in node.targets):
                collect(node.value)
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "append" \
                    and isinstance(node.func.value, ast.Name) \
                    and node.func.value.id == rows_arg.id:
                for a in node.args:
                    collect(a)
    return out


def check_bench_config_keys(project: Project) -> list[Finding]:
    config = declared_config_keys(project)
    norm_map = {_norm(k): k for k in config}
    findings = []
    for mod in project.modules:
        if mod.relpath.endswith("benchmarks/common.py"):
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            cn = (call_name(node) or "").rsplit(".", 1)[-1]
            if cn not in {"save", "emit"} or len(node.args) < 2:
                continue
            from ..astutil import enclosing_function
            fn = enclosing_function(node)
            for row in _row_dicts(node.args[1], fn):
                keys = list(str_keys(row))
                if not keys:
                    continue
                bad = []
                for k in keys:
                    if k in config:
                        continue
                    near = norm_map.get(_norm(k))
                    if near is None:
                        near = next((c for c in config
                                     if _edit_distance_leq1(k, c)), None)
                    if near:
                        bad.append((k, near))
                for k, near in bad:
                    findings.append(Finding(
                        RULE_CONFIG, mod.relpath, row.lineno,
                        f"{qualname(node)}#{k}",
                        f"bench row key '{k}' looks like CONFIG_KEYS entry "
                        f"'{near}' but is not declared: the row will not "
                        "merge by this field (smoke runs would clobber "
                        "gate rows). Use the declared key or add the new "
                        "key to benchmarks/common.CONFIG_KEYS."))
                if not any(k in config for k in keys):
                    findings.append(Finding(
                        RULE_NO_CONFIG, mod.relpath, row.lineno,
                        f"{qualname(node)}#no-config",
                        "bench row carries no CONFIG_KEYS field at all: it "
                        "merges by full-JSON identity, so every re-run "
                        "appends a duplicate row instead of replacing the "
                        "stale measurement."))
    return findings


CHECKERS = [check_telemetry_kinds, check_bench_config_keys]
