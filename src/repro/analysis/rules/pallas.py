"""Pallas kernel-contract rules (family 3).

Every ``pl.pallas_call`` site is parsed into a :class:`PallasSite` (also
consumed by analysis/vmem.py for the static VMEM-footprint estimates) and
checked for the contracts that are cheap to get wrong and expensive to
debug on hardware:

* ``pallas-spec-mismatch`` — grid/BlockSpec arithmetic drift: an index_map
  whose arity differs from ``len(grid)``, an index_map returning a tuple of
  different rank than its block shape, a block shape whose rank differs
  from the corresponding ``out_shape``, mismatched out_specs/out_shape
  counts, or an operand count different from ``len(in_specs)``.
* ``pallas-interpret-hardcoded`` — ``interpret=`` missing or a literal
  True/False instead of a plumbed parameter: kernels must stay runnable in
  interpret mode on CPU CI AND compiled on TPU, from the same call site
  (every kernel in this repo threads ``interpret`` through its public
  wrapper for exactly that reason).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

from ..astutil import call_name, enclosing_function, parent, qualname
from ..engine import Finding, Project

RULE_SPEC = "pallas-spec-mismatch"
RULE_INTERPRET = "pallas-interpret-hardcoded"

_PALLAS_SUFFIX = "pallas_call"


@dataclass
class BlockSpecInfo:
    node: ast.Call
    block: ast.AST | None           # the block-shape tuple expression
    index_map: ast.AST | None       # usually a Lambda

    @property
    def block_rank(self) -> int | None:
        if isinstance(self.block, (ast.Tuple, ast.List)):
            return len(self.block.elts)
        return None


@dataclass
class PallasSite:
    mod: object                     # engine.Module
    call: ast.Call                  # the pl.pallas_call(...) call itself
    fn: ast.AST | None              # enclosing function def
    grid: ast.AST | None
    in_specs: list[BlockSpecInfo] = field(default_factory=list)
    out_specs: list[BlockSpecInfo] = field(default_factory=list)
    out_shapes: list[ast.Call] = field(default_factory=list)
    scratch_shapes: list[ast.AST] = field(default_factory=list)
    interpret: ast.AST | None = None
    operands: list[ast.AST] = field(default_factory=list)

    @property
    def anchor(self) -> str:
        return qualname(self.call)

    @property
    def kernel_name(self) -> str:
        f = self.fn
        return f.name if isinstance(f, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)) else "<module>"


def _as_blockspec(node: ast.AST) -> BlockSpecInfo | None:
    if isinstance(node, ast.Call) and \
            (call_name(node) or "").rsplit(".", 1)[-1] == "BlockSpec":
        block = node.args[0] if node.args else None
        imap = node.args[1] if len(node.args) > 1 else None
        for kw in node.keywords:
            if kw.arg == "index_map":
                imap = kw.value
            if kw.arg == "block_shape":
                block = kw.value
        return BlockSpecInfo(node=node, block=block, index_map=imap)
    return None


def _spec_list(node: ast.AST) -> list[BlockSpecInfo]:
    out = []
    elts = node.elts if isinstance(node, (ast.List, ast.Tuple)) else [node]
    for e in elts:
        bs = _as_blockspec(e)
        if bs is not None:
            out.append(bs)
    return out


def _shape_list(node: ast.AST) -> list[ast.Call]:
    elts = node.elts if isinstance(node, (ast.List, ast.Tuple)) else [node]
    return [e for e in elts
            if isinstance(e, ast.Call)
            and (call_name(e) or "").endswith("ShapeDtypeStruct")]


def resolve_local(name_node: ast.AST, fn: ast.AST | None) -> ast.AST:
    """Follow one local ``x = <tuple literal>`` assignment inside ``fn`` so
    ``grid = (C, m // bm); ... grid=grid`` still checks."""
    if not isinstance(name_node, ast.Name) or fn is None:
        return name_node
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == name_node.id:
                    return node.value
    return name_node


def iter_pallas_sites(project: Project) -> list[PallasSite]:
    sites = []
    for mod in project.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if not (call_name(node) or "").endswith(_PALLAS_SUFFIX):
                continue
            fn = enclosing_function(node)
            site = PallasSite(mod=mod, call=node, fn=fn, grid=None)
            for kw in node.keywords:
                if kw.arg == "grid":
                    site.grid = resolve_local(kw.value, fn)
                elif kw.arg == "in_specs":
                    site.in_specs = _spec_list(kw.value)
                elif kw.arg == "out_specs":
                    site.out_specs = _spec_list(kw.value)
                elif kw.arg == "out_shape":
                    site.out_shapes = _shape_list(kw.value)
                elif kw.arg == "scratch_shapes":
                    v = kw.value
                    site.scratch_shapes = (list(v.elts) if isinstance(
                        v, (ast.List, ast.Tuple)) else [v])
                elif kw.arg == "interpret":
                    site.interpret = kw.value
            outer = parent(node)
            if isinstance(outer, ast.Call) and outer.func is node:
                site.operands = list(outer.args)
            sites.append(site)
    return sites


def _grid_len(site: PallasSite) -> int | None:
    g = site.grid
    if isinstance(g, (ast.Tuple, ast.List)):
        return len(g.elts)
    if isinstance(g, (ast.Constant, ast.Name, ast.BinOp)):
        return 1 if not isinstance(g, ast.Name) else None
    return None


def _lambda_arity(node: ast.AST) -> int | None:
    if isinstance(node, ast.Lambda):
        a = node.args
        return len(a.posonlyargs) + len(a.args)
    return None


def _lambda_ret_rank(node: ast.AST) -> int | None:
    if isinstance(node, ast.Lambda):
        return (len(node.body.elts)
                if isinstance(node.body, (ast.Tuple, ast.List)) else 1)
    return None


def _shape_rank(struct: ast.Call) -> int | None:
    if struct.args and isinstance(struct.args[0], (ast.Tuple, ast.List)):
        return len(struct.args[0].elts)
    return None


def check_pallas_contracts(project: Project) -> list[Finding]:
    findings = []
    for site in iter_pallas_sites(project):
        mod, line = site.mod, site.call.lineno
        anchor = site.anchor

        def spec(msg: str, ln: int = line, token: str = "") -> None:
            findings.append(Finding(
                RULE_SPEC, mod.relpath, ln,
                f"{anchor}#{token}" if token else anchor,
                f"pallas_call in '{site.kernel_name}': {msg}"))

        G = _grid_len(site)
        all_specs = [("in_specs", i, s) for i, s in enumerate(site.in_specs)]
        all_specs += [("out_specs", i, s) for i, s in
                      enumerate(site.out_specs)]
        for kind, i, s in all_specs:
            ar = _lambda_arity(s.index_map)
            if G is not None and ar is not None and ar != G:
                spec(f"{kind}[{i}] index_map takes {ar} args but the grid "
                     f"has {G} dims — every grid axis must be consumed",
                     s.node.lineno, f"{kind}{i}-arity")
            rr = _lambda_ret_rank(s.index_map)
            br = s.block_rank
            if rr is not None and br is not None and rr != br:
                spec(f"{kind}[{i}] index_map returns {rr} coordinates for a "
                     f"rank-{br} block shape", s.node.lineno,
                     f"{kind}{i}-rank")
        if site.out_specs and site.out_shapes and \
                len(site.out_specs) != len(site.out_shapes):
            spec(f"{len(site.out_specs)} out_specs but "
                 f"{len(site.out_shapes)} out_shape entries",
                 token="out-count")
        for i, (s, struct) in enumerate(zip(site.out_specs,
                                            site.out_shapes)):
            br, sr = s.block_rank, _shape_rank(struct)
            if br is not None and sr is not None and br != sr:
                spec(f"out_specs[{i}] block is rank {br} but out_shape[{i}] "
                     f"is rank {sr}", s.node.lineno, f"outshape{i}-rank")
        if site.in_specs and site.operands and \
                len(site.in_specs) != len(site.operands):
            spec(f"{len(site.operands)} operands passed but "
                 f"{len(site.in_specs)} in_specs declared", token="operands")

        if site.interpret is None:
            findings.append(Finding(
                RULE_INTERPRET, mod.relpath, line, f"{anchor}#interpret",
                f"pallas_call in '{site.kernel_name}' does not pass "
                "interpret=: the kernel cannot run on CPU CI. Thread an "
                "interpret parameter through the public wrapper."))
        elif isinstance(site.interpret, ast.Constant):
            findings.append(Finding(
                RULE_INTERPRET, mod.relpath, line, f"{anchor}#interpret",
                f"pallas_call in '{site.kernel_name}' hardcodes "
                f"interpret={site.interpret.value!r}: plumb it from the "
                "caller so the same site runs interpreted on CPU and "
                "compiled on TPU."))
    return findings


CHECKERS = [check_pallas_contracts]
