"""bnlint rule engine: project loading, findings, baseline and suppression.

Design (docs/static-analysis.md has the user-facing version):

* A **Project** is a set of parsed modules (never imported, only ``ast``)
  plus the repo root, so cross-file rules (schema kinds, CONFIG_KEYS, the
  pytree registry) can read their source of truth even when it is outside
  the scanned paths.
* A **Finding** is anchored by ``(rule, path, anchor)`` where the anchor is
  the enclosing def/class qualname (plus an optional discriminator token),
  NOT a line number — baselines survive unrelated edits to the same file.
* Two suppression channels: the **baseline file** (shipped next to this
  package, every entry REQUIRES a non-empty reason string) for accepted
  findings, and inline ``# bnlint: disable=rule-id -- reason`` comments on
  (or immediately above) the flagged line for point exemptions.
"""
from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass, field

from .astutil import add_parents

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")

_SUPPRESS_RE = re.compile(r"#\s*bnlint:\s*disable=([\w\-*,]+)")


@dataclass(frozen=True)
class Finding:
    rule: str       # rule id, e.g. "retrace-eager-switch"
    path: str       # repo-relative posix path
    line: int
    anchor: str     # stable anchor: qualname[#token]
    message: str

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.anchor}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def as_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "anchor": self.anchor, "message": self.message}


@dataclass
class Module:
    relpath: str            # posix, relative to the project root
    source: str
    tree: ast.Module
    package: str            # dotted package for relative-import resolution
    suppressions: dict[int, set[str]] = field(default_factory=dict)

    @classmethod
    def load(cls, root: str, relpath: str) -> "Module | None":
        path = os.path.join(root, relpath)
        try:
            with open(path, encoding="utf-8") as f:
                source = f.read()
            tree = add_parents(ast.parse(source, filename=relpath))
        except (OSError, SyntaxError):
            return None
        sup: dict[int, set[str]] = {}
        for i, line in enumerate(source.splitlines(), start=1):
            m = _SUPPRESS_RE.search(line)
            if m:
                sup[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
        return cls(relpath=relpath, source=source, tree=tree,
                   package=_package_of(relpath), suppressions=sup)

    def suppressed(self, finding: Finding) -> bool:
        for ln in (finding.line, finding.line - 1):
            rules = self.suppressions.get(ln)
            if rules and ({"*"} & rules or finding.rule in rules):
                return True
        return False


def _package_of(relpath: str) -> str:
    """Dotted package of a file under src/ (empty elsewhere)."""
    parts = relpath.replace(os.sep, "/").split("/")
    if parts and parts[0] == "src":
        parts = parts[1:]
    return ".".join(parts[:-1])


class Project:
    """Parsed view of the scanned paths + on-demand access to out-of-scan
    source-of-truth files (schema.py, benchmarks/common.py, core/mcmc.py)."""

    def __init__(self, root: str, modules: list[Module]):
        self.root = root
        self.modules = modules
        self._by_path = {m.relpath: m for m in modules}
        self._external: dict[str, Module | None] = {}

    def find(self, suffix: str) -> Module | None:
        """Scanned module whose relpath ends with ``suffix``, else load it
        from disk under the project root (parsed, never imported)."""
        suffix = suffix.replace(os.sep, "/")
        for m in self.modules:
            if m.relpath.replace(os.sep, "/").endswith(suffix):
                return m
        if suffix not in self._external:
            rel = suffix.lstrip("/")
            self._external[suffix] = (Module.load(self.root, rel)
                                      if os.path.exists(
                                          os.path.join(self.root, rel))
                                      else None)
        return self._external[suffix]

    def module_for(self, finding: Finding) -> Module | None:
        return self._by_path.get(finding.path)


def load_project(paths: list[str], root: str | None = None) -> Project:
    root = os.path.abspath(root or os.getcwd())
    files: list[str] = []
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(ap) and ap.endswith(".py"):
            files.append(ap)
        elif os.path.isdir(ap):
            for dirpath, dirnames, filenames in os.walk(ap):
                dirnames[:] = sorted(d for d in dirnames
                                     if d not in {"__pycache__", ".git"})
                files.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames) if f.endswith(".py"))
    modules = []
    for f in files:
        rel = os.path.relpath(f, root).replace(os.sep, "/")
        mod = Module.load(root, rel)
        if mod is not None:
            modules.append(mod)
    return Project(root, modules)


# ------------------------------------------------------------------ baseline

class BaselineError(ValueError):
    """Malformed baseline file (missing reason, wrong shape)."""


def load_baseline(path: str) -> dict[str, str]:
    """``finding.key -> reason``. Every entry must carry a non-empty reason —
    a suppression nobody can justify is a bug magnet, not a baseline."""
    if not os.path.exists(path):
        return {}
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    entries = data.get("findings", []) if isinstance(data, dict) else data
    out: dict[str, str] = {}
    for e in entries:
        for fld in ("rule", "path", "anchor", "reason"):
            if not str(e.get(fld, "")).strip():
                raise BaselineError(
                    f"baseline entry {e!r} is missing a non-empty {fld!r} "
                    "(every baselined finding needs a stated reason)")
        out[f"{e['rule']}:{e['path']}:{e['anchor']}"] = e["reason"]
    return out


def write_baseline(path: str, findings: list[Finding],
                   reasons: dict[str, str] | None = None) -> None:
    reasons = reasons or {}
    entries = [{"rule": f.rule, "path": f.path, "anchor": f.anchor,
                "reason": reasons.get(f.key, "TODO: justify or fix")}
               for f in sorted(set(findings), key=lambda f: f.key)]
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"version": 1, "findings": entries}, fh, indent=1)
        fh.write("\n")


@dataclass
class LintResult:
    new: list[Finding]                  # unbaselined, unsuppressed
    baselined: list[Finding]
    suppressed: list[Finding]
    stale_baseline: list[str]           # baseline keys that no longer fire
    all_findings: list[Finding]


def run_rules(project: Project) -> list[Finding]:
    from . import rules
    findings: list[Finding] = []
    for checker in rules.CHECKERS:
        findings.extend(checker(project))
    return sorted(set(findings), key=lambda f: (f.path, f.line, f.rule))


def lint(paths: list[str], root: str | None = None,
         baseline_path: str | None = DEFAULT_BASELINE) -> LintResult:
    project = load_project(paths, root)
    findings = run_rules(project)
    baseline = load_baseline(baseline_path) if baseline_path else {}
    new, base, sup = [], [], []
    for f in findings:
        mod = project.module_for(f)
        if mod is not None and mod.suppressed(f):
            sup.append(f)
        elif f.key in baseline:
            base.append(f)
        else:
            new.append(f)
    fired = {f.key for f in findings}
    stale = sorted(k for k in baseline if k not in fired)
    return LintResult(new=new, baselined=base, suppressed=sup,
                      stale_baseline=stale, all_findings=findings)
