"""Static per-kernel VMEM-footprint estimates for every pl.pallas_call site.

TPU cores have ~16 MiB of VMEM; Pallas double-buffers pipelined in/out
blocks, so the resident footprint of a kernel invocation is roughly

    2 * (sum of in-spec block bytes + sum of out-spec block bytes)
      + scratch bytes.

The estimator evaluates each BlockSpec/scratch shape expression
symbolically from the AST: enclosing-function parameter defaults
(``block_s=2048``), one level of local assignments (``bw = block_s //
32``), module constants, and — for dims only known at run time (``n``,
``w``, ``s``, ...) — a documented assumption table. Every assumption used
is recorded in the emitted row, so the numbers are honest estimates, not
measurements: they ride into the BENCH trajectories as ``mode="static"``
rows (``python -m repro.analysis --emit-vmem``) to seed the kernel
autotuning campaign with a cheap, always-current capacity model.
"""
from __future__ import annotations

import ast
import importlib.util
import os

from .astutil import call_name, const_int
from .engine import Project, load_project
from .rules.pallas import PallasSite, iter_pallas_sites

VMEM_BUDGET_BYTES = 16 * 1024 * 1024     # ~16 MiB/core (Pallas TPU guide)

# run-time dims with no static default anywhere: the documented estimate
# basis (n/s/q match the repo's n=64 gate configs; w the default window)
ASSUMED_DIMS = {"n": 64, "s": 4, "q": 3, "w": 8, "Q": 81, "n_planes": 3,
                "D": 128, "P": 3, "C": 256, "W": 8192, "S": 262144,
                "m": 4096, "BH": 8, "Tq": 2048, "Tk": 2048}

_DTYPE_BYTES = {"float64": 8, "int64": 8, "float32": 4, "int32": 4,
                "uint32": 4, "f32": 4, "bfloat16": 2, "float16": 2,
                "int16": 2, "uint16": 2, "int8": 1, "uint8": 1, "bool_": 1}


class _Unresolved(Exception):
    def __init__(self, name: str):
        super().__init__(name)
        self.name = name


class _Env:
    """Name -> int resolution: local assigns, param defaults, module
    constants, then the assumption table (recording what was assumed)."""

    def __init__(self, site: PallasSite):
        self.exprs: dict[str, ast.AST] = {}
        self.assumed: dict[str, int] = {}
        self._stack: set[str] = set()
        mod_tree = site.mod.tree
        for node in mod_tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                self.exprs.setdefault(node.targets[0].id, node.value)
        fn = site.fn
        if fn is not None:
            a = fn.args
            pos = list(a.posonlyargs) + list(a.args)
            for p, d in zip(pos[len(pos) - len(a.defaults):], a.defaults):
                self.exprs[p.arg] = d
            for p, d in zip(a.kwonlyargs, a.kw_defaults):
                if d is not None:
                    self.exprs[p.arg] = d
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name):
                    self.exprs[node.targets[0].id] = node.value

    def lookup(self, name: str) -> int:
        if name in self._stack:
            raise _Unresolved(name)
        expr = self.exprs.get(name)
        if expr is not None:
            self._stack.add(name)
            try:
                return self.eval(expr)
            except _Unresolved:
                pass
            finally:
                self._stack.discard(name)
        if name in ASSUMED_DIMS:
            self.assumed[name] = ASSUMED_DIMS[name]
            return ASSUMED_DIMS[name]
        raise _Unresolved(name)

    def eval(self, node: ast.AST) -> int:
        v = const_int(node)
        if v is not None:
            return v
        if isinstance(node, ast.Name):
            return self.lookup(node.id)
        if isinstance(node, ast.BinOp):
            lh, rh = self.eval(node.left), self.eval(node.right)
            op = type(node.op)
            table = {ast.Add: lambda: lh + rh, ast.Sub: lambda: lh - rh,
                     ast.Mult: lambda: lh * rh,
                     ast.FloorDiv: lambda: lh // max(rh, 1),
                     ast.Div: lambda: lh // max(rh, 1),
                     ast.Mod: lambda: lh % max(rh, 1),
                     ast.Pow: lambda: lh ** rh}
            if op in table:
                return table[op]()
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            return -self.eval(node.operand)
        if isinstance(node, ast.Call):
            cn = (call_name(node) or "").rsplit(".", 1)[-1]
            if cn in {"min", "max"} and node.args:
                vals = [self.eval(a) for a in node.args]
                return min(vals) if cn == "min" else max(vals)
        raise _Unresolved(ast.dump(node)[:40])

    def shape_elems(self, shape: ast.AST) -> int:
        if isinstance(shape, (ast.Tuple, ast.List)):
            total = 1
            for e in shape.elts:
                total *= max(self.eval(e), 1)
            return total
        return max(self.eval(shape), 1)


def _dtype_bytes(node: ast.AST | None) -> int:
    name = ""
    if node is not None:
        for sub in ast.walk(node):
            d = sub if isinstance(sub, ast.Attribute) else None
            if d is not None and d.attr in _DTYPE_BYTES:
                name = d.attr
                break
    return _DTYPE_BYTES.get(name, 4)     # operand dtypes default to 4 B


def estimate_site(site: PallasSite) -> dict | None:
    """Static VMEM row for one pallas_call site (None if nothing to sum)."""
    env = _Env(site)

    def block_bytes(specs, dtypes) -> int:
        total = 0
        for spec, dt in zip(specs, dtypes):
            if spec.block is None:
                continue
            try:
                total += env.shape_elems(spec.block) * dt
            except _Unresolved:
                continue
        return total

    in_bytes = block_bytes(site.in_specs, [4] * len(site.in_specs))
    out_dtypes = [_dtype_bytes(s.args[1] if len(s.args) > 1 else
                               next((kw.value for kw in s.keywords
                                     if kw.arg == "dtype"), None))
                  for s in site.out_shapes]
    out_dtypes += [4] * (len(site.out_specs) - len(out_dtypes))
    out_bytes = block_bytes(site.out_specs, out_dtypes)
    scratch_bytes = 0
    for sc in site.scratch_shapes:
        if isinstance(sc, ast.Call) and sc.args:
            try:
                dt = _dtype_bytes(sc.args[1] if len(sc.args) > 1 else
                                  next((kw.value for kw in sc.keywords
                                        if kw.arg == "dtype"), None))
                scratch_bytes += env.shape_elems(sc.args[0]) * dt
            except _Unresolved:
                continue
    if not (in_bytes or out_bytes or scratch_bytes):
        return None
    total = 2 * (in_bytes + out_bytes) + scratch_bytes
    block = None
    for name in ("block_s", "block_m", "block_q", "block"):
        if name in env.exprs:
            try:
                block = env.eval(env.exprs[name])
                break
            except _Unresolved:
                pass
    return {
        "mode": "static",
        "variant": site.kernel_name,
        "block": block,
        "kernel_path": site.mod.relpath,
        "vmem_in_bytes": in_bytes,
        "vmem_out_bytes": out_bytes,
        "vmem_scratch_bytes": scratch_bytes,
        "vmem_bytes": total,
        "vmem_mib": round(total / 2**20, 4),
        "vmem_frac_of_budget": round(total / VMEM_BUDGET_BYTES, 5),
        "double_buffered": True,
        "assumed_dims": dict(sorted(env.assumed.items())),
    }


def estimate_project(project: Project) -> list[dict]:
    rows = []
    for site in iter_pallas_sites(project):
        row = estimate_site(site)
        if row is not None:
            rows.append(row)
    return rows


def _bench_file_for(row: dict) -> str:
    """order_score kernels ride the MCMC trajectory; the count / fused /
    flash kernels are all upstream-of-sampler compute and ride the
    preprocess trajectory."""
    return ("BENCH_mcmc" if "order_score" in row["kernel_path"]
            else "BENCH_preprocess")


def emit_vmem_rows(paths: list[str], root: str | None = None,
                   save=None) -> list[dict]:
    """Estimate every scanned kernel and merge the rows into the BENCH
    trajectories via benchmarks/common.save (config-keyed merge: the static
    rows land BESIDE the measured rows, never on top of them)."""
    project = load_project(paths, root)
    rows = estimate_project(project)
    if save is None:
        common = os.path.join(project.root, "benchmarks", "common.py")
        spec = importlib.util.spec_from_file_location("_bnlint_bench_common",
                                                      common)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        save = mod.save
    by_file: dict[str, list[dict]] = {}
    for row in rows:
        by_file.setdefault(_bench_file_for(row), []).append(row)
    for name, file_rows in sorted(by_file.items()):
        save(name, file_rows)
    return rows
