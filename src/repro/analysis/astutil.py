"""Shared AST helpers for the bnlint rule engine.

Everything here is pure syntax: no imports of the analysed code are ever
executed. Helpers resolve the small set of idioms this codebase actually
uses — ``@functools.partial(jax.jit, static_argnames=...)`` decorators,
``name = functools.partial(jax.jit, ...)(impl)`` wrapper assignments,
``kernel = functools.partial(_impl_kernel, **statics)`` aliases feeding
``pl.pallas_call`` — so the rules stay precise on this repo without trying
to be a general Python type checker.
"""
from __future__ import annotations

import ast

_PARENT = "_bnlint_parent"


def add_parents(tree: ast.AST) -> ast.AST:
    """Attach parent pointers so rules can walk outward from a node."""
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            setattr(child, _PARENT, parent)
    return tree


def parent(node: ast.AST) -> ast.AST | None:
    return getattr(node, _PARENT, None)


def dotted(node: ast.AST) -> str | None:
    """'jax.lax.switch'-style dotted name of a Name/Attribute chain."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def call_name(node: ast.AST) -> str | None:
    """Dotted callee name of a Call node (None for computed callees)."""
    if isinstance(node, ast.Call):
        return dotted(node.func)
    return None


def qualname(node: ast.AST) -> str:
    """Dotted chain of enclosing defs/classes + the node's own name (or the
    nearest enclosing def for anonymous nodes) — the stable baseline anchor."""
    names = []
    cur: ast.AST | None = node
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            names.append(cur.name)
        cur = parent(cur)
    return ".".join(reversed(names)) or "<module>"


def enclosing_function(node: ast.AST):
    """Nearest enclosing FunctionDef/AsyncFunctionDef (None at module level)."""
    cur = parent(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = parent(cur)
    return None


def names_in(node: ast.AST) -> set[str]:
    """All Name identifiers loaded anywhere under ``node``."""
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


# --------------------------------------------------------------------- jit

_JIT_NAMES = {"jax.jit", "jit"}
_PARTIAL_NAMES = {"functools.partial", "partial"}


def is_jit_expr(node: ast.AST) -> bool:
    """True if ``node`` evaluates to jax.jit, possibly through
    functools.partial — covers ``@jax.jit``, ``@partial(jax.jit, ...)`` and
    the wrapper half of ``partial(jax.jit, ...)(impl)``."""
    if dotted(node) in _JIT_NAMES:
        return True
    if isinstance(node, ast.Call):
        cn = call_name(node)
        if cn in _JIT_NAMES:
            return True
        if cn in _PARTIAL_NAMES and node.args:
            return is_jit_expr(node.args[0])
    return False


def jit_static_names(node: ast.AST) -> tuple[str, ...]:
    """static_argnames mentioned anywhere under a jit wrapper expression."""
    out: list[str] = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.keyword) and sub.arg == "static_argnames":
            v = sub.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                out.append(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                out.extend(e.value for e in v.elts
                           if isinstance(e, ast.Constant)
                           and isinstance(e.value, str))
    return tuple(out)


def jitted_functions(tree: ast.Module) -> dict[str, tuple]:
    """Map of jit-covered names in a module: ``name -> (funcdef | None,
    static_argnames)``.

    Covers both spellings used in this repo:

    * decorator: ``@jax.jit`` / ``@functools.partial(jax.jit, ...)``
    * module-level wrapper assignment:
      ``public = functools.partial(jax.jit, ...)(_impl)`` — BOTH the public
      alias and the private impl are recorded as covered (the impl has a
      jitted entry point; eager callers are expected to use the alias).
    """
    funcs: dict[str, ast.FunctionDef] = {}
    out: dict[str, tuple] = {}
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            funcs[node.name] = node
            for dec in node.decorator_list:
                if is_jit_expr(dec):
                    out[node.name] = (node, jit_static_names(dec))
    for node in tree.body:
        if not (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)):
            continue
        call = node.value
        wrapped, statics = None, ()
        if is_jit_expr(call.func) and call.args \
                and isinstance(call.args[0], ast.Name):
            # partial(jax.jit, ...)(impl)
            wrapped = call.args[0].id
            statics = jit_static_names(call.func) or jit_static_names(call)
        elif call_name(call) in _JIT_NAMES and call.args \
                and isinstance(call.args[0], ast.Name):
            # jax.jit(impl, static_argnames=...)
            wrapped = call.args[0].id
            statics = jit_static_names(call)
        if wrapped:
            fn = funcs.get(wrapped)
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out[tgt.id] = (fn, statics)
            out.setdefault(wrapped, (fn, statics))
    return out


def partial_aliases(scope: ast.AST) -> dict[str, tuple[str, set[str]]]:
    """``alias -> (wrapped_name, bound_kwarg_names)`` for
    ``alias = functools.partial(fn, **kw)`` assignments under ``scope``."""
    out: dict[str, tuple[str, set[str]]] = {}
    for node in ast.walk(scope):
        if not (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)):
            continue
        call = node.value
        if call_name(call) in _PARTIAL_NAMES and call.args \
                and isinstance(call.args[0], ast.Name):
            bound = {kw.arg for kw in call.keywords if kw.arg}
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out[tgt.id] = (call.args[0].id, bound)
    return out


def local_functions(scope: ast.AST) -> dict[str, ast.FunctionDef]:
    """Immediate (non-recursive) function defs in a body-bearing scope."""
    out = {}
    for node in getattr(scope, "body", []):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[node.name] = node
    return out


def own_body_nodes(fn: ast.AST):
    """Walk a function's body EXCLUDING nested function/class subtrees —
    nested defs are separate call-graph nodes with their own hotness."""
    stack = list(getattr(fn, "body", []))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def const_int(node: ast.AST) -> int | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    return None


def str_keys(d: ast.AST) -> dict[str, ast.AST]:
    """Constant-string keys of a Dict literal or dict(...) call."""
    out: dict[str, ast.AST] = {}
    if isinstance(d, ast.Dict):
        for k, v in zip(d.keys, d.values):
            if isinstance(k, ast.Constant) and isinstance(k.value, str):
                out[k.value] = v
    elif isinstance(d, ast.Call) and call_name(d) == "dict":
        for kw in d.keywords:
            if kw.arg:
                out[kw.arg] = kw.value
    return out


def import_map(tree: ast.Module, package: str) -> dict[str, str]:
    """Alias -> absolute dotted module for a module living in ``package``
    (e.g. package='repro.core' resolves ``from .order_scoring import x`` and
    ``from ..telemetry import taps``)."""
    out: dict[str, str] = {}
    parts = package.split(".") if package else []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out[alias.asname or alias.name.split(".")[0]] = alias.name
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base = parts[:len(parts) - node.level + 1]
                mod = ".".join(base + ([node.module] if node.module else []))
            else:
                mod = node.module or ""
            for alias in node.names:
                out[alias.asname or alias.name] = (
                    f"{mod}.{alias.name}" if mod else alias.name)
    return out
