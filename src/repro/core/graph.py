"""DAG utilities: random ground-truth networks, CPTs, adjacency recovery."""
from __future__ import annotations

import numpy as np

from .combinatorics import candidates_to_nodes, unrank_parent_set

__all__ = ["random_dag", "random_cpts", "adjacency_from_best",
           "adjacency_from_ranks", "parents_list_from_adjacency",
           "topological_order"]


def random_dag(rng: np.random.Generator, n: int, max_parents: int,
               edge_prob: float = 0.25) -> np.ndarray:
    """Random DAG adjacency (adj[m, i] = 1 ⇔ edge m → i) with ≤ max_parents."""
    order = rng.permutation(n)
    adj = np.zeros((n, n), dtype=np.int8)
    for pos in range(1, n):
        i = order[pos]
        preds = order[:pos]
        k = min(len(preds), max_parents)
        npar = rng.binomial(k, edge_prob) if k else 0
        if npar:
            for m in rng.choice(preds, size=npar, replace=False):
                adj[m, i] = 1
    return adj


def random_cpts(rng: np.random.Generator, adj: np.ndarray, q: int,
                concentration: float = 0.5) -> list[np.ndarray]:
    """Dirichlet CPTs: cpts[i] has shape (q^{|parents|}, q). Low concentration
    gives sharp (informative) conditionals."""
    n = adj.shape[0]
    cpts = []
    for i in range(n):
        r = q ** int(adj[:, i].sum())
        cpts.append(rng.dirichlet(np.full(q, concentration), size=r))
    return cpts


def topological_order(adj: np.ndarray) -> np.ndarray:
    """Kahn's algorithm; raises on cycles."""
    n = adj.shape[0]
    indeg = adj.sum(axis=0).astype(int).copy()
    queue = [i for i in range(n) if indeg[i] == 0]
    out = []
    while queue:
        v = queue.pop()
        out.append(v)
        for w in np.nonzero(adj[v])[0]:
            indeg[w] -= 1
            if indeg[w] == 0:
                queue.append(int(w))
    if len(out) != n:
        raise ValueError("graph has a cycle")
    return np.asarray(out)


def parents_list_from_adjacency(adj: np.ndarray) -> list[np.ndarray]:
    return [np.nonzero(adj[:, i])[0] for i in range(adj.shape[0])]


def adjacency_from_best(best_idx: np.ndarray, pst: np.ndarray) -> np.ndarray:
    """Recover adjacency from per-node best PST indices (the learned graph)."""
    n = len(best_idx)
    adj = np.zeros((n, n), dtype=np.int8)
    for i in range(n):
        cands = pst[int(best_idx[i])]
        for m in candidates_to_nodes(cands[cands >= 0], i):
            adj[int(m), i] = 1
    return adj


def adjacency_from_ranks(best_idx: np.ndarray, *, s: int) -> np.ndarray:
    """adjacency_from_best WITHOUT the (S, s) PST: each winning rank is
    unranked arithmetically (paper Algorithm 2). Identical output — the PST
    is built size-ascending/lexicographic, i.e. exactly in rank order — but
    usable from the pruned representation, whose footprint stays O(n·K)."""
    n = len(best_idx)
    adj = np.zeros((n, n), dtype=np.int8)
    for i in range(n):
        cands = unrank_parent_set(n - 1, s, int(best_idx[i]))
        for m in candidates_to_nodes(cands, i):
            adj[int(m), i] = 1
    return adj
