"""Pairwise prior component (paper §IV).

Interface matrix ``R ∈ [0,1]^{n×n}``: R[i, m] is the user's confidence in the
existence of an edge m → i (0.5 = no bias). The pairwise prior function

    PPF(i, m) = 100 · (R[i, m] − 0.5)³            (paper Eq. 10, log10 units)

is added to ls(i, π) for every m ∈ π (Eq. 9). We work in natural log, so the
stored value is ``PPF · ln 10`` — the paper's "±10 log10 units at R→0/1"
semantics is preserved exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

LN10 = float(np.log(10.0))

__all__ = ["ppf", "ppf_ln", "prior_chunk", "prior_table", "make_prior_matrix"]


def ppf(R: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. 10 (log10 units)."""
    return 100.0 * (R - 0.5) ** 3


def ppf_ln(R: jnp.ndarray) -> jnp.ndarray:
    """PPF converted to natural-log units (internal score space)."""
    return ppf(R) * LN10


def prior_chunk(R: jnp.ndarray, node: int | jnp.ndarray,
                pst_chunk: jnp.ndarray) -> jnp.ndarray:
    """Σ_{m∈π} PPF_ln(node, m) for a chunk of parent sets (C, s), -1 padded."""
    pnodes = pst_chunk + (pst_chunk >= node)             # candidate -> node id
    vals = ppf_ln(R[node, jnp.clip(pnodes, 0)])          # (C, s)
    return jnp.where(pst_chunk < 0, 0.0, vals).sum(-1)


def prior_table(R: jnp.ndarray, pst: jnp.ndarray, n: int,
                chunk: int = 8192) -> jnp.ndarray:
    """Full (n, S) additive prior table."""
    R = jnp.asarray(R, jnp.float32)
    S = pst.shape[0]
    rows = []
    for i in range(n):
        out = [prior_chunk(R, i, pst[c0:min(c0 + chunk, S)])
               for c0 in range(0, S, chunk)]
        rows.append(jnp.concatenate(out))
    return jnp.stack(rows)


def make_prior_matrix(n: int, *, known_edges=(), forbidden_edges=(),
                      confidence: float = 0.8) -> np.ndarray:
    """Convenience builder: R=0.5 everywhere, `confidence` on known edges
    (m → i given as (m, i)), `1-confidence` on forbidden ones."""
    R = np.full((n, n), 0.5, np.float32)
    for (m, i) in known_edges:
        R[i, m] = confidence
    for (m, i) in forbidden_edges:
        R[i, m] = 1.0 - confidence
    return R
