"""Combinatorial machinery for parent-set enumeration (paper §V-B, Algorithm 2).

The paper indexes all subsets of at most ``s`` elements out of ``n`` candidates so
that (a) a GPU thread can *unrank* an index into its subset arithmetically
(Algorithm 2), and (b) a materialized parent-set table (PST) can replace the
arithmetic with a table read.  We implement both:

* :func:`unrank_combination` — faithful, non-recursive Algorithm 2 (lexicographic
  k-combinations of ``n`` elements).
* :func:`rank_combination` — the inverse bijection.  This is the TPU-native
  replacement for the paper's *hash table*: instead of hashing (node, parent-set)
  into a chained table, the rank IS the address into a dense ``(n, S)`` score
  table.  O(s) integer math, no pointer chasing, gatherable.
* :func:`build_pst` — the parent-set table, size-ascending then lexicographic.

Layout notes
------------
Parent sets are subsets of the ``n-1`` *candidate* indices ``{0..n-2}`` shared by
every node; candidate ``c`` of node ``i`` refers to node ``c + (c >= i)``.  PST rows
are padded to width ``s`` with ``-1``.
"""
from __future__ import annotations

import math
from functools import lru_cache

import numpy as np

__all__ = [
    "n_parent_sets",
    "size_offsets",
    "binom_table",
    "unrank_combination",
    "rank_combination",
    "rank_combinations_batch",
    "build_pst",
    "rank_parent_set",
    "unrank_parent_set",
    "candidates_to_nodes",
    "nodes_to_candidates",
]


def n_parent_sets(n_candidates: int, s: int) -> int:
    """S = sum_{j=0}^{s} C(n_candidates, j) — paper §III-B."""
    return sum(math.comb(n_candidates, j) for j in range(s + 1))


def size_offsets(n_candidates: int, s: int) -> np.ndarray:
    """Start offset of each size-k block in the PST, k = 0..s (+ total sentinel)."""
    sizes = [math.comb(n_candidates, j) for j in range(s + 1)]
    return np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)


@lru_cache(maxsize=None)
def binom_table(n_max: int, k_max: int) -> np.ndarray:
    """C(n, k) for 0 <= n <= n_max, 0 <= k <= k_max (int64, exact for our sizes)."""
    t = np.zeros((n_max + 1, k_max + 1), dtype=np.int64)
    t[:, 0] = 1
    for n in range(1, n_max + 1):
        for k in range(1, k_max + 1):
            t[n, k] = t[n - 1, k - 1] + t[n - 1, k]
    return t


def unrank_combination(n: int, k: int, l: int) -> np.ndarray:
    """Paper Algorithm 2: the l-th (0-based) k-combination of {0..n-1} in
    lexicographic order, non-recursive.

    The paper states it for 1-based elements and 1-based rank; we use 0-based on
    both ends (the bijection is identical up to the shift).
    """
    if not (0 <= l < math.comb(n, k)):
        raise ValueError(f"rank {l} out of range for C({n},{k})")
    comb = np.empty(k, dtype=np.int64)
    low = -1  # last chosen element (0-based); paper's `low` is the 1-based analogue
    for pos in range(k):
        remaining = k - pos
        # find the smallest next element a > low such that the number of
        # combinations starting with a covers rank l
        s = 0
        n_rest = n - (low + 1)  # candidates remaining
        acc = 0
        while True:
            s += 1
            c = math.comb(n_rest - s, remaining - 1)
            if acc + c > l:
                break
            acc += c
        comb[pos] = low + s
        l -= acc
        low = comb[pos]
    return comb


def rank_combination(n: int, comb: np.ndarray) -> int:
    """Inverse of :func:`unrank_combination` (lexicographic rank, 0-based)."""
    comb = np.asarray(comb, dtype=np.int64)
    k = len(comb)
    rank = 0
    low = -1
    for pos, a in enumerate(comb):
        remaining = k - pos
        n_rest = n - (low + 1)
        for step in range(1, int(a) - low):
            rank += math.comb(n_rest - step, remaining - 1)
        low = int(a)
    return rank


def rank_combinations_batch(n: int, s: int, rows: np.ndarray,
                            sizes: np.ndarray) -> np.ndarray:
    """Vectorized :func:`rank_parent_set` over arbitrarily-shaped batches.

    rows: (..., s) sorted element indices over {0..n-1}, padded with -1 at the
    tail; sizes: (...) set sizes. Returns (...) int64 global indices into the
    size-ascending :func:`build_pst`(n, s) ordering.

    Uses the hockey-stick identity to collapse :func:`rank_combination`'s inner
    loop:  sum_{x=a}^{b} C(n-1-x, r) = C(n-a, r+1) - C(n-1-b, r+1), so the lex
    rank of {c_0 < ... < c_{k-1}} is  sum_j [C(n-1-c_{j-1}, k-j) - C(n-c_j, k-j)]
    with c_{-1} = -1. O(s) table lookups per row, no Python per-row loop —
    this is what makes the preprocess/ assembly gather map cheap to build.
    """
    rows = np.asarray(rows, dtype=np.int64)
    sizes = np.asarray(sizes, dtype=np.int64)
    B = binom_table(n + 1, s + 1)
    off = size_offsets(n, s)
    j = np.arange(rows.shape[-1])
    valid = j < sizes[..., None]
    prev = np.concatenate(
        [np.full(rows.shape[:-1] + (1,), -1, np.int64), rows[..., :-1]],
        axis=-1)
    c = np.where(valid, rows, 0)
    p = np.where(valid, prev, 0)
    r = np.where(valid, sizes[..., None] - j, 1)      # k - j for each position
    term = (B[np.clip(n - 1 - p, 0, n), np.clip(r, 0, s + 1)]
            - B[np.clip(n - c, 0, n), np.clip(r, 0, s + 1)])
    return off[sizes] + np.where(valid, term, 0).sum(-1)


def build_pst(n_candidates: int, s: int) -> tuple[np.ndarray, np.ndarray]:
    """Parent-set table: (S, s) int32 padded with -1, and (S,) int32 sizes.

    Order: size-ascending blocks (empty set first), lexicographic within a block.
    (The paper lists size-4-first; only the block order differs — see DESIGN.md §8.)
    """
    rows = []
    sizes = []
    for k in range(s + 1):
        if k == 0:
            rows.append(np.full((1, s), -1, dtype=np.int32))
            sizes.append(np.zeros(1, dtype=np.int32))
            continue
        block = np.empty((math.comb(n_candidates, k), s), dtype=np.int32)
        block[:] = -1
        # enumerate lexicographically without per-row unranking (O(S) total)
        c = np.arange(k, dtype=np.int64)
        idx = 0
        while True:
            block[idx, :k] = c
            idx += 1
            # next lexicographic combination
            j = k - 1
            while j >= 0 and c[j] == n_candidates - k + j:
                j -= 1
            if j < 0:
                break
            c[j] += 1
            for jj in range(j + 1, k):
                c[jj] = c[jj - 1] + 1
        rows.append(block)
        sizes.append(np.full(idx, k, dtype=np.int32))
    return np.concatenate(rows, axis=0), np.concatenate(sizes)


def rank_parent_set(n_candidates: int, s: int, parents: np.ndarray) -> int:
    """Global PST index of a candidate-index parent set (any order). The
    hash-table-equivalent lookup: table[node, rank_parent_set(...)] == ls(node, π)."""
    parents = np.sort(np.asarray(parents, dtype=np.int64))
    k = len(parents)
    if k > s:
        raise ValueError(f"parent set of size {k} exceeds limit s={s}")
    off = size_offsets(n_candidates, s)
    return int(off[k] + (rank_combination(n_candidates, parents) if k else 0))


def unrank_parent_set(n_candidates: int, s: int, rank: int) -> np.ndarray:
    """Inverse of :func:`rank_parent_set`: global PST rank -> sorted candidate
    indices. Locates the size-k block from :func:`size_offsets`, then applies
    paper Algorithm 2 within it — O(s·n) integer math, NO materialized PST.
    This is what lets the pruned representation drop the (S, s) table
    entirely (adjacency recovery decodes the ≤ n winning ranks on the fly)."""
    off = size_offsets(n_candidates, s)
    if not (0 <= rank < off[-1]):
        raise ValueError(f"rank {rank} outside [0, S={off[-1]})")
    k = int(np.searchsorted(off, rank, side="right")) - 1
    if k == 0:
        return np.empty(0, dtype=np.int64)
    return unrank_combination(n_candidates, k, int(rank) - int(off[k]))


def candidates_to_nodes(cands: np.ndarray, node: int) -> np.ndarray:
    """Map candidate indices {0..n-2} to node ids {0..n-1}\\{node}. -1 padding maps to -1."""
    cands = np.asarray(cands)
    out = cands + (cands >= node)
    return np.where(cands < 0, -1, out)


def nodes_to_candidates(nodes: np.ndarray, node: int) -> np.ndarray:
    """Inverse of :func:`candidates_to_nodes`."""
    nodes = np.asarray(nodes)
    if np.any(nodes == node):
        raise ValueError("a node cannot be its own parent")
    out = nodes - (nodes > node)
    return np.where(nodes < 0, -1, out)
