"""Order-space Metropolis–Hastings MCMC (paper §III, Algorithm 1).

Random walk over topological orders, accepted with probability
min(1, P(≺_new)/P(≺)) — in log space, ``log u < score(≺_new) − score(≺)``.
The best graph (per-node argmax parent sets) is produced by the scorer itself
on every iteration, so the global best graph is tracked for free — no
postprocessing (paper §III-B).

Two proposal regimes:

* ``window=0`` (legacy): the paper's unbounded random transposition
  (:func:`_propose_swap`), full rescore every iteration.
* ``window=w ≥ 2``: a mixture of three SYMMETRIC bounded-window moves
  (:func:`propose_move`), drawn categorically per iteration —

    - bounded swap: positions (p, p+d), d ~ U[1, w-1];
    - single-node insertion: node at position a re-inserted at b, |a-b| < w
      (out-of-range targets degrade to a no-op, preserving symmetry);
    - window reversal: positions [p, p+len-1] reversed, len ~ U[2, w]
      (an involution, trivially symmetric).

  Every move permutes positions only inside a window of ≤ w positions
  starting at the returned ``lo``, which is what makes the incremental
  O(w·S) rescore (core/order_scoring.score_order_delta) exact. Richer move
  sets also mix better than pure transpositions (Kuipers et al. 1803.07859;
  Agrawal et al. 1803.05554). All moves are symmetric, so the acceptance
  test stays the pure score ratio.

Everything is a `lax.scan` over iterations; chains are vmapped (and sharded
over the `data`/`pod` mesh axes by launch/bn_learn.py).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .order_scoring import inverse_permutation

__all__ = ["ChainState", "init_chain", "mcmc_run", "mcmc_run_chains",
           "mcmc_step", "propose_move", "exchange_best"]

ScoreFn = Callable[[jnp.ndarray], tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]
# pos (n,) -> (score, best_idx (n,), best_ls (n,))
DeltaFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray],
                   tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]
# (new_pos (n,), lo, prev_ls (n,), prev_idx (n,)) -> same triple
#
# The sampler is representation-agnostic: both callables close over EITHER a
# dense core.scores.ScoreTable (score_order_blocked / the Pallas kernel /
# the sharded scorer) or a preprocess.SparseScoreTable (score_order_pruned,
# O(n*K)); best_idx is a global PST rank in every case, so best-graph
# tracking and adjacency recovery are identical. launch/bn_learn.make_score_fn
# and make_delta_fn do the dispatch.


class ChainState(NamedTuple):
    key: jax.Array
    pos: jax.Array          # (n,) int32 — pos[v] = position of node v in ≺
    score: jax.Array        # f32 — score of current order
    cur_idx: jax.Array      # (n,) int32 — best parent-set idx under current order
    best_score: jax.Array   # f32 — best graph score seen so far
    best_idx: jax.Array     # (n,) int32 — its parent sets
    best_pos: jax.Array     # (n,) int32 — its order
    accepts: jax.Array      # int32
    # appended LAST so positionally-named checkpoint leaves of the previous
    # 8-field layout stay aligned on restore
    cur_ls: jax.Array       # (n,) f32 — per-node best local scores (delta cache)


def init_chain(key: jax.Array, n: int, score_fn: ScoreFn) -> ChainState:
    key, sub = jax.random.split(key)
    pos = jax.random.permutation(sub, n).astype(jnp.int32)
    score, idx, ls = score_fn(pos)
    return ChainState(key, pos, score, idx, score, idx, pos, jnp.int32(0), ls)


def _propose_swap(key: jax.Array, pos: jax.Array) -> jax.Array:
    """Swap the positions of two distinct random nodes (paper §III-C)."""
    n = pos.shape[0]
    ka, kb = jax.random.split(key)
    a = jax.random.randint(ka, (), 0, n)
    b = jax.random.randint(kb, (), 0, n - 1)
    b = b + (b >= a)  # distinct
    pa, pb = pos[a], pos[b]
    return pos.at[a].set(pb).at[b].set(pa)


def propose_move(key: jax.Array, pos: jax.Array, *, window: int):
    """Bounded-window move mixture. Returns (new_pos, lo) where every changed
    position lies in [lo, lo+window-1]. Requires window ≥ 2 (and n ≥ 2).

    Symmetry: each move's reverse is generated with the same probability
    (swap/reversal pick unordered windows; insertion draws (a, ±d) and the
    inverse is (b, ∓d), equiprobable), so Metropolis acceptance needs no
    Hastings correction.
    """
    n = pos.shape[0]
    w = min(window, n)
    k_mv, k1, k2, k3 = jax.random.split(key, 4)
    order = inverse_permutation(pos)

    def swap(_):
        d = jax.random.randint(k1, (), 1, w)
        p = jax.random.randint(k2, (), 0, n - d)
        a, b = order[p], order[p + d]
        return pos.at[a].set(p + d).at[b].set(p), p

    def insert(_):
        a = jax.random.randint(k1, (), 0, n)
        d = jax.random.randint(k2, (), 1, w)
        sgn = jnp.where(jax.random.bernoulli(k3), 1, -1)
        b = a + sgn * d
        b = jnp.where((b >= 0) & (b < n), b, a)           # off-edge -> no-op
        x = order[a]
        down = ((pos > a) & (pos <= b)).astype(pos.dtype)  # a < b: shift left
        up = ((pos >= b) & (pos < a)).astype(pos.dtype)    # a > b: shift right
        new = (pos - down + up).at[x].set(b)
        return new.astype(pos.dtype), jnp.minimum(a, b)

    def reverse(_):
        ln = jax.random.randint(k1, (), 2, w + 1)
        p = jax.random.randint(k2, (), 0, n - ln + 1)
        hi = p + ln - 1
        inwin = (pos >= p) & (pos <= hi)
        return jnp.where(inwin, p + hi - pos, pos).astype(pos.dtype), p

    mv = jax.random.randint(k_mv, (), 0, 3)
    new_pos, lo = jax.lax.switch(mv, [swap, insert, reverse], None)
    return new_pos, lo.astype(jnp.int32)


def mcmc_step(state: ChainState, score_fn: ScoreFn,
              delta_fn: DeltaFn | None = None,
              window: int = 0) -> ChainState:
    """One MH iteration. window ≥ 2 selects the bounded-window move mixture;
    delta_fn (requires window ≥ 2) selects the incremental O(window·S)
    rescore seeded from the chain's (cur_ls, cur_idx) cache."""
    assert delta_fn is None or window >= 2, \
        "the delta path needs bounded-window proposals (window >= 2)"
    key, k_prop, k_u = jax.random.split(state.key, 3)
    if window >= 2:
        new_pos, lo = propose_move(k_prop, state.pos, window=window)
    else:
        new_pos, lo = _propose_swap(k_prop, state.pos), jnp.int32(0)
    if delta_fn is not None:
        new_score, new_idx, new_ls = delta_fn(new_pos, lo, state.cur_ls,
                                              state.cur_idx)
    else:
        new_score, new_idx, new_ls = score_fn(new_pos)
    log_u = jnp.log(jax.random.uniform(k_u, (), minval=1e-38))
    accept = log_u < (new_score - state.score)

    pos = jnp.where(accept, new_pos, state.pos)
    score = jnp.where(accept, new_score, state.score)
    cur_idx = jnp.where(accept, new_idx, state.cur_idx)
    cur_ls = jnp.where(accept, new_ls, state.cur_ls)

    better = accept & (new_score > state.best_score)
    return ChainState(
        key=key, pos=pos, score=score, cur_idx=cur_idx, cur_ls=cur_ls,
        best_score=jnp.where(better, new_score, state.best_score),
        best_idx=jnp.where(better, new_idx, state.best_idx),
        best_pos=jnp.where(better, new_pos, state.best_pos),
        accepts=state.accepts + accept.astype(jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("n", "score_fn", "iters", "trace",
                                             "delta_fn", "window"))
def mcmc_run(key: jax.Array, n: int, score_fn: ScoreFn, iters: int,
             trace: bool = False, delta_fn: DeltaFn | None = None,
             window: int = 0):
    """Run one chain for `iters` iterations. Returns (final_state, score_trace)."""
    state = init_chain(key, n, score_fn)

    def body(st, _):
        st = mcmc_step(st, score_fn, delta_fn, window)
        return st, (st.score if trace else None)

    state, tr = jax.lax.scan(body, state, None, length=iters)
    return state, tr


def mcmc_run_chains(key: jax.Array, n_chains: int, n: int, score_fn: ScoreFn,
                    iters: int, delta_fn: DeltaFn | None = None,
                    window: int = 0):
    """vmapped independent chains (DP axis). Returns stacked final states."""
    keys = jax.random.split(key, n_chains)
    run = functools.partial(mcmc_run, n=n, score_fn=score_fn, iters=iters,
                            delta_fn=delta_fn, window=window)
    states, _ = jax.vmap(lambda k: run(k))(keys)
    return states


def exchange_best(states: ChainState) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Cross-chain best-graph reduction (max + index-resolved argmax — the same
    reduction pattern as the scoring kernel, one level up)."""
    w = jnp.argmax(states.best_score)
    return states.best_score[w], states.best_idx[w], states.best_pos[w]
