"""Order-space Metropolis–Hastings MCMC (paper §III, Algorithm 1).

Random walk over topological orders: propose by swapping two random nodes,
accept with probability min(1, P(≺_new)/P(≺)) — in log space,
``log u < score(≺_new) − score(≺)``. The best graph (per-node argmax parent
sets) is produced by the scorer itself on every iteration, so the global best
graph is tracked for free — no postprocessing (paper §III-B).

Everything is a `lax.scan` over iterations; chains are vmapped (and sharded
over the `data`/`pod` mesh axes by launch/bn_learn.py).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["ChainState", "init_chain", "mcmc_run", "mcmc_run_chains", "exchange_best"]

ScoreFn = Callable[[jnp.ndarray], tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]
# pos (n,) -> (score, best_idx (n,), best_ls (n,))


class ChainState(NamedTuple):
    key: jax.Array
    pos: jax.Array          # (n,) int32 — pos[v] = position of node v in ≺
    score: jax.Array        # f32 — score of current order
    cur_idx: jax.Array      # (n,) int32 — best parent-set idx under current order
    best_score: jax.Array   # f32 — best graph score seen so far
    best_idx: jax.Array     # (n,) int32 — its parent sets
    best_pos: jax.Array     # (n,) int32 — its order
    accepts: jax.Array      # int32


def init_chain(key: jax.Array, n: int, score_fn: ScoreFn) -> ChainState:
    key, sub = jax.random.split(key)
    pos = jax.random.permutation(sub, n).astype(jnp.int32)
    score, idx, _ = score_fn(pos)
    return ChainState(key, pos, score, idx, score, idx, pos, jnp.int32(0))


def _propose_swap(key: jax.Array, pos: jax.Array) -> jax.Array:
    """Swap the positions of two distinct random nodes (paper §III-C)."""
    n = pos.shape[0]
    ka, kb = jax.random.split(key)
    a = jax.random.randint(ka, (), 0, n)
    b = jax.random.randint(kb, (), 0, n - 1)
    b = b + (b >= a)  # distinct
    pa, pb = pos[a], pos[b]
    return pos.at[a].set(pb).at[b].set(pa)


def mcmc_step(state: ChainState, score_fn: ScoreFn) -> ChainState:
    key, k_prop, k_u = jax.random.split(state.key, 3)
    new_pos = _propose_swap(k_prop, state.pos)
    new_score, new_idx, _ = score_fn(new_pos)
    log_u = jnp.log(jax.random.uniform(k_u, (), minval=1e-38))
    accept = log_u < (new_score - state.score)

    pos = jnp.where(accept, new_pos, state.pos)
    score = jnp.where(accept, new_score, state.score)
    cur_idx = jnp.where(accept, new_idx, state.cur_idx)

    better = accept & (new_score > state.best_score)
    return ChainState(
        key=key, pos=pos, score=score, cur_idx=cur_idx,
        best_score=jnp.where(better, new_score, state.best_score),
        best_idx=jnp.where(better, new_idx, state.best_idx),
        best_pos=jnp.where(better, new_pos, state.best_pos),
        accepts=state.accepts + accept.astype(jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("n", "score_fn", "iters", "trace"))
def mcmc_run(key: jax.Array, n: int, score_fn: ScoreFn, iters: int,
             trace: bool = False):
    """Run one chain for `iters` iterations. Returns (final_state, score_trace)."""
    state = init_chain(key, n, score_fn)

    def body(st, _):
        st = mcmc_step(st, score_fn)
        return st, (st.score if trace else None)

    state, tr = jax.lax.scan(body, state, None, length=iters)
    return state, tr


def mcmc_run_chains(key: jax.Array, n_chains: int, n: int, score_fn: ScoreFn,
                    iters: int):
    """vmapped independent chains (DP axis). Returns stacked final states."""
    keys = jax.random.split(key, n_chains)
    run = functools.partial(mcmc_run, n=n, score_fn=score_fn, iters=iters)
    states, _ = jax.vmap(lambda k: run(k))(keys)
    return states


def exchange_best(states: ChainState) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Cross-chain best-graph reduction (max + index-resolved argmax — the same
    reduction pattern as the scoring kernel, one level up)."""
    w = jnp.argmax(states.best_score)
    return states.best_score[w], states.best_idx[w], states.best_pos[w]
