"""Order-space Metropolis–Hastings MCMC (paper §III, Algorithm 1).

Random walk over topological orders, accepted with probability
min(1, P(≺_new)/P(≺)) — in log space, ``log u < score(≺_new) − score(≺)``.
The best graph (per-node argmax parent sets) is produced by the scorer itself
on every iteration, so the global best graph is tracked for free — no
postprocessing (paper §III-B).

Two proposal regimes:

* ``window=0`` (legacy): the paper's unbounded random transposition
  (:func:`_propose_swap`), full rescore every iteration.
* ``window=w ≥ 2``: a mixture of three SYMMETRIC bounded-window moves
  (:func:`propose_move`), drawn categorically per iteration —

    - bounded swap: positions (p, p+d), d ~ U[1, w-1];
    - single-node insertion: node at position a re-inserted at b, |a-b| < w
      (out-of-range targets degrade to a no-op, preserving symmetry);
    - window reversal: positions [p, p+len-1] reversed, len ~ U[2, w]
      (an involution, trivially symmetric).

  Every move permutes positions only inside a window of ≤ w positions
  starting at the returned ``lo``, which is what makes the incremental
  O(w·S) rescore (core/order_scoring.score_order_delta) exact. Richer move
  sets also mix better than pure transpositions (Kuipers et al. 1803.07859;
  Agrawal et al. 1803.05554). All moves are symmetric, so the acceptance
  test stays the pure score ratio.

Everything is a `lax.scan` over iterations; chains are vmapped (and sharded
over the `data`/`pod` mesh axes by launch/bn_learn.py).

Cached consistency bitmasks (ChainState.mask_planes)
----------------------------------------------------

The bitmask-cached delta path (core/order_scoring §Cached consistency
bitmasks) carries its per-node packed violation-count planes in
``ChainState.mask_planes``: shape (n, P, S/32) uint32, where P =
ceil(log2(s+1)) bit planes count, per (node, parent-set), the parents that
do NOT precede the node — bit b of word j refers to PST rank 32j+b
(LSB-first), and a set is consistent iff its count is zero across all
planes. The planes are built once at :func:`init_chain` (``planes_fn``),
patched for the ≤ window moved nodes per proposal, and adopted on accept —
exactly mirroring the (cur_ls, cur_idx) cache discipline, so the invariant
"mask_planes describes the CURRENT order" holds at every iteration. Paths
that don't use the cache carry a zero-size placeholder.

Adaptive move windows (freeze after burn-in)
--------------------------------------------

:func:`mcmc_step_adaptive` tunes the move window from the running accept
rate: a SMALL STATIC set of candidate windows is pre-traced (one
`lax.switch` branch per window, each with its own delta closure, so the
delta ≡ full bitwise guarantee holds per window), and a dual-averaging
iterate in index space (Nesterov 2009, the same scheme NUTS uses for step
size) nudges the selected index toward ``target_accept``: too-high accept
rate ⇒ wider window (bigger moves), too-low ⇒ narrower. The selection is
FROZEN once ``step ≥ burn_in``: a kernel whose parameters keep adapting
forever is not a valid Markov chain (diminishing-adaptation conditions are
easy to violate), whereas adapt-then-freeze makes every post-burn-in sample
come from one fixed Metropolis kernel — the standard warm-up contract.

Convergence telemetry (segmented runs)
--------------------------------------

:func:`make_traced_segment_runner` is the segmented counterpart of the
one-shot run loops above: the same scan, cut into host-visible segments,
optionally carrying a telemetry ``TraceState`` (repro.telemetry.taps)
beside the chain stack and calling an in-scan tap each iteration. The host
drains the trace between segments to compute split-R̂ / edge-marginal R̂
and may stop the run early (bn_learn ``--stop-on-converge``) — runs then
terminate on CONVERGENCE, with the iteration count as the cap, instead of
the other way around. Global-iteration arithmetic keeps tap and exchange
cadences identical across segment and checkpoint-restart boundaries.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .order_scoring import inverse_permutation

__all__ = ["ChainState", "BitmaskDelta", "init_chain", "mcmc_run",
           "mcmc_run_adaptive", "mcmc_run_chains",
           "mcmc_run_chains_adaptive", "mcmc_step", "mcmc_step_adaptive",
           "propose_move", "exchange_best", "exchange_step",
           "make_traced_segment_runner", "DEFAULT_TARGET_ACCEPT"]

ScoreFn = Callable[[jnp.ndarray], tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]
# pos (n,) -> (score, best_idx (n,), best_ls (n,))
DeltaFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray],
                   tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]
# (new_pos (n,), lo, prev_ls (n,), prev_idx (n,)) -> same triple
#
# The sampler is representation-agnostic: both callables close over EITHER a
# dense core.scores.ScoreTable (score_order_blocked / the Pallas kernel /
# the sharded scorer) or a preprocess.SparseScoreTable (score_order_pruned,
# O(n*K)); best_idx is a global PST rank in every case, so best-graph
# tracking and adjacency recovery are identical. launch/bn_learn.make_score_fn
# and make_delta_fn do the dispatch.

DEFAULT_TARGET_ACCEPT = 0.234   # classic random-walk Metropolis optimum


class BitmaskDelta(NamedTuple):
    """Marker wrapper for the EXTENDED delta contract — the bitmask-cached
    path needs the previous order and the cached planes, and hands back the
    patched planes for the sampler to adopt on accept:

        fn(new_pos, lo, prev_ls, prev_idx, old_pos, planes)
            -> (score, best_idx, best_ls, new_planes)

    Wrapping (instead of widening DeltaFn) keeps every existing plain delta
    closure — pruned, sharded, kernel — working unchanged."""
    fn: Callable


class ChainState(NamedTuple):
    key: jax.Array
    pos: jax.Array          # (n,) int32 — pos[v] = position of node v in ≺
    score: jax.Array        # f32 — score of current order
    cur_idx: jax.Array      # (n,) int32 — best parent-set idx under current order
    best_score: jax.Array   # f32 — best graph score seen so far
    best_idx: jax.Array     # (n,) int32 — its parent sets
    best_pos: jax.Array     # (n,) int32 — its order
    accepts: jax.Array      # int32
    # appended LAST so positionally-named checkpoint leaves of the previous
    # 8-field layout stay aligned on restore
    cur_ls: jax.Array       # (n,) f32 — per-node best local scores (delta cache)
    # --- appended by the bitmask/adaptive engine (ISSUE 3); restore of a
    # pre-tentpole checkpoint backfills these (checkpointer allow_missing)
    mask_planes: jax.Array  # (n, P, S/32) uint32 violation planes, or (0,)
    win_idx: jax.Array      # int32 — index into the static adaptive window set
    adapt_err: jax.Array    # f32 — dual-averaging Σ(accept − target)
    step: jax.Array         # int32 — iteration counter (burn-in freeze)


def _no_planes() -> jax.Array:
    """Zero-size placeholder for paths without the bitmask cache."""
    return jnp.zeros((0,), jnp.uint32)


def init_chain(key: jax.Array, n: int, score_fn: ScoreFn,
               planes_fn: Callable[[jnp.ndarray], jax.Array] | None = None,
               win_idx: int = 0) -> ChainState:
    key, sub = jax.random.split(key)
    pos = jax.random.permutation(sub, n).astype(jnp.int32)
    score, idx, ls = score_fn(pos)
    planes = planes_fn(pos) if planes_fn is not None else _no_planes()
    return ChainState(key, pos, score, idx, score, idx, pos, jnp.int32(0), ls,
                      planes, jnp.int32(win_idx), jnp.float32(0.0),
                      jnp.int32(0))


def _propose_swap(key: jax.Array, pos: jax.Array) -> jax.Array:
    """Swap the positions of two distinct random nodes (paper §III-C)."""
    n = pos.shape[0]
    ka, kb = jax.random.split(key)
    a = jax.random.randint(ka, (), 0, n)
    b = jax.random.randint(kb, (), 0, n - 1)
    b = b + (b >= a)  # distinct
    pa, pb = pos[a], pos[b]
    return pos.at[a].set(pb).at[b].set(pa)


def _propose_move_impl(key: jax.Array, pos: jax.Array, *, window: int):
    """Bounded-window move mixture. Returns (new_pos, lo) where every changed
    position lies in [lo, lo+window-1]. Requires window ≥ 2 (and n ≥ 2);
    window > n is clamped to n (callers that should refuse instead — the CLI
    — validate before tracing, launch/bn_learn.main).

    Already-traced callers (the scan bodies) use this raw impl so the move
    inlines into the engine computation; the public `propose_move` below is
    the jitted entry point for eager callers. The branch closures below are
    rebuilt on every Python call, so an un-jitted eager call re-traces and
    re-compiles the `lax.switch` each time — thousands of such calls (the
    property tests) exhaust the JIT code-mapping budget and crash LLVM.

    Symmetry: each move's reverse is generated with the same probability
    (swap/reversal pick unordered windows; insertion draws (a, ±d) and the
    inverse is (b, ∓d), equiprobable), so Metropolis acceptance needs no
    Hastings correction.
    """
    if window < 2:
        raise ValueError(
            f"propose_move needs window >= 2, got {window}: window=1 has no "
            "in-window move (use window=0 for the legacy unbounded swap)")
    n = pos.shape[0]
    w = min(window, n)
    k_mv, k1, k2, k3 = jax.random.split(key, 4)
    order = inverse_permutation(pos)

    def swap(_):
        d = jax.random.randint(k1, (), 1, w)
        p = jax.random.randint(k2, (), 0, n - d)
        a, b = order[p], order[p + d]
        return pos.at[a].set(p + d).at[b].set(p), p

    def insert(_):
        a = jax.random.randint(k1, (), 0, n)
        d = jax.random.randint(k2, (), 1, w)
        sgn = jnp.where(jax.random.bernoulli(k3), 1, -1)
        b = a + sgn * d
        b = jnp.where((b >= 0) & (b < n), b, a)           # off-edge -> no-op
        x = order[a]
        down = ((pos > a) & (pos <= b)).astype(pos.dtype)  # a < b: shift left
        up = ((pos >= b) & (pos < a)).astype(pos.dtype)    # a > b: shift right
        new = (pos - down + up).at[x].set(b)
        return new.astype(pos.dtype), jnp.minimum(a, b)

    def reverse(_):
        ln = jax.random.randint(k1, (), 2, w + 1)
        p = jax.random.randint(k2, (), 0, n - ln + 1)
        hi = p + ln - 1
        inwin = (pos >= p) & (pos <= hi)
        return jnp.where(inwin, p + hi - pos, pos).astype(pos.dtype), p

    mv = jax.random.randint(k_mv, (), 0, 3)
    new_pos, lo = jax.lax.switch(mv, [swap, insert, reverse], None)
    return new_pos, lo.astype(jnp.int32)


propose_move = functools.partial(jax.jit,
                                 static_argnames=("window",))(_propose_move_impl)


def _propose_and_score(state: ChainState, k_prop: jax.Array,
                       score_fn: ScoreFn,
                       delta_fn: DeltaFn | BitmaskDelta | None, window: int):
    """One proposal + rescore under a STATIC window, dispatching between the
    full, plain-delta and bitmask-delta paths. Returns
    (new_pos, new_score, new_idx, new_ls, new_planes)."""
    if window >= 2:
        new_pos, lo = _propose_move_impl(k_prop, state.pos, window=window)
    else:
        new_pos, lo = _propose_swap(k_prop, state.pos), jnp.int32(0)
    if isinstance(delta_fn, BitmaskDelta):
        new_score, new_idx, new_ls, new_planes = delta_fn.fn(
            new_pos, lo, state.cur_ls, state.cur_idx, state.pos,
            state.mask_planes)
    elif delta_fn is not None:
        new_score, new_idx, new_ls = delta_fn(new_pos, lo, state.cur_ls,
                                              state.cur_idx)
        new_planes = state.mask_planes
    else:
        new_score, new_idx, new_ls = score_fn(new_pos)
        new_planes = state.mask_planes
    return new_pos, new_score, new_idx, new_ls, new_planes


def _accept_update(state: ChainState, key, k_u, proposal) -> ChainState:
    """Shared MH accept/reject + cache/best bookkeeping."""
    new_pos, new_score, new_idx, new_ls, new_planes = proposal
    log_u = jnp.log(jax.random.uniform(k_u, (), minval=1e-38))
    accept = log_u < (new_score - state.score)

    pos = jnp.where(accept, new_pos, state.pos)
    score = jnp.where(accept, new_score, state.score)
    cur_idx = jnp.where(accept, new_idx, state.cur_idx)
    cur_ls = jnp.where(accept, new_ls, state.cur_ls)
    mask_planes = jnp.where(accept, new_planes, state.mask_planes)

    better = accept & (new_score > state.best_score)
    return accept, ChainState(
        key=key, pos=pos, score=score, cur_idx=cur_idx, cur_ls=cur_ls,
        mask_planes=mask_planes,
        best_score=jnp.where(better, new_score, state.best_score),
        best_idx=jnp.where(better, new_idx, state.best_idx),
        best_pos=jnp.where(better, new_pos, state.best_pos),
        accepts=state.accepts + accept.astype(jnp.int32),
        win_idx=state.win_idx, adapt_err=state.adapt_err,
        step=state.step + 1,
    )


def mcmc_step(state: ChainState, score_fn: ScoreFn,
              delta_fn: DeltaFn | BitmaskDelta | None = None,
              window: int = 0) -> ChainState:
    """One MH iteration. window ≥ 2 selects the bounded-window move mixture;
    delta_fn (requires window ≥ 2) selects the incremental O(window·S)
    rescore seeded from the chain's (cur_ls, cur_idx) cache — wrapped in
    :class:`BitmaskDelta`, additionally from its cached consistency planes."""
    assert delta_fn is None or window >= 2, \
        "the delta path needs bounded-window proposals (window >= 2)"
    key, k_prop, k_u = jax.random.split(state.key, 3)
    proposal = _propose_and_score(state, k_prop, score_fn, delta_fn, window)
    _, new_state = _accept_update(state, key, k_u, proposal)
    return new_state


def mcmc_step_adaptive(state: ChainState, score_fn: ScoreFn,
                       delta_fns: tuple, windows: tuple[int, ...], *,
                       target_accept: float = DEFAULT_TARGET_ACCEPT,
                       burn_in: int = 0, da_gamma: float = 0.15,
                       da_t0: int = 10) -> ChainState:
    """One MH iteration with adaptive window selection (module docstring).

    windows: static, sorted candidate windows (each ≥ 2); delta_fns: matching
    tuple of DeltaFn/BitmaskDelta/None closures. state.win_idx picks the
    pre-traced `lax.switch` branch; while step < burn_in a dual-averaging
    iterate in index space moves win_idx toward target_accept, after that it
    is frozen (MCMC validity — adapt-then-freeze)."""
    assert len(windows) == len(delta_fns) and len(windows) >= 1
    key, k_prop, k_u = jax.random.split(state.key, 3)

    def branch(j):
        def go(_):
            return _propose_and_score(state, k_prop, score_fn, delta_fns[j],
                                      windows[j])
        return go

    idx = jnp.clip(state.win_idx, 0, len(windows) - 1)
    proposal = jax.lax.switch(idx, [branch(j) for j in range(len(windows))],
                              None)
    accept, new_state = _accept_update(state, key, k_u, proposal)

    # dual averaging in window-INDEX space: accept above target ⇒ push the
    # iterate up (wider moves), below ⇒ down; frozen once step ≥ burn_in
    t = new_state.step.astype(jnp.float32)            # 1-based after update
    adapting = new_state.step <= jnp.int32(burn_in)
    err = jnp.where(adapting,
                    state.adapt_err + (accept.astype(jnp.float32)
                                       - jnp.float32(target_accept)),
                    state.adapt_err)
    mu = jnp.float32((len(windows) - 1) / 2.0)
    x = mu + jnp.sqrt(t) / (jnp.float32(da_gamma) * (t + jnp.float32(da_t0))) \
        * err
    prop_idx = jnp.clip(jnp.round(x).astype(jnp.int32), 0, len(windows) - 1)
    win_idx = jnp.where(new_state.step < jnp.int32(burn_in), prop_idx,
                        state.win_idx)
    return new_state._replace(win_idx=win_idx, adapt_err=err)


@functools.partial(jax.jit, static_argnames=("n", "score_fn", "iters", "trace",
                                             "delta_fn", "window",
                                             "planes_fn"))
def mcmc_run(key: jax.Array, n: int, score_fn: ScoreFn, iters: int,
             trace: bool = False,
             delta_fn: DeltaFn | BitmaskDelta | None = None,
             window: int = 0, planes_fn=None):
    """Run one chain for `iters` iterations. Returns (final_state, score_trace).
    planes_fn (pos -> violation planes) is required iff delta_fn is a
    BitmaskDelta — it seeds the chain's consistency-mask cache."""
    state = init_chain(key, n, score_fn, planes_fn=planes_fn)

    def body(st, _):
        st = mcmc_step(st, score_fn, delta_fn, window)
        return st, (st.score if trace else None)

    state, tr = jax.lax.scan(body, state, None, length=iters)
    return state, tr


@functools.partial(jax.jit, static_argnames=("n", "score_fn", "iters",
                                             "windows", "delta_fns",
                                             "planes_fn", "burn_in",
                                             "target_accept", "trace"))
def mcmc_run_adaptive(key: jax.Array, n: int, score_fn: ScoreFn, iters: int, *,
                      windows: tuple[int, ...], delta_fns: tuple = None,
                      planes_fn=None, burn_in: int = None,
                      target_accept: float = DEFAULT_TARGET_ACCEPT,
                      trace: bool = False):
    """Run one chain with adaptive window selection. burn_in defaults to
    iters // 5; after it the window is frozen. Returns (final_state, trace)
    where trace (if requested) is (score (iters,), win_idx (iters,))."""
    if delta_fns is None:
        delta_fns = (None,) * len(windows)
    if burn_in is None:
        burn_in = iters // 5
    state = init_chain(key, n, score_fn, planes_fn=planes_fn,
                       win_idx=len(windows) // 2)

    def body(st, _):
        st = mcmc_step_adaptive(st, score_fn, delta_fns, windows,
                                target_accept=target_accept, burn_in=burn_in)
        return st, ((st.score, st.win_idx) if trace else None)

    state, tr = jax.lax.scan(body, state, None, length=iters)
    return state, tr


def exchange_step(states: ChainState) -> ChainState:
    """In-scan cross-chain exchange: the best chain (argmax best_score)
    re-seeds the worst chain's position/cache state — current pos, score,
    (cur_ls, cur_idx) and mask_planes are copied TOGETHER, so the re-seeded
    chain's caches describe its new order by construction, and its best_*
    triple is replaced by the donor's (≥ its own by argmin choice, keeping
    per-chain best_score monotone). PRNG keys, accept counts and adaptive
    stats stay per-slot, so the clone diverges immediately — the same
    re-seeding discipline as runtime/straggler.rebalance_chains, applied
    inside the scan instead of at the end.

    Degenerate ranking (all-equal best_score — e.g. early iterations, or a
    flat table) gives argmax == argmin: there is no information to transfer,
    so the exchange is explicitly a NO-OP instead of a self-copy — no leaf
    traffic (mask_planes can be large and mesh-sharded), and the invariant
    that win_idx / dual-averaging stats / keys / accept counts stay strictly
    per-slot holds trivially on every round.

    The ranking is NaN/inf-SAFE for graceful degradation under the run
    supervisor's fault model: a poisoned chain (non-finite best_score) ranks
    as -inf, so it is always the recipient and never the donor — one sick
    chain cannot spread through the exchange while it waits to be healed at
    the next segment boundary. On all-finite inputs the masked rank is
    bitwise the raw best_score, so healthy runs are unchanged."""
    rank = jnp.where(jnp.isfinite(states.best_score), states.best_score,
                     -jnp.inf)
    b = jnp.argmax(rank)
    w = jnp.argmin(rank)

    def copy(st: ChainState) -> ChainState:
        def mv(leaf):
            return leaf.at[w].set(leaf[b])

        return st._replace(
            pos=mv(st.pos), score=mv(st.score),
            cur_idx=mv(st.cur_idx), cur_ls=mv(st.cur_ls),
            mask_planes=mv(st.mask_planes), best_score=mv(st.best_score),
            best_idx=mv(st.best_idx), best_pos=mv(st.best_pos))

    return jax.lax.cond(b == w, lambda st: st, copy, states)


def _run_chain_rounds(states, step, iters: int, exchange_every: int,
                      n_chains: int):
    """Shared chain-scan skeleton: vmapped `step` for `iters` iterations,
    with the in-scan exchange spliced in every `exchange_every` (plus a
    trailing partial round)."""
    def sweep(states, length):
        def body(st, _):
            return jax.vmap(step)(st), None
        states, _ = jax.lax.scan(body, states, None, length=length)
        return states

    if exchange_every <= 0 or n_chains < 2:
        return sweep(states, iters)
    rounds, rem = divmod(iters, exchange_every)

    def round_body(st, _):
        return exchange_step(sweep(st, exchange_every)), None

    states, _ = jax.lax.scan(round_body, states, None, length=rounds)
    return sweep(states, rem)


@functools.partial(jax.jit, static_argnames=("n_chains", "n", "score_fn",
                                             "iters", "delta_fn", "window",
                                             "exchange_every", "planes_fn"))
def mcmc_run_chains(key: jax.Array, n_chains: int, n: int, score_fn: ScoreFn,
                    iters: int, delta_fn: DeltaFn | BitmaskDelta | None = None,
                    window: int = 0, exchange_every: int = 0, planes_fn=None):
    """vmapped independent chains (DP axis). Returns stacked final states.

    exchange_every > 0 runs the periodic in-scan :func:`exchange_step` every
    that many iterations (plus a trailing partial round), instead of only
    reducing at the end: slow chains inherit the current best basin while
    the walk is still running — the paper's end-only best-graph exchange
    promoted to a restart heuristic. 0 keeps fully independent chains."""
    keys = jax.random.split(key, n_chains)
    states = jax.vmap(
        lambda k: init_chain(k, n, score_fn, planes_fn=planes_fn))(keys)
    return _run_chain_rounds(
        states, lambda s: mcmc_step(s, score_fn, delta_fn, window), iters,
        exchange_every, n_chains)


@functools.partial(jax.jit, static_argnames=("n_chains", "n", "score_fn",
                                             "iters", "windows", "delta_fns",
                                             "planes_fn", "burn_in",
                                             "target_accept",
                                             "exchange_every"))
def mcmc_run_chains_adaptive(key: jax.Array, n_chains: int, n: int,
                             score_fn: ScoreFn, iters: int, *,
                             windows: tuple[int, ...], delta_fns: tuple = None,
                             planes_fn=None, burn_in: int = None,
                             target_accept: float = DEFAULT_TARGET_ACCEPT,
                             exchange_every: int = 0):
    """mcmc_run_chains with per-chain adaptive window selection: each chain
    runs its own dual-averaging warm-up (adaptive stats are deliberately NOT
    copied by exchange_step, so a re-seeded chain keeps its own tuning)."""
    if delta_fns is None:
        delta_fns = (None,) * len(windows)
    if burn_in is None:
        burn_in = iters // 5
    keys = jax.random.split(key, n_chains)
    states = jax.vmap(
        lambda k: init_chain(k, n, score_fn, planes_fn=planes_fn,
                             win_idx=len(windows) // 2))(keys)
    step = lambda s: mcmc_step_adaptive(s, score_fn, delta_fns, windows,
                                        target_accept=target_accept,
                                        burn_in=burn_in)
    return _run_chain_rounds(states, step, iters, exchange_every, n_chains)


def make_traced_segment_runner(step, *, tap=None, exchange=None,
                               exchange_every: int = 0,
                               stacked_step: bool = False):
    """The SEGMENTED run loop shared by every telemetry-aware path (the
    single-device, checkpointed and sharded drivers in launch/bn_learn, and
    benchmarks/telemetry_bench): a jitted

        run_segment(states, trace, start, *, length) -> (states, trace)

    scanning ``length`` iterations from global iteration ``start``. The host
    calls it in a while loop, draining/analysing ``trace`` between segments
    — which is what makes stop-on-converge possible at all: the scan stays
    fully accelerator-resident, and the host only intervenes at segment
    granularity.

    * ``step``: per-chain ChainState -> ChainState (vmapped here), or — with
      ``stacked_step=True`` — a whole-stack step like
      core/sharded_scoring.sharded_chain_step (one shard_map program for all
      chains).
    * ``tap``: optional in-scan telemetry tap ``(trace, states, it) ->
      trace`` (telemetry/taps.make_tap); ``it`` is the global 1-based
      iteration, so trace cadence survives segment/restart boundaries.
      With no tap, ``trace`` is carried untouched (pass None).
    * ``exchange``: optional ``(states, trace) -> (states, trace)`` run
      every ``exchange_every`` global iterations (telemetry counts re-seeds
      via telemetry/taps.exchange_step_traced; plain runs wrap
      :func:`exchange_step`). The cadence uses the same global-iteration
      arithmetic as the checkpointed loop, so it survives restarts too.
    """
    if exchange is None:
        exchange = lambda st, tr: (exchange_step(st), tr)

    @functools.partial(jax.jit, static_argnames=("length",))
    def run_segment(states, trace, start, *, length: int):
        def body(carry, i):
            st, tr = carry
            st = step(st) if stacked_step else jax.vmap(step)(st)
            it = start + i + 1
            if tap is not None:
                tr = tap(tr, st, it)
            if exchange_every > 0:
                st, tr = jax.lax.cond(it % exchange_every == 0,
                                      lambda c: exchange(*c), lambda c: c,
                                      (st, tr))
            return (st, tr), None

        (states, trace), _ = jax.lax.scan(body, (states, trace),
                                          jnp.arange(length))
        return states, trace

    return run_segment


def exchange_best(states: ChainState) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Cross-chain best-graph reduction (max + index-resolved argmax — the same
    reduction pattern as the scoring kernel, one level up)."""
    w = jnp.argmax(states.best_score)
    return states.best_score[w], states.best_idx[w], states.best_pos[w]
