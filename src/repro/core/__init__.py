"""Core library: the paper's Bayesian-network structure learner.

Order-space MCMC with max-based order scoring (Eq. 6), precomputed local-score
table (Eq. 4), pairwise priors (Eq. 10), and parent-set-table task decomposition
(§V) — adapted for TPU (DESIGN.md §2).
"""
from .combinatorics import (build_pst, n_parent_sets, rank_combination,
                            rank_combinations_batch, rank_parent_set,
                            unrank_combination, unrank_parent_set)
from .graph import (adjacency_from_best, adjacency_from_ranks, random_cpts,
                    random_dag, topological_order)
from .mcmc import (BitmaskDelta, ChainState, exchange_best, exchange_step,
                   init_chain, mcmc_run, mcmc_run_adaptive, mcmc_run_chains,
                   mcmc_run_chains_adaptive, mcmc_step, mcmc_step_adaptive,
                   propose_move)
from .metrics import edge_posterior, roc_point, structural_hamming
from .order_scoring import (NEG_INF, build_membership_planes,
                            build_violation_planes, delta_window,
                            score_order_chunked, score_order_delta,
                            score_order_delta_bitmask, score_order_pruned,
                            score_order_pruned_delta, score_order_ref)
from .priors import make_prior_matrix, ppf, ppf_ln, prior_table
from .scores import (ScoreTable, build_score_table, score_single,
                     validate_prior_matrix)

__all__ = [
    "build_pst", "n_parent_sets", "rank_combination",
    "rank_combinations_batch", "rank_parent_set", "unrank_combination",
    "unrank_parent_set", "adjacency_from_best", "adjacency_from_ranks",
    "random_cpts", "random_dag",
    "topological_order", "BitmaskDelta", "ChainState", "exchange_best",
    "exchange_step", "init_chain", "mcmc_run", "mcmc_run_adaptive",
    "mcmc_run_chains", "mcmc_run_chains_adaptive", "mcmc_step",
    "mcmc_step_adaptive", "propose_move",
    "roc_point", "structural_hamming", "edge_posterior", "NEG_INF", "build_membership_planes",
    "build_violation_planes", "delta_window", "score_order_chunked",
    "score_order_delta", "score_order_delta_bitmask", "score_order_pruned",
    "score_order_pruned_delta",
    "score_order_ref", "make_prior_matrix", "ppf",
    "ppf_ln", "prior_table", "ScoreTable", "build_score_table", "score_single",
    "validate_prior_matrix",
]
