"""Accuracy metrics: ROC points (paper §VI, Figs. 9–11)."""
from __future__ import annotations

import numpy as np

__all__ = ["roc_point", "structural_hamming"]


def roc_point(learned: np.ndarray, truth: np.ndarray) -> tuple[float, float]:
    """(FP rate, TP rate) of a learned adjacency vs ground truth.

    TP rate = recovered true edges / true edges;
    FP rate = spurious edges / true non-edges (diagonal excluded).
    """
    n = truth.shape[0]
    off = ~np.eye(n, dtype=bool)
    t = truth.astype(bool) & off
    l = learned.astype(bool) & off
    pos = t.sum()
    neg = off.sum() - pos
    tp = (l & t).sum()
    fp = (l & ~t).sum()
    return (float(fp) / max(neg, 1), float(tp) / max(pos, 1))


def structural_hamming(learned: np.ndarray, truth: np.ndarray) -> int:
    n = truth.shape[0]
    off = ~np.eye(n, dtype=bool)
    return int(((learned.astype(bool) ^ truth.astype(bool)) & off).sum())
