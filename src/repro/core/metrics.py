"""Accuracy metrics: ROC points (paper §VI, Figs. 9–11), posterior edge
marginals from the telemetry edge-count accumulator, and the posterior
summary graphs the query layer serves (service/query.py, ``bn_learn
--emit-consensus``): the MAP DAG under a fixed order (:func:`map_dag`) and
the thresholded consensus graph (:func:`consensus_graph`)."""
from __future__ import annotations

import numpy as np

__all__ = ["roc_point", "structural_hamming", "edge_posterior", "map_dag",
           "consensus_graph"]


def _as_adjacency(a, name: str) -> np.ndarray:
    """Validate one adjacency argument: square 2-D, boolean-ified, diagonal
    (self-loops) dropped — self-loops are representation noise, not edges,
    so bearers compare equal to their loop-free counterpart."""
    a = np.asarray(a)
    if a.ndim != 2 or a.shape[0] != a.shape[1]:
        raise ValueError(f"{name} must be a square (n, n) adjacency, "
                         f"got shape {a.shape}")
    return a.astype(bool) & ~np.eye(a.shape[0], dtype=bool)


def roc_point(learned: np.ndarray, truth: np.ndarray) -> tuple[float, float]:
    """(FP rate, TP rate) of a learned adjacency vs ground truth.

    TP rate = recovered true edges / true edges;
    FP rate = spurious edges / true non-edges (diagonal excluded).
    Degenerate inputs are well-defined rather than errors: n = 0 (or an
    edgeless truth) yields rate 0 via the max(·, 1) clamps, and self-loops
    on either argument are ignored.
    """
    t = _as_adjacency(truth, "truth")
    l = _as_adjacency(learned, "learned")
    if t.shape != l.shape:
        raise ValueError(f"adjacency shapes differ: learned {l.shape} "
                         f"vs truth {t.shape}")
    n = t.shape[0]
    off = ~np.eye(n, dtype=bool)
    pos = t.sum()
    neg = off.sum() - pos
    tp = (l & t).sum()
    fp = (l & ~t).sum()
    return (float(fp) / max(neg, 1), float(tp) / max(pos, 1))


def structural_hamming(learned: np.ndarray, truth: np.ndarray) -> int:
    """Number of off-diagonal entries where the two adjacencies disagree
    (0 for n = 0; self-loops ignored on both sides)."""
    t = _as_adjacency(truth, "truth")
    l = _as_adjacency(learned, "learned")
    if t.shape != l.shape:
        raise ValueError(f"adjacency shapes differ: learned {l.shape} "
                         f"vs truth {t.shape}")
    return int((l ^ t).sum())


def edge_posterior(edge_counts: np.ndarray, n_samples: int) -> np.ndarray:
    """Posterior edge-presence probabilities from accumulated counts.

    ``edge_counts`` is the telemetry accumulator — (n, n) per-edge sample
    counts, or (C, n, n) per-chain counts which are POOLED over chains (each
    chain contributes ``n_samples`` thinned adjacency samples). Returns an
    (n, n) float array in [0, 1] with a zero diagonal; ``n_samples == 0``
    (no taps yet) returns all zeros instead of dividing by zero.
    """
    counts = np.asarray(edge_counts, np.float64)
    if counts.ndim == 3:
        total = n_samples * counts.shape[0]
        counts = counts.sum(0)
    elif counts.ndim == 2:
        total = n_samples
    else:
        raise ValueError("edge_counts must be (n, n) or (C, n, n), got "
                         f"shape {counts.shape}")
    if counts.shape[0] != counts.shape[1]:
        raise ValueError(f"edge_counts must be square, got {counts.shape}")
    if np.any(counts < 0) or (total and np.any(counts > total)):
        raise ValueError("edge_counts outside [0, n_samples]")
    p = counts / total if total else np.zeros_like(counts)
    np.fill_diagonal(p, 0.0)
    return p


def map_dag(st, pos) -> np.ndarray:
    """MAP adjacency under a fixed order: per node, the argmax-scoring
    parent set CONSISTENT with ``pos`` (every parent precedes the child).

    ``st`` is either representation of the score table — a
    preprocess.SparseScoreTable (packed pruned lists; O(n·K) per node) or a
    dense core.scores.ScoreTable (O(n·S·s), small-n path) — duck-typed on
    ``kept_parents``. ``pos`` is the (n,) position vector the sampler
    carries (pos[v] = position of node v in the order). Fed the walk's
    ``best_pos`` and the walk's own table, this reproduces exactly the
    adjacency the engine reports via ``best_idx`` (the scorer's per-node
    argmax is the same maximisation), but it is callable offline from
    artifacts alone — which is what the service query layer needs. Ties
    resolve to the LOWEST rank, matching the jitted scorers' argmax.
    Returns an (n, n) int8 adjacency, adj[parent, child] = 1.
    """
    pos = np.asarray(pos)
    if pos.ndim != 1:
        raise ValueError(f"pos must be a flat (n,) order, got {pos.shape}")
    n = pos.shape[0]
    adj = np.zeros((n, n), np.int8)
    if hasattr(st, "kept_parents"):             # pruned representation
        kp = np.asarray(st.kept_parents)        # (n, K, s) node ids, -1 pad
        kl = np.asarray(st.kept_ls)             # (n, K) f32, NEG_INF pad
        ki = np.asarray(st.kept_idx)            # (n, K) ranks, -1 pad
        for i in range(n):
            real = kp[i] >= 0                   # (K, s)
            ok = (ki[i] >= 0) & np.where(
                real, pos[np.clip(kp[i], 0, n - 1)] < pos[i], True).all(1)
            if not ok.any():                    # rank 0 is always kept
                continue
            scores = np.where(ok, kl[i], -np.inf)
            parents = kp[i, int(np.argmax(scores))]
            adj[parents[parents >= 0], i] = 1
        return adj
    table = np.asarray(st.table)                # dense oracle path
    pst = np.asarray(st.pst)                    # (S, s) candidate ids, -1 pad
    for i in range(n):
        pn = pst + (pst >= i)                   # candidate -> node ids
        real = pst >= 0
        ok = np.where(real, pos[np.clip(pn, 0, n - 1)] < pos[i], True).all(1)
        k = int(np.argmax(np.where(ok, table[i], -np.inf)))
        adj[pn[k][real[k]], i] = 1
    return adj


def consensus_graph(edge_probs: np.ndarray, threshold: float = 0.5
                    ) -> np.ndarray:
    """Thresholded posterior adjacency: edge (p, c) is present iff its
    posterior probability (from :func:`edge_posterior`) is >= ``threshold``.

    Unlike the MAP DAG this summary is PER-EDGE, so it may contain cycles —
    it answers "which edges does the posterior believe in", not "which
    single DAG". Returns (n, n) int8; self-loops are dropped like every
    other metric here. threshold must lie in (0, 1]: at 0 every edge would
    be 'present' (vacuous), above 1 none could be.
    """
    p = np.asarray(edge_probs, np.float64)
    if p.ndim != 2 or p.shape[0] != p.shape[1]:
        raise ValueError(f"edge_probs must be square (n, n), got {p.shape}")
    if np.any(p < 0) or np.any(p > 1):
        raise ValueError("edge_probs outside [0, 1] — pass the output of "
                         "edge_posterior, not raw counts")
    if not 0.0 < threshold <= 1.0:
        raise ValueError(f"threshold must lie in (0, 1], got {threshold}")
    adj = (p >= threshold).astype(np.int8)
    np.fill_diagonal(adj, 0)
    return adj
