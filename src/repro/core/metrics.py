"""Accuracy metrics: ROC points (paper §VI, Figs. 9–11) and posterior edge
marginals from the telemetry edge-count accumulator."""
from __future__ import annotations

import numpy as np

__all__ = ["roc_point", "structural_hamming", "edge_posterior"]


def _as_adjacency(a, name: str) -> np.ndarray:
    """Validate one adjacency argument: square 2-D, boolean-ified, diagonal
    (self-loops) dropped — self-loops are representation noise, not edges,
    so bearers compare equal to their loop-free counterpart."""
    a = np.asarray(a)
    if a.ndim != 2 or a.shape[0] != a.shape[1]:
        raise ValueError(f"{name} must be a square (n, n) adjacency, "
                         f"got shape {a.shape}")
    return a.astype(bool) & ~np.eye(a.shape[0], dtype=bool)


def roc_point(learned: np.ndarray, truth: np.ndarray) -> tuple[float, float]:
    """(FP rate, TP rate) of a learned adjacency vs ground truth.

    TP rate = recovered true edges / true edges;
    FP rate = spurious edges / true non-edges (diagonal excluded).
    Degenerate inputs are well-defined rather than errors: n = 0 (or an
    edgeless truth) yields rate 0 via the max(·, 1) clamps, and self-loops
    on either argument are ignored.
    """
    t = _as_adjacency(truth, "truth")
    l = _as_adjacency(learned, "learned")
    if t.shape != l.shape:
        raise ValueError(f"adjacency shapes differ: learned {l.shape} "
                         f"vs truth {t.shape}")
    n = t.shape[0]
    off = ~np.eye(n, dtype=bool)
    pos = t.sum()
    neg = off.sum() - pos
    tp = (l & t).sum()
    fp = (l & ~t).sum()
    return (float(fp) / max(neg, 1), float(tp) / max(pos, 1))


def structural_hamming(learned: np.ndarray, truth: np.ndarray) -> int:
    """Number of off-diagonal entries where the two adjacencies disagree
    (0 for n = 0; self-loops ignored on both sides)."""
    t = _as_adjacency(truth, "truth")
    l = _as_adjacency(learned, "learned")
    if t.shape != l.shape:
        raise ValueError(f"adjacency shapes differ: learned {l.shape} "
                         f"vs truth {t.shape}")
    return int((l ^ t).sum())


def edge_posterior(edge_counts: np.ndarray, n_samples: int) -> np.ndarray:
    """Posterior edge-presence probabilities from accumulated counts.

    ``edge_counts`` is the telemetry accumulator — (n, n) per-edge sample
    counts, or (C, n, n) per-chain counts which are POOLED over chains (each
    chain contributes ``n_samples`` thinned adjacency samples). Returns an
    (n, n) float array in [0, 1] with a zero diagonal; ``n_samples == 0``
    (no taps yet) returns all zeros instead of dividing by zero.
    """
    counts = np.asarray(edge_counts, np.float64)
    if counts.ndim == 3:
        total = n_samples * counts.shape[0]
        counts = counts.sum(0)
    elif counts.ndim == 2:
        total = n_samples
    else:
        raise ValueError("edge_counts must be (n, n) or (C, n, n), got "
                         f"shape {counts.shape}")
    if counts.shape[0] != counts.shape[1]:
        raise ValueError(f"edge_counts must be square, got {counts.shape}")
    if np.any(counts < 0) or (total and np.any(counts > total)):
        raise ValueError("edge_counts outside [0, n_samples]")
    p = counts / total if total else np.zeros_like(counts)
    np.fill_diagonal(p, 0.0)
    return p
