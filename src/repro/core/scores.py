"""Bayesian-Dirichlet local scores in log space (paper Eq. 3/4) and the
precomputed score table (the paper's "hash table", §III-A).

``ls(i, π) = |π|·ln γ + Σ_k [ lnΓ(α_k) − lnΓ(α_k + N_k)
                              + Σ_j ( lnΓ(N_jk + α_jk) − lnΓ(α_jk) ) ]``

with BDeu hyperparameters ``α_jk = ess / (r_i · q)``, ``α_k = ess / r_i``,
``r_i = q^{|π|}``.  Natural log internally (the paper's log10 is a constant
factor that cancels in Metropolis–Hastings ratios; priors are rescaled to
match — see priors.py).

Counting N_jk is formulated as one-hot × one-hot matmuls so the hot loop is
MXU work on TPU (see kernels/count for the Pallas version; this module is the
pure-jnp oracle and the default CPU path).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import gammaln

from .combinatorics import build_pst, n_parent_sets

__all__ = ["count_parent_child", "local_scores_chunk", "build_score_table",
           "ScoreTable", "validate_prior_matrix"]


def count_parent_child(data_ext: jnp.ndarray, node: int | jnp.ndarray,
                       parent_cols: jnp.ndarray, q: int, s: int) -> jnp.ndarray:
    """Contingency counts N[c, parent_config, child_state] for a chunk of parent sets.

    data_ext: (m, n+1) int32 — data with an appended all-zeros column so padded
      parents (mapped to column n) contribute digit 0.
    parent_cols: (C, s) int32 column indices into data_ext (already node-mapped,
      padding -> n).
    Returns (C, q**s, q) float32 counts.
    """
    m = data_ext.shape[0]
    cols = data_ext[:, parent_cols]                      # (m, C, s)
    pw = (q ** jnp.arange(s, dtype=jnp.int32))           # (s,)
    code = jnp.sum(cols * pw, axis=-1)                   # (m, C)
    Q = q ** s
    oh_code = jax.nn.one_hot(code, Q, dtype=jnp.float32)         # (m, C, Q)
    oh_child = jax.nn.one_hot(data_ext[:, node], q, dtype=jnp.float32)  # (m, q)
    # MXU-shaped contraction over samples
    return jnp.einsum("mcQ,mj->cQj", oh_code, oh_child)


def _bin_digits(q: int, s: int) -> np.ndarray:
    """(q**s, s) digit decomposition of each parent-config bin index, base q."""
    Q = q ** s
    b = np.arange(Q, dtype=np.int64)
    return np.stack([(b // q ** j) % q for j in range(s)], axis=-1)


@functools.partial(jax.jit, static_argnames=("q", "s", "use_pallas"))
def local_scores_chunk(data_ext: jnp.ndarray, node: jnp.ndarray,
                       pst_chunk: jnp.ndarray, psize_chunk: jnp.ndarray,
                       *, q: int, s: int,
                       log_gamma: float, ess: float,
                       use_pallas: bool = False) -> jnp.ndarray:
    """ls(node, π) for a chunk of parent sets. pst_chunk: (C, s) candidate idx, -1 pad.

    use_pallas=True routes the counting matmul through kernels/count
    (count_contingency, interpret mode off-TPU) instead of the pure-jnp
    einsum — same (C, Q, q) contract, MXU-tiled on real hardware."""
    n = data_ext.shape[1] - 1
    # candidate -> node column; padding -> the zeros column n
    pcols = pst_chunk + (pst_chunk >= node)
    pcols = jnp.where(pst_chunk < 0, n, pcols)
    if use_pallas:
        from ..kernels.count import count_contingency  # late: kernels layer
        counts = count_contingency(data_ext, data_ext[:, node], pcols,
                                   q=q, s=s)                      # (C, Q, q)
    else:
        counts = count_parent_child(data_ext, node, pcols, q, s)  # (C, Q, q)

    k = psize_chunk.astype(jnp.float32)                                # (C,)
    r = jnp.power(float(q), k)                                         # q^{|π|}
    alpha_jk = ess / (r * q)                                           # (C,)
    alpha_k = ess / r

    digits = jnp.asarray(_bin_digits(q, s))                            # (Q, s)
    pad_pos = jnp.arange(s)[None, :] >= psize_chunk[:, None]           # (C, s)
    # bin active iff every padded position has digit 0
    active = jnp.all(jnp.where(pad_pos[:, None, :], digits[None] == 0, True),
                     axis=-1)                                          # (C, Q)

    Nk = counts.sum(-1)                                                # (C, Q)
    a_k = alpha_k[:, None]
    a_jk = alpha_jk[:, None, None]
    term_k = gammaln(a_k) - gammaln(a_k + Nk)                          # (C, Q)
    term_jk = (gammaln(counts + a_jk) - gammaln(a_jk)).sum(-1)         # (C, Q)
    return k * log_gamma + jnp.sum(active * (term_k + term_jk), axis=-1)


class ScoreTable:
    """Dense (n, S) local-score table + its PST. The TPU-native 'hash table'."""

    def __init__(self, table: jnp.ndarray, pst: np.ndarray, psizes: np.ndarray,
                 q: int, s: int):
        self.table = table          # (n, S) float32
        self.pst = jnp.asarray(pst)        # (S, s) int32, -1 padded
        self.psizes = jnp.asarray(psizes)  # (S,) int32
        self.q = q
        self.s = s

    @property
    def n(self) -> int:
        return self.table.shape[0]

    @property
    def S(self) -> int:
        return self.table.shape[1]


def validate_prior_matrix(prior_matrix, n: int) -> None:
    """Up-front prior_matrix check with actionable errors: must be a square
    (n, n) interface matrix with entries in [0, 1] (paper §IV). Catching this
    here beats a shape error surfacing mid-way through a chunked build."""
    if prior_matrix is None:
        return
    R = np.asarray(prior_matrix)
    if R.ndim != 2 or R.shape[0] != R.shape[1]:
        raise ValueError("prior_matrix must be square (n, n); got shape "
                         f"{R.shape}")
    if R.shape[0] != n:
        raise ValueError(f"prior_matrix is {R.shape[0]}x{R.shape[0]} but the "
                         f"data has n={n} variables")
    if not np.all(np.isfinite(R)) or R.min() < 0.0 or R.max() > 1.0:
        raise ValueError("prior_matrix entries must be finite confidences "
                         f"in [0, 1]; got range [{R.min()}, {R.max()}]")


@functools.partial(jax.jit, static_argnames=("q", "s", "use_pallas"))
def _node_scores_batched(data_ext, node, pst_chunks, psz_chunks, R, *,
                         q: int, s: int, log_gamma: float, ess: float,
                         use_pallas: bool):
    """All chunks of one node in a single device program (a lax.map over the
    stacked (nc, chunk, s) PST) — one launch per node instead of one per
    (node, chunk), so the host never blocks between chunks."""
    from .priors import prior_chunk  # late import to avoid cycle

    def body(args):
        pst_c, psz_c = args
        ls = local_scores_chunk(data_ext, node, pst_c, psz_c, q=q, s=s,
                                log_gamma=log_gamma, ess=ess,
                                use_pallas=use_pallas)
        if R is not None:
            ls = ls + prior_chunk(R, node, pst_c)
        return ls

    return jax.lax.map(body, (pst_chunks, psz_chunks)).reshape(-1)


def build_score_table(data: np.ndarray, *, q: int, s: int,
                      gamma: float = 0.1, ess: float = 1.0,
                      chunk: int = 1024,
                      prior_matrix: np.ndarray | None = None,
                      use_pallas: bool = False) -> ScoreTable:
    """Preprocessing (paper §III-A): all local scores for |π| <= s.

    data: (m, n) integer states in [0, q). Optionally folds the pairwise prior
    (paper §IV) into the table — priors are per-(node, parent-set) additive
    constants, so baking them in preserves Eq. 9 exactly.

    Chunk launches are batched per node (_node_scores_batched); the Python
    loop only runs over nodes and never syncs on a device result — the single
    block happens when the caller first reads the stacked table. This is the
    reference path; preprocess/pipeline.build_score_table_fused is the fast
    one (same table).
    """
    data = np.asarray(data, dtype=np.int32)
    m, n = data.shape
    if np.any(data < 0) or np.any(data >= q):
        raise ValueError(f"data states must lie in [0, {q})")
    validate_prior_matrix(prior_matrix, n)
    S = n_parent_sets(n - 1, s)
    pst, psizes = build_pst(n - 1, s)
    data_ext = jnp.asarray(np.concatenate([data, np.zeros((m, 1), np.int32)], axis=1))
    log_gamma = float(np.log(gamma))

    # stack chunks to a uniform width (pad rows are all -1 / size 0: they
    # score as the empty set and are sliced off below)
    chunk = min(chunk, S)
    pad = (-S) % chunk
    pst_chunks = jnp.asarray(
        np.pad(pst, ((0, pad), (0, 0)), constant_values=-1)
        .reshape(-1, chunk, s))
    psz_chunks = jnp.asarray(
        np.pad(psizes, (0, pad)).reshape(-1, chunk))
    R = None if prior_matrix is None else jnp.asarray(prior_matrix, jnp.float32)
    rows = [_node_scores_batched(data_ext, jnp.int32(i), pst_chunks,
                                 psz_chunks, R, q=q, s=s,
                                 log_gamma=log_gamma, ess=ess,
                                 use_pallas=use_pallas)[:S]
            for i in range(n)]
    table = jnp.stack(rows)
    return ScoreTable(table, pst, psizes, q, s)


def score_single(data: np.ndarray, node: int, parent_nodes: list[int], *,
                 q: int, s: int, gamma: float = 0.1, ess: float = 1.0) -> float:
    """Scalar oracle for tests: ls(node, parents as *node ids*)."""
    from .combinatorics import nodes_to_candidates
    data = np.asarray(data, np.int32)
    m, n = data.shape
    cands = np.sort(nodes_to_candidates(np.asarray(parent_nodes, np.int64), node))
    row = np.full((1, s), -1, np.int32)
    row[0, : len(cands)] = cands
    data_ext = jnp.asarray(np.concatenate([data, np.zeros((m, 1), np.int32)], 1))
    ls = local_scores_chunk(data_ext, jnp.int32(node), jnp.asarray(row),
                            jnp.asarray([len(cands)], jnp.int32), q=q, s=s,
                            log_gamma=float(math.log(gamma)), ess=ess)
    return float(ls[0])
