"""Multi-device order scoring: the paper's two-level GPU reduction (threads →
shared-memory tree, Fig. 7) promoted one level up to devices → ICI.

The parent-set axis S is sharded over the ``model`` mesh axis (the paper's
"assign h blocks per node, split P_{π_i} over threads" becomes "split the
score-table columns over devices"); each device computes a local masked
max+argmax over its shard (VPU work — on TPU via the Pallas kernel, here via
the chunked oracle), then:

  global max   = pmax  over 'model'              (the paper's tree reduction)
  global argmax= pmin  over 'model' of (idx where local==global else +inf)
                 — deterministic tie-break, exactly the role of the
                 thread-id tracking in the paper's Fig. 7.

MCMC chains ride the ``data``/``pod`` axes unchanged (independent chains =
pure DP), so the whole sampler is one shard_map program on the production
mesh — scoring is TP, chains are DP, and the only cross-device traffic per
iteration is the (n,)-vector pmax/pmin pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .order_scoring import (NEG_INF, _score_nodes_blocked, consistent_mask,
                            delta_window, score_order_blocked,
                            score_order_chunked, splice_window, window_nodes)

__all__ = ["score_order_sharded", "make_sharded_score_fn",
           "make_sharded_delta_fn", "pad_table", "sharded_chain_step"]

INT_MAX = jnp.int32(2**31 - 1)


def pad_table(table, pst, mult: int):
    """Pad S to a multiple of `mult` (device count × block)."""
    S = table.shape[1]
    pad = (-S) % mult
    if pad:
        table = jnp.pad(table, ((0, 0), (0, pad)), constant_values=NEG_INF)
        pst = jnp.pad(pst, ((0, pad), (0, 0)), constant_values=-1)
    return table, pst


def _local_score(table_l, pst_l, pos, offset, block: int,
                 blocked: bool = True):
    """Masked max+argmax over this device's S-shard. Returns (n,), (n,) with
    argmax as a GLOBAL PST index (offset by the shard's start).

    blocked=True uses the block-outer/node-inner scorer (§Perf hillclimb:
    the PST block is read once for all nodes instead of once per node)."""
    fn = score_order_blocked if blocked else score_order_chunked
    _, idx_l, ls_l = fn(table_l, pst_l, pos,
                        block=min(block, table_l.shape[1]))
    return ls_l, idx_l + offset


def score_order_sharded(table, pst, pos, mesh, *, axis: str = "model",
                        block: int = 4096):
    """Same contract as score_order_chunked, S sharded over `axis`.

    table: (n, S) already padded so S % mesh.shape[axis] == 0.
    Under jit with the table sharded P(None, axis) this is one shard_map
    region; the collective payload is 2 × (n,) per call.
    """
    from jax.experimental.shard_map import shard_map

    n, S = table.shape
    tp = mesh.shape[axis]
    shard = S // tp
    in_specs = (P(None, axis), P(axis, None), P(None))
    out_specs = (P(), P(None), P(None))

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    def go(table_l, pst_l, pos):
        my = jax.lax.axis_index(axis)
        ls_l, idx_l = _local_score(table_l, pst_l, pos, my * shard, block)
        ls_g = jax.lax.pmax(ls_l, axis)                       # Fig. 7, level 2
        cand = jnp.where(ls_l >= ls_g, idx_l, INT_MAX)
        idx_g = jax.lax.pmin(cand, axis)                      # id resolution
        return ls_g.sum(), idx_g, ls_g

    return go(table, pst, pos)


def _local_delta(table_l, pst_l, pos, lo, offset, *, window: int, block: int,
                 axis: str):
    """Device-local window rescore + the same pmax/pmin reduction, but on
    (window,)-vectors instead of (n,) — the delta path's collective payload
    shrinks with the window too. Returns (win_nodes, ls_g, idx_g)."""
    win = window_nodes(pos, lo, window)
    ls_l, idx_l = _score_nodes_blocked(table_l[win], win, pst_l, pos,
                                       block=min(block, table_l.shape[1]))
    idx_l = idx_l + offset
    ls_g = jax.lax.pmax(ls_l, axis)                       # Fig. 7, level 2
    cand = jnp.where(ls_l >= ls_g, idx_l, INT_MAX)
    idx_g = jax.lax.pmin(cand, axis)                      # id resolution
    return win, ls_g, idx_g


def sharded_chain_step(states, table, pst, mesh, *, axis: str = "model",
                       block: int = 4096, window: int = 0):
    """One MCMC iteration for ALL chains on the production mesh, as a single
    shard_map program: chains are DP over the pod/data axes, the score table
    is TP over `axis`. Per iteration the cross-device traffic is the (n,)
    pmax/pmin pair per chain — or (window,) on the delta path.

    states: ChainState with a leading chains dim C divisible by the data-axes
    extent. table must be padded (pad_table) to axis_size × block.
    window ≥ 2 (and ≤ DELTA_CROSSOVER·n, else it degrades to the full path)
    enables bounded-window proposals + incremental O(window·S/tp) rescoring
    per device.

    The bitmask/adaptive ChainState leaves added by ISSUE 3 ride the same
    per-chain P(data-axes) specs as every other leaf (mask_planes is the
    zero-size placeholder here: the sharded delta path recomputes its window
    masks per shard — S-sharding the cached planes over `axis` is the
    natural next step, ROADMAP §perf).
    """
    from jax.experimental.shard_map import shard_map

    from .mcmc import mcmc_step

    n, S = table.shape
    tp = mesh.shape[axis]
    shard = S // tp
    w = delta_window(n, window)
    dax = tuple(a for a in mesh.axis_names if a != axis)
    st_specs = jax.tree.map(lambda _: P(dax), states)
    in_specs = (st_specs, P(None, axis), P(axis, None))
    out_specs = st_specs

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    def go(states_l, table_l, pst_l):
        my = jax.lax.axis_index(axis)

        def score_fn(pos):
            ls_l, idx_l = _local_score(table_l, pst_l, pos, my * shard, block)
            ls_g = jax.lax.pmax(ls_l, axis)
            cand = jnp.where(ls_l >= ls_g, idx_l, INT_MAX)
            idx_g = jax.lax.pmin(cand, axis)
            return ls_g.sum(), idx_g, ls_g

        delta_fn = None
        if w:
            def delta_fn(pos, lo, prev_ls, prev_idx):
                win, ls_g, idx_g = _local_delta(
                    table_l, pst_l, pos, lo, my * shard, window=w,
                    block=block, axis=axis)
                return splice_window(prev_ls, prev_idx, win, ls_g, idx_g)

        return jax.vmap(lambda s: mcmc_step(s, score_fn, delta_fn, w))(states_l)

    return go(states, table, pst)


def make_sharded_score_fn(table, pst, mesh, *, axis: str = "model",
                          block: int = 4096):
    """Closure with the (n,)-contract used by core.mcmc — the drop-in
    multi-device replacement for make_score_fn."""
    tp = mesh.shape[axis]
    block = min(block, max((table.shape[1] + tp - 1) // tp, 8))
    table, pst = pad_table(table, pst, tp * block)

    def fn(pos):
        return score_order_sharded(table, pst, pos, mesh, axis=axis,
                                   block=block)
    return fn


def make_sharded_delta_fn(table, pst, mesh, *, window: int,
                          axis: str = "model", block: int = 4096):
    """Delta-path companion of make_sharded_score_fn (same padding rules, so
    the two are bitwise-consistent). Returns a DeltaFn with the core.mcmc
    contract, or None when the crossover heuristic rejects the window."""
    from jax.experimental.shard_map import shard_map

    n = table.shape[0]
    w = delta_window(n, window)
    if not w:
        return None
    tp = mesh.shape[axis]
    block = min(block, max((table.shape[1] + tp - 1) // tp, 8))
    table, pst = pad_table(table, pst, tp * block)
    shard = table.shape[1] // tp
    in_specs = (P(None, axis), P(axis, None), P(None), P(), P(None), P(None))
    out_specs = (P(), P(None), P(None))

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    def go(table_l, pst_l, pos, lo, prev_ls, prev_idx):
        my = jax.lax.axis_index(axis)
        win, ls_g, idx_g = _local_delta(table_l, pst_l, pos, lo, my * shard,
                                        window=w, block=block, axis=axis)
        return splice_window(prev_ls, prev_idx, win, ls_g, idx_g)

    def fn(pos, lo, prev_ls, prev_idx):
        return go(table, pst, pos, lo, prev_ls, prev_idx)
    return fn
