"""Multi-device order scoring: the paper's two-level GPU reduction (threads →
shared-memory tree, Fig. 7) promoted one level up to devices → ICI.

The parent-set axis S is sharded over the ``model`` mesh axis (the paper's
"assign h blocks per node, split P_{π_i} over threads" becomes "split the
score-table columns over devices"); each device computes a local masked
max+argmax over its shard (VPU work — on TPU via the Pallas kernel, here via
the chunked oracle), then:

  global max   = pmax  over 'model'              (the paper's tree reduction)
  global argmax= pmin  over 'model' of (idx where local==global else +inf)
                 — deterministic tie-break, exactly the role of the
                 thread-id tracking in the paper's Fig. 7.

MCMC chains ride the ``data``/``pod`` axes unchanged (independent chains =
pure DP), so the whole sampler is one shard_map program on the production
mesh — scoring is TP, chains are DP, and the only cross-device traffic per
iteration is the (n,)-vector pmax/pmin pair — or (window,) on the delta path.

Sharded consistency planes (the mesh-native bitmask engine)
-----------------------------------------------------------

The bitmask-cached delta engine (core/order_scoring §Cached consistency
bitmasks) is S-sharded right along with the table: each device holds its own
``(n, P, shard/32)`` slice of ``ChainState.mask_planes`` (word j of the local
slice covers GLOBAL PST ranks [32·(my·shard/32 + j), …] — the word axis is
just the rank axis divided by 32, so the table's shard boundaries are plane
word boundaries as long as the shard size is a multiple of 32, which
:func:`_shard_block` guarantees). Everything about the cache is
device-local:

* **build** — :func:`make_sharded_planes_fn` runs ``build_violation_planes``
  per shard inside the shard_map region (init / checkpoint restore), each
  device packing only its own S-shard's words;
* **patch** — ``update_window_planes`` runs on the local words (membership
  planes are sharded ``P(None, model)`` like the table, candidate axis
  replicated);
* **score** — the masked max+argmax folds over the local words
  (``_score_nodes_blocked_bitmask`` here, the fused plane-patch + masked
  argmax Pallas kernel ``order_score_window_bitmask_fused_pallas`` on TPU),
  and only then does the usual (w,) pmax/pmin pair cross ICI.

The planes themselves NEVER cross ICI: the per-iteration collective payload
of the bitmask delta path is identical to the plain delta path's — two
(window,) vectors per chain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .mcmc import BitmaskDelta
from .order_scoring import (MASK_WORD_BITS, NEG_INF, PAD_SET,
                            _score_nodes_blocked,
                            _score_nodes_blocked_bitmask,
                            build_membership_planes, build_violation_planes,
                            delta_window, planes_consistent_words,
                            score_order_blocked, score_order_chunked,
                            splice_window, update_window_planes, window_nodes)

__all__ = ["score_order_sharded", "make_sharded_score_fn",
           "make_sharded_delta_fn", "make_sharded_bitmask_fns",
           "make_sharded_planes_fn", "pad_table", "sharded_chain_step"]

INT_MAX = jnp.int32(2**31 - 1)


def pad_table(table, pst, mult: int):
    """Pad S to a multiple of `mult` (device count × block). Scores pad with
    NEG_INF; PST rows pad with the PAD_SET sentinel (-2), which every
    consistency path treats as structurally inconsistent — a padded rank can
    never reach best_idx, independent of the table pad value."""
    S = table.shape[1]
    pad = (-S) % mult
    if pad:
        table = jnp.pad(table, ((0, 0), (0, pad)), constant_values=NEG_INF)
        pst = jnp.pad(pst, ((0, pad), (0, 0)), constant_values=PAD_SET)
    return table, pst


def _shard_block(S: int, tp: int, block: int) -> int:
    """Shared block rounding for every sharded maker: bounded by the shard
    size, floored at one packed word (32 ranks) and rounded up to the word
    multiple so the packed consistency-mask layout tiles the shard exactly."""
    block = min(block, max((S + tp - 1) // tp, MASK_WORD_BITS))
    return block + (-block) % MASK_WORD_BITS


def _local_score(table_l, pst_l, pos, offset, block: int,
                 blocked: bool = True):
    """Masked max+argmax over this device's S-shard. Returns (n,), (n,) with
    argmax as a GLOBAL PST index (offset by the shard's start).

    blocked=True uses the block-outer/node-inner scorer (§Perf hillclimb:
    the PST block is read once for all nodes instead of once per node)."""
    fn = score_order_blocked if blocked else score_order_chunked
    _, idx_l, ls_l = fn(table_l, pst_l, pos,
                        block=min(block, table_l.shape[1]))
    return ls_l, idx_l + offset


def score_order_sharded(table, pst, pos, mesh, *, axis: str = "model",
                        block: int = 4096):
    """Same contract as score_order_chunked, S sharded over `axis`.

    table: (n, S) already padded so S % mesh.shape[axis] == 0.
    Under jit with the table sharded P(None, axis) this is one shard_map
    region; the collective payload is 2 × (n,) per call.
    """
    from jax.experimental.shard_map import shard_map

    n, S = table.shape
    tp = mesh.shape[axis]
    shard = S // tp
    in_specs = (P(None, axis), P(axis, None), P(None))
    out_specs = (P(), P(None), P(None))

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    def go(table_l, pst_l, pos):
        my = jax.lax.axis_index(axis)
        ls_l, idx_l = _local_score(table_l, pst_l, pos, my * shard, block)
        ls_g = jax.lax.pmax(ls_l, axis)                       # Fig. 7, level 2
        cand = jnp.where(ls_l >= ls_g, idx_l, INT_MAX)
        idx_g = jax.lax.pmin(cand, axis)                      # id resolution
        return ls_g.sum(), idx_g, ls_g

    return go(table, pst, pos)


def _pmax_pmin(ls_l, idx_l, axis: str):
    """The Fig. 7 level-2 reduction: global max + deterministic index
    resolution (smallest global rank among the tied shards)."""
    ls_g = jax.lax.pmax(ls_l, axis)
    cand = jnp.where(ls_l >= ls_g, idx_l, INT_MAX)
    idx_g = jax.lax.pmin(cand, axis)
    return ls_g, idx_g


def _local_delta(table_l, pst_l, pos, lo, offset, *, window: int, block: int,
                 axis: str):
    """Device-local window rescore + the same pmax/pmin reduction, but on
    (window,)-vectors instead of (n,) — the delta path's collective payload
    shrinks with the window too. Returns (win_nodes, ls_g, idx_g)."""
    win = window_nodes(pos, lo, window)
    ls_l, idx_l = _score_nodes_blocked(table_l[win], win, pst_l, pos,
                                       block=min(block, table_l.shape[1]))
    ls_g, idx_g = _pmax_pmin(ls_l, idx_l + offset, axis)
    return win, ls_g, idx_g


def _local_bitmask_delta(table_l, cm_l, pos, lo, offset, pos_old, planes_l, *,
                         window: int, block: int, axis: str,
                         use_kernel: bool = False,
                         interpret: bool | None = None):
    """Device-local bitmask-cached window rescore: patch the local plane
    words, fold the masked max over the local shard, reduce the (w,) pair
    over ICI. planes_l: (n, P, shard/32) — this device's slice of the chain's
    cached violation planes; the patched slice is returned for adoption on
    accept and never leaves the device.

    use_kernel=True routes patch+score through the ONE fused Pallas kernel
    (order_score_window_bitmask_fused_pallas); the default runs the same
    word ops in XLA (`update_window_planes` + `_score_nodes_blocked_bitmask`)
    — bitwise-identical by construction."""
    win = window_nodes(pos, lo, window)
    rows = table_l[win]
    planes_win = planes_l[win]
    if use_kernel:
        from ..kernels.order_score.kernel import \
            order_score_window_bitmask_fused_pallas

        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        n_cand = cm_l.shape[0]
        cm_lo = cm_l[jnp.clip(win, 0, n_cand - 1)]
        cm_hi = cm_l[jnp.clip(win - 1, 0, n_cand - 1)]
        ls_l, idx_l, new_win = order_score_window_bitmask_fused_pallas(
            rows, win, pos_old, pos, planes_win, cm_lo, cm_hi,
            block_s=min(block, rows.shape[1]), interpret=interpret)
    else:
        new_win = update_window_planes(cm_l, pos_old, pos, win, planes_win)
        words = planes_consistent_words(new_win)
        ls_l, idx_l = _score_nodes_blocked_bitmask(
            rows, words, block=min(block, rows.shape[1]))
    ls_g, idx_g = _pmax_pmin(ls_l, idx_l + offset, axis)
    return win, ls_g, idx_g, planes_l.at[win].set(new_win)


def make_sharded_planes_fn(pst, mesh, *, axis: str = "model",
                           stacked: bool = True):
    """Violation-plane builder that runs PER SHARD inside the shard_map
    region — each device packs only its own S-shard's words, so neither the
    build (init / checkpoint restore) nor any later patch moves plane words
    across ICI.

    pst: the PADDED (S, s) table (same padding as the scoring closures).
    stacked=True: (C, n) chain-stacked positions -> (C, n, P, S/32) planes
    sharded (chains over the data axes, words over `axis`); stacked=False:
    one (n,) position -> (n, P, S/32) (init_chain's planes_fn contract)."""
    from jax.experimental.shard_map import shard_map

    dax = tuple(a for a in mesh.axis_names if a != axis)
    pos_spec = P(dax, None) if stacked else P(None)
    out_spec = (P(dax, None, None, axis) if stacked
                else P(None, None, axis))

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(pos_spec, P(axis, None)),
                       out_specs=out_spec, check_rep=False)
    def build(pos, pst_l):
        if stacked:
            return jax.vmap(lambda p: build_violation_planes(pst_l, p))(pos)
        return build_violation_planes(pst_l, pos)

    return lambda pos: build(pos, pst)


def sharded_chain_step(states, table, pst, mesh, cm=None, *,
                       axis: str = "model", block: int = 4096,
                       window: int = 0, use_kernel: bool = False):
    """One MCMC iteration for ALL chains on the production mesh, as a single
    shard_map program: chains are DP over the pod/data axes, the score table
    is TP over `axis`. Per iteration the cross-device traffic is the (n,)
    pmax/pmin pair per chain — or (window,) on the delta path.

    states: ChainState with a leading chains dim C divisible by the data-axes
    extent. table must be padded (pad_table) to axis_size × block.
    window ≥ 2 (and ≤ DELTA_CROSSOVER·n, else it degrades to the full path)
    enables bounded-window proposals + incremental O(window·S/tp) rescoring
    per device.

    cm (the (n-1, S/32) membership planes, padded like the table) switches
    the delta path to the sharded bitmask engine: states.mask_planes must
    then carry the (C, n, P, S/32) cached violation planes (seeded by
    :func:`make_sharded_planes_fn`), S-sharded over `axis` alongside the
    table — each device patches and scores its own plane words and only the
    (w,) pmax/pmin pair crosses ICI. Without cm (or with the zero-size
    placeholder in states.mask_planes) the delta path recomputes window
    masks from per-shard position gathers.
    """
    from jax.experimental.shard_map import shard_map

    from .mcmc import mcmc_step

    n, S = table.shape
    tp = mesh.shape[axis]
    shard = S // tp
    w = delta_window(n, window)
    mask = cm is not None and bool(w) and states.mask_planes.ndim == 4
    dax = tuple(a for a in mesh.axis_names if a != axis)
    st_specs = jax.tree.map(lambda _: P(dax), states)
    if mask:
        st_specs = st_specs._replace(mask_planes=P(dax, None, None, axis))
    in_specs = (st_specs, P(None, axis), P(axis, None))
    operands = (states, table, pst)
    if mask:
        in_specs += (P(None, axis),)
        operands += (cm,)
    out_specs = st_specs

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    def go(states_l, table_l, pst_l, *rest):
        my = jax.lax.axis_index(axis)

        def score_fn(pos):
            ls_l, idx_l = _local_score(table_l, pst_l, pos, my * shard, block)
            ls_g, idx_g = _pmax_pmin(ls_l, idx_l, axis)
            return ls_g.sum(), idx_g, ls_g

        delta_fn = None
        if mask:
            cm_l = rest[0]

            def bitmask_fn(pos, lo, prev_ls, prev_idx, pos_old, planes_l):
                win, ls_g, idx_g, new_planes = _local_bitmask_delta(
                    table_l, cm_l, pos, lo, my * shard, pos_old, planes_l,
                    window=w, block=block, axis=axis, use_kernel=use_kernel)
                tot, bi, bl = splice_window(prev_ls, prev_idx, win, ls_g,
                                            idx_g)
                return tot, bi, bl, new_planes

            delta_fn = BitmaskDelta(bitmask_fn)
        elif w:
            def delta_fn(pos, lo, prev_ls, prev_idx):
                win, ls_g, idx_g = _local_delta(
                    table_l, pst_l, pos, lo, my * shard, window=w,
                    block=block, axis=axis)
                return splice_window(prev_ls, prev_idx, win, ls_g, idx_g)

        return jax.vmap(lambda s: mcmc_step(s, score_fn, delta_fn, w))(states_l)

    return go(*operands)


def make_sharded_score_fn(table, pst, mesh, *, axis: str = "model",
                          block: int = 4096):
    """Closure with the (n,)-contract used by core.mcmc — the drop-in
    multi-device replacement for make_score_fn."""
    tp = mesh.shape[axis]
    block = _shard_block(table.shape[1], tp, block)
    table, pst = pad_table(table, pst, tp * block)

    def fn(pos):
        return score_order_sharded(table, pst, pos, mesh, axis=axis,
                                   block=block)
    return fn


def make_sharded_delta_fn(table, pst, mesh, *, window: int,
                          axis: str = "model", block: int = 4096):
    """Delta-path companion of make_sharded_score_fn (same padding rules, so
    the two are bitwise-consistent). Returns a DeltaFn with the core.mcmc
    contract, or None when the crossover heuristic rejects the window. This
    is the mask-RECOMPUTE variant; :func:`make_sharded_bitmask_fns` is the
    cached-planes engine."""
    from jax.experimental.shard_map import shard_map

    n = table.shape[0]
    w = delta_window(n, window)
    if not w:
        return None
    tp = mesh.shape[axis]
    block = _shard_block(table.shape[1], tp, block)
    table, pst = pad_table(table, pst, tp * block)
    shard = table.shape[1] // tp
    in_specs = (P(None, axis), P(axis, None), P(None), P(), P(None), P(None))
    out_specs = (P(), P(None), P(None))

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    def go(table_l, pst_l, pos, lo, prev_ls, prev_idx):
        my = jax.lax.axis_index(axis)
        win, ls_g, idx_g = _local_delta(table_l, pst_l, pos, lo, my * shard,
                                        window=w, block=block, axis=axis)
        return splice_window(prev_ls, prev_idx, win, ls_g, idx_g)

    def fn(pos, lo, prev_ls, prev_idx):
        return go(table, pst, pos, lo, prev_ls, prev_idx)
    return fn


def make_sharded_bitmask_fns(table, pst, mesh, *, window: int,
                             axis: str = "model", block: int = 4096,
                             use_kernel: bool = False):
    """(delta_fn, planes_fn) for the mesh-native bitmask engine, padded with
    the same rules as make_sharded_score_fn so the three closures are
    bitwise-consistent:

    * delta_fn: a :class:`BitmaskDelta` with the extended per-chain contract
      ``fn(new_pos, lo, prev_ls, prev_idx, old_pos, planes) -> (score,
      best_idx, best_ls, new_planes)`` where planes is the chain's
      (n, P, S/32) cache, S-sharded over `axis` — plane words stay on their
      device; the collective payload is the (w,) pmax/pmin pair.
    * planes_fn: (n,) pos -> freshly-built sharded planes (init_chain's
      ``planes_fn`` contract / checkpoint-restore rebuild), built per shard
      inside shard_map.

    Returns (None, None) when the crossover heuristic rejects the window."""
    from jax.experimental.shard_map import shard_map

    n = table.shape[0]
    w = delta_window(n, window)
    if not w:
        return None, None
    tp = mesh.shape[axis]
    block = _shard_block(table.shape[1], tp, block)
    table, pst = pad_table(table, pst, tp * block)
    shard = table.shape[1] // tp
    cm = build_membership_planes(pst, n)
    planes_fn = make_sharded_planes_fn(pst, mesh, axis=axis, stacked=False)

    in_specs = (P(None, axis), P(None, axis), P(None), P(), P(None), P(None),
                P(None), P(None, None, axis))
    out_specs = (P(), P(None), P(None), P(None, None, axis))

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    def go(table_l, cm_l, pos, lo, prev_ls, prev_idx, pos_old, planes_l):
        my = jax.lax.axis_index(axis)
        win, ls_g, idx_g, new_planes = _local_bitmask_delta(
            table_l, cm_l, pos, lo, my * shard, pos_old, planes_l,
            window=w, block=block, axis=axis, use_kernel=use_kernel)
        tot, bi, bl = splice_window(prev_ls, prev_idx, win, ls_g, idx_g)
        return tot, bi, bl, new_planes

    def fn(pos, lo, prev_ls, prev_idx, pos_old, planes):
        return go(table, cm, pos, lo, prev_ls, prev_idx, pos_old, planes)

    return BitmaskDelta(fn), planes_fn
