"""Order scoring (paper Eq. 6): score(≺) = Σ_i max_{π_i consistent with ≺} ls(i, π_i).

This is the hot loop the paper puts on the GPU. Three interchangeable paths:

* :func:`score_order_ref` — pure-jnp oracle (chunked over S);
* kernels/order_score — the Pallas TPU kernel (same contract);
* :func:`score_order_sharded` — the multi-device version: the parent-set axis is
  sharded over the ``model`` mesh axis and reduced with pmax + index-resolved
  pmin — the paper's thread→block→global reduction tree promoted to
  lane→block→device→ICI (DESIGN.md §2).

Contract: given table (n, S), pst (S, s), psizes (S,), pos (n,) (pos[v] =
position of node v in ≺), return (total_score, best_idx (n,), best_ls (n,))
where best_idx[i] is the PST index of the argmax parent set — i.e. the best
graph consistent with the order, produced *during* scoring (no postprocessing,
paper §III-B).

Incremental (delta) scoring
---------------------------

:func:`score_order_delta` is the per-iteration fast path of the MCMC sampler.
A bounded-window move (core/mcmc.py: adjacent/bounded swap, single-node
insertion, window reversal) permutes only the positions in ``[lo, lo+w-1]``.
A node whose position is OUTSIDE that window keeps its exact predecessor set
(the whole window lies on one side of it), so its consistency masks — and
therefore its cached (best_ls, best_idx) — are unchanged. Only the ≤ w nodes
occupying the window need rescoring: O(w·S) work instead of O(n·S).

Delta contract: given the proposal's NEW ``pos``, the PREVIOUS order's
``(prev_ls, prev_idx)`` and the window start ``lo`` (clipped internally to
``[0, n-window]`` — clipping only widens the recompute set, which is safe
because rescoring an unaffected node reproduces its cached value bitwise),
return the same ``(total, best_idx, best_ls)`` triple, *exactly* equal to a
full rescore: the window nodes go through the same `_score_nodes_blocked`
inner loop (same blocks, same first-wins tie-break) and the total is
``best_ls.sum()`` (same reduction order as the full path).

Crossover heuristic: the delta path wins only while ``window`` is small
relative to n; :func:`delta_window` returns 0 (meaning "use the full blocked
path") when ``window < 2`` or ``window > DELTA_CROSSOVER · n``. The decision
is static (window and n are trace-time constants), so no lax.cond is paid —
and under vmap over chains no dead full-rescore branch is materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-3.0e38)

__all__ = ["consistent_mask", "score_order_ref", "score_order_chunked",
           "score_order_blocked", "score_order_sum", "score_order_delta",
           "score_order_pruned", "score_order_pruned_delta",
           "delta_window", "inverse_permutation", "window_nodes",
           "splice_window", "DELTA_CROSSOVER", "NEG_INF"]

DELTA_CROSSOVER = 0.5   # delta pays off while window ≤ this fraction of n


def delta_window(n: int, window: int, crossover: float = DELTA_CROSSOVER) -> int:
    """Static crossover decision: the window to use for the delta path, or 0
    to mean "rescore everything with the blocked full path"."""
    if window < 2 or window > max(2, int(n * crossover)):
        return 0
    return min(window, n)


def inverse_permutation(pos: jnp.ndarray) -> jnp.ndarray:
    """order (n,) with order[p] = node at position p (inverse of pos)."""
    n = pos.shape[0]
    return jnp.zeros((n,), jnp.int32).at[pos].set(
        jnp.arange(n, dtype=jnp.int32))


def window_nodes(pos: jnp.ndarray, lo: jnp.ndarray, window: int) -> jnp.ndarray:
    """(window,) ids of the nodes occupying positions [lo, lo+window-1],
    with lo clipped into [0, n-window] (clipping only widens the recompute
    set — safe, see the delta contract above)."""
    n = pos.shape[0]
    lo = jnp.clip(lo.astype(jnp.int32), 0, n - window)
    return jax.lax.dynamic_slice_in_dim(inverse_permutation(pos), lo, window)


def splice_window(prev_ls: jnp.ndarray, prev_idx: jnp.ndarray,
                  win: jnp.ndarray, ls_w: jnp.ndarray, idx_w: jnp.ndarray):
    """Scatter freshly-rescored window results into the cached per-node
    arrays and return the (total, best_idx, best_ls) contract triple. The
    ONE splice used by every delta path (blocked, kernel, sharded), so the
    bitwise delta≡full guarantee lives in a single place."""
    best_ls = prev_ls.at[win].set(ls_w)
    best_idx = prev_idx.at[win].set(idx_w)
    return best_ls.sum(), best_idx, best_ls


def consistent_mask(pst: jnp.ndarray, node: jnp.ndarray,
                    pos: jnp.ndarray) -> jnp.ndarray:
    """(C,) bool — parent set consistent with order: all parents precede node.

    pst: (C, s) candidate indices (-1 pad); node: scalar; pos: (n,).
    """
    pnode = pst + (pst >= node)                       # (C, s) node ids
    ppos = pos[jnp.clip(pnode, 0)]                    # (C, s)
    ok = jnp.where(pst < 0, True, ppos < pos[node])
    return jnp.all(ok, axis=-1)


@functools.partial(jax.jit, static_argnames=())
def score_order_ref(table: jnp.ndarray, pst: jnp.ndarray,
                    pos: jnp.ndarray):
    """Unchunked oracle. table: (n, S); pst: (S, s); pos: (n,)."""
    n, S = table.shape

    def per_node(i, row):
        mask = consistent_mask(pst, i, pos)
        masked = jnp.where(mask, row, NEG_INF)
        idx = jnp.argmax(masked)
        return masked[idx], idx

    best_ls, best_idx = jax.vmap(per_node)(jnp.arange(n), table)
    return best_ls.sum(), best_idx.astype(jnp.int32), best_ls


@functools.partial(jax.jit, static_argnames=())
def score_order_sum(table: jnp.ndarray, pst: jnp.ndarray, pos: jnp.ndarray):
    """The BASELINE the paper argues against (§III-B): Linderman et al.'s
    sum-based order score  Σ_i log Σ_{π consistent} exp ls(i, π).

    Needs exp/log per parent set (the paper's first objection), does NOT
    produce the best graph (a postprocessing pass — one max-scorer call — is
    required, the paper's third objection), and the best graph may not be
    consistent with the best order (second objection; demonstrated in
    benchmarks/baseline_sum.py). Same contract as score_order_ref, but
    best_idx/best_ls come from the embedded max pass (the postprocessing)."""
    n, S = table.shape

    def per_node(i, row):
        mask = consistent_mask(pst, i, pos)
        masked = jnp.where(mask, row, NEG_INF)
        total = jax.scipy.special.logsumexp(masked)
        idx = jnp.argmax(masked)
        return total, masked[idx], idx

    tot, best_ls, best_idx = jax.vmap(per_node)(jnp.arange(n), table)
    return tot.sum(), best_idx.astype(jnp.int32), best_ls


def _score_nodes_blocked(rows: jnp.ndarray, node_ids: jnp.ndarray,
                         pst: jnp.ndarray, pos: jnp.ndarray, *, block: int):
    """Block-outer/node-inner masked max+argmax for an ARBITRARY node subset.

    rows: (k, S) score-table rows for node_ids; node_ids: (k,) actual node
    ids (the candidate→node shift depends on them); pos: (n,) the full
    position vector. Returns (best_ls (k,), best_idx (k,)).

    This is the single inner loop shared by the full blocked path
    (node_ids = arange(n)) and the delta path (node_ids = the moved window),
    so both produce bitwise-identical values and identical first-block /
    first-index tie-breaking.
    """
    k, S = rows.shape
    n = pos.shape[0]
    nb = S // block
    # Candidate c maps to node c + (c >= i), so a parent's position is either
    # pos[c] or pos[c+1]: gather BOTH once per block (node-independent) and
    # pick per node with an elementwise select — no per-(node, block) gather.
    pos_ext = jnp.concatenate([pos, jnp.zeros((1,), pos.dtype)])

    def body(carry, b):
        bmax, barg = carry                                # (k,), (k,)
        tbl = jax.lax.dynamic_slice_in_dim(rows, b * block, block, axis=1)
        psl = jax.lax.dynamic_slice_in_dim(pst, b * block, block, axis=0)
        safe = jnp.clip(psl, 0)
        ppos_lo = pos_ext[safe]                           # (blk, s) c -> c
        ppos_hi = pos_ext[jnp.minimum(safe + 1, n)]       # (blk, s) c -> c+1

        def per_node(i, row):
            ppos = jnp.where(psl >= i, ppos_hi, ppos_lo)
            ok = jnp.where(psl < 0, True, ppos < pos[i])
            masked = jnp.where(jnp.all(ok, axis=-1), row, NEG_INF)
            a = jnp.argmax(masked)
            return masked[a], a

        v, a = jax.vmap(per_node)(node_ids, tbl)          # (k,), (k,)
        better = v > bmax
        return (jnp.where(better, v, bmax),
                jnp.where(better, a + b * block, barg)), None

    (best_ls, best_idx), _ = jax.lax.scan(
        body, (jnp.full((k,), NEG_INF), jnp.zeros((k,), jnp.int32)),
        jnp.arange(nb))
    return best_ls, best_idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block",))
def score_order_blocked(table: jnp.ndarray, pst: jnp.ndarray,
                        pos: jnp.ndarray, *, block: int = 4096):
    """Same contract as score_order_chunked, restructured block-OUTER /
    node-INNER (§Perf hillclimb #3): the PST block is loaded once and the
    consistency masks for ALL n nodes are computed against it while it is
    hot, so HBM traffic drops from n·(S·4 + S·s·4) to n·S·4 + S·s·4 —
    ~(s+1)/(1+s/n)× less. This is exactly the Pallas kernel's revisiting-grid
    order (grid (S/blk, n), PST block index depends on dim 0 only)."""
    n, S = table.shape
    assert S % block == 0, "pad S to a multiple of block"
    best_ls, best_idx = _score_nodes_blocked(table, jnp.arange(n), pst, pos,
                                             block=block)
    return best_ls.sum(), best_idx, best_ls


@functools.partial(jax.jit, static_argnames=("window", "block"))
def score_order_delta(table: jnp.ndarray, pst: jnp.ndarray, pos: jnp.ndarray,
                      prev_ls: jnp.ndarray, prev_idx: jnp.ndarray,
                      lo: jnp.ndarray, *, window: int, block: int = 4096):
    """Incremental rescore after a bounded-window move (module docstring).

    pos is the PROPOSED order; (prev_ls, prev_idx) are the per-node caches of
    the order it was proposed from; lo is the first position the move could
    have touched. Recomputes only the `window` nodes occupying positions
    [lo, lo+window-1] under the new order — O(window·S) vs O(n·S) — and
    returns (total, best_idx (n,), best_ls (n,)) exactly equal to
    score_order_blocked(table, pst, pos, block=block)."""
    n, S = table.shape
    assert S % block == 0, "pad S to a multiple of block"
    w = min(window, n)
    win = window_nodes(pos, lo, w)                        # (w,) node ids
    rows = table[win]                                     # (w, S)
    ls_w, idx_w = _score_nodes_blocked(rows, win, pst, pos, block=block)
    return splice_window(prev_ls, prev_idx, win, ls_w, idx_w)


def _score_nodes_pruned(kept_ls: jnp.ndarray, kept_parents: jnp.ndarray,
                        kept_idx: jnp.ndarray, node_ids: jnp.ndarray,
                        pos: jnp.ndarray):
    """Masked max+argmax over per-node PRUNED candidate lists (the sparse
    hot path — O(K) per node instead of O(S)).

    kept_ls: (k, K) scores (NEG_INF pad); kept_parents: (k, K, s) parent NODE
    ids (-1 pad — already node-mapped at build, unlike the shared PST);
    kept_idx: (k, K) global PST ranks (the contract's best_idx space).
    Rows align with node_ids. Returns (best_ls (k,), best_idx (k,)).
    """
    def per_node(i, ls_row, par_row, idx_row):
        ppos = pos[jnp.clip(par_row, 0)]                     # (K, s)
        ok = jnp.where(par_row < 0, True, ppos < pos[i])
        masked = jnp.where(jnp.all(ok, axis=-1), ls_row, NEG_INF)
        a = jnp.argmax(masked)                               # first-wins ties
        return masked[a], idx_row[a]

    best_ls, best_idx = jax.vmap(per_node)(node_ids, kept_ls, kept_parents,
                                           kept_idx)
    return best_ls, best_idx.astype(jnp.int32)


@jax.jit
def score_order_pruned(kept_ls: jnp.ndarray, kept_parents: jnp.ndarray,
                       kept_idx: jnp.ndarray, pos: jnp.ndarray):
    """score_order over a preprocess.SparseScoreTable's packed arrays — the
    same (score, best_idx, best_ls) contract as score_order_blocked, with
    best_idx in the global PST rank space.

    Exactness: equals the dense scorer whenever each node's dense-consistent
    argmax survived pruning (always true for delta = +inf; the empty set is
    always kept so the result is defined for every order). See
    preprocess/sparse.py for the guarantee statement and its tests."""
    n = pos.shape[0]
    best_ls, best_idx = _score_nodes_pruned(kept_ls, kept_parents, kept_idx,
                                            jnp.arange(n, dtype=jnp.int32),
                                            pos)
    return best_ls.sum(), best_idx, best_ls


@functools.partial(jax.jit, static_argnames=("window",))
def score_order_pruned_delta(kept_ls: jnp.ndarray, kept_parents: jnp.ndarray,
                             kept_idx: jnp.ndarray, pos: jnp.ndarray,
                             prev_ls: jnp.ndarray, prev_idx: jnp.ndarray,
                             lo: jnp.ndarray, *, window: int):
    """Incremental companion of score_order_pruned: O(window*K) per move,
    spliced through the same splice_window as every other delta path so
    delta == full holds bitwise within the pruned representation."""
    n = pos.shape[0]
    w = min(window, n)
    win = window_nodes(pos, lo, w)
    ls_w, idx_w = _score_nodes_pruned(kept_ls[win], kept_parents[win],
                                      kept_idx[win], win, pos)
    return splice_window(prev_ls, prev_idx, win, ls_w, idx_w)


@functools.partial(jax.jit, static_argnames=("block",))
def score_order_chunked(table: jnp.ndarray, pst: jnp.ndarray,
                        pos: jnp.ndarray, *, block: int = 4096):
    """Same contract, streaming S in blocks (bounded working set; mirrors the
    kernel's VMEM tiling). S must be padded to a multiple of `block` by the
    caller (pad table with NEG_INF)."""
    n, S = table.shape
    assert S % block == 0, "pad S to a multiple of block"
    nb = S // block

    def per_node(i, row):
        def body(carry, b):
            bmax, barg = carry
            sl = jax.lax.dynamic_slice_in_dim(row, b * block, block)
            psl = jax.lax.dynamic_slice_in_dim(pst, b * block, block, axis=0)
            mask = consistent_mask(psl, i, pos)
            masked = jnp.where(mask, sl, NEG_INF)
            a = jnp.argmax(masked)
            v = masked[a]
            better = v > bmax
            return (jnp.where(better, v, bmax),
                    jnp.where(better, a + b * block, barg)), None

        (bmax, barg), _ = jax.lax.scan(body, (NEG_INF, jnp.int32(0)),
                                       jnp.arange(nb))
        return bmax, barg

    best_ls, best_idx = jax.vmap(per_node)(jnp.arange(n), table)
    return best_ls.sum(), best_idx.astype(jnp.int32), best_ls
