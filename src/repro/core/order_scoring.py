"""Order scoring (paper Eq. 6): score(≺) = Σ_i max_{π_i consistent with ≺} ls(i, π_i).

This is the hot loop the paper puts on the GPU. Three interchangeable paths:

* :func:`score_order_ref` — pure-jnp oracle (chunked over S);
* kernels/order_score — the Pallas TPU kernel (same contract);
* :func:`score_order_sharded` — the multi-device version: the parent-set axis is
  sharded over the ``model`` mesh axis and reduced with pmax + index-resolved
  pmin — the paper's thread→block→global reduction tree promoted to
  lane→block→device→ICI (DESIGN.md §2).

Contract: given table (n, S), pst (S, s), psizes (S,), pos (n,) (pos[v] =
position of node v in ≺), return (total_score, best_idx (n,), best_ls (n,))
where best_idx[i] is the PST index of the argmax parent set — i.e. the best
graph consistent with the order, produced *during* scoring (no postprocessing,
paper §III-B).

Incremental (delta) scoring
---------------------------

:func:`score_order_delta` is the per-iteration fast path of the MCMC sampler.
A bounded-window move (core/mcmc.py: adjacent/bounded swap, single-node
insertion, window reversal) permutes only the positions in ``[lo, lo+w-1]``.
A node whose position is OUTSIDE that window keeps its exact predecessor set
(the whole window lies on one side of it), so its consistency masks — and
therefore its cached (best_ls, best_idx) — are unchanged. Only the ≤ w nodes
occupying the window need rescoring: O(w·S) work instead of O(n·S).

Delta contract: given the proposal's NEW ``pos``, the PREVIOUS order's
``(prev_ls, prev_idx)`` and the window start ``lo`` (clipped internally to
``[0, n-window]`` — clipping only widens the recompute set, which is safe
because rescoring an unaffected node reproduces its cached value bitwise),
return the same ``(total, best_idx, best_ls)`` triple, *exactly* equal to a
full rescore: the window nodes go through the same `_score_nodes_blocked`
inner loop (same blocks, same first-wins tie-break) and the total is
``best_ls.sum()`` (same reduction order as the full path).

Crossover heuristic: the delta path wins only while ``window`` is small
relative to n; :func:`delta_window` returns 0 (meaning "use the full blocked
path") when ``window < 2`` or ``window > DELTA_CROSSOVER · n``. The decision
is static (window and n are trace-time constants), so no lax.cond is paid —
and under vmap over chains no dead full-rescore branch is materialized.

Cached consistency bitmasks (the accelerator-resident fast path)
----------------------------------------------------------------

Even the delta path above recomputes its window masks from scratch: per PST
block it gathers a ``(blk, s)`` slab of parent positions and compares against
the child's position — O(w·S·s) gather+compare work per proposal. That mask
is *almost entirely reusable*: a bounded-window move changes, for a window
node i, only the precedence of the ≤ w other window nodes (everything outside
the window keeps its side of i — see the delta contract). So we cache the
mask and patch it with word ops:

* **membership planes** (:func:`build_membership_planes`, order-independent,
  built ONCE): ``cm[c]`` is a packed (S/32,)-word bitmask with bit t set iff
  candidate c appears in parent set t — LSB-first within each uint32 word,
  word j covering PST ranks [32j, 32j+31].
* **violation-count planes** (:func:`build_violation_planes`, carried in
  ``ChainState.mask_planes``): per node, ``ceil(log2(s+1))`` packed bit-plane
  words holding, per parent set, the COUNT of parents that do not precede the
  node (0 ⇔ consistent). Counts — not booleans — because an OR of violators
  is not invertible, while a counter supports exact ±1 updates via a packed
  ripple-carry (:func:`_planes_add`/:func:`_planes_sub`).

Per proposal, :func:`score_order_delta_bitmask` patches the ≤ w window nodes'
planes with one plane-add/-sub per (node, moved-parent) pair — O(w²·S/32)
word ops — and derives the boolean mask as ``~(V₀|V₁|…)``, replacing the
O(w·S·s) gather+compare entirely. The masked max+argmax then runs over the
same blocks with the same first-wins tie-break as `_score_nodes_blocked`, so
the result is bitwise-identical to a full `score_order_blocked` rescore.
On accept, the sampler splices the patched planes back into the chain cache
(core/mcmc.py), preserving the invariant that ``mask_planes`` always
describes the CURRENT order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = jnp.float32(-3.0e38)

__all__ = ["consistent_mask", "score_order_ref", "score_order_chunked",
           "score_order_blocked", "score_order_sum", "score_order_sum_cached",
           "score_order_sum_delta", "score_order_delta",
           "score_order_delta_bitmask", "score_order_pruned",
           "score_order_pruned_delta", "delta_window", "inverse_permutation",
           "window_nodes", "splice_window", "DELTA_CROSSOVER", "NEG_INF",
           "PAD_SET",
           "MASK_WORD_BITS", "mask_plane_count", "pack_mask_words",
           "unpack_mask_words", "build_membership_planes",
           "build_violation_planes", "planes_consistent_words",
           "update_window_planes"]

DELTA_CROSSOVER = 0.5   # delta pays off while window ≤ this fraction of n

# PST pad-ROW sentinel. A real parent-set row uses -1 for its unused trailing
# slots (the empty set is all -1), which every consistency check treats as
# vacuously satisfied. Rows appended purely to pad S to a block/shard multiple
# must NOT inherit that meaning — a -1-padded row is indistinguishable from
# the (always-consistent) empty set and scores as a real candidate, leaving
# only the NEG_INF table pad between a padded rank and best_idx. Padding rows
# with PAD_SET instead makes them STRUCTURALLY inconsistent in every path
# (gather, bitmask, kernel): best_idx can never name a rank ≥ S no matter how
# the table was padded.
PAD_SET = -2


def delta_window(n: int, window: int, crossover: float = DELTA_CROSSOVER) -> int:
    """Static crossover decision: the window to use for the delta path, or 0
    to mean "rescore everything with the blocked full path"."""
    if window < 2 or window > max(2, int(n * crossover)):
        return 0
    return min(window, n)


def inverse_permutation(pos: jnp.ndarray) -> jnp.ndarray:
    """order (n,) with order[p] = node at position p (inverse of pos)."""
    n = pos.shape[0]
    return jnp.zeros((n,), jnp.int32).at[pos].set(
        jnp.arange(n, dtype=jnp.int32))


def window_nodes(pos: jnp.ndarray, lo: jnp.ndarray, window: int) -> jnp.ndarray:
    """(window,) ids of the nodes occupying positions [lo, lo+window-1],
    with lo clipped into [0, n-window] (clipping only widens the recompute
    set — safe, see the delta contract above)."""
    n = pos.shape[0]
    lo = jnp.clip(lo.astype(jnp.int32), 0, n - window)
    return jax.lax.dynamic_slice_in_dim(inverse_permutation(pos), lo, window)


def splice_window(prev_ls: jnp.ndarray, prev_idx: jnp.ndarray,
                  win: jnp.ndarray, ls_w: jnp.ndarray, idx_w: jnp.ndarray):
    """Scatter freshly-rescored window results into the cached per-node
    arrays and return the (total, best_idx, best_ls) contract triple. The
    ONE splice used by every delta path (blocked, kernel, sharded), so the
    bitwise delta≡full guarantee lives in a single place."""
    best_ls = prev_ls.at[win].set(ls_w)
    best_idx = prev_idx.at[win].set(idx_w)
    return best_ls.sum(), best_idx, best_ls


def consistent_mask(pst: jnp.ndarray, node: jnp.ndarray,
                    pos: jnp.ndarray) -> jnp.ndarray:
    """(C,) bool — parent set consistent with order: all parents precede node.

    pst: (C, s) candidate indices (-1 = empty slot, PAD_SET = pad row —
    structurally inconsistent); node: scalar; pos: (n,).
    """
    pnode = pst + (pst >= node)                       # (C, s) node ids
    ppos = pos[jnp.clip(pnode, 0)]                    # (C, s)
    ok = jnp.where(pst < 0, pst > PAD_SET, ppos < pos[node])
    return jnp.all(ok, axis=-1)


@functools.partial(jax.jit, static_argnames=())
def score_order_ref(table: jnp.ndarray, pst: jnp.ndarray,
                    pos: jnp.ndarray):
    """Unchunked oracle. table: (n, S); pst: (S, s); pos: (n,)."""
    n, S = table.shape

    def per_node(i, row):
        mask = consistent_mask(pst, i, pos)
        masked = jnp.where(mask, row, NEG_INF)
        idx = jnp.argmax(masked)
        return masked[idx], idx

    best_ls, best_idx = jax.vmap(per_node)(jnp.arange(n), table)
    return best_ls.sum(), best_idx.astype(jnp.int32), best_ls


@functools.partial(jax.jit, static_argnames=())
def score_order_sum(table: jnp.ndarray, pst: jnp.ndarray, pos: jnp.ndarray):
    """The BASELINE the paper argues against (§III-B): Linderman et al.'s
    sum-based order score  Σ_i log Σ_{π consistent} exp ls(i, π).

    Needs exp/log per parent set (the paper's first objection), does NOT
    produce the best graph (a postprocessing pass — one max-scorer call — is
    required, the paper's third objection), and the best graph may not be
    consistent with the best order (second objection; demonstrated in
    benchmarks/baseline_sum.py). Same contract as score_order_ref, but
    best_idx/best_ls come from the embedded max pass (the postprocessing)."""
    n, S = table.shape

    def per_node(i, row):
        mask = consistent_mask(pst, i, pos)
        masked = jnp.where(mask, row, NEG_INF)
        total = jax.scipy.special.logsumexp(masked)
        idx = jnp.argmax(masked)
        return total, masked[idx], idx

    tot, best_ls, best_idx = jax.vmap(per_node)(jnp.arange(n), table)
    return tot.sum(), best_idx.astype(jnp.int32), best_ls


def _sum_nodes(rows: jnp.ndarray, node_ids: jnp.ndarray, pst: jnp.ndarray,
               pos: jnp.ndarray):
    """Per-node logsumexp over consistent sets + embedded argmax, for an
    ARBITRARY node subset — the single inner loop shared by the cached-full
    and the delta sum paths (the same sharing that makes the max paths'
    delta ≡ full guarantee bitwise)."""
    def per_node(i, row):
        masked = jnp.where(consistent_mask(pst, i, pos), row, NEG_INF)
        return jax.scipy.special.logsumexp(masked), jnp.argmax(masked)

    lse, idx = jax.vmap(per_node)(node_ids, rows)
    return lse, idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=())
def score_order_sum_cached(table: jnp.ndarray, pst: jnp.ndarray,
                           pos: jnp.ndarray):
    """score_order_sum restated so the sampler's per-node cache works for it:
    the third output is the PER-NODE LOGSUMEXP vector (it sums to the score,
    which is what ChainState.cur_ls must satisfy for splice_window to keep
    the running total exact) instead of the max-pass best_ls. best_idx stays
    the embedded argmax (the postprocessing pass, paper §III-B objection 3)."""
    n = pos.shape[0]
    lse, idx = _sum_nodes(table, jnp.arange(n), pst, pos)
    return lse.sum(), idx, lse


@functools.partial(jax.jit, static_argnames=("window",))
def score_order_sum_delta(table: jnp.ndarray, pst: jnp.ndarray,
                          pos: jnp.ndarray, prev_lse: jnp.ndarray,
                          prev_idx: jnp.ndarray, lo: jnp.ndarray, *,
                          window: int):
    """Incremental companion of score_order_sum_cached: a bounded-window
    move leaves every out-of-window node's consistency mask — hence its
    logsumexp — untouched, so only the window nodes' running logsumexp needs
    recomputing, spliced through the same splice_window as every max-path
    delta. O(window·S) per move; makes benchmarks/baseline_sum.py a
    like-for-like incremental-vs-incremental comparison."""
    n = pos.shape[0]
    w = min(window, n)
    win = window_nodes(pos, lo, w)
    lse_w, idx_w = _sum_nodes(table[win], win, pst, pos)
    return splice_window(prev_lse, prev_idx, win, lse_w, idx_w)


def _score_nodes_blocked(rows: jnp.ndarray, node_ids: jnp.ndarray,
                         pst: jnp.ndarray, pos: jnp.ndarray, *, block: int):
    """Block-outer/node-inner masked max+argmax for an ARBITRARY node subset.

    rows: (k, S) score-table rows for node_ids; node_ids: (k,) actual node
    ids (the candidate→node shift depends on them); pos: (n,) the full
    position vector. Returns (best_ls (k,), best_idx (k,)).

    This is the single inner loop shared by the full blocked path
    (node_ids = arange(n)) and the delta path (node_ids = the moved window),
    so both produce bitwise-identical values and identical first-block /
    first-index tie-breaking.
    """
    k, S = rows.shape
    n = pos.shape[0]
    nb = S // block
    # Candidate c maps to node c + (c >= i), so a parent's position is either
    # pos[c] or pos[c+1]: gather BOTH once per block (node-independent) and
    # pick per node with an elementwise select — no per-(node, block) gather.
    pos_ext = jnp.concatenate([pos, jnp.zeros((1,), pos.dtype)])

    def body(carry, b):
        bmax, barg = carry                                # (k,), (k,)
        tbl = jax.lax.dynamic_slice_in_dim(rows, b * block, block, axis=1)
        psl = jax.lax.dynamic_slice_in_dim(pst, b * block, block, axis=0)
        safe = jnp.clip(psl, 0)
        ppos_lo = pos_ext[safe]                           # (blk, s) c -> c
        ppos_hi = pos_ext[jnp.minimum(safe + 1, n)]       # (blk, s) c -> c+1

        def per_node(i, row):
            ppos = jnp.where(psl >= i, ppos_hi, ppos_lo)
            ok = jnp.where(psl < 0, psl > PAD_SET, ppos < pos[i])
            masked = jnp.where(jnp.all(ok, axis=-1), row, NEG_INF)
            a = jnp.argmax(masked)
            return masked[a], a

        v, a = jax.vmap(per_node)(node_ids, tbl)          # (k,), (k,)
        better = v > bmax
        return (jnp.where(better, v, bmax),
                jnp.where(better, a + b * block, barg)), None

    (best_ls, best_idx), _ = jax.lax.scan(
        body, (jnp.full((k,), NEG_INF), jnp.zeros((k,), jnp.int32)),
        jnp.arange(nb))
    return best_ls, best_idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block",))
def score_order_blocked(table: jnp.ndarray, pst: jnp.ndarray,
                        pos: jnp.ndarray, *, block: int = 4096):
    """Same contract as score_order_chunked, restructured block-OUTER /
    node-INNER (§Perf hillclimb #3): the PST block is loaded once and the
    consistency masks for ALL n nodes are computed against it while it is
    hot, so HBM traffic drops from n·(S·4 + S·s·4) to n·S·4 + S·s·4 —
    ~(s+1)/(1+s/n)× less. This is exactly the Pallas kernel's revisiting-grid
    order (grid (S/blk, n), PST block index depends on dim 0 only)."""
    n, S = table.shape
    assert S % block == 0, "pad S to a multiple of block"
    best_ls, best_idx = _score_nodes_blocked(table, jnp.arange(n), pst, pos,
                                             block=block)
    return best_ls.sum(), best_idx, best_ls


@functools.partial(jax.jit, static_argnames=("window", "block"))
def score_order_delta(table: jnp.ndarray, pst: jnp.ndarray, pos: jnp.ndarray,
                      prev_ls: jnp.ndarray, prev_idx: jnp.ndarray,
                      lo: jnp.ndarray, *, window: int, block: int = 4096):
    """Incremental rescore after a bounded-window move (module docstring).

    pos is the PROPOSED order; (prev_ls, prev_idx) are the per-node caches of
    the order it was proposed from; lo is the first position the move could
    have touched. Recomputes only the `window` nodes occupying positions
    [lo, lo+window-1] under the new order — O(window·S) vs O(n·S) — and
    returns (total, best_idx (n,), best_ls (n,)) exactly equal to
    score_order_blocked(table, pst, pos, block=block)."""
    n, S = table.shape
    assert S % block == 0, "pad S to a multiple of block"
    w = min(window, n)
    win = window_nodes(pos, lo, w)                        # (w,) node ids
    rows = table[win]                                     # (w, S)
    ls_w, idx_w = _score_nodes_blocked(rows, win, pst, pos, block=block)
    return splice_window(prev_ls, prev_idx, win, ls_w, idx_w)


# --------------------------------------------------------------------------
# Cached consistency bitmasks (module docstring §Cached consistency bitmasks)
# --------------------------------------------------------------------------

MASK_WORD_BITS = 32


def mask_plane_count(s: int) -> int:
    """Bit planes needed to count 0..s violating parents per set."""
    return max(1, int(s).bit_length())


def pack_mask_words(bits: jnp.ndarray) -> jnp.ndarray:
    """(..., S) bool/int -> (..., S/32) uint32, LSB-first (bit b of word j is
    PST rank 32j+b). S must be a multiple of 32."""
    S = bits.shape[-1]
    assert S % MASK_WORD_BITS == 0, "pad S to a multiple of 32"
    w = jnp.left_shift(jnp.uint32(1),
                       jnp.arange(MASK_WORD_BITS, dtype=jnp.uint32))
    grouped = bits.reshape(bits.shape[:-1] + (-1, MASK_WORD_BITS))
    return jnp.sum(jnp.where(grouped != 0, w, jnp.uint32(0)), axis=-1,
                   dtype=jnp.uint32)


def unpack_mask_words(words: jnp.ndarray) -> jnp.ndarray:
    """(..., W) uint32 -> (..., 32W) bool — inverse of pack_mask_words."""
    shifts = jnp.arange(MASK_WORD_BITS, dtype=jnp.uint32)
    bits = jnp.right_shift(words[..., None], shifts) & jnp.uint32(1)
    return (bits != 0).reshape(words.shape[:-1] + (-1,))


def build_membership_planes(pst, n: int) -> jnp.ndarray:
    """(n-1, S/32) uint32: cm[c] bit t ⇔ candidate c ∈ parent set t.

    Order-independent — built once per table (host loop over the s PST
    columns, O(S·s)); -1 padding never sets a bit. Membership lives in the
    shared CANDIDATE space: child i reads node x's plane at cm[x - (x > i)].
    """
    pst_np = np.asarray(pst)
    S, s = pst_np.shape
    assert S % MASK_WORD_BITS == 0, "pad S to a multiple of 32"
    mem = np.zeros((max(n - 1, 1), S), dtype=bool)
    for col in range(s):
        v = pst_np[:, col]
        ok = v >= 0
        mem[v[ok], np.nonzero(ok)[0]] = True
    w = (np.uint64(1) << np.arange(MASK_WORD_BITS, dtype=np.uint64))
    grouped = mem.reshape(mem.shape[0], -1, MASK_WORD_BITS).astype(np.uint64)
    words = (grouped * w).sum(axis=-1).astype(np.uint32)
    return jnp.asarray(words)


@jax.jit
def build_violation_planes(pst: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """(n, P, S/32) uint32 violation-count bit planes for order `pos` — the
    from-scratch builder (init_chain / checkpoint-restore / the oracle the
    incremental updates are tested against). O(n·S·s), one full-rescore's
    worth of mask work, paid once."""
    n = pos.shape[0]
    P = mask_plane_count(pst.shape[1])

    def per_node(i):
        pnode = pst + (pst >= i)
        ppos = pos[jnp.clip(pnode, 0)]
        # PAD_SET entries count as permanent violations: pad rows carry count
        # s forever (membership planes never touch them), so padded ranks are
        # structurally inconsistent in the bitmask path too
        viol = jnp.sum(((pst >= 0) & (ppos >= pos[i])) | (pst <= PAD_SET),
                       axis=-1, dtype=jnp.int32)               # (S,)
        planes = [pack_mask_words((viol >> p) & 1) for p in range(P)]
        return jnp.stack(planes)                               # (P, S/32)

    # lax.map keeps the peak temporary at O(S) instead of O(n·S)
    return jax.lax.map(per_node, jnp.arange(n, dtype=jnp.int32))


def _planes_add(planes: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
    """Add 1 to the packed counters at the positions set in `bits` —
    ripple-carry over the P planes. planes: (P, W); bits: (W,)."""
    out, carry = [], bits
    for p in range(planes.shape[0]):
        v = planes[p]
        out.append(v ^ carry)
        carry = v & carry
    return jnp.stack(out)


def _planes_sub(planes: jnp.ndarray, bits: jnp.ndarray) -> jnp.ndarray:
    """Subtract 1 at the positions set in `bits` (ripple borrow)."""
    out, borrow = [], bits
    for p in range(planes.shape[0]):
        v = planes[p]
        out.append(v ^ borrow)
        borrow = (~v) & borrow
    return jnp.stack(out)


def planes_consistent_words(planes: jnp.ndarray) -> jnp.ndarray:
    """(..., P, W) count planes -> (..., W) packed consistency mask:
    bit set ⇔ violation count is zero ⇔ parent set consistent."""
    acc = planes[..., 0, :]
    for p in range(1, planes.shape[-2]):
        acc = acc | planes[..., p, :]
    return ~acc


def update_window_planes(cm: jnp.ndarray, pos_old: jnp.ndarray,
                         pos_new: jnp.ndarray, win: jnp.ndarray,
                         planes_win: jnp.ndarray) -> jnp.ndarray:
    """Patch the window nodes' violation planes from order pos_old to
    pos_new. Exactness rests on the delta contract: for a window node i, the
    only parents whose side of i can change are the other window nodes, so
    one plane-add/-sub per (i, x) pair — O(w²·S/32) word ops — reproduces
    build_violation_planes(pst, pos_new)[win] bitwise.

    cm: (n-1, S/32) membership planes; win: (w,) node ids occupying the
    window under BOTH orders (moves permute within the window);
    planes_win: (w, P, S/32) the cached planes rows for `win` under pos_old.
    """
    n_cand = cm.shape[0]

    def per_node(i, planes_i):
        pi_old, pi_new = pos_old[i], pos_new[i]

        def body(planes_i, x):
            was = pos_old[x] > pi_old
            now = pos_new[x] > pi_new
            cand = jnp.clip(x - (x > i).astype(x.dtype), 0, n_cand - 1)
            row = cm[cand]                       # (S/32,) membership of x
            zero = jnp.zeros_like(row)
            # x == i gives was == now, so both updates degrade to no-ops
            planes_i = _planes_add(planes_i, jnp.where(now & ~was, row, zero))
            planes_i = _planes_sub(planes_i, jnp.where(was & ~now, row, zero))
            return planes_i, None

        planes_i, _ = jax.lax.scan(body, planes_i, win)
        return planes_i

    return jax.vmap(per_node)(win, planes_win)


def _score_nodes_blocked_bitmask(rows: jnp.ndarray, mask_words: jnp.ndarray,
                                 *, block: int):
    """`_score_nodes_blocked` with the consistency mask read from packed
    words instead of recomputed from (blk, s) position gathers. Same block
    order, same first-wins fold — bitwise-identical given an identical mask.

    rows: (k, S); mask_words: (k, S/32). Returns (best_ls (k,), best_idx (k,)).
    """
    k, S = rows.shape
    assert S % block == 0 and block % MASK_WORD_BITS == 0
    nb = S // block
    bw = block // MASK_WORD_BITS

    def body(carry, b):
        bmax, barg = carry
        tbl = jax.lax.dynamic_slice_in_dim(rows, b * block, block, axis=1)
        wrd = jax.lax.dynamic_slice_in_dim(mask_words, b * bw, bw, axis=1)
        ok = unpack_mask_words(wrd)                           # (k, blk)
        masked = jnp.where(ok, tbl, NEG_INF)
        a = jnp.argmax(masked, axis=1)
        v = jnp.take_along_axis(masked, a[:, None], axis=1)[:, 0]
        better = v > bmax
        return (jnp.where(better, v, bmax),
                jnp.where(better, a.astype(jnp.int32) + b * block, barg)), None

    (best_ls, best_idx), _ = jax.lax.scan(
        body, (jnp.full((k,), NEG_INF), jnp.zeros((k,), jnp.int32)),
        jnp.arange(nb))
    return best_ls, best_idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("window", "block"))
def score_order_delta_bitmask(table: jnp.ndarray, cm: jnp.ndarray,
                              pos: jnp.ndarray, prev_ls: jnp.ndarray,
                              prev_idx: jnp.ndarray, lo: jnp.ndarray,
                              pos_old: jnp.ndarray, planes: jnp.ndarray, *,
                              window: int, block: int = 4096):
    """Bitmask-cached incremental rescore (module docstring): patch the
    window nodes' cached violation planes with word ops, score them against
    the packed mask, splice. No per-proposal (blk, s) position gathers — the
    PST is not even an argument. Returns the usual (total, best_idx (n,),
    best_ls (n,)) contract triple PLUS the patched (n, P, S/32) planes, which
    the sampler adopts on accept."""
    n, S = table.shape
    assert S % block == 0, "pad S to a multiple of block"
    w = min(window, n)
    win = window_nodes(pos, lo, w)                            # (w,) node ids
    new_planes_win = update_window_planes(cm, pos_old, pos, win, planes[win])
    words = planes_consistent_words(new_planes_win)           # (w, S/32)
    ls_w, idx_w = _score_nodes_blocked_bitmask(table[win], words, block=block)
    tot, best_idx, best_ls = splice_window(prev_ls, prev_idx, win, ls_w, idx_w)
    return tot, best_idx, best_ls, planes.at[win].set(new_planes_win)


def _score_nodes_pruned(kept_ls: jnp.ndarray, kept_parents: jnp.ndarray,
                        kept_idx: jnp.ndarray, node_ids: jnp.ndarray,
                        pos: jnp.ndarray):
    """Masked max+argmax over per-node PRUNED candidate lists (the sparse
    hot path — O(K) per node instead of O(S)).

    kept_ls: (k, K) scores (NEG_INF pad); kept_parents: (k, K, s) parent NODE
    ids (-1 pad — already node-mapped at build, unlike the shared PST);
    kept_idx: (k, K) global PST ranks (the contract's best_idx space).
    Rows align with node_ids. Returns (best_ls (k,), best_idx (k,)).
    """
    def per_node(i, ls_row, par_row, idx_row):
        ppos = pos[jnp.clip(par_row, 0)]                     # (K, s)
        ok = jnp.where(par_row < 0, True, ppos < pos[i])
        masked = jnp.where(jnp.all(ok, axis=-1), ls_row, NEG_INF)
        a = jnp.argmax(masked)                               # first-wins ties
        return masked[a], idx_row[a]

    best_ls, best_idx = jax.vmap(per_node)(node_ids, kept_ls, kept_parents,
                                           kept_idx)
    return best_ls, best_idx.astype(jnp.int32)


@jax.jit
def score_order_pruned(kept_ls: jnp.ndarray, kept_parents: jnp.ndarray,
                       kept_idx: jnp.ndarray, pos: jnp.ndarray):
    """score_order over a preprocess.SparseScoreTable's packed arrays — the
    same (score, best_idx, best_ls) contract as score_order_blocked, with
    best_idx in the global PST rank space.

    Exactness: equals the dense scorer whenever each node's dense-consistent
    argmax survived pruning (always true for delta = +inf; the empty set is
    always kept so the result is defined for every order). See
    preprocess/sparse.py for the guarantee statement and its tests."""
    n = pos.shape[0]
    best_ls, best_idx = _score_nodes_pruned(kept_ls, kept_parents, kept_idx,
                                            jnp.arange(n, dtype=jnp.int32),
                                            pos)
    return best_ls.sum(), best_idx, best_ls


@functools.partial(jax.jit, static_argnames=("window",))
def score_order_pruned_delta(kept_ls: jnp.ndarray, kept_parents: jnp.ndarray,
                             kept_idx: jnp.ndarray, pos: jnp.ndarray,
                             prev_ls: jnp.ndarray, prev_idx: jnp.ndarray,
                             lo: jnp.ndarray, *, window: int):
    """Incremental companion of score_order_pruned: O(window*K) per move,
    spliced through the same splice_window as every other delta path so
    delta == full holds bitwise within the pruned representation."""
    n = pos.shape[0]
    w = min(window, n)
    win = window_nodes(pos, lo, w)
    ls_w, idx_w = _score_nodes_pruned(kept_ls[win], kept_parents[win],
                                      kept_idx[win], win, pos)
    return splice_window(prev_ls, prev_idx, win, ls_w, idx_w)


@functools.partial(jax.jit, static_argnames=("block",))
def score_order_chunked(table: jnp.ndarray, pst: jnp.ndarray,
                        pos: jnp.ndarray, *, block: int = 4096):
    """Same contract, streaming S in blocks (bounded working set; mirrors the
    kernel's VMEM tiling). S must be padded to a multiple of `block` by the
    caller (pad table with NEG_INF)."""
    n, S = table.shape
    assert S % block == 0, "pad S to a multiple of block"
    nb = S // block

    def per_node(i, row):
        def body(carry, b):
            bmax, barg = carry
            sl = jax.lax.dynamic_slice_in_dim(row, b * block, block)
            psl = jax.lax.dynamic_slice_in_dim(pst, b * block, block, axis=0)
            mask = consistent_mask(psl, i, pos)
            masked = jnp.where(mask, sl, NEG_INF)
            a = jnp.argmax(masked)
            v = masked[a]
            better = v > bmax
            return (jnp.where(better, v, bmax),
                    jnp.where(better, a + b * block, barg)), None

        (bmax, barg), _ = jax.lax.scan(body, (NEG_INF, jnp.int32(0)),
                                       jnp.arange(nb))
        return bmax, barg

    best_ls, best_idx = jax.vmap(per_node)(jnp.arange(n), table)
    return best_ls.sum(), best_idx.astype(jnp.int32), best_ls
