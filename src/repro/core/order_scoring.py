"""Order scoring (paper Eq. 6): score(≺) = Σ_i max_{π_i consistent with ≺} ls(i, π_i).

This is the hot loop the paper puts on the GPU. Three interchangeable paths:

* :func:`score_order_ref` — pure-jnp oracle (chunked over S);
* kernels/order_score — the Pallas TPU kernel (same contract);
* :func:`score_order_sharded` — the multi-device version: the parent-set axis is
  sharded over the ``model`` mesh axis and reduced with pmax + index-resolved
  pmin — the paper's thread→block→global reduction tree promoted to
  lane→block→device→ICI (DESIGN.md §2).

Contract: given table (n, S), pst (S, s), psizes (S,), pos (n,) (pos[v] =
position of node v in ≺), return (total_score, best_idx (n,), best_ls (n,))
where best_idx[i] is the PST index of the argmax parent set — i.e. the best
graph consistent with the order, produced *during* scoring (no postprocessing,
paper §III-B).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-3.0e38)

__all__ = ["consistent_mask", "score_order_ref", "score_order_chunked",
           "score_order_blocked", "score_order_sum", "NEG_INF"]


def consistent_mask(pst: jnp.ndarray, node: jnp.ndarray,
                    pos: jnp.ndarray) -> jnp.ndarray:
    """(C,) bool — parent set consistent with order: all parents precede node.

    pst: (C, s) candidate indices (-1 pad); node: scalar; pos: (n,).
    """
    pnode = pst + (pst >= node)                       # (C, s) node ids
    ppos = pos[jnp.clip(pnode, 0)]                    # (C, s)
    ok = jnp.where(pst < 0, True, ppos < pos[node])
    return jnp.all(ok, axis=-1)


@functools.partial(jax.jit, static_argnames=())
def score_order_ref(table: jnp.ndarray, pst: jnp.ndarray,
                    pos: jnp.ndarray):
    """Unchunked oracle. table: (n, S); pst: (S, s); pos: (n,)."""
    n, S = table.shape

    def per_node(i, row):
        mask = consistent_mask(pst, i, pos)
        masked = jnp.where(mask, row, NEG_INF)
        idx = jnp.argmax(masked)
        return masked[idx], idx

    best_ls, best_idx = jax.vmap(per_node)(jnp.arange(n), table)
    return best_ls.sum(), best_idx.astype(jnp.int32), best_ls


@functools.partial(jax.jit, static_argnames=())
def score_order_sum(table: jnp.ndarray, pst: jnp.ndarray, pos: jnp.ndarray):
    """The BASELINE the paper argues against (§III-B): Linderman et al.'s
    sum-based order score  Σ_i log Σ_{π consistent} exp ls(i, π).

    Needs exp/log per parent set (the paper's first objection), does NOT
    produce the best graph (a postprocessing pass — one max-scorer call — is
    required, the paper's third objection), and the best graph may not be
    consistent with the best order (second objection; demonstrated in
    benchmarks/baseline_sum.py). Same contract as score_order_ref, but
    best_idx/best_ls come from the embedded max pass (the postprocessing)."""
    n, S = table.shape

    def per_node(i, row):
        mask = consistent_mask(pst, i, pos)
        masked = jnp.where(mask, row, NEG_INF)
        total = jax.scipy.special.logsumexp(masked)
        idx = jnp.argmax(masked)
        return total, masked[idx], idx

    tot, best_ls, best_idx = jax.vmap(per_node)(jnp.arange(n), table)
    return tot.sum(), best_idx.astype(jnp.int32), best_ls


@functools.partial(jax.jit, static_argnames=("block",))
def score_order_blocked(table: jnp.ndarray, pst: jnp.ndarray,
                        pos: jnp.ndarray, *, block: int = 4096):
    """Same contract as score_order_chunked, restructured block-OUTER /
    node-INNER (§Perf hillclimb #3): the PST block is loaded once and the
    consistency masks for ALL n nodes are computed against it while it is
    hot, so HBM traffic drops from n·(S·4 + S·s·4) to n·S·4 + S·s·4 —
    ~(s+1)/(1+s/n)× less. This is exactly the Pallas kernel's revisiting-grid
    order (grid (S/blk, n), PST block index depends on dim 0 only)."""
    n, S = table.shape
    assert S % block == 0, "pad S to a multiple of block"
    nb = S // block
    nodes = jnp.arange(n)
    # Candidate c maps to node c + (c >= i), so a parent's position is either
    # pos[c] or pos[c+1]: gather BOTH once per block (node-independent) and
    # pick per node with an elementwise select — no per-(node, block) gather.
    pos_ext = jnp.concatenate([pos, jnp.zeros((1,), pos.dtype)])

    def body(carry, b):
        bmax, barg = carry                                # (n,), (n,)
        tbl = jax.lax.dynamic_slice_in_dim(table, b * block, block, axis=1)
        psl = jax.lax.dynamic_slice_in_dim(pst, b * block, block, axis=0)
        safe = jnp.clip(psl, 0)
        ppos_lo = pos_ext[safe]                           # (blk, s) c -> c
        ppos_hi = pos_ext[jnp.minimum(safe + 1, n)]       # (blk, s) c -> c+1

        def per_node(i, row):
            ppos = jnp.where(psl >= i, ppos_hi, ppos_lo)
            ok = jnp.where(psl < 0, True, ppos < pos[i])
            masked = jnp.where(jnp.all(ok, axis=-1), row, NEG_INF)
            a = jnp.argmax(masked)
            return masked[a], a

        v, a = jax.vmap(per_node)(nodes, tbl)             # (n,), (n,)
        better = v > bmax
        return (jnp.where(better, v, bmax),
                jnp.where(better, a + b * block, barg)), None

    (best_ls, best_idx), _ = jax.lax.scan(
        body, (jnp.full((n,), NEG_INF), jnp.zeros((n,), jnp.int32)),
        jnp.arange(nb))
    return best_ls.sum(), best_idx.astype(jnp.int32), best_ls


@functools.partial(jax.jit, static_argnames=("block",))
def score_order_chunked(table: jnp.ndarray, pst: jnp.ndarray,
                        pos: jnp.ndarray, *, block: int = 4096):
    """Same contract, streaming S in blocks (bounded working set; mirrors the
    kernel's VMEM tiling). S must be padded to a multiple of `block` by the
    caller (pad table with NEG_INF)."""
    n, S = table.shape
    assert S % block == 0, "pad S to a multiple of block"
    nb = S // block

    def per_node(i, row):
        def body(carry, b):
            bmax, barg = carry
            sl = jax.lax.dynamic_slice_in_dim(row, b * block, block)
            psl = jax.lax.dynamic_slice_in_dim(pst, b * block, block, axis=0)
            mask = consistent_mask(psl, i, pos)
            masked = jnp.where(mask, sl, NEG_INF)
            a = jnp.argmax(masked)
            v = masked[a]
            better = v > bmax
            return (jnp.where(better, v, bmax),
                    jnp.where(better, a + b * block, barg)), None

        (bmax, barg), _ = jax.lax.scan(body, (NEG_INF, jnp.int32(0)),
                                       jnp.arange(nb))
        return bmax, barg

    best_ls, best_idx = jax.vmap(per_node)(jnp.arange(n), table)
    return best_ls.sum(), best_idx.astype(jnp.int32), best_ls
