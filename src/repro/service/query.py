"""Query layer: materialized posterior artifacts, stamped and validated.

The service answers THREE structural queries per job (the query surface
parallel bnlearn-style BN servers expose — PAPERS.md, arxiv 1406.7648):

* **posterior** — the (n, n) edge-probability matrix from the telemetry
  edge accumulator (``core/metrics.edge_posterior``): the full per-edge
  marginal, the most reusable artifact (any threshold, any edge query,
  ROC sweeps are all derived from it).
* **map** — the single best DAG: the walk's best order decoded through the
  per-node consistent parent-set argmax (``core/metrics.map_dag``).
* **consensus** — the thresholded posterior adjacency
  (``core/metrics.consensus_graph``): "which edges does the posterior
  believe at probability ≥ t"; recomputed on the fly for ad-hoc
  thresholds since it is a pure function of the posterior matrix.

All three come from the job's ``_finish`` result dict — the SAME dict a
standalone ``bn_learn --emit-consensus`` run returns — so service answers
are bitwise-comparable to one-shot answers by construction (the CI smoke
asserts exactly that). Every response carries the provenance stamp
(schema.STAMP): job id, iterations, R̂ status + convergence vote, and the
heal/reseed counts, so a client can judge an answer's trustworthiness
without a second round trip.
"""
from __future__ import annotations

import numpy as np

from ..core.metrics import consensus_graph
from .schema import SCHEMA, validate_response

__all__ = ["stamp", "job_response", "posterior_response", "map_response",
           "consensus_response", "materialize", "error_response"]


def stamp(job) -> dict:
    """The provenance fields every per-job response carries."""
    res = job.result or {}
    tele = res.get("telemetry") or {}
    iters_done = (res.get("iters_run") if res else
                  (job.sup.iters_done if job.sup is not None else 0))
    return {
        "schema": SCHEMA,
        "job_id": job.id,
        "iters": int(job.cfg.iters),
        "iters_done": int(iters_done or 0),
        "converged": bool(tele.get("converged", False)),
        "score_rhat": float(tele.get("score_rhat", float("nan"))),
        "edge_rhat": float(tele.get("edge_rhat", float("nan"))),
        "heals": len(res.get("heals", [])),
        "reseeds": [int(x) for x in tele.get("reseeds", [])],
    }


def job_response(job, *, deduped: bool | None = None) -> dict:
    resp = {**stamp(job), "kind": "job", "state": job.state,
            "deduped": bool(job.deduped if deduped is None else deduped),
            "attached": int(job.attached), "n": job.n,
            "chains": int(job.chains)}
    if job.error:
        resp["error"] = job.error
    validate_response(resp)
    return resp


def _require_done(job) -> dict:
    if job.state != "done" or job.result is None:
        raise LookupError(f"job {job.id} is {job.state}: artifacts exist "
                          "only once the job is done")
    return job.result


def posterior_response(job) -> dict:
    res = _require_done(job)
    tele = res.get("telemetry") or {}
    probs = np.asarray(res["edge_posterior"])
    resp = {**stamp(job), "kind": "posterior", "n": int(probs.shape[0]),
            "edge_probs": probs.tolist(),
            "edge_samples": int(tele.get("edge_samples",
                                         res.get("edge_samples", 0)))}
    validate_response(resp)
    return resp


def map_response(job) -> dict:
    res = _require_done(job)
    adj = np.asarray(res["map_dag"])
    resp = {**stamp(job), "kind": "map", "n": int(adj.shape[0]),
            "adjacency": adj.astype(int).tolist(),
            "score": float(res["score"])}
    validate_response(resp)
    return resp


def consensus_response(job, threshold: float | None = None) -> dict:
    """Default threshold → the job's precomputed consensus artifact
    (bitwise what the standalone run emitted); an explicit threshold is
    recomputed from the posterior matrix — a pure derivation, so it stays
    consistent with the posterior answer by construction."""
    res = _require_done(job)
    if threshold is None:
        threshold = job.cfg.consensus_threshold
        adj = np.asarray(res["consensus"])
    else:
        adj = consensus_graph(np.asarray(res["edge_posterior"]),
                              float(threshold))
    resp = {**stamp(job), "kind": "consensus", "n": int(adj.shape[0]),
            "adjacency": adj.astype(int).tolist(),
            "threshold": float(threshold)}
    validate_response(resp)
    return resp


def materialize(job) -> dict:
    """All three artifact responses at once (the persisted result.json the
    offline ``bn_query`` CLI reads)."""
    return {"posterior": posterior_response(job),
            "map": map_response(job),
            "consensus": consensus_response(job)}


def error_response(message: str) -> dict:
    resp = {"schema": SCHEMA, "kind": "error", "error": str(message)}
    validate_response(resp)
    return resp
