"""Job manager: admission, dedup, and the per-job learning engine.

A JOB is one dataset-learning request — (data, LearnConfig) — run as a
vmapped fleet of chains through the SAME code path a standalone
``bn_learn`` run takes: ``prepare_run`` (preprocess + disk cache +
collector), ``make_engine_closures`` (scorer/delta/plane closures) and
``_build_segmented`` (vmapped init, jitted traced segment runner, armed
RunSupervisor). Because the engine construction is shared, a job advanced
segment-by-segment by the multi-job scheduler produces BITWISE-identical
posterior artifacts to a one-shot run of the same (data, config, seed):
the interleaving only changes *when* each segment executes on the host,
never the segment boundaries or any PRNG stream.

Admission rides the preprocess cache's content key: two requests with
identical (data, q, s, ess, gamma, prior, pruning) AND identical
run-affecting config (iters, chains, seed, windows, telemetry cadence, …)
hash to the same job id, so the second request ATTACHES to the in-flight
or completed job instead of recomputing — the dedup layer the ROADMAP's
"millions of users" story needs. Requests that share only the dataset
fingerprint still share the preprocess disk cache entry (the score table
is built once); requests differing in any run-affecting field are distinct
jobs.

Job lifecycle: ``queued`` (admitted, engine not built) → ``running``
(engine compiled, advancing one supervised segment per scheduler tick) →
``done`` (artifacts materialized + persisted to the job's run directory
for the offline ``bn_query`` CLI) or ``failed`` (exception captured).
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from dataclasses import dataclass, replace

import numpy as np

from ..core.mcmc import exchange_best
from ..launch.bn_learn import (LearnConfig, _build_segmented, _finish,
                               make_engine_closures, prepare_run)
from ..preprocess.cache import cache_key
from .query import job_response, materialize

__all__ = ["DatasetSpec", "Job", "JobManager", "admission_key",
           "load_dataset", "service_config"]

# run-affecting LearnConfig fields folded into the admission key beside the
# preprocess content key. Anything that can change the walk or its artifacts
# belongs here; presentation-only fields (trace_dir, run_name, cache_dir,
# checkpoint paths) deliberately do not — two users asking the same question
# from different directories are the SAME job.
_RUN_FIELDS = ("iters", "chains", "seed", "window", "mask_cache",
               "adapt_window", "burn_in", "exchange_every", "scorer",
               "use_kernel", "block", "preprocess", "auto_prune",
               "trace_every", "check_every", "stop_on_converge",
               "rhat_threshold", "patience", "consensus_threshold",
               "supervise", "heal_patience")


@dataclass(frozen=True)
class DatasetSpec:
    """What to learn on: a named generator network, a synthetic DAG, or a
    file-backed sample matrix (``.npy`` int array, rows = samples)."""
    network: str = "stn"     # alarm | stn | synth | file
    n: int = 16              # node count (network == "synth")
    m: int = 300             # samples to draw (generator networks)
    seed: int = 0            # data-generation seed
    noise: float = 0.0       # label-noise fraction (generator networks)
    path: str = ""           # network == "file": .npy sample matrix


def load_dataset(spec: DatasetSpec, q: int) -> np.ndarray:
    """Materialise the sample matrix for one dataset spec — the same
    generators the ``bn_learn`` CLI uses, so a service job and a standalone
    run of the same spec see byte-identical data."""
    if spec.network == "file":
        data = np.load(spec.path, allow_pickle=False)
        if data.ndim != 2:
            raise ValueError(f"dataset file {spec.path} must hold a 2-D "
                             f"(samples, nodes) matrix, got {data.shape}")
        return np.asarray(data, np.int8)
    from ..data.bn_sampler import inject_noise
    from ..launch.bn_learn import _network_data
    _, data = _network_data(spec.network, spec.m, q, spec.seed,
                            n_synth=spec.n)
    if spec.noise:
        data = inject_noise(np.random.default_rng(spec.seed + 1), data,
                            spec.noise, q)
    return data


def service_config(overrides: dict | None = None, **kw) -> LearnConfig:
    """LearnConfig with the service invariants applied: telemetry is always
    on (the posterior artifacts come from the edge accumulator),
    ``emit_consensus`` materializes them, and stop-on-converge lets the
    scheduler reclaim a converged job's slots early. Callers may override
    anything else; unknown keys are rejected (they would silently change
    nothing but still alter the admission hash a client expects)."""
    fields = {f for f in LearnConfig.__dataclass_fields__}
    merged = {**(overrides or {}), **kw}
    unknown = set(merged) - fields
    if unknown:
        raise ValueError(f"unknown config field(s): {sorted(unknown)}")
    merged.setdefault("chains", 4)
    merged.setdefault("stop_on_converge", True)
    merged["telemetry"] = True
    merged["emit_consensus"] = True
    return LearnConfig(**merged)


def admission_key(data: np.ndarray, cfg: LearnConfig,
                  prior_matrix: np.ndarray | None = None) -> str:
    """Content-addressed job id: the preprocess cache key (data, q, s, ess,
    gamma, prior, pruning) extended with every run-affecting config field.
    Identical requests — however many users submit them — collapse to one
    id, which is the admission/dedup contract."""
    prune_delta = cfg.prune_delta if cfg.prune_delta > 0 else None
    base = cache_key(data, q=cfg.q, s=cfg.s, gamma=cfg.gamma, ess=cfg.ess,
                     prior_matrix=prior_matrix, prune_delta=prune_delta)
    run = repr(tuple(getattr(cfg, f) for f in _RUN_FIELDS))
    h = hashlib.sha256((base + run).encode()).hexdigest()[:16]
    return f"job-{h}"


class Job:
    """One admitted dataset-learning request (see module docstring)."""

    def __init__(self, job_id: str, data: np.ndarray, cfg: LearnConfig, *,
                 run_dir: str = "",
                 prior_matrix: np.ndarray | None = None):
        self.id = job_id
        self.data = data
        self.cfg = cfg
        self.prior_matrix = prior_matrix
        self.run_dir = run_dir
        self.state = "queued"
        self.deduped = False          # set on the response for re-submits
        self.attached = 1             # requests collapsed onto this job
        self.error = ""
        self.result: dict | None = None
        self.sup = None               # armed RunSupervisor once running
        self.extra_chains = 0         # elastic expansion beyond cfg.chains
        self.submitted_at = time.time()
        self._st = self._collector = self._pre = None
        self._closures = None
        self._t0 = 0.0

    @property
    def n(self) -> int:
        return int(self.data.shape[1])

    @property
    def chains(self) -> int:
        """Device slots this job occupies (grows under elastic cloning)."""
        return self.cfg.chains + self.extra_chains

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        """Build + compile the engine (the expensive admission step — the
        scheduler calls it only once slots are available)."""
        import jax
        cfg = self.cfg
        self._st, self._collector, self._pre = prepare_run(
            self.data, cfg, prior_matrix=self.prior_matrix)
        self._closures = make_engine_closures(self._st, cfg, self.n)
        (score_fn, window, delta_fn, planes_fn, adaptive_ws, delta_fns,
         burn_in, _mask_on) = self._closures
        key = jax.random.key(cfg.seed)
        self._t0 = time.time()
        self.sup = _build_segmented(self._st, cfg, key, self.n, score_fn,
                                    window, delta_fn, planes_fn, adaptive_ws,
                                    delta_fns, burn_in, self._collector)
        self.state = "running"

    def advance(self) -> bool:
        """One supervised segment; True while more remain. Exceptions mark
        the job failed instead of taking the server down."""
        try:
            return self.sup.advance()
        except Exception as exc:              # noqa: BLE001 — job isolation
            self.state = "failed"
            self.error = f"{type(exc).__name__}: {exc}"
            return False

    def finish(self) -> dict:
        """Materialise the result dict (identical to what a standalone
        ``learn_structure`` call returns, artifacts included), persist the
        query artifacts for ``bn_query``, and retire the job."""
        res = self.sup.result()
        best_score, best_idx, best_pos = exchange_best(res.states)
        (_score_fn, window, delta_fn, _planes_fn, adaptive_ws, _delta_fns,
         _burn_in, mask_on) = self._closures
        self.result = _finish(
            self.cfg, self._st, res.states, best_score, best_idx,
            window=window, adaptive_ws=adaptive_ws, mask_on=mask_on,
            sharded=False, t_pre=self._pre["t_pre"],
            cache_hit=self._pre["cache_hit"],
            auto_pruned=self._pre["auto_pruned"],
            t_iter=time.time() - self._t0, iters_run=res.iters_run,
            stopped=res.stopped, collector=self._collector, heals=res.heals,
            trace=res.trace, best_pos=best_pos)
        self.state = "done"
        self._st = self._closures = None      # free the table
        if self.run_dir:
            self._persist()
        return self.result

    def _persist(self) -> None:
        """Write the job's validated artifact responses to its run
        directory — the offline surface ``bn_query`` reads. Write-to-temp +
        atomic replace, same discipline as the checkpointer."""
        d = os.path.join(self.run_dir, self.id)
        os.makedirs(d, exist_ok=True)
        doc = {"job": job_response(self), **materialize(self)}
        tmp = os.path.join(d, f".result.{os.getpid()}.tmp")
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, os.path.join(d, "result.json"))


class JobManager:
    """Admission + registry. Thread-compatible: the HTTP front end only
    touches it under the server lock; all jax work happens on the scheduler
    thread."""

    def __init__(self, *, run_dir: str = "experiments/service",
                 cache_dir: str = ""):
        self.run_dir = run_dir
        self.cache_dir = cache_dir
        self.jobs: dict[str, Job] = {}

    def submit(self, data: np.ndarray, cfg: LearnConfig, *,
               prior_matrix: np.ndarray | None = None) -> tuple[Job, bool]:
        """Admit one request. Returns (job, deduped): an identical request
        attaches to the existing in-flight/completed job (same id, no
        recompute) — that is the whole point of content-addressed ids."""
        job_id = admission_key(data, cfg, prior_matrix)
        job = self.jobs.get(job_id)
        if job is not None:
            job.attached += 1
            return job, True
        # the job owns its trace + cache wiring; these fields are NOT part
        # of the admission hash, so forcing them here cannot split dedup
        cfg = replace(cfg, run_name=job_id,
                      trace_dir=os.path.join(self.run_dir, "traces"),
                      cache_dir=self.cache_dir)
        job = Job(job_id, data, cfg, run_dir=os.path.join(self.run_dir,
                                                          "jobs"),
                  prior_matrix=prior_matrix)
        self.jobs[job_id] = job
        return job, False

    def get(self, job_id: str) -> Job | None:
        return self.jobs.get(job_id)
