"""Posterior service: long-running BN structure learning with queryable
posteriors.

The package splits the server into the classic three layers:

* :mod:`repro.service.jobs`      — admission, content-addressed dedup, and
  the per-job engine (the same ``prepare_run``/``make_engine_closures``/
  ``_build_segmented`` path standalone ``bn_learn`` uses, so service
  answers are bitwise-comparable to one-shot runs).
* :mod:`repro.service.scheduler` — packs jobs onto a chain-slot budget,
  advancing each active job one supervised segment per tick with optional
  elastic fleet cloning into idle slots.
* :mod:`repro.service.query`     — materialized, stamped, schema-validated
  posterior / MAP / consensus responses (:mod:`repro.service.schema`).

The HTTP front end lives in :mod:`repro.launch.bn_serve`; the offline
artifact reader in :mod:`repro.launch.bn_query`.
"""
from .jobs import (DatasetSpec, Job, JobManager, admission_key,
                   load_dataset, service_config)
from .query import (consensus_response, error_response, job_response,
                    map_response, materialize, posterior_response)
from .scheduler import FleetScheduler, expand_fleet
from .schema import SCHEMA, validate_response

__all__ = [
    "SCHEMA", "validate_response",
    "DatasetSpec", "Job", "JobManager", "admission_key", "load_dataset",
    "service_config",
    "FleetScheduler", "expand_fleet",
    "job_response", "posterior_response", "map_response",
    "consensus_response", "materialize", "error_response",
]
