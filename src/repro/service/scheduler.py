"""Fleet scheduler: many jobs, one device budget, round-robin segments.

The engine already cuts every supervised run into jitted segments with the
host in between (RunSupervisor.begin/advance). The scheduler exploits
exactly that seam: each TICK admits whatever queued jobs fit the slot
budget, then advances every active job by ONE segment, round-robin. A
small-n segment is milliseconds of device time, so interleaving K jobs
costs each of them only the other jobs' segment latency — no job-level
head-of-line blocking, and per-job ``stop_on_converge`` retires finished
jobs from the pack early, freeing their slots for queued work.

Determinism: interleaving changes WHEN a job's segments run, never what
they compute — each job owns its states/trace/PRNG streams and its
supervisor, so a job's artifacts are bitwise-identical to running it alone
(tests/test_service.py pins this). The one opt-in exception is ELASTIC
cloning: when jobs finish and slots sit idle, ``expand_fleet`` widens a
running job's fleet by cloning its best finite chain into fresh slots via
``straggler.rebalance_chains`` (fresh PRNG keys, planes rebuilt, telemetry
rows re-seeded from the donor — the same machinery chain healing uses).
More chains sharpen the posterior and the cross-chain R̂, but the walk is
no longer the standalone walk, so elasticity defaults OFF and is never
applied to jobs that were admitted with it disabled.
"""
from __future__ import annotations

import logging
from collections import deque

import numpy as np

from ..runtime.straggler import (StragglerPolicy, best_finite_chain,
                                 rebalance_chains)
from ..runtime.supervisor import _reseed_trace

__all__ = ["FleetScheduler", "expand_fleet"]

logger = logging.getLogger(__name__)


def expand_fleet(job, extra: int) -> int:
    """Widen a running job's chain fleet by ``extra`` cloned slots.

    The new slots are stacked copies of slot 0, immediately re-seeded as
    clones of the BEST finite chain with fresh fold_in-derived keys by
    ``rebalance_chains`` (patience-1 policy, only the new slots marked
    unprogressed). Consistency planes are rebuilt for the cloned positions
    under this engine's padding, the telemetry rows are re-seeded from the
    donor, and the supervisor/collector bookkeeping grows to match. The
    jitted segment runner recompiles once for the new chain count.

    Returns the number of slots actually added (0 if the job isn't
    running)."""
    import jax
    import jax.numpy as jnp

    if extra <= 0 or job.sup is None or job.state != "running":
        return 0
    sup = job.sup
    states, trace = sup.states, sup.trace
    C = int(np.asarray(states.pos).shape[0])
    donor = best_finite_chain(states.best_score)

    def pad(leaf):
        return jnp.concatenate([leaf, jnp.repeat(leaf[:1], extra, axis=0)])

    raw = states._replace(key=jax.random.key_data(states.key))
    padded = jax.tree.map(pad, raw)
    states = padded._replace(key=jax.random.wrap_key_data(padded.key))
    # clone best→new: only the fresh slots are unprogressed, so the
    # patience-1 policy re-seeds exactly them (fresh keys, caches copied)
    progressed = np.ones(C + extra, bool)
    progressed[C:] = False
    key = jax.random.fold_in(
        jax.random.key(int(job.cfg.seed) ^ 0xE1A57C), sup.iters_done)
    states, _, healed = rebalance_chains(
        key, states, progressed, np.zeros(C + extra, np.int64),
        StragglerPolicy(patience=1), return_mask=True)
    if sup.planes_fn is not None:
        states = states._replace(mask_planes=sup.planes_fn(states.pos))
    else:
        states = states._replace(
            mask_planes=jnp.zeros((C + extra, 0), jnp.uint32))
    if trace is not None:
        per_chain = trace._replace(
            scores=pad(trace.scores), accepts=pad(trace.accepts),
            win_hist=pad(trace.win_hist),
            edge_counts=pad(trace.edge_counts), reseeds=pad(trace.reseeds))
        trace = _reseed_trace(per_chain, healed, donor)
    sup.grow(extra)                       # miss/progress bookkeeping
    if sup.collector is not None:
        sup.collector.grow(extra)         # accept-rate diff baseline
    sup.states, sup.trace = states, trace
    job.extra_chains += extra
    logger.info("elastic: job %s grew %d -> %d chains (donor %d)",
                job.id, C, C + extra, donor)
    return extra


class FleetScheduler:
    """Packs admitted jobs onto ``slots`` chain slots (see module
    docstring). Drive with :meth:`step` per tick or :meth:`run` to
    completion."""

    def __init__(self, manager, *, slots: int = 64, elastic: bool = False,
                 elastic_cap: int = 0):
        self.manager = manager
        self.slots = int(slots)
        self.elastic = bool(elastic)
        # per-job ceiling for elastic growth (0 = up to the slot budget)
        self.elastic_cap = int(elastic_cap)
        self.active: list = []
        self.pending: deque = deque()

    # ------------------------------------------------------------ admission
    def submit(self, data, cfg, *, prior_matrix=None):
        """Admit through the manager's dedup layer; genuinely new jobs
        queue for slots. Returns (job, deduped)."""
        job, deduped = self.manager.submit(data, cfg,
                                           prior_matrix=prior_matrix)
        if not deduped:
            if job.chains > self.slots:
                job.state = "failed"
                job.error = (f"job needs {job.chains} chain slots, budget "
                             f"is {self.slots}")
            else:
                self.pending.append(job)
        return job, deduped

    @property
    def slots_used(self) -> int:
        return sum(j.chains for j in self.active)

    def _admit(self) -> None:
        while self.pending and \
                self.pending[0].chains + self.slots_used <= self.slots:
            job = self.pending.popleft()
            try:
                job.start()
            except Exception as exc:       # noqa: BLE001 — job isolation
                job.state = "failed"
                job.error = f"{type(exc).__name__}: {exc}"
                continue
            self.active.append(job)

    # ----------------------------------------------------------------- tick
    def step(self) -> bool:
        """One scheduler tick: admit, advance every active job ONE segment
        (round-robin), retire finished/failed jobs (their slots free
        immediately), then optionally grow elastic jobs into idle slots.
        Returns True while any job is active or pending."""
        self._admit()
        for job in list(self.active):
            more = job.advance()
            if job.state == "failed":
                self.active.remove(job)
                logger.warning("job %s failed: %s", job.id, job.error)
            elif not more:
                self.active.remove(job)   # slots reclaimed HERE
                job.finish()
        # elastic growth only once the queue is empty: queued jobs have
        # strictly better claim on free slots than speculative clones
        if self.elastic and self.active and not self.pending:
            free = self.slots - self.slots_used
            if free > 0:
                job = min((j for j in self.active if j.sup is not None),
                          key=lambda j: j.chains, default=None)
                if job is not None:
                    cap = self.elastic_cap or self.slots
                    grow = min(free, cap - job.chains)
                    if grow > 0:
                        expand_fleet(job, grow)
        return bool(self.active or self.pending)

    def run(self) -> None:
        """Drive every admitted job to completion (offline / test use; the
        server drives :meth:`step` from its own loop)."""
        while self.step():
            pass
