"""Versioned response schema for the posterior service (``bn-service/v1``).

Every payload the service emits — over the ``bn_serve`` HTTP endpoint, from
the offline ``bn_query`` CLI, or persisted to a job's run directory — is a
self-describing JSON object carrying ``schema`` + ``kind``, validated at
WRITE time (the query layer refuses to emit a malformed response) and
re-validated by the CI smoke (launch/serve_smoke.py). The contract mirrors
the telemetry trace schema (telemetry/schema.py): required fields per kind,
unknown extra keys allowed, version bumped only when a required field
changes meaning.

Response kinds
--------------

* ``job``       — admission/status answer: job id, lifecycle state
  (queued / running / done / failed), dedup attachment count, progress.
* ``posterior`` — the (n, n) edge-probability matrix from the telemetry
  edge accumulator (core/metrics.edge_posterior), with its sample count.
* ``map``       — MAP DAG: best order + per-node consistent parent-set
  argmax (core/metrics.map_dag), plus the walk's best score.
* ``consensus`` — thresholded edge-posterior adjacency
  (core/metrics.consensus_graph); may contain cycles by construction.
* ``job_list``  — all admitted jobs, each entry a full ``job`` response.
* ``health``    — server liveness + scheduler occupancy.
* ``error``     — structured failure (unknown job, bad request, failed job).
* ``shutdown``  — acknowledgement of a clean stop.

Every artifact response is STAMPED: job id, iterations done, convergence
status (both R̂s + the patience vote), and the heal/reseed counts — a
client can always tell how trustworthy an answer is and whether the fleet
had to self-repair while producing it.
"""
from __future__ import annotations

__all__ = ["SCHEMA", "REQUIRED", "STAMP", "validate_response"]

SCHEMA = "bn-service/v1"

_NUM = (int, float)

# the provenance stamp carried by every per-job artifact response
STAMP: dict[str, type | tuple] = {
    "job_id": str, "iters_done": int, "iters": int, "converged": bool,
    "score_rhat": _NUM, "edge_rhat": _NUM, "heals": int, "reseeds": list,
}

REQUIRED: dict[str, dict[str, type | tuple]] = {
    "job": {**STAMP, "state": str, "deduped": bool, "attached": int,
            "n": int, "chains": int},
    "posterior": {**STAMP, "n": int, "edge_probs": list,
                  "edge_samples": int},
    "map": {**STAMP, "n": int, "adjacency": list, "score": _NUM},
    "consensus": {**STAMP, "n": int, "adjacency": list, "threshold": _NUM},
    "job_list": {"jobs": list},
    "health": {"state": str, "jobs": int, "active": int, "pending": int,
               "slots": int, "slots_used": int},
    "error": {"error": str},
    "shutdown": {"state": str},
}


def validate_response(resp) -> None:
    """Raise ValueError unless ``resp`` is a valid ``bn-service/v1``
    response. NaN R̂s are legal (not enough taps yet) — same contract as
    the telemetry rows they are copied from."""
    if not isinstance(resp, dict):
        raise ValueError(f"service response must be a dict, got {type(resp)}")
    if resp.get("schema") != SCHEMA:
        raise ValueError(f"response schema {resp.get('schema')!r} != "
                         f"{SCHEMA!r}")
    kind = resp.get("kind")
    if kind not in REQUIRED:
        raise ValueError(f"unknown response kind {kind!r} "
                         f"(expected one of {sorted(REQUIRED)})")
    for field, typ in REQUIRED[kind].items():
        if field not in resp:
            raise ValueError(f"{kind} response missing required field "
                             f"{field!r}")
        if not isinstance(resp[field], typ):
            raise ValueError(
                f"{kind} response field {field!r} has type "
                f"{type(resp[field]).__name__}, expected {typ}")
