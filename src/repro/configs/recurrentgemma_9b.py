"""recurrentgemma-9b [hybrid] — Griffin: RG-LRU + local attention, 1:2.

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000, window 2048.
Pattern: (rglru, rglru, attn) repeating. [arXiv:2402.19427; unverified]
Sub-quadratic: runs long_500k (bounded window + O(1) recurrent state).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    block_pattern=("rglru", "rglru", "attn"),
    window=2048,
    conv_width=4,
    lru_dim=4096,
    rope_theta=10000.0,
    source="arXiv:2402.19427; unverified",
)
