"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
[hf:Snowflake/snowflake-arctic-base; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    head_dim=128,
    n_experts=128,
    experts_top_k=2,
    moe_dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base; hf",
)
