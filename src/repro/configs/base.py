"""Config system: architecture configs + input-shape cells.

Every assigned architecture is a `ModelConfig`; the four LM shape cells are
`ShapeConfig`s. `reduced()` yields the family-preserving small config used by
CPU smoke tests (the full config is exercised only via the dry-run).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "pad_to"]


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec
    n_layers: int
    d_model: int
    n_heads: int                 # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # encoder-decoder (seamless)
    enc_layers: int = 0
    enc_seq_divisor: int = 4     # encoder frames = seq // divisor (stub frontend)

    # MoE
    n_experts: int = 0
    experts_top_k: int = 0
    moe_dense_residual: bool = False   # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25

    # hybrid (recurrentgemma): repeating block pattern, e.g. ("rglru","rglru","attn")
    block_pattern: tuple[str, ...] = ()
    window: int = 0              # local-attention window (0 = global)
    conv_width: int = 4          # RG-LRU temporal conv taps
    lru_dim: int = 0             # RG-LRU recurrence width (0 -> d_model)

    # ssm (rwkv6)
    rwkv_head_dim: int = 64

    # numerics / training
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    rope_theta: float = 500000.0
    tie_embeddings: bool = False
    use_bias: bool = False
    remat: bool = True

    # notes for DESIGN/roofline
    source: str = ""

    # -------------------------------------------------- derived properties
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SSM state / bounded window)"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def padded_heads(self, tp: int) -> int:
        return pad_to(self.n_heads, tp) if self.n_heads else 0

    def padded_vocab(self, tp: int) -> int:
        return pad_to(self.vocab, 128 * tp)

    def padded_experts(self, tp: int) -> int:
        return pad_to(self.n_experts, tp) if self.n_experts else 0

    def layer_pattern(self) -> tuple[str, ...]:
        return self.block_pattern if self.block_pattern else ("attn",)

    # -------------------------------------------------- parameter counting
    def param_count(self) -> tuple[int, int]:
        """(total, active-per-token) parameter counts — used for 6·N·D model
        FLOPs in the roofline (MoE uses the active count)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hq, hkv, hd = max(self.n_heads, 1), max(self.n_kv_heads, 1), self.hd

        def attn_p():
            return d * hq * hd + 2 * d * hkv * hd + hq * hd * d

        def mlp_p(f):
            return 3 * d * f      # SwiGLU (gate, up, down)

        def rglru_p():
            w = self.lru_dim or d
            return 2 * d * w + w * d + self.conv_width * w + 2 * w  # in/gate, out, conv, lambda

        def rwkv_p():
            return 4 * d * d + d * d + 6 * d * 32 * 2 + mlp_p(ff) // 3 * 0  # r,k,v,g,o + lora-ish mixers

        total = active = 0
        pattern = self.layer_pattern()
        for li in range(self.n_layers):
            kind = pattern[li % len(pattern)]
            if self.family == "ssm":
                lp = rwkv_p() + 3 * d * ff
                total += lp; active += lp
                continue
            if kind == "attn":
                total += attn_p(); active += attn_p()
            elif kind == "rglru":
                total += rglru_p(); active += rglru_p()
            if self.family == "moe":
                e = mlp_p(ff)
                total += self.n_experts * e
                active += self.experts_top_k * e
                if self.moe_dense_residual:
                    total += mlp_p(ff); active += mlp_p(ff)
            else:
                total += mlp_p(ff); active += mlp_p(ff)
        enc = 0
        if self.enc_layers:
            enc = self.enc_layers * (attn_p() + mlp_p(ff))
            # decoder cross-attention
            total += self.n_layers * attn_p(); active += self.n_layers * attn_p()
        total += enc; active += enc
        emb = v * d * (1 if self.tie_embeddings else 2)
        total += emb; active += emb
        return total, active

    # -------------------------------------------------- reduced smoke config
    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny variant for CPU smoke tests."""
        pat = self.block_pattern
        n_layers = (2 * len(pat) + (2 if self.name.startswith("recurrentgemma")
                                    else 0)) if pat else 2
        return replace(
            self,
            n_layers=n_layers,
            enc_layers=min(self.enc_layers, 2),
            d_model=128,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32 if self.n_heads else 0,
            d_ff=256 if self.family != "moe" else 64,
            vocab=512,
            n_experts=min(self.n_experts, 8),
            experts_top_k=min(self.experts_top_k, 2),
            window=min(self.window, 64) if self.window else 0,
            lru_dim=128 if self.lru_dim else 0,
            rwkv_head_dim=32,
            param_dtype="float32",
            remat=False,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
