"""rwkv6-7b [ssm] — Finch: data-dependent decay, attention-free.

32L d_model=4096 d_ff=14336 vocab=65536, head size 64. [arXiv:2404.05892; hf]
Sub-quadratic: runs long_500k (O(1) recurrent state per layer).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=14336,
    vocab=65536,
    rwkv_head_dim=64,
    source="arXiv:2404.05892; hf",
)
