"""Config registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from .base import SHAPES, ModelConfig, ShapeConfig

_ARCH_MODULES = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "command-r-plus-104b": "command_r_plus_104b",
    "yi-34b": "yi_34b",
    "llama3-405b": "llama3_405b",
    "granite-20b": "granite_20b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "arctic-480b": "arctic_480b",
    "chameleon-34b": "chameleon_34b",
    "rwkv6-7b": "rwkv6_7b",
}

ARCH_IDS = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f".{_ARCH_MODULES[arch]}", __package__)
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells(arch: str) -> list[str]:
    """The shape cells defined for an arch (long_500k only for sub-quadratic)."""
    cfg = get_config(arch)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out


__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "ShapeConfig", "cells",
           "get_config", "get_shape"]
