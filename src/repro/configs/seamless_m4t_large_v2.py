"""seamless-m4t-large-v2 [audio] — enc-dec multimodal backbone.

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.
[arXiv:2308.11596; hf]. Modality frontend is a stub: input_specs() provides
precomputed audio-frame embeddings for the encoder.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,          # decoder layers
    enc_layers=24,        # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    head_dim=64,
    rope_theta=10000.0,
    source="arXiv:2308.11596; hf",
)
