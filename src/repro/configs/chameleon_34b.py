"""chameleon-34b [vlm] — early-fusion VQ image tokens; backbone only.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
[arXiv:2405.09818; unverified]. VQ image tokens are ordinary vocabulary ids,
so the backbone is a decoder-only LM; the image tokenizer frontend is a stub.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="dense",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    head_dim=128,
    source="arXiv:2405.09818; unverified",
)
