"""The paper's own workload: Bayesian-network structure learning configs.

`BN_SIZES` mirrors the paper's Table III sweep (13..60 nodes, s=4); the two
reference networks (§VI) are STN (11 nodes) and ALARM (37 nodes).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class BNConfig:
    name: str
    n_nodes: int
    arity: int = 3            # paper's gene-expression discretization (3 states)
    max_parents: int = 4      # paper: s = 4
    gamma: float = 0.1        # structure penalty
    ess: float = 1.0          # BDeu equivalent sample size
    n_samples: int = 1000     # paper's experiments use 1,000 observations
    iterations: int = 10_000
    n_chains: int = 1
    score_block: int = 2048   # kernel/VMEM tile on the parent-set axis


CONFIG = BNConfig(name="bn-60", n_nodes=60)          # paper's headline scale
STN = BNConfig(name="bn-stn-11", n_nodes=11, arity=3, n_samples=1000)
ALARM = BNConfig(name="bn-alarm-37", n_nodes=37, arity=3, n_samples=1000)

BN_SIZES = [13, 15, 17, 20, 25, 30, 35, 40, 45, 50, 55, 60]  # Table III
