"""bn_query: offline reader for persisted posterior-service artifacts.

    PYTHONPATH=src python -m repro.launch.bn_query --run-dir \
        experiments/service [--job job-<hash>] [--kind posterior|map|consensus]
        [--threshold 0.7] [--json]

The server (``bn_serve``) persists every finished job's validated artifact
responses to ``<run_dir>/jobs/<job_id>/result.json`` — so answers stay
queryable after the server stops, from cron jobs, or over plain files on a
shared filesystem. With no ``--job`` the CLI lists every persisted job with
its stamp (iterations, R̂ status, heals). ``--threshold`` recomputes the
consensus adjacency from the persisted posterior matrix — the same pure
derivation the live endpoint uses.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from ..service import validate_response
from ..service.schema import SCHEMA

__all__ = ["load_result", "list_jobs", "main"]


def list_jobs(run_dir: str) -> list[str]:
    jobs_dir = os.path.join(run_dir, "jobs")
    if not os.path.isdir(jobs_dir):
        return []
    return sorted(j for j in os.listdir(jobs_dir)
                  if os.path.isfile(os.path.join(jobs_dir, j,
                                                 "result.json")))


def load_result(run_dir: str, job_id: str) -> dict:
    path = os.path.join(run_dir, "jobs", job_id, "result.json")
    with open(path) as f:
        doc = json.load(f)
    for key in ("job", "posterior", "map", "consensus"):
        if key not in doc:
            raise ValueError(f"{path}: missing {key!r} section — not a "
                             f"{SCHEMA} result document")
        validate_response(doc[key])
    return doc


def _fmt_stamp(resp: dict) -> str:
    return (f"iters {resp['iters_done']}/{resp['iters']} "
            f"converged={resp['converged']} "
            f"rhat={resp['score_rhat']:.4f}/{resp['edge_rhat']:.4f} "
            f"heals={resp['heals']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--run-dir", default="experiments/service")
    ap.add_argument("--job", default="",
                    help="job id; omit to list persisted jobs")
    ap.add_argument("--kind", default="posterior",
                    choices=["posterior", "map", "consensus", "job"])
    ap.add_argument("--threshold", type=float, default=None,
                    help="recompute consensus at this probability")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="print the raw response document")
    args = ap.parse_args(argv)

    if not args.job:
        jobs = list_jobs(args.run_dir)
        if not jobs:
            print(f"no persisted jobs under {args.run_dir}/jobs")
            return 1
        for jid in jobs:
            doc = load_result(args.run_dir, jid)
            print(f"{jid}  state={doc['job']['state']}  "
                  f"n={doc['job']['n']}  {_fmt_stamp(doc['job'])}")
        return 0

    doc = load_result(args.run_dir, args.job)
    resp = doc[args.kind]
    if args.kind == "consensus" and args.threshold is not None:
        from ..core.metrics import consensus_graph
        probs = np.asarray(doc["posterior"]["edge_probs"])
        adj = consensus_graph(probs, args.threshold)
        resp = {**resp, "threshold": float(args.threshold),
                "adjacency": adj.astype(int).tolist()}
        validate_response(resp)
    if args.as_json:
        json.dump(resp, sys.stdout, indent=2)
        print()
        return 0
    print(f"{args.job} [{args.kind}]  {_fmt_stamp(resp)}")
    if args.kind == "posterior":
        probs = np.asarray(resp["edge_probs"])
        print(f"edge_samples={resp['edge_samples']}  "
              f"max_p={probs.max():.3f}  "
              f"edges@0.5={int((probs >= 0.5).sum())}")
        with np.printoptions(precision=3, suppress=True, linewidth=120):
            print(probs)
    elif args.kind in ("map", "consensus"):
        adj = np.asarray(resp["adjacency"])
        extra = (f"score={resp['score']:.4f}" if args.kind == "map" else
                 f"threshold={resp['threshold']}")
        print(f"edges={int(adj.sum())}  {extra}")
        print(adj)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
