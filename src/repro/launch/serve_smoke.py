"""serve_smoke: end-to-end CI gate for the bn_serve posterior service.

    PYTHONPATH=src python -m repro.launch.serve_smoke

Starts the HTTP server IN-PROCESS on an ephemeral port, then exercises the
whole service contract over real HTTP:

1. submits two small synthetic datasets, one of them twice — the duplicate
   must come back with the SAME job id and ``deduped: true``;
2. polls job status to completion (per-job stop-on-converge may retire a
   job early; its slots must be reclaimed);
3. fetches posterior / MAP / consensus artifacts and validates every
   response against the ``bn-service/v1`` schema;
4. asserts each job's artifacts are BITWISE-equal to a standalone
   ``learn_structure`` run of the same (data, config, seed) — the service's
   core determinism promise (JSON float64 round-trips exactly, so the
   HTTP hop cannot blur the comparison);
5. checks the offline ``bn_query`` CLI reads the persisted artifacts back;
6. shuts the server down cleanly via POST /v1/shutdown.

Exit code 0 = every gate passed. Runs on CPU in well under a minute.
"""
from __future__ import annotations

import json
import sys
import tempfile
import threading
import time
import urllib.request

import numpy as np

__all__ = ["main"]

_POLL_TIMEOUT = 300.0      # seconds until we declare the service hung


def _http(method: str, url: str, payload: dict | None = None) -> dict:
    data = None if payload is None else json.dumps(payload).encode()
    req = urllib.request.Request(url, data=data, method=method,
                                 headers={"Content-Type":
                                          "application/json"})
    with urllib.request.urlopen(req, timeout=60) as resp:
        return json.loads(resp.read())


def main(argv=None) -> int:
    from ..launch.bn_learn import learn_structure
    from ..launch.bn_query import load_result
    from ..launch.bn_serve import BNServer
    from ..service import load_dataset, service_config, validate_response
    from ..service.jobs import DatasetSpec

    run_dir = tempfile.mkdtemp(prefix="serve_smoke_")
    srv = BNServer(("127.0.0.1", 0), slots=16, run_dir=run_dir)
    host, port = srv.server_address[:2]
    base = f"http://{host}:{port}"
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    print(f"serve_smoke: server up at {base}, run_dir={run_dir}")

    config = {"iters": 400, "chains": 3, "check_every": 100,
              "trace_every": 10, "seed": 11, "stop_on_converge": True,
              "patience": 1}
    specs = [
        {"network": "synth", "n": 8, "m": 150, "seed": 3},
        {"network": "synth", "n": 10, "m": 150, "seed": 4},
    ]

    health = _http("GET", f"{base}/v1/health")
    validate_response(health)
    assert health["state"] == "up", health

    # --- submit both datasets, plus an exact duplicate of the first
    jobs = [_http("POST", f"{base}/v1/jobs",
                  {"dataset": s, "config": config}) for s in specs]
    dup = _http("POST", f"{base}/v1/jobs",
                {"dataset": specs[0], "config": config})
    for j in jobs + [dup]:
        validate_response(j)
    assert not jobs[0]["deduped"] and not jobs[1]["deduped"], jobs
    assert dup["deduped"] and dup["job_id"] == jobs[0]["job_id"], \
        f"dedup broken: {dup['job_id']} vs {jobs[0]['job_id']}"
    assert dup["attached"] == 2, dup
    assert jobs[0]["job_id"] != jobs[1]["job_id"]
    print(f"serve_smoke: dedup OK ({dup['job_id']} attached twice)")

    # --- poll to completion
    ids = [j["job_id"] for j in jobs]
    deadline = time.time() + _POLL_TIMEOUT
    states: dict[str, dict] = {}
    while time.time() < deadline:
        states = {i: _http("GET", f"{base}/v1/jobs/{i}") for i in ids}
        if all(s["state"] in ("done", "failed") for s in states.values()):
            break
        time.sleep(0.5)
    for i, s in states.items():
        validate_response(s)
        assert s["state"] == "done", f"job {i}: {s}"
    print("serve_smoke: both jobs done "
          f"(iters_done={[states[i]['iters_done'] for i in ids]}, "
          f"converged={[states[i]['converged'] for i in ids]})")

    # --- slots reclaimed once everything finished
    health = _http("GET", f"{base}/v1/health")
    assert health["slots_used"] == 0 and health["active"] == 0, health

    # --- artifacts: schema-valid AND bitwise-equal to standalone runs
    for spec, jid in zip(specs, ids):
        post = _http("GET", f"{base}/v1/jobs/{jid}/posterior")
        mapr = _http("GET", f"{base}/v1/jobs/{jid}/map")
        cons = _http("GET", f"{base}/v1/jobs/{jid}/consensus")
        cons_lo = _http("GET",
                        f"{base}/v1/jobs/{jid}/consensus?threshold=0.25")
        for r in (post, mapr, cons, cons_lo):
            validate_response(r)
        assert cons_lo["threshold"] == 0.25
        assert np.asarray(cons_lo["adjacency"]).sum() >= \
            np.asarray(cons["adjacency"]).sum()

        cfg = service_config(config)
        data = load_dataset(DatasetSpec(**spec), cfg.q)
        ref = learn_structure(data, cfg)
        same = {
            "posterior": np.array_equal(np.asarray(post["edge_probs"]),
                                        np.asarray(ref["edge_posterior"])),
            "map": np.array_equal(np.asarray(mapr["adjacency"]),
                                  np.asarray(ref["map_dag"])),
            "consensus": np.array_equal(np.asarray(cons["adjacency"]),
                                        np.asarray(ref["consensus"])),
            "score": mapr["score"] == float(ref["score"]),
        }
        assert all(same.values()), f"job {jid} diverged: {same}"
        print(f"serve_smoke: {jid} bitwise-equal to standalone "
              f"(n={post['n']}, edge_samples={post['edge_samples']})")

    # --- offline CLI reads the persisted artifacts back
    for jid in ids:
        doc = load_result(run_dir, jid)
        assert doc["job"]["state"] == "done"
    print("serve_smoke: bn_query round-trip OK")

    # --- clean shutdown
    bye = _http("POST", f"{base}/v1/shutdown")
    validate_response(bye)
    t.join(timeout=60)
    assert not t.is_alive(), "server thread did not stop"
    print("serve_smoke: clean shutdown — PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
