"""End-to-end LM training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-34b --steps 200 \
        --reduced --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container use --reduced (family-preserving small config); on a
pod the same driver runs the full config on the production mesh. Features:
deterministic resumable data, async checkpointing + auto-resume, optional
int8 gradient compression (error feedback), straggler/elastic hooks.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from ..configs import ARCH_IDS, get_config
from ..data.lm_pipeline import batch_iterator
from ..models import Model
from ..models.layers import set_mesh
from ..optim import (AdamWConfig, adamw_init, adamw_update, compress_grads,
                     compress_init, warmup_cosine)
from .mesh import make_local_mesh, make_production_mesh


def make_train_step(model: Model, opt_cfg: AdamWConfig, total_steps: int,
                    compress: bool = False):
    def step_fn(params, opt_state, comp_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        if compress:
            grads, comp_state = compress_grads(grads, comp_state)
        lr_scale = warmup_cosine(opt_state.step, warmup=max(total_steps // 20, 1),
                                 total=total_steps)
        params, opt_state, metrics = adamw_update(grads, opt_state, params,
                                                  opt_cfg, lr_scale)
        return params, opt_state, comp_state, {"loss": loss, **metrics}
    return jax.jit(step_fn, donate_argnums=(0, 1, 2))


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-moe-3b-a800m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--reduced-100m", action="store_true",
                    help="~100M-param family-preserving config (examples)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    elif args.reduced_100m:
        cfg = dataclasses.replace(
            cfg.reduced(), n_layers=12, d_model=768, d_ff=2048,
            n_heads=12, n_kv_heads=4, head_dim=64, vocab=32768)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh(1, 1))
    set_mesh(mesh)
    model = Model(cfg, tp=mesh.shape["model"])
    opt_cfg = AdamWConfig(lr=args.lr)

    params = model.init(jax.random.key(args.seed))
    opt_state = adamw_init(params, opt_cfg)
    comp_state = compress_init(params)
    start_step = 0

    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), meta = restore_checkpoint(
            args.ckpt_dir, (params, opt_state))
        start_step = meta["step"] + 1
        print(f"resumed from step {meta['step']}")

    step_fn = make_train_step(model, opt_cfg, args.steps,
                              compress=args.compress_grads)
    enc_shape = ((args.batch, args.seq // cfg.enc_seq_divisor, cfg.d_model)
                 if cfg.family == "encdec" else None)
    data = batch_iterator(start_step, global_batch=args.batch,
                          seq_len=args.seq, vocab=cfg.vocab, seed=args.seed,
                          enc_feats_shape=enc_shape)

    losses = []
    t0 = time.time()
    for step, batch in zip(range(start_step, args.steps), data):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, comp_state, metrics = step_fn(
            params, opt_state, comp_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/max(len(losses),1):.2f}s/step)",
                  flush=True)
        if ckpt and (step % args.ckpt_every == 0 or step == args.steps - 1):
            ckpt.save(step, (params, opt_state), {"step": step})
    if ckpt:
        ckpt.wait()
    set_mesh(None)
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "losses": losses}


if __name__ == "__main__":
    main()
