"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch × shape × mesh), in seconds:

  compute    = HLO_FLOPs_total  / (chips × 197e12 bf16 FLOP/s)
  memory     = HLO_bytes_total  / (chips × 819e9  B/s HBM)
  collective = coll_bytes_total / (chips × 50e9   B/s per ICI link)

`cost_analysis()` on the SPMD-partitioned module reports *per-device* flops
and bytes; collective bytes are parsed from the compiled HLO text (operand
sizes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute). Totals are per-device × chips, so the ratios above
reduce to per-device quantities over per-chip rates.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass

__all__ = ["HW", "parse_collective_bytes", "roofline_terms", "RooflineReport"]

# TPU v5e (target hardware; this container is CPU-only)
HW = {
    "peak_flops": 197e12,     # bf16 per chip
    "hbm_bw": 819e9,          # bytes/s per chip
    "ici_bw": 50e9,           # bytes/s per link
}

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# result type(s) between '=' and the op name; post-optimization HLO operands
# are bare %refs, so sizes come from the result shape + replica-group algebra.
_INSTR_RE = re.compile(
    r"=\s*([^=\n]*?)\s*"
    r"(all-reduce(?:-start)?|all-gather(?:-start)?|reduce-scatter|"
    r"all-to-all|collective-permute(?:-start)?|ragged-all-to-all)"
    r"\(")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[\d,]+\}|\[(\d+),(\d+)\])")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return default
    if m.group(2) is not None:        # iota form [n_groups, group_size]<=[...]
        return int(m.group(3))
    first = m.group(1)[2:].split("}")[0]
    return max(len(first.split(",")), 1)


def parse_collective_bytes(hlo_text: str, n_devices: int = 16) -> dict[str, int]:
    """Per-device bytes moved on the interconnect, per collective type.

    Ring-algorithm accounting on the result size S with group size g:
    all-reduce 2S(g−1)/g, all-gather S(g−1)/g, reduce-scatter S(g−1),
    all-to-all S(g−1)/g, collective-permute S.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        op = m.group(2).replace("-start", "")
        S = sum(_shape_bytes(dt, dims)
                for dt, dims in _SHAPE_RE.findall(m.group(1)))
        g = _group_size(line, n_devices)
        if op == "all-reduce":
            moved = 2 * S * (g - 1) / g
        elif op == "reduce-scatter":
            moved = S * (g - 1)
        elif op == "collective-permute":
            moved = S
        else:                          # all-gather, all-to-all
            moved = S * (g - 1) / g
        out[op] = out.get(op, 0) + int(moved)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float            # 6·N(_active)·D, global
    useful_ratio: float           # model_flops / (flops_per_device·chips)
    peak_memory_bytes: float = 0.0

    def as_dict(self):
        return asdict(self)


def roofline_terms(*, arch: str, shape: str, mesh_name: str, chips: int,
                   flops_per_device: float, bytes_per_device: float,
                   coll: dict[str, int], model_flops: float,
                   peak_memory: float = 0.0) -> RooflineReport:
    coll_bytes = float(sum(coll.values()))
    t_c = flops_per_device / HW["peak_flops"]
    t_m = bytes_per_device / HW["hbm_bw"]
    t_x = coll_bytes / HW["ici_bw"]
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    total_flops = flops_per_device * chips
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops_per_device, bytes_per_device=bytes_per_device,
        coll_bytes_per_device=coll_bytes, coll_breakdown=coll,
        t_compute=t_c, t_memory=t_m, t_collective=t_x, bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops / total_flops) if total_flops else 0.0,
        peak_memory_bytes=peak_memory)


def model_flops_for(cfg, shape_cfg) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); D = tokens processed this step.
    Train steps cost 3× forward (fwd + bwd)."""
    total, active = cfg.param_count()
    n = active
    if shape_cfg.mode == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6.0 * n * tokens
    if shape_cfg.mode == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape_cfg.global_batch          # decode: 1 token/seq
