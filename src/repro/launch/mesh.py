"""Production meshes.

Single pod: 16×16 = 256 chips (TPU v5e pod), axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — `pod` is an
outer data-parallel axis (gradient all-reduce over DCI/optical links; the
gradient-compression path in optim/grad_compress targets exactly this hop) or,
optionally, a pipeline axis (launch/pipeline.py).

Functions, not module constants: importing this module never touches jax
device state (required so smoke tests see 1 CPU device while the dry-run sees
512 placeholder devices via XLA_FLAGS).
"""
from __future__ import annotations

from ..runtime.jax_compat import make_auto_mesh

__all__ = ["make_production_mesh", "make_local_mesh", "batch_axes_of"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many real devices exist (tests/examples)."""
    return make_auto_mesh((data, model), ("data", "model"))


def batch_axes_of(mesh) -> tuple[str, ...]:
    """Mesh axes that carry the batch/chains dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
