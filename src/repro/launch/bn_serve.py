"""bn_serve: the long-running BN posterior service over local HTTP.

    PYTHONPATH=src python -m repro.launch.bn_serve --port 8787 \
        --slots 64 --run-dir experiments/service

Architecture: ONE driver thread owns every jax operation — it builds
engines and advances each active job one supervised segment per scheduler
tick (``FleetScheduler.step``). The stdlib ThreadingHTTPServer front end
never touches the device; handlers only enqueue dataset specs and read
materialized results under the server lock. That split keeps request
latency independent of segment latency and sidesteps jax's
single-host-thread dispatch model entirely.

Endpoints (all JSON, schema ``bn-service/v1`` — repro/service/schema.py):

    POST /v1/jobs                    {"dataset": {...DatasetSpec fields},
                                      "config": {...LearnConfig overrides}}
                                     -> job response (dedup-aware: an
                                        identical request returns the SAME
                                        job id with deduped=true)
    GET  /v1/jobs                    -> list of job responses
    GET  /v1/jobs/<id>               -> job status
    GET  /v1/jobs/<id>/posterior     -> (n, n) edge-probability matrix
    GET  /v1/jobs/<id>/map           -> MAP DAG + score
    GET  /v1/jobs/<id>/consensus[?threshold=t]
                                     -> thresholded consensus adjacency
    GET  /v1/health                  -> liveness + scheduler occupancy
    POST /v1/shutdown                -> drain and stop cleanly

Every artifact response is stamped with job id, iterations done, R̂ status
and heal/reseed counts. Artifacts are also persisted to
``<run_dir>/jobs/<id>/result.json`` for the offline ``bn_query`` CLI, so
the server can be stopped and its answers remain queryable.
"""
from __future__ import annotations

import argparse
import json
import logging
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

from ..service import (DatasetSpec, FleetScheduler, JobManager,
                       consensus_response, error_response, job_response,
                       load_dataset, map_response, posterior_response,
                       service_config, validate_response)
from ..service.schema import SCHEMA

__all__ = ["BNServer", "main"]

logger = logging.getLogger(__name__)

# driver idle sleep when nothing is active (seconds); ticks are back-to-back
# while jobs are running
_IDLE_SLEEP = 0.05


class BNServer(ThreadingHTTPServer):
    """ThreadingHTTPServer + scheduler driver thread (module docstring)."""

    daemon_threads = True

    def __init__(self, addr, *, slots: int = 64, elastic: bool = False,
                 run_dir: str = "experiments/service", cache_dir: str = ""):
        super().__init__(addr, _Handler)
        self.manager = JobManager(run_dir=run_dir, cache_dir=cache_dir)
        self.scheduler = FleetScheduler(self.manager, slots=slots,
                                        elastic=elastic)
        self.lock = threading.Lock()
        self.stopping = threading.Event()
        self._driver = threading.Thread(target=self._drive, daemon=True,
                                        name="bn-serve-driver")
        self._driver.start()

    # ---------------------------------------------------------- driver loop
    def _drive(self) -> None:
        """The ONLY thread that touches jax: tick the scheduler until asked
        to stop, then drain in-flight jobs so no work is lost."""
        while not self.stopping.is_set():
            with self.lock:
                busy = self.scheduler.step()
            if not busy:
                time.sleep(_IDLE_SLEEP)
        with self.lock:                      # drain: finish active jobs
            while self.scheduler.active and self.scheduler.step():
                pass

    def shutdown_clean(self) -> None:
        self.stopping.set()
        self._driver.join(timeout=600)
        self.shutdown()

    # ------------------------------------------------------------- handlers
    def submit(self, payload: dict) -> dict:
        spec = DatasetSpec(**payload.get("dataset", {}))
        cfg = service_config(payload.get("config", {}))
        data = load_dataset(spec, cfg.q)
        with self.lock:
            job, deduped = self.scheduler.submit(data, cfg)
            return job_response(job, deduped=deduped)

    def health(self) -> dict:
        with self.lock:
            resp = {"schema": SCHEMA, "kind": "health",
                    "state": "stopping" if self.stopping.is_set() else "up",
                    "jobs": len(self.manager.jobs),
                    "active": len(self.scheduler.active),
                    "pending": len(self.scheduler.pending),
                    "slots": self.scheduler.slots,
                    "slots_used": self.scheduler.slots_used}
        validate_response(resp)
        return resp


class _Handler(BaseHTTPRequestHandler):
    server: BNServer

    def log_message(self, fmt, *args):        # route through logging, quiet
        logger.debug("%s " + fmt, self.address_string(), *args)

    def _send(self, code: int, doc: dict) -> None:
        body = json.dumps(doc).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _job(self, job_id: str):
        with self.server.lock:
            return self.server.manager.get(job_id)

    def do_GET(self) -> None:               # noqa: N802 — http.server API
        url = urlparse(self.path)
        parts = [p for p in url.path.split("/") if p]
        try:
            if parts == ["v1", "health"]:
                return self._send(200, self.server.health())
            if parts == ["v1", "jobs"]:
                with self.server.lock:
                    jobs = list(self.server.manager.jobs.values())
                    return self._send(
                        200, {"schema": SCHEMA, "kind": "job_list",
                              "jobs": [job_response(j) for j in jobs]})
            if len(parts) in (3, 4) and parts[:2] == ["v1", "jobs"]:
                job = self._job(parts[2])
                if job is None:
                    return self._send(404, error_response(
                        f"unknown job {parts[2]!r}"))
                if len(parts) == 3:
                    return self._send(200, job_response(job))
                artifact = parts[3]
                with self.server.lock:
                    if artifact == "posterior":
                        return self._send(200, posterior_response(job))
                    if artifact == "map":
                        return self._send(200, map_response(job))
                    if artifact == "consensus":
                        q = parse_qs(url.query)
                        t = q.get("threshold", [None])[0]
                        return self._send(200, consensus_response(
                            job, None if t is None else float(t)))
                return self._send(404, error_response(
                    f"unknown artifact {artifact!r} (posterior|map|"
                    "consensus)"))
            return self._send(404, error_response(f"no route {url.path!r}"))
        except LookupError as exc:          # artifact requested too early
            return self._send(409, error_response(str(exc)))
        except Exception as exc:            # noqa: BLE001 — server stays up
            logger.exception("GET %s failed", self.path)
            return self._send(500, error_response(
                f"{type(exc).__name__}: {exc}"))

    def do_POST(self) -> None:              # noqa: N802 — http.server API
        url = urlparse(self.path)
        parts = [p for p in url.path.split("/") if p]
        try:
            if parts == ["v1", "shutdown"]:
                self._send(200, {"schema": SCHEMA, "kind": "shutdown",
                                 "state": "stopping"})
                # shut down from another thread: shutdown() blocks until
                # serve_forever exits, which can't happen inside a handler
                threading.Thread(target=self.server.shutdown_clean,
                                 daemon=True).start()
                return
            if parts == ["v1", "jobs"]:
                length = int(self.headers.get("Content-Length", 0))
                payload = json.loads(self.rfile.read(length) or b"{}")
                return self._send(202, self.server.submit(payload))
            return self._send(404, error_response(f"no route {url.path!r}"))
        except (TypeError, ValueError, KeyError, OSError) as exc:
            return self._send(400, error_response(
                f"{type(exc).__name__}: {exc}"))
        except Exception as exc:            # noqa: BLE001 — server stays up
            logger.exception("POST %s failed", self.path)
            return self._send(500, error_response(
                f"{type(exc).__name__}: {exc}"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8787)
    ap.add_argument("--slots", type=int, default=64,
                    help="chain-slot budget shared by all active jobs")
    ap.add_argument("--elastic", action="store_true",
                    help="clone chains into idle slots (breaks standalone "
                         "bitwise parity for the grown job)")
    ap.add_argument("--run-dir", default="experiments/service")
    ap.add_argument("--cache-dir", default="",
                    help="preprocess disk cache shared across jobs")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    srv = BNServer((args.host, args.port), slots=args.slots,
                   elastic=args.elastic, run_dir=args.run_dir,
                   cache_dir=args.cache_dir)
    host, port = srv.server_address[:2]
    logger.info("bn_serve listening on http://%s:%d (slots=%d)",
                host, port, args.slots)
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        srv.shutdown_clean()


if __name__ == "__main__":
    main()
