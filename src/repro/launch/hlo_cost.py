"""Trip-count-aware cost analysis of post-optimization HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
ignoring the trip count. Every model here scans over layers (and RWKV scans
over time chunks), so XLA's numbers under-count FLOPs/bytes by ~n_layers× —
useless for a roofline. This module re-derives the three roofline inputs from
the compiled HLO text with while-loop bodies multiplied by their trip counts:

  * flops       — dot (2·M·N·K via operand-shape tracking), elementwise,
                  reductions; fused computations are recursed into.
  * bytes       — per scheduled instruction: operand + result bytes (XLA's
                  "bytes accessed" convention, fusion counted at the call
                  site); bookkeeping ops (tuple/gte/bitcast/parameter) are
                  free.
  * collectives — per-device bytes moved on the interconnect under ring
                  algorithms: all-reduce 2S(g−1)/g, all-gather/all-to-all
                  S(g−1)/g, reduce-scatter S(g−1)/g, collective-permute S,
                  with S the full (gathered) payload and g the group size.

Trip counts: ``lax.scan``/``fori_loop`` lower to a while whose condition is
``compare(gte(param, i), constant(N)), direction=LT`` with the induction
variable starting at 0 and stepping by 1 — so the constant IS the trip count
(LE → N+1). Loops that don't match the pattern fall back to 1 and are
reported in ``unknown_loops``.

Validated in tests/test_hlo_cost.py against unrolled-vs-scanned parity and
analytic FLOP counts.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c128": 16, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

# result "type" of an instruction: one or a (possibly nested) tuple of shapes
_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\](?:\{[^}]*\})?")
_NAME_RE = re.compile(r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OP_RE = re.compile(r"\s*([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(
    r"replica_groups=(?:\[(\d+),(\d+)\]|\{(\{[\d,]+\}))")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DIRECTION_RE = re.compile(r"direction=(\w+)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "and", "or", "xor", "not", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "compare", "select", "clamp",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "sqrt", "rsqrt", "cbrt", "sine", "cosine", "tan", "atan2",
    "erf", "is-finite", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "stochastic-convert",
}
_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "opt-barrier", "partition-id",
    "replica-id", "rng-get-and-update-state", "domain",
    "get-dimension-size",
}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all"}

# Ops whose bytes we do NOT charge: on TPU these fuse into their consumers
# (elementwise, casts, layout changes) — charging them models the CPU
# backend's fusion policy, not the target's. Their FLOPs are still counted.
_BYTE_FREE = _ELEMENTWISE | {"copy", "convert", "broadcast", "iota",
                             "reshape", "transpose", "reverse", "map"}


def _parse_shapes(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _nbytes(shapes) -> int:
    return sum(_DTYPE_BYTES[dt] * math.prod(dims) for dt, dims in shapes)


def _nelems(shapes) -> int:
    return sum(math.prod(dims) for dt, dims in shapes)


@dataclass
class _Instr:
    name: str
    op: str
    shapes: list                 # result shapes [(dtype, dims), ...]
    operands: list[str]
    attrs: str                   # full line tail for attr regexes
    line: str


@dataclass
class _Comp:
    name: str
    instrs: list = field(default_factory=list)
    by_name: dict = field(default_factory=dict)


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: dict = field(default_factory=dict)
    loops: list = field(default_factory=list)       # (body_name, trip)
    unknown_loops: list = field(default_factory=list)

    def _add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.coll_bytes += mult * other.coll_bytes
        for k, v in other.coll_breakdown.items():
            self.coll_breakdown[k] = self.coll_breakdown.get(k, 0) + mult * v


def _parse_module(hlo: str) -> tuple[dict[str, _Comp], str | None]:
    comps: dict[str, _Comp] = {}
    entry = None
    cur: _Comp | None = None
    for line in hlo.splitlines():
        if line.endswith("{") and "->" in line:
            m = _COMP_RE.match(line.strip())
            if m:
                cur = _Comp(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _NAME_RE.match(line)
        if not m:
            continue
        name = m.group(2)
        rest = line[m.end():]
        # result type: bracket-matched tuple (possibly nested) or single token
        if rest.startswith("("):
            depth = 0
            for j, ch in enumerate(rest):
                depth += (ch == "(") - (ch == ")")
                if depth == 0:
                    break
            type_str, rest = rest[:j + 1], rest[j + 1:]
        else:
            sp = rest.find(" ")
            type_str, rest = rest[:sp], rest[sp:]
        mo = _OP_RE.match(rest)
        if not mo:
            continue
        op = mo.group(1)
        tail = rest[mo.end():]
        # operands are inside the first (...) — attrs follow; keeping the whole
        # tail is fine because operand names are only used for shape lookup.
        depth, i = 1, 0
        for i, ch in enumerate(tail):
            depth += (ch == "(") - (ch == ")")
            if depth == 0:
                break
        operands = _OPERAND_RE.findall(tail[:i])
        instr = _Instr(name, op, _parse_shapes(type_str), operands,
                       tail[i:], line)
        cur.instrs.append(instr)
        cur.by_name[name] = instr
    return comps, entry


def _group_size(attrs: str, n_devices: int) -> int:
    m = _GROUPS_RE.search(attrs)
    if not m:
        return n_devices
    if m.group(2) is not None:          # iota form [n_groups, group_size]
        return int(m.group(2))
    first = m.group(3)[1:].split("}")[0]
    return max(len(first.split(",")), 1)


def _trip_count(cond: _Comp) -> int | None:
    """lax.scan pattern: compare(gte, constant(N)) LT (possibly via a
    wrapped-fusion); induction starts at 0, step 1 → trip = N."""
    const = None
    for ins in cond.instrs:
        m = _CONST_RE.search(ins.line)
        if ins.op == "constant" and m:
            const = int(m.group(1))
    direction = None
    for ins in cond.instrs:
        m = _DIRECTION_RE.search(ins.attrs)
        if ins.op == "compare" and m:
            direction = m.group(1)
    if const is None:
        return None
    if direction == "LE":
        return const + 1
    return const                         # LT or compare hidden in a fusion


def _dot_flops(ins: _Instr, comp: _Comp) -> float:
    out = 2.0 * _nelems(ins.shapes)
    m = _CONTRACT_RE.search(ins.attrs)
    if not m or not ins.operands:
        return out
    lhs = comp.by_name.get(ins.operands[0])
    if lhs is None or not lhs.shapes:
        return out
    dims = lhs.shapes[0][1]
    k = 1
    for d in m.group(1).split(","):
        if d and int(d) < len(dims):
            k *= dims[int(d)]
    return out * k


# ops that touch HBM-resident buffers even when fused (their operand is a
# large buffer being sliced/gathered, not a fused intermediate)
_MEM_OPS = {"dynamic-slice", "dynamic-update-slice", "slice", "gather",
            "scatter", "concatenate", "pad", "sort"}


def _fusion_flops(comp: _Comp, comps: dict) -> tuple[float, float]:
    """(FLOPs, memory-op bytes) inside a fused computation. The fusion's
    result bytes are charged at the call site; here we add only the ops that
    stream HBM-resident buffers (slices/gathers/dots) — fused elementwise
    intermediates never leave VMEM on the target."""
    fl = by = 0.0
    for ins in comp.instrs:
        if ins.op in _ELEMENTWISE:
            fl += _nelems(ins.shapes)
        elif ins.op == "dot":
            fl += _dot_flops(ins, comp)
            by += _nbytes(ins.shapes)
            for o in ins.operands:
                d = comp.by_name.get(o)
                if d is not None and d.op != "constant":
                    by += _nbytes(d.shapes)
        elif ins.op in _MEM_OPS:
            by += _nbytes(ins.shapes)
        elif ins.op in ("reduce", "reduce-window"):
            # count the elements folded in
            src = comp.by_name.get(ins.operands[0]) if ins.operands else None
            fl += _nelems(src.shapes) if src and src.shapes else _nelems(ins.shapes)
        elif ins.op == "fusion":
            m = _CALLS_RE.search(ins.attrs)
            if m and m.group(1) in comps:
                f2, b2 = _fusion_flops(comps[m.group(1)], comps)
                fl += f2
                by += b2
    return fl, by


def _cost_of(comp: _Comp, comps: dict, n_devices: int,
             memo: dict, out: HloCost) -> HloCost:
    if comp.name in memo:
        return memo[comp.name]
    c = HloCost()
    for ins in comp.instrs:
        op = ins.op
        if op in _FREE:
            continue
        rb = _nbytes(ins.shapes)
        ob = 0
        for o in ins.operands:
            d = comp.by_name.get(o)
            if d is not None and d.op != "constant":
                ob += _nbytes(d.shapes)
        if op == "while":
            body = _BODY_RE.search(ins.attrs)
            cond = _COND_RE.search(ins.attrs)
            trip = None
            if cond and cond.group(1) in comps:
                trip = _trip_count(comps[cond.group(1)])
            if trip is None:
                trip = 1
                out.unknown_loops.append(ins.name)
            if body and body.group(1) in comps:
                bc = _cost_of(comps[body.group(1)], comps, n_devices, memo, out)
                c._add(bc, trip)
                out.loops.append((body.group(1), trip))
            continue
        if op in ("call", "conditional", "async-start"):
            for m in _OPERAND_RE.finditer(ins.attrs):
                if m.group(1) in comps:
                    c._add(_cost_of(comps[m.group(1)], comps, n_devices,
                                    memo, out))
            continue
        base = op.replace("-start", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            g = _group_size(ins.attrs, n_devices)
            if base == "all-reduce":
                moved = 2.0 * rb * (g - 1) / g
            elif base == "all-gather":
                moved = rb * (g - 1) / g      # rb is the gathered result
            elif base == "reduce-scatter":
                moved = ob * (g - 1) / g      # ob is the full input
            elif base == "collective-permute":
                moved = rb
            else:                             # all-to-all variants
                moved = rb * (g - 1) / g
            c.coll_bytes += moved
            c.coll_breakdown[base] = c.coll_breakdown.get(base, 0) + moved
            c.bytes += rb + ob
            continue
        if op == "fusion":
            m = _CALLS_RE.search(ins.attrs)
            if m and m.group(1) in comps:
                f2, b2 = _fusion_flops(comps[m.group(1)], comps)
                c.flops += f2
                # result write + HBM-touching inner ops; operand reads are
                # the producers' counted writes (avoids double-charging
                # every producer->consumer hop, which TPU fusion elides)
                c.bytes += rb + b2
            else:
                c.bytes += rb + ob
            continue
        if op == "dot":
            c.flops += _dot_flops(ins, comp)
        elif op == "convolution":
            c.flops += 2.0 * _nelems(ins.shapes) * 128  # unused by our models
        elif op in _ELEMENTWISE:
            c.flops += _nelems(ins.shapes)
        elif op in ("reduce", "reduce-window"):
            src = comp.by_name.get(ins.operands[0]) if ins.operands else None
            c.flops += _nelems(src.shapes) if src and src.shapes else 0
        if op not in _BYTE_FREE:
            c.bytes += rb + ob
    memo[comp.name] = c
    return c


def analyze_hlo(hlo_text: str, n_devices: int) -> HloCost:
    """Per-device roofline inputs from post-optimization HLO text."""
    comps, entry = _parse_module(hlo_text)
    out = HloCost()
    if entry is None:
        return out
    memo: dict[str, HloCost] = {}
    # Fused computations are charged at their call sites; while bodies at the
    # while. Only the entry computation is walked directly.
    c = _cost_of(comps[entry], comps, n_devices, memo, out)
    out.flops, out.bytes = c.flops, c.bytes
    out.coll_bytes, out.coll_breakdown = c.coll_bytes, dict(c.coll_breakdown)
    return out
