import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# The two lines above MUST run before any jax import (jax locks the device
# count at first init). Everything below is ordinary code.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (arch × shape) cell, on the single-pod 16×16 mesh and the 2-pod
2×16×16 mesh:   jit(step).lower(**input_specs).compile()
then record memory_analysis (fits?), cost_analysis (FLOPs/bytes for
§Roofline), and the collective schedule parsed from the compiled HLO.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all            # every cell, both meshes
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json (incremental;
existing cells are skipped unless --force).
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, cells, get_config, get_shape
from ..models.layers import set_mesh
from ..optim import AdamWConfig, adamw_init, opt_state_specs
from .hlo_cost import analyze_hlo
from ..runtime.jax_compat import mesh_context
from .mesh import make_production_mesh
from .roofline import model_flops_for, roofline_terms
from .specs import build_step, input_specs

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# §Perf hillclimb winners (EXPERIMENTS.md §Perf): beyond-paper optimized
# configurations, recorded SEPARATELY from the paper-faithful baselines.
# --opt runs exactly these cells into experiments/dryrun_opt/.
OPT_OVERRIDES = {
    ("rwkv6-7b", "train_4k"): {"rwkv_chunk": 256, "rwkv_sp": True},
    ("rwkv6-7b", "prefill_32k"): {"rwkv_chunk": 256, "rwkv_sp": True},
    ("granite-moe-3b-a800m", "train_4k"): {"moe_gathered": True,
                                           "fsdp_only": True},
    ("granite-moe-3b-a800m", "prefill_32k"): {"moe_gathered": True},
    ("arctic-480b", "train_4k"): {"moe_ep": True},
    ("arctic-480b", "prefill_32k"): {"moe_ep": True},
    # memory-fit config: grad-accumulation + bf16 moments + ZeRO-over-pods
    # (9.66 GiB/dev on 2x16x16 — fits 16 GB v5e; see EXPERIMENTS.md §Perf)
    ("llama3-405b", "train_4k"): {"microbatch": 8, "zero_pod": True,
                                  "accum_dtype": "bf16",
                                  "moment_dtype": "bf16"},
    # dense/hybrid/encdec trains at batch == chips: pure-FSDP strategy
    # (activation collectives vanish; weights gathered per layer)
    ("yi-34b", "train_4k"): {"fsdp_only": True},
    ("granite-20b", "train_4k"): {"fsdp_only": True},
    ("chameleon-34b", "train_4k"): {"fsdp_only": True},
    ("command-r-plus-104b", "train_4k"): {"fsdp_only": True},
    ("recurrentgemma-9b", "train_4k"): {"fsdp_only": True},
    ("seamless-m4t-large-v2", "train_4k"): {"fsdp_only": True},
}


def _sh(mesh, spec_tree):
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def _zero_pod(spec_tree):
    """ZeRO over pods: extend every FSDP ('data') entry in the param/opt
    PartitionSpecs to ('pod', 'data') — parameter and optimizer state shards
    span both pods instead of being pod-replicated (launch-level rewrite;
    the model code is mesh-agnostic)."""
    from jax.sharding import PartitionSpec as P

    def fix(spec):
        return P(*[("pod", "data") if e == "data" else e for e in spec])
    return jax.tree.map(fix, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = int(np.prod(list(mesh.shape.values())))
    cfg = get_config(arch)
    shp = get_shape(shape_name)
    set_mesh(mesh)
    try:
        ov = dict(overrides or {})
        _DT = {"bf16": jnp.bfloat16, "f32": jnp.float32}
        for key in ("accum_dtype", "moment_dtype"):
            if isinstance(ov.get(key), str):
                ov[key] = _DT[ov[key]]
        zero_pod = ov.pop("zero_pod", False) and multi_pod
        step, model = build_step(arch, shape_name, mesh, **ov)
        inputs, in_sp = input_specs(arch, shape_name, mesh)
        pspecs = model.specs()
        if zero_pod:
            pspecs = _zero_pod(pspecs)

        if shp.mode == "train":
            opt_specs = opt_state_specs(pspecs)
            ocfg = AdamWConfig()
            if ov.get("moment_dtype") is not None:
                ocfg = ocfg._replace(moment_dtype=ov["moment_dtype"])
            abstract_opt = jax.eval_shape(
                lambda p: adamw_init(p, ocfg), model.abstract())
            args = (model.abstract(), abstract_opt,
                    {k: v for k, v in inputs.items()})
            shardings = (_sh(mesh, pspecs), _sh(mesh, opt_specs),
                         _sh(mesh, {k: in_sp[k] for k in inputs}))
        elif shp.mode == "prefill":
            names = ["tokens"] + (["enc_feats"] if "enc_feats" in inputs else [])
            args = tuple([model.abstract()] + [inputs[n] for n in names])
            shardings = tuple([_sh(mesh, pspecs)] +
                              [_sh(mesh, in_sp[n]) for n in names])
        else:
            args = (model.abstract(), inputs["cache"], inputs["tokens"])
            shardings = (_sh(mesh, pspecs), _sh(mesh, in_sp["cache"]),
                         _sh(mesh, in_sp["tokens"]))

        t0 = time.time()
        with mesh_context(mesh):
            jitted = jax.jit(step, in_shardings=shardings)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        # Trip-count-aware accounting (XLA's cost_analysis counts while
        # bodies once — ~n_layers× under-count; see hlo_cost.py).
        hc = analyze_hlo(hlo, n_devices=chips)
        rep = roofline_terms(
            arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
            flops_per_device=hc.flops,
            bytes_per_device=hc.bytes,
            coll=hc.coll_breakdown, model_flops=model_flops_for(cfg, shp),
            peak_memory=float(getattr(mem, "peak_memory_in_bytes", 0) or 0))
        record = rep.as_dict()
        record.update({
            "ok": True,
            "mode": shp.mode,
            "xla_flops_per_device": float(cost.get("flops", 0.0)),
            "xla_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
            "loops": [list(t) for t in hc.loops],
            "unknown_loops": hc.unknown_loops,
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0) or 0),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0) or 0),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0) or 0),
            "t_lower_s": t_lower, "t_compile_s": t_compile,
            "overrides": {k: str(v) for k, v in (overrides or {}).items()},
        })
        return record
    finally:
        set_mesh(None)


def run_bn_cell(multi_pod: bool, *, n: int = 60, s: int = 4,
                block: int = 4096, window: int = 8) -> dict:
    """The paper's own workload on the production mesh: one MCMC iteration
    for all chains (DP over pod/data) with the (n, S) score table AND the
    cached consistency bit planes sharded over `model` (TP) —
    launch/bn_learn --sharded at scale. The compiled program is the
    mesh-native bitmask delta engine: each device patches and scores its own
    (n, P, shard/32) plane words; only the (window,) pmax/pmin pair crosses
    ICI per iteration."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..core.combinatorics import n_parent_sets
    from ..core.mcmc import ChainState
    from ..core.order_scoring import mask_plane_count
    from ..core.sharded_scoring import sharded_chain_step

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = int(np.prod(list(mesh.shape.values())))
    tp = mesh.shape["model"]
    S = n_parent_sets(n - 1, s)
    S_pad = S + (-S) % (tp * block)
    C = chips // tp                      # one chain per data-axis slot
    Pn = mask_plane_count(s)
    W = S_pad // 32

    dax = tuple(a for a in mesh.axis_names if a != "model")
    key = jax.random.key(0)
    states = ChainState(
        key=jax.ShapeDtypeStruct((C,) + key.shape, key.dtype),
        pos=jax.ShapeDtypeStruct((C, n), jnp.int32),
        score=jax.ShapeDtypeStruct((C,), jnp.float32),
        cur_idx=jax.ShapeDtypeStruct((C, n), jnp.int32),
        cur_ls=jax.ShapeDtypeStruct((C, n), jnp.float32),
        best_score=jax.ShapeDtypeStruct((C,), jnp.float32),
        best_idx=jax.ShapeDtypeStruct((C, n), jnp.int32),
        best_pos=jax.ShapeDtypeStruct((C, n), jnp.int32),
        accepts=jax.ShapeDtypeStruct((C,), jnp.int32),
        # S-sharded cached consistency planes (ISSUE 4): plane words live
        # with their table shard and never cross ICI
        mask_planes=jax.ShapeDtypeStruct((C, n, Pn, W), jnp.uint32),
        win_idx=jax.ShapeDtypeStruct((C,), jnp.int32),
        adapt_err=jax.ShapeDtypeStruct((C,), jnp.float32),
        step=jax.ShapeDtypeStruct((C,), jnp.int32))
    table = jax.ShapeDtypeStruct((n, S_pad), jnp.float32)
    pst = jax.ShapeDtypeStruct((S_pad, s), jnp.int32)
    cm = jax.ShapeDtypeStruct((n - 1, W), jnp.uint32)

    sh = lambda spec: NamedSharding(mesh, spec)
    st_sh = jax.tree.map(lambda _: sh(P(dax)), states)._replace(
        mask_planes=sh(P(dax, None, None, "model")))
    def step(states, table, pst, cm):
        return sharded_chain_step(states, table, pst, mesh, cm, block=block,
                                  window=window)

    t0 = time.time()
    with mesh_context(mesh):
        lowered = jax.jit(step, in_shardings=(
            st_sh, sh(P(None, "model")), sh(P("model", None)),
            sh(P(None, "model")))) \
            .lower(states, table, pst, cm)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hc = analyze_hlo(compiled.as_text(), n_devices=chips)
    rep = roofline_terms(
        arch="bn-60", shape=f"score_n{n}_s{s}", mesh_name=mesh_name,
        chips=chips, flops_per_device=hc.flops, bytes_per_device=hc.bytes,
        coll=hc.coll_breakdown,
        # "useful work" for the scoring kernel = one pass over the table
        model_flops=float(C * n * S),
        peak_memory=float(getattr(mem, "peak_memory_in_bytes", 0) or 0))
    record = rep.as_dict()
    record.update({"ok": True, "mode": "bn_score", "chains": C,
                   "S": S, "S_pad": S_pad, "block": block, "window": window,
                   "mask_planes": [Pn, W],
                   "t_lower_s": t_lower, "t_compile_s": t_compile,
                   "loops": [list(t) for t in hc.loops],
                   "unknown_loops": hc.unknown_loops})
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--bn", action="store_true",
                    help="the paper's own workload (sharded order scoring)")
    ap.add_argument("--opt", action="store_true",
                    help="run the §Perf optimized cells into dryrun_opt/")
    ap.add_argument("--bn-block", type=int, default=4096)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.opt:
        out = os.path.join(os.path.dirname(args.out), "dryrun_opt")
        os.makedirs(out, exist_ok=True)
        failures = 0
        for (arch, shape), ov in OPT_OVERRIDES.items():
            for mp in (False, True):
                mesh_name = "2x16x16" if mp else "16x16"
                chips = 512 if mp else 256
                if ov.get("fsdp_only") and \
                        get_shape(shape).global_batch % chips:
                    # fsdp_only shards the batch over every axis — needs
                    # global_batch % chips == 0; fall back to the gathered
                    # dispatch alone (strategy is scale-dependent)
                    ov = {k: v for k, v in ov.items() if k != "fsdp_only"}
                if not ov:
                    print(f"skip {arch} {shape} {mesh_name} "
                          f"(no applicable override at this scale)")
                    continue
                path = os.path.join(out, f"{arch}__{shape}__{mesh_name}.json")
                if os.path.exists(path) and not args.force:
                    print(f"skip {arch} {shape} {mesh_name} (exists)")
                    continue
                print(f"=== OPT {arch} × {shape} × {mesh_name} {ov}",
                      flush=True)
                try:
                    rec = run_cell(arch, shape, mp, overrides=ov)
                    print(f"    ok: bottleneck {rec['bottleneck']}, "
                          f"t_max {max(rec['t_compute'], rec['t_memory'], rec['t_collective']):.3f}s",
                          flush=True)
                except Exception as e:
                    failures += 1
                    rec = {"ok": False, "arch": arch, "shape": shape,
                           "mesh": mesh_name,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    print(f"    FAIL {type(e).__name__}: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
        if failures:
            raise SystemExit(f"{failures} opt cells failed")
        return

    if args.bn or args.all:
        failures = 0
        for mp in (False, True):
            mesh_name = "2x16x16" if mp else "16x16"
            path = os.path.join(args.out, f"bn-60__score__{mesh_name}.json")
            if os.path.exists(path) and not args.force:
                print(f"skip bn-60 {mesh_name} (exists)")
                continue
            print(f"=== bn-60 × score × {mesh_name}", flush=True)
            try:
                rec = run_bn_cell(mp, block=args.bn_block)
                print(f"    ok: compile {rec['t_compile_s']:.1f}s, "
                      f"bottleneck {rec['bottleneck']}", flush=True)
            except Exception as e:
                failures += 1
                rec = {"ok": False, "arch": "bn-60", "mesh": mesh_name,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()}
                print(f"    FAIL {type(e).__name__}: {e}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
        if args.bn and not args.all:
            if failures:
                raise SystemExit(f"{failures} bn cells failed")
            return

    if args.all:
        todo = [(a, s, mp) for a in ARCH_IDS for s in cells(a)
                for mp in (False, True)]
    else:
        todo = [(args.arch, args.shape, args.multi_pod)]

    failures = 0
    for arch, shape, mp in todo:
        mesh_name = "2x16x16" if mp else "16x16"
        path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
        if os.path.exists(path) and not args.force:
            print(f"skip {arch} {shape} {mesh_name} (exists)")
            continue
        print(f"=== {arch} × {shape} × {mesh_name}", flush=True)
        try:
            rec = run_cell(arch, shape, mp)
            print(f"    ok: compile {rec['t_compile_s']:.1f}s, "
                  f"peak {rec['peak_memory_bytes']/2**30:.2f} GiB/dev, "
                  f"bottleneck {rec['bottleneck']}", flush=True)
        except Exception as e:
            failures += 1
            rec = {"ok": False, "arch": arch, "shape": shape,
                   "mesh": mesh_name, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()}
            print(f"    FAIL {type(e).__name__}: {e}", flush=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
