"""Batched LM serving driver: prefill + greedy decode loop with a KV cache.

This module serves the LANGUAGE-MODEL side of the repo only (the sequence
architectures under ``repro.models``). It does NOT serve Bayesian-network
structure learning — for the long-running BN posterior service (job
admission, multi-dataset fleet scheduling, posterior/MAP/consensus queries
over HTTP) use ``repro.launch.bn_serve``; for offline artifact queries use
``repro.launch.bn_query``.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \
        --batch 4 --prompt-len 32 --gen 16

Continuous-batching shape: all requests share the step; finished requests are
masked (greedy argmax keeps emitting pad, which is dropped on detokenize).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..models import Model
from ..models.layers import set_mesh
from .mesh import make_local_mesh, make_production_mesh


def greedy_generate(model: Model, params, prompts: jnp.ndarray, gen: int,
                    *, enc_feats=None, cache_len: int | None = None):
    """prompts: (B, T0) -> (B, T0+gen) tokens, greedy."""
    B, T0 = prompts.shape
    cache_len = cache_len or (T0 + gen + 8)
    cache = model.init_cache(B, cache_len)
    prefill = jax.jit(lambda p, t, c: model.prefill(p, t, c,
                                                    enc_feats=enc_feats))
    decode = jax.jit(model.decode_step)

    logits, cache = prefill(params, prompts, cache)
    out = [prompts]
    tok = jnp.argmax(logits[:, -1:, :model.cfg.vocab], axis=-1).astype(jnp.int32)
    for _ in range(gen):
        out.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:, :model.cfg.vocab], axis=-1).astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="rwkv6-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh(1, 1))
    set_mesh(mesh)
    model = Model(cfg, tp=mesh.shape["model"])
    params = model.init(jax.random.key(args.seed))
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    enc = (jax.random.normal(jax.random.key(2),
                             (args.batch, args.prompt_len // cfg.enc_seq_divisor,
                              cfg.d_model))
           if cfg.family == "encdec" else None)

    t0 = time.time()
    toks = greedy_generate(model, params, prompts, args.gen, enc_feats=enc)
    dt = time.time() - t0
    toks = np.asarray(toks)
    print(f"generated {args.gen} tokens × {args.batch} requests "
          f"in {dt:.2f}s ({args.gen*args.batch/dt:.1f} tok/s)")
    print("sample:", toks[0, -args.gen:].tolist())
    set_mesh(None)
    assert toks.shape == (args.batch, args.prompt_len + args.gen)
    assert (toks >= 0).all() and (toks < cfg.vocab).all()
    return {"tokens": toks, "seconds": dt}


if __name__ == "__main__":
    main()
