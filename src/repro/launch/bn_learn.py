"""End-to-end Bayesian-network structure learning driver (the paper's full
pipeline, Fig. 2): preprocess → multi-chain order-MCMC → best-graph exchange.

Usage (also the library entry point used by examples/ and benchmarks/):

  python -m repro.launch.bn_learn --network alarm --iters 2000 --chains 4
  python -m repro.launch.bn_learn --network synth --n 64 --s 3 \
      --preprocess fused --prune-delta 30        # fused pipeline + compression

--preprocess fused routes score-table construction through preprocess/
(count-once-per-subset + LUT scoring, ~20x the reference loop at n = 64 on
CPU) with a disk cache (--cache-dir) so repeat runs skip the stage entirely;
--prune-delta > 0 additionally hash-compresses the table to per-node score
lists, and the MCMC hot path switches to the O(n*K) pruned scorer. Above
S >= AUTO_PRUNE_S parent sets per node the fused path makes that pruned
engine the DEFAULT (delta = AUTO_PRUNE_DELTA, built streamingly with no
dense (n, S) intermediate — preprocess/streaming.py); --no-auto-prune
reverts to the dense build. That switch is what takes the driver to the
n = 100, s = 4 scale.

The per-iteration engine (ISSUE 3) defaults to the bitmask-cached delta path
on dense tables (cached consistency planes in ChainState, patched with word
ops per proposal — --no-mask-cache reverts to the gather+compare delta);
--adapt-window tunes the move window from the accept rate over a static
power-of-two set and freezes it after --burn-in; --exchange-every N runs the
cross-chain best→worst re-seed INSIDE the scan instead of only at the end.

Chains are embarrassingly parallel (DP over the data/pod mesh axes at scale,
vmap locally); the best-graph exchange at the end is the same max+argmax
reduction the scoring kernel uses, one level up. Periodic checkpointing makes
the walk restartable — a killed worker re-joins from the last snapshot (new
ChainState leaves are backfilled when restoring a pre-bitmask snapshot, and
the consistency planes are rebuilt from the restored positions; telemetry
trace leaves append after the ChainState leaves and backfill the same way).

--telemetry (ISSUE 7) threads the repro.telemetry subsystem through every
run loop: in-scan accelerator-resident taps (score/accept rings, window
histogram, thinned posterior edge counts) carried beside ChainState through
the shared segmented runner, and a host-side collector between segments
computing split-R̂ over the chain score traces and max-R̂ over cross-chain
edge marginals, appended as schema-versioned JSONL under --trace-dir.
--stop-on-converge turns the R̂ pair into an early-stopping rule (both below
--rhat-threshold for --patience consecutive checks), so long runs stop on
convergence rather than on the iteration cap.

--supervise (ISSUE 8) hands the segmented host loop — single-device,
adaptive AND sharded — to the fault-tolerant run supervisor
(runtime/supervisor.py): restores go through digest-verified checkpoints
(corrupt steps are quarantined, the run falls back to the newest step that
verifies), and between segments the supervisor folds the collector's
stuck/diverged flags plus its own NaN/inf + progress guards into
telemetry-driven chain healing (straggler cloning from the best finite
chain, planes/caches/trace leaves re-seeded together, one ``heal`` JSONL
row per event). --fault-plan injects deterministic chaos (crashes around
checkpoint writes, checkpoint/cache corruption, chain poisoning/stalls —
grammar in runtime/faults.py) so the recovery machinery is testable:
``make chaos-smoke`` asserts a crash-injected run resumes and finishes
bitwise-identical to an uninterrupted one.
"""
from __future__ import annotations

import argparse
import functools
import time
from dataclasses import asdict, dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (adjacency_from_ranks, build_score_table, mcmc_run,
                    random_cpts, roc_point)
from ..core.metrics import consensus_graph, edge_posterior, map_dag
from ..core.combinatorics import n_parent_sets
from ..core.mcmc import (BitmaskDelta, ChainState, exchange_best, init_chain,
                         make_traced_segment_runner, mcmc_run_adaptive,
                         mcmc_run_chains, mcmc_run_chains_adaptive, mcmc_step,
                         mcmc_step_adaptive)
from ..core.order_scoring import (build_membership_planes,
                                  build_violation_planes, delta_window,
                                  score_order_blocked, score_order_delta,
                                  score_order_delta_bitmask,
                                  score_order_pruned,
                                  score_order_pruned_delta,
                                  score_order_sum_cached,
                                  score_order_sum_delta)
from ..data.bn_sampler import ancestral_sample, inject_noise
from ..data.networks import (alarm_adjacency, stn_adjacency,
                             synthetic_adjacency)
from ..preprocess import SparseScoreTable, build_score_table_fused
from ..runtime.faults import parse_fault_plan
from ..runtime.supervisor import (N_STATE_LEAVES, RunSupervisor, pack_tree,
                                  unpack_tree)

__all__ = ["LearnConfig", "learn_structure", "make_score_fn",
           "make_delta_fn", "make_engine_closures", "prepare_run",
           "adaptive_window_set", "reconcile_mask_planes",
           "main", "AUTO_PRUNE_S", "AUTO_PRUNE_DELTA"]

# Above this many parent sets per node, the fused path defaults to the
# streaming-pruned engine (preprocess/streaming.py + the O(n*K) pruned
# scorers): the dense (n, S) table at S = 200k, n = 100 is ~80 MB and the
# (n, S) rank map doubles it, while the pruned table is a few MB — and at
# the n = 100, s = 4 gate (S ~ 3.9M) dense assembly is simply out of reach.
AUTO_PRUNE_S = 200_000
# Default pruning delta for the auto-switch. Kept wide (natural-log units):
# parent sets more than 20 nats below a node's per-node best contribute
# nothing to the max-scorer walk in practice, so the exactness condition
# (dense argmax survives pruning) holds at equilibrium.
AUTO_PRUNE_DELTA = 20.0


@dataclass
class LearnConfig:
    q: int = 2                    # states per variable
    s: int = 4                    # max parent-set size (paper uses 4)
    gamma: float = 0.1            # structure penalty
    ess: float = 1.0              # BDeu equivalent sample size
    iters: int = 1000
    chains: int = 1
    seed: int = 0
    block: int = 4096             # score-table streaming block
    use_kernel: bool = False      # Pallas kernel (interpret=True on CPU)
    scorer: str = "max"           # "max" (paper Eq. 6) | "sum" (baseline [5])
    window: int = 8               # bounded-move window; delta rescoring when
                                  # 2 <= window <= DELTA_CROSSOVER*n (0 = off)
    mask_cache: bool = True       # cached consistency bitmasks on the dense
                                  # delta paths (blocked + kernel)
    adapt_window: bool = False    # adaptive window set + burn-in freeze
    burn_in: int = 0              # adaptation horizon (0 = iters // 5)
    exchange_every: int = 0       # in-scan cross-chain exchange period (0 =
                                  # end-only reduction)
    checkpoint_every: int = 0     # 0 = off
    checkpoint_dir: str = ""
    sharded: bool = False         # run the MCMC on the sharded mesh path:
                                  # chains DP over 'data', score table +
                                  # cached consistency planes TP over 'model'
    sharded_tp: int = 0           # model-axis extent (0 = all devices)
    preprocess: str = "reference"  # "reference" (core/scores host loop) |
                                   # "fused" (preprocess/ pipeline)
    prune_delta: float = 0.0      # > 0: hash-compress the table, keeping per
                                  # node only parent sets within this delta
                                  # of its best (fused pipeline only)
    auto_prune: bool = True       # fused path: switch to the streaming
                                  # pruned engine (delta=AUTO_PRUNE_DELTA)
                                  # when S >= AUTO_PRUNE_S and the run is
                                  # compatible (max scorer, not sharded)
    cache_dir: str = ""           # preprocessing disk cache ("" = off)
    # --- convergence telemetry (repro.telemetry; ISSUE 7) ----------------
    telemetry: bool = False       # in-scan taps + host collector + JSONL
    trace_every: int = 8          # tap cadence (iterations per ring write)
    check_every: int = 0          # collector check period (0 = auto:
                                  # max(64, 16 * trace_every); checkpointed
                                  # runs check at checkpoint boundaries)
    stop_on_converge: bool = False  # R̂ early stopping (implies telemetry)
    emit_consensus: bool = False  # materialize posterior artifacts in the
                                  # result dict — edge-probability matrix,
                                  # MAP DAG, thresholded consensus graph —
                                  # from the telemetry edge accumulator
                                  # (implies telemetry; the same artifacts
                                  # the service query layer serves)
    consensus_threshold: float = 0.5  # edge-posterior cut for the consensus
    rhat_threshold: float = 1.05  # both R̂s must drop below this ...
    patience: int = 3             # ... for this many consecutive checks
    trace_dir: str = "experiments/runs"  # JSONL trace directory
    run_name: str = ""            # trace file stem ("" = timestamped)
    # --- fault-tolerant run supervisor (runtime/supervisor; ISSUE 8) -----
    supervise: bool = False       # telemetry-driven chain healing between
                                  # segments (NaN/inf + progress guards,
                                  # collector stuck/diverged flags)
    fault_plan: str = ""          # deterministic chaos spec (grammar in
                                  # runtime/faults.py), e.g.
                                  # "corrupt@1:bitflip;crash@1:after"
    heal_patience: int = 1        # consecutive unhealthy checks before a
                                  # chain is healed (1 = next boundary)


def _padded(st, block: int):
    """(table, pst, block) with S padded to a multiple of block — shared by
    the full and delta closures so both see identical blocks. block is
    rounded up to a multiple of 32 so the packed consistency-mask words of
    the bitmask cache line up with the same block structure."""
    from ..core.sharded_scoring import pad_table
    block = min(block, st.table.shape[1])
    block = block + (-block) % 32
    table, pst = pad_table(st.table, st.pst, block)
    return table, pst, block


def adaptive_window_set(n: int) -> tuple[int, ...]:
    """Static candidate windows for --adapt-window: powers of two from 2 up
    to the delta-crossover cap (each pre-traced as its own lax.switch
    branch, so the set must stay small)."""
    ws, w = [], 2
    while delta_window(n, w) == w:
        ws.append(w)
        w *= 2
    return tuple(ws) or (2,)


def make_score_fn(st, cfg: LearnConfig):
    """(pos) -> (score, best_idx, best_ls) closure over either table
    representation: dense ScoreTable (blocked/kernel scorers) or
    preprocess.SparseScoreTable (packed pruned scorer, O(n*K))."""
    if isinstance(st, SparseScoreTable):
        if cfg.scorer == "sum":
            raise ValueError(
                "the sum (logsumexp) baseline scorer needs the dense table: "
                "run without --prune-delta (pruned entries would silently "
                "drop out of the logsumexp)")
        return functools.partial(score_order_pruned, st.kept_ls,
                                 st.kept_parents, st.kept_idx)
    if cfg.scorer == "sum":
        # the Linderman et al. [5] baseline the paper improves on (§III-B);
        # the _cached variant's third output is the per-node logsumexp, so
        # the sampler's cur_ls cache feeds score_order_sum_delta
        return functools.partial(score_order_sum_cached, st.table, st.pst)
    if cfg.use_kernel:
        from ..kernels.order_score import order_score
        return functools.partial(order_score, st.table, st.pst)
    table, pst, block = _padded(st, cfg.block)
    return functools.partial(score_order_blocked, table, pst, block=block)


def _delta_context(st, cfg: LearnConfig):
    """(kind, tables, cm, planes_fn) — the WINDOW-INDEPENDENT state shared
    by every per-window delta closure (built once, even when the adaptive
    path needs one closure per candidate window): padded tables, membership
    planes, and the chain-cache builder. planes_fn is non-None exactly when
    the closures will be BitmaskDeltas."""
    if isinstance(st, SparseScoreTable):
        return "sparse", (st.kept_ls, st.kept_parents, st.kept_idx), None, None
    if cfg.scorer == "sum":
        return "sum", (st.table, st.pst), None, None
    if cfg.use_kernel:
        from ..kernels.order_score.ops import pad_for_kernel

        # pre-pad once so the per-iteration call's pad is a no-op (the
        # blocked path hoists its padding the same way via _padded)
        ktable, kpst = pad_for_kernel(st.table, st.pst, 2048)
        if cfg.mask_cache:
            return "kernel", (ktable, kpst), \
                build_membership_planes(kpst, ktable.shape[0]), \
                functools.partial(build_violation_planes, kpst)
        return "kernel", (ktable, kpst), None, None
    table, pst, block = _padded(st, cfg.block)
    if cfg.mask_cache:
        return "blocked", (table, pst, block), \
            build_membership_planes(pst, table.shape[0]), \
            functools.partial(build_violation_planes, pst)
    return "blocked", (table, pst, block), None, None


def _delta_for_window(ctx, w: int):
    """Delta closure for one STATIC window w ≥ 2 over a shared
    :func:`_delta_context` — the per-window factory behind make_delta_fn and
    the adaptive window set."""
    kind, tables, cm, planes_fn = ctx
    if kind == "sparse":
        def sfn(pos, lo, prev_ls, prev_idx):
            return score_order_pruned_delta(*tables, pos, prev_ls, prev_idx,
                                            lo, window=w)
        return sfn
    if kind == "sum":
        table, pst = tables

        def lfn(pos, lo, prev_ls, prev_idx):
            return score_order_sum_delta(table, pst, pos, prev_ls, prev_idx,
                                         lo, window=w)
        return lfn
    if kind == "kernel":
        from ..kernels.order_score import (order_score_delta,
                                           order_score_delta_bitmask)

        ktable, kpst = tables
        if cm is not None:
            def kbfn(pos, lo, prev_ls, prev_idx, pos_old, planes):
                return order_score_delta_bitmask(ktable, cm, pos, prev_ls,
                                                 prev_idx, lo, pos_old,
                                                 planes, window=w)
            return BitmaskDelta(kbfn)

        def kfn(pos, lo, prev_ls, prev_idx):
            return order_score_delta(ktable, kpst, pos, prev_ls,
                                     prev_idx, lo, window=w)
        return kfn
    table, pst, block = tables
    if cm is not None:
        def bfn(pos, lo, prev_ls, prev_idx, pos_old, planes):
            return score_order_delta_bitmask(table, cm, pos, prev_ls,
                                             prev_idx, lo, pos_old, planes,
                                             window=w, block=block)
        return BitmaskDelta(bfn)

    def fn(pos, lo, prev_ls, prev_idx):
        return score_order_delta(table, pst, pos, prev_ls, prev_idx, lo,
                                 window=w, block=block)
    return fn


def make_delta_fn(st, cfg: LearnConfig):
    """(window, delta_fn, planes_fn) for the incremental per-iteration path,
    or (0, None, None) when the crossover heuristic rejects the window.
    delta_fn is a BitmaskDelta (and planes_fn builds the chain's cached
    consistency planes) on the dense max paths when cfg.mask_cache."""
    n = st.n if isinstance(st, SparseScoreTable) else st.table.shape[0]
    w = delta_window(n, cfg.window)
    if not w:
        return 0, None, None
    ctx = _delta_context(st, cfg)
    return w, _delta_for_window(ctx, w), ctx[3]


def reconcile_mask_planes(states: ChainState, planes_fn) -> ChainState:
    """Checkpoint interop across engine variants (ISSUE 4 bugfix): the
    ``mask_planes`` leaf is a DERIVED cache, and snapshots written by
    different engines disagree about its shape — sharded runs snapshot the
    zero-size placeholder, single-device bitmask runs may carry full
    (n, P, S/32) planes built under another padding, and pre-bitmask layouts
    have no leaf at all (backfilled by the checkpointer's ``allow_missing``,
    which covers MISSING leaves only, never wrong-shaped ones). Instead of
    letting a wrong-shaped restored leaf shape-mismatch the first jitted
    step, ALWAYS rebuild the cache from the restored positions when this
    engine uses it (``planes_fn``: stacked (C, n) pos -> (C, n, P, W)
    planes), and reset it to the placeholder when it doesn't."""
    if planes_fn is not None:
        return states._replace(mask_planes=planes_fn(states.pos))
    return states._replace(
        mask_planes=jnp.zeros((states.pos.shape[0], 0), jnp.uint32))


def _auto_check_every(cfg: LearnConfig) -> int:
    """Collector check period for non-checkpointed telemetry runs: frequent
    enough that --stop-on-converge reacts soon after mixing, coarse enough
    that each segment accumulates a meaningful number of taps (≥ 16 at the
    default --trace-every 8) and segment re-entry cost stays negligible."""
    return cfg.check_every or max(64, 16 * cfg.trace_every)


# checkpoint tree layout now lives with the run supervisor
# (runtime/supervisor.py); aliases kept for callers of the old names
_N_STATE_LEAVES = N_STATE_LEAVES
_pack_tree = pack_tree
_unpack_tree = unpack_tree


def _make_pack_unpack(n_chains: int):
    """Checkpoint (de)serialisation closures shared by every segmented
    driver: typed PRNG keys are not numpy-serializable, so the key leaf is
    snapshot as key data; the consistency planes are a pos-derived cache —
    snapshot a zero-size stand-in and rebuild after restore (smaller
    checkpoints, and pre-tentpole snapshots restore through the same
    path)."""
    dummy_planes = jnp.zeros((n_chains, 0), jnp.uint32)
    pack = lambda s: jax.tree.map(
        np.asarray, s._replace(key=jax.random.key_data(s.key),
                               mask_planes=dummy_planes))
    unpack = lambda t: ChainState(*t)._replace(
        key=jax.random.wrap_key_data(jnp.asarray(t[0])))
    return pack, unpack


def _make_supervisor(cfg: LearnConfig, seg: int, collector,
                     stacked_planes_fn) -> RunSupervisor:
    """One RunSupervisor per run, shared config plumbing for the
    single-device and sharded drivers."""
    pack, unpack = _make_pack_unpack(cfg.chains)
    faults = (parse_fault_plan(cfg.fault_plan, seed=cfg.seed)
              if cfg.fault_plan else None)
    return RunSupervisor(
        iters=cfg.iters, seg=seg, chains=cfg.chains,
        checkpoint_dir=cfg.checkpoint_dir,
        checkpoint_every=cfg.checkpoint_every,
        collector=collector, stop_on_converge=cfg.stop_on_converge,
        faults=faults, heal=cfg.supervise, heal_patience=cfg.heal_patience,
        seed=cfg.seed, planes_fn=stacked_planes_fn, cache_dir=cfg.cache_dir,
        pack=pack, unpack=unpack)


def _run_sharded(st, cfg: LearnConfig, key, n: int, collector=None):
    """The production-mesh MCMC path (--sharded): every iteration is ONE
    shard_map program (core/sharded_scoring.sharded_chain_step) — chains DP
    over 'data', score table + cached consistency planes TP over 'model';
    per iteration only the (window,) pmax/pmin pair crosses ICI.

    With ``collector`` (telemetry on) the walk is cut into check_every-sized
    segments carrying a TraceState beside the chain stack; the taps read
    only per-chain quantities that the engine's own pmax/pmin reduction
    already replicated, so telemetry adds ZERO collective traffic over the
    model axis — the collector drains between segments and may stop the run
    early. The host loop (verified restore, chaos injection, chain healing)
    is the shared RunSupervisor — the sharded engine gets the same fault
    tolerance as the single-device ones.
    Returns (states, delta_window, mask_on, iters_run, stopped, heals,
    trace)."""
    from ..core.sharded_scoring import (_shard_block, make_sharded_planes_fn,
                                        pad_table, score_order_sharded,
                                        sharded_chain_step)
    from ..runtime.jax_compat import make_auto_mesh, mesh_context

    if isinstance(st, SparseScoreTable):
        raise ValueError(
            "--sharded needs the dense (n, S) table: the pruned "
            "representation is already O(n*K) per device (drop --prune-delta)")
    if cfg.scorer == "sum":
        raise ValueError("--sharded supports the max scorer (paper Eq. 6) "
                         "only")
    if cfg.adapt_window:
        raise ValueError("--sharded does not compose with --adapt-window "
                         "yet: per-window delta closures would each need "
                         "their own shard_map branch")
    ndev = jax.device_count()
    tp = cfg.sharded_tp or ndev
    if ndev % tp:
        raise ValueError(f"--sharded-tp {tp} does not divide the "
                         f"{ndev}-device platform")
    dp = ndev // tp
    if cfg.chains % dp:
        raise ValueError(f"--chains {cfg.chains} must be divisible by the "
                         f"data-axis extent {dp}")
    mesh = make_auto_mesh((dp, tp), ("data", "model"))
    block = _shard_block(st.table.shape[1], tp, cfg.block)
    table, pst = pad_table(st.table, st.pst, tp * block)
    w = delta_window(n, cfg.window)
    mask_on = bool(w) and cfg.mask_cache
    cm = build_membership_planes(pst, n) if mask_on else None
    splanes_fn = (make_sharded_planes_fn(pst, mesh, stacked=True)
                  if mask_on else None)

    def score_fn(pos):
        return score_order_sharded(table, pst, pos, mesh, block=block)

    exch = cfg.exchange_every if cfg.chains > 1 else 0
    telem = collector is not None
    trace = tap = exchange = None
    if telem:
        from ..telemetry import exchange_step_traced, init_trace, make_tap
        trace = init_trace(cfg.chains, n)
        tap = make_tap(n, cfg.s, cfg.trace_every)
        exchange = exchange_step_traced

    def step(stt):
        return sharded_chain_step(stt, table, pst, mesh, cm, block=block,
                                  window=cfg.window,
                                  use_kernel=cfg.use_kernel)

    run_segment = make_traced_segment_runner(step, tap=tap, exchange=exchange,
                                             exchange_every=exch,
                                             stacked_step=True)

    checkpointed = bool(cfg.checkpoint_every and cfg.checkpoint_dir)
    seg = cfg.checkpoint_every if checkpointed else \
        (_auto_check_every(cfg) if telem or cfg.supervise or cfg.fault_plan
         else cfg.iters)
    with mesh_context(mesh):
        keys = jax.random.split(key, cfg.chains)
        states = jax.vmap(lambda k: init_chain(k, n, score_fn))(keys)
        if mask_on:
            # per-shard plane build: each device packs its own S-shard words
            states = states._replace(mask_planes=splanes_fn(states.pos))
        sup = _make_supervisor(cfg, seg, collector,
                               splanes_fn if mask_on else None)
        res = sup.run(run_segment, states, trace)
        states = res.states
        jax.block_until_ready(states.best_score)
    return (states, w, mask_on, res.iters_run, res.stopped, res.heals,
            res.trace)


def _build_segmented(st, cfg: LearnConfig, key, n: int, score_fn, window,
                     delta_fn, planes_fn, adaptive_ws, delta_fns, burn_in,
                     collector):
    """Construct (but do not drive) the segmented single-device engine:
    vmapped chain init, the jitted traced segment runner, and the armed
    RunSupervisor. Shared by :func:`_run_segmented` (one-shot CLI) and the
    posterior service's job manager (service/jobs.py) — both drive the SAME
    supervisor object, so a service job interleaved with other jobs walks
    through bitwise-identical segment boundaries to a standalone run.

    Returns the RunSupervisor, armed via ``begin`` (drive with ``advance``
    until ``finished``, then read ``result()``)."""
    telem = collector is not None
    checkpointed = bool(cfg.checkpoint_every and cfg.checkpoint_dir)
    C = cfg.chains
    keys = jax.random.split(key, C)
    wi0 = len(adaptive_ws) // 2 if adaptive_ws else 0
    states = jax.vmap(lambda k: init_chain(k, n, score_fn,
                                           planes_fn=planes_fn,
                                           win_idx=wi0))(keys)
    if adaptive_ws:
        # valid across segments: win_idx/adapt_err/step are ChainState
        # leaves, so the dual-averaging iterate and the burn-in freeze use
        # GLOBAL step counts no matter where segment boundaries fall
        step = lambda s: mcmc_step_adaptive(s, score_fn, delta_fns,
                                            adaptive_ws, burn_in=burn_in)
    else:
        step = lambda s: mcmc_step(s, score_fn, delta_fn, window)
    exch = cfg.exchange_every if C > 1 else 0
    trace = tap = exchange = None
    if telem:
        from ..telemetry import exchange_step_traced, init_trace, make_tap
        trace = init_trace(C, n, n_windows=max(len(adaptive_ws), 1))
        tap = make_tap(n, cfg.s, cfg.trace_every)
        exchange = exchange_step_traced
    run_segment = make_traced_segment_runner(step, tap=tap, exchange=exchange,
                                             exchange_every=exch)
    seg = cfg.checkpoint_every if checkpointed else _auto_check_every(cfg)

    sup = _make_supervisor(
        cfg, seg, collector,
        (jax.vmap(planes_fn) if planes_fn is not None else None))
    return sup.begin(run_segment, states, trace)


def _run_segmented(st, cfg: LearnConfig, key, n: int, score_fn, window,
                   delta_fn, planes_fn, adaptive_ws, delta_fns, burn_in,
                   collector):
    """Unified segmented driver for the single-device engines: used whenever
    the run is checkpointed, telemetry is on, or the run is supervised (the
    reasons the host must see the walk at sub-run granularity). One jitted
    segment runner carries (ChainState, TraceState) through the scan; the
    host loop between segments — verified restore, checkpoint snapshots,
    collector checks / early stop, chaos injection and chain healing — is
    the shared RunSupervisor (runtime/supervisor.py).

    Returns (stacked states, iters_run, stopped_early, heals, trace)."""
    sup = _build_segmented(st, cfg, key, n, score_fn, window, delta_fn,
                           planes_fn, adaptive_ws, delta_fns, burn_in,
                           collector)
    while sup.advance():
        pass
    res = sup.result()
    return res.states, res.iters_run, res.stopped, res.heals, res.trace


def _finish(cfg: LearnConfig, st, states, best_score, best_idx, *, window,
            adaptive_ws, mask_on, sharded, t_pre, cache_hit, auto_pruned,
            t_iter, iters_run, stopped, collector, heals=(), trace=None,
            best_pos=None) -> dict:
    """Common run epilogue: adjacency decode, per-chain statistics, the
    result dict, and — with telemetry on — the final trace row. ``states``
    may be a single un-stacked ChainState (chains == 1 fast paths) or the
    stacked multi-chain state; per-chain stats use atleast_1d either way."""
    adj = adjacency_from_ranks(np.asarray(best_idx), s=cfg.s)
    acc = np.atleast_1d(np.asarray(states.accepts))
    chain_rates = [float(a) / max(iters_run, 1) for a in acc]
    if adaptive_ws:
        wi = np.atleast_1d(np.asarray(states.win_idx))
        win_hist = np.bincount(np.clip(wi, 0, len(adaptive_ws) - 1),
                               minlength=len(adaptive_ws)).tolist()
    else:
        win_hist = []
    exch = cfg.exchange_every if cfg.chains > 1 else 0
    out = {
        "adjacency": adj,
        "delta_window": window,       # 0 = full rescore every iteration
        "adaptive_windows": list(adaptive_ws),
        "mask_cache": mask_on,
        "sharded": sharded,
        "exchange_every": cfg.exchange_every,
        "exchange_count": (iters_run // exch) if exch else 0,
        "score": float(best_score),
        "preprocess_s": t_pre,
        "preprocess_cache_hit": cache_hit,
        "auto_pruned": auto_pruned,
        "iteration_s": t_iter,
        "per_iteration_s": t_iter / max(iters_run, 1),
        "accept_rate": float(acc.sum()) / max(iters_run * max(cfg.chains, 1),
                                              1),
        "chain_accept_rates": chain_rates,
        "window_hist": win_hist,      # final per-chain win_idx histogram
        "iters_run": iters_run,
        "stopped_early": stopped,
        "S": st.S,
        "heals": list(heals),         # supervisor chain-healing events
        "telemetry": None,
    }
    if cfg.emit_consensus and trace is not None:
        # the service query layer's posterior artifacts, materialized here
        # for parity: standalone --emit-consensus answers must be bitwise
        # equal to what bn_serve returns for the same (data, config, seed)
        from ..telemetry import drain
        snap = drain(trace)
        probs = edge_posterior(snap["edge_counts"], snap["edge_taps"])
        out["edge_posterior"] = probs
        out["edge_samples"] = int(snap["edge_taps"])
        out["consensus"] = consensus_graph(probs, cfg.consensus_threshold)
        out["map_dag"] = (map_dag(st, np.asarray(best_pos))
                          if best_pos is not None else adj)
    if collector is not None:
        collector.finalize(iters_run=iters_run, stopped_early=stopped,
                           best_score=float(best_score))
        out["telemetry"] = {
            "run": collector.run,
            "trace_path": collector.path,
            "score_rhat": collector.last.get("score_rhat", float("nan")),
            "edge_rhat": collector.last.get("edge_rhat", float("nan")),
            "converged": collector.last.get("converged", False),
            "reseeds": collector.last.get("reseeds", []),
        }
    return out


def make_engine_closures(st, cfg: LearnConfig, n: int):
    """Every closure the single-device engines need, shared by
    :func:`learn_structure` and the service job manager: (score_fn, window,
    delta_fn, planes_fn, adaptive_ws, delta_fns, burn_in, mask_on)."""
    score_fn = make_score_fn(st, cfg)
    checkpointed = bool(cfg.checkpoint_every and cfg.checkpoint_dir)
    adaptive_ws: tuple[int, ...] = ()
    delta_fns: tuple = ()
    burn_in = 0
    if cfg.adapt_window:
        if checkpointed:
            raise ValueError("--adapt-window does not compose with "
                             "checkpointing yet: the dual-averaging state "
                             "would restart each segment, breaking the "
                             "burn-in freeze contract")
        adaptive_ws = adaptive_window_set(n)
        ctx = _delta_context(st, cfg)        # shared: pads/planes built ONCE
        delta_fns = tuple(_delta_for_window(ctx, w) for w in adaptive_ws)
        window, delta_fn, planes_fn = 0, None, ctx[3]
        burn_in = cfg.burn_in or cfg.iters // 5
    else:
        window, delta_fn, planes_fn = make_delta_fn(st, cfg)
    mask_on = isinstance(delta_fn, BitmaskDelta) or \
        (cfg.adapt_window and planes_fn is not None)
    return (score_fn, window, delta_fn, planes_fn, adaptive_ws, delta_fns,
            burn_in, mask_on)


def prepare_run(data: np.ndarray, cfg: LearnConfig, *,
                prior_matrix: np.ndarray | None = None):
    """The preprocess + telemetry half of the pipeline, shared by
    :func:`learn_structure` and the posterior service's job manager
    (service/jobs.py): builds the score table (reference or fused pipeline,
    auto-prune switch, disk cache) and the telemetry collector.

    Returns (st, collector, pre) with pre = {"t_pre", "cache_hit",
    "auto_pruned"}."""
    n = data.shape[1]
    telem = cfg.telemetry or cfg.stop_on_converge or cfg.emit_consensus
    collector = None
    if telem:
        from ..telemetry import Collector
        collector = Collector(cfg.trace_dir, run_name=cfg.run_name,
                              rhat_threshold=cfg.rhat_threshold,
                              patience=cfg.patience,
                              trace_every=cfg.trace_every)
        collector.start(config={**asdict(cfg), "n": n,
                                "m": int(data.shape[0])})
    t0 = time.time()
    cache_hit = False
    prune_delta = cfg.prune_delta if cfg.prune_delta > 0 else None
    auto_pruned = False
    if (cfg.preprocess == "fused" and prune_delta is None and cfg.auto_prune
            and not cfg.sharded and cfg.scorer == "max"
            and n_parent_sets(n - 1, cfg.s) >= AUTO_PRUNE_S):
        # default engine above the size threshold: streaming-pruned table +
        # O(n*K) pruned scorers — the dense (n, S) build is the memory wall
        prune_delta = AUTO_PRUNE_DELTA
        auto_pruned = True
    if cfg.preprocess == "fused":
        st, pre_info = build_score_table_fused(
            data, q=cfg.q, s=cfg.s, gamma=cfg.gamma, ess=cfg.ess,
            prior_matrix=prior_matrix, prune_delta=prune_delta,
            cache_dir=cfg.cache_dir or None, return_info=True)
        cache_hit = pre_info["cache_hit"]
    else:
        st = build_score_table(data, q=cfg.q, s=cfg.s, gamma=cfg.gamma,
                               ess=cfg.ess, prior_matrix=prior_matrix)
    jax.block_until_ready(st.kept_ls if isinstance(st, SparseScoreTable)
                          else st.table)
    t_pre = time.time() - t0
    if collector is not None:
        stages = (pre_info.get("stages", {})
                  if cfg.preprocess == "fused" else {})
        collector.stage("preprocess", t_pre, cache_hit=cache_hit,
                        auto_pruned=auto_pruned, **stages)
    return st, collector, {"t_pre": t_pre, "cache_hit": cache_hit,
                           "auto_pruned": auto_pruned}


def learn_structure(data: np.ndarray, cfg: LearnConfig, *,
                    prior_matrix: np.ndarray | None = None) -> dict:
    """Full pipeline. Returns {adjacency, score, preprocess_s, iteration_s,
    per_iteration_s, accept_rate, chain_accept_rates, window_hist,
    exchange_count, iters_run, stopped_early, telemetry, ...}."""
    n = data.shape[1]
    telem = cfg.telemetry or cfg.stop_on_converge or cfg.emit_consensus
    st, collector, pre = prepare_run(data, cfg, prior_matrix=prior_matrix)
    t_pre, cache_hit = pre["t_pre"], pre["cache_hit"]
    auto_pruned = pre["auto_pruned"]

    key = jax.random.key(cfg.seed)

    if cfg.sharded:
        t0 = time.time()
        (states, window, mask_on, iters_run, stopped, heals,
         trace) = _run_sharded(st, cfg, key, n, collector)
        t_iter = time.time() - t0
        best_score, best_idx, best_pos = exchange_best(states)
        return _finish(cfg, st, states, best_score, best_idx, window=window,
                       adaptive_ws=(), mask_on=mask_on, sharded=True,
                       t_pre=t_pre, cache_hit=cache_hit,
                       auto_pruned=auto_pruned, t_iter=t_iter,
                       iters_run=iters_run, stopped=stopped,
                       collector=collector, heals=heals, trace=trace,
                       best_pos=best_pos)

    (score_fn, window, delta_fn, planes_fn, adaptive_ws, delta_fns,
     burn_in, mask_on) = make_engine_closures(st, cfg, n)

    checkpointed = bool(cfg.checkpoint_every and cfg.checkpoint_dir)
    supervised = cfg.supervise or bool(cfg.fault_plan)
    iters_run, stopped = cfg.iters, False
    heals: list = []
    trace = None
    t0 = time.time()
    if not checkpointed and not telem and not supervised:
        # fast paths: the whole walk is ONE jitted program, no segmentation
        if cfg.adapt_window:
            if cfg.chains == 1:
                states, _ = mcmc_run_adaptive(
                    key, n, score_fn, cfg.iters, windows=adaptive_ws,
                    delta_fns=delta_fns, planes_fn=planes_fn,
                    burn_in=burn_in)
            else:
                states = mcmc_run_chains_adaptive(
                    key, cfg.chains, n, score_fn, cfg.iters,
                    windows=adaptive_ws, delta_fns=delta_fns,
                    planes_fn=planes_fn, burn_in=burn_in,
                    exchange_every=cfg.exchange_every)
        elif cfg.chains == 1:
            states, _ = mcmc_run(key, n, score_fn, cfg.iters,
                                 delta_fn=delta_fn, window=window,
                                 planes_fn=planes_fn)
        else:
            states = mcmc_run_chains(key, cfg.chains, n, score_fn, cfg.iters,
                                     delta_fn=delta_fn, window=window,
                                     exchange_every=cfg.exchange_every,
                                     planes_fn=planes_fn)
    else:
        # segmented path: checkpointing, telemetry and/or supervision need
        # the host between scan segments (snapshots, collector checks,
        # early stop, chaos injection, chain healing)
        states, iters_run, stopped, heals, trace = _run_segmented(
            st, cfg, key, n, score_fn, window, delta_fn,
            planes_fn, adaptive_ws, delta_fns, burn_in, collector)
    jax.block_until_ready(states.best_score)
    if np.asarray(states.best_score).ndim:
        best_score, best_idx, best_pos = exchange_best(states)
    else:
        best_score, best_idx = states.best_score, states.best_idx
        best_pos = states.best_pos
    t_iter = time.time() - t0

    # rank-decoded adjacency (Algorithm 2 in reverse): identical to the old
    # PST row lookup, but works from the O(n*K) pruned representation too
    return _finish(cfg, st, states, best_score, best_idx, window=window,
                   adaptive_ws=adaptive_ws, mask_on=mask_on, sharded=False,
                   t_pre=t_pre, cache_hit=cache_hit, auto_pruned=auto_pruned,
                   t_iter=t_iter, iters_run=iters_run, stopped=stopped,
                   collector=collector, heals=heals, trace=trace,
                   best_pos=best_pos)


def _network_data(name: str, m: int, q: int, seed: int, n_synth: int = 64):
    rng = np.random.default_rng(seed)
    if name == "synth":
        # synthetic scale-benchmark network (n defaults to 64 — past the
        # paper's headline n > 60 claim)
        adj = synthetic_adjacency(rng, n_synth)
    else:
        adj = {"alarm": alarm_adjacency, "stn": stn_adjacency}[name]()
    cpts = random_cpts(rng, adj, q)
    return adj, ancestral_sample(rng, adj, cpts, m, q)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--network", default="alarm",
                    choices=["alarm", "stn", "synth"])
    ap.add_argument("--n", type=int, default=64,
                    help="node count for --network synth")
    ap.add_argument("--samples", type=int, default=1000)
    ap.add_argument("--iters", type=int, default=1000)
    ap.add_argument("--chains", type=int, default=1)
    ap.add_argument("--q", type=int, default=2)
    ap.add_argument("--s", type=int, default=4)
    ap.add_argument("--noise", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--window", type=int, default=8,
                    help="bounded-move window for delta rescoring (0 = full)")
    ap.add_argument("--no-mask-cache", action="store_true",
                    help="disable the cached consistency bitmasks on the "
                         "dense delta paths (debug / A-B timing)")
    ap.add_argument("--adapt-window", action="store_true",
                    help="tune the move window from the running accept rate "
                         "over a static power-of-two set; frozen after "
                         "--burn-in iterations (MCMC validity)")
    ap.add_argument("--burn-in", type=int, default=0,
                    help="adaptation horizon for --adapt-window "
                         "(0 = iters // 5)")
    ap.add_argument("--sharded", action="store_true",
                    help="run MCMC on the production-mesh path: chains DP "
                         "over 'data', score table + cached consistency "
                         "planes TP over 'model' (one shard_map program per "
                         "iteration)")
    ap.add_argument("--sharded-tp", type=int, default=0,
                    help="model-axis extent for --sharded "
                         "(0 = all visible devices)")
    ap.add_argument("--exchange-every", type=int, default=0,
                    help="> 0: in-scan cross-chain exchange period — the "
                         "best chain re-seeds the worst every this many "
                         "iterations (0 = end-only reduction)")
    ap.add_argument("--preprocess", default="reference",
                    choices=["reference", "fused"],
                    help="score-table construction: core/scores host loop or "
                         "the fused preprocess/ pipeline")
    ap.add_argument("--prune-delta", type=float, default=0.0,
                    help="> 0: hash-compress the score table, keeping per "
                         "node only parent sets within this delta of its "
                         "best (fused preprocessing only)")
    ap.add_argument("--no-auto-prune", action="store_true",
                    help="disable the automatic switch to the streaming "
                         "pruned engine above S >= %d parent sets per node "
                         "(fused preprocessing only)" % AUTO_PRUNE_S)
    ap.add_argument("--cache-dir", default="experiments/score_cache",
                    help="preprocessing disk cache directory ('' disables); "
                         "only consulted with --preprocess fused")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--telemetry", action="store_true",
                    help="convergence telemetry: in-scan chain traces + "
                         "host-side split-R̂/edge-R̂ checks, appended as "
                         "schema-versioned JSONL under --trace-dir")
    ap.add_argument("--trace-every", type=int, default=8,
                    help="telemetry tap cadence in iterations (ring writes "
                         "+ thinned posterior adjacency samples)")
    ap.add_argument("--check-every", type=int, default=0,
                    help="collector check period (0 = auto: max(64, 16 * "
                         "trace_every); checkpointed runs check at "
                         "checkpoint boundaries)")
    ap.add_argument("--stop-on-converge", action="store_true",
                    help="stop early once split-R̂ AND edge-marginal R̂ stay "
                         "below --rhat-threshold for --patience consecutive "
                         "checks (implies --telemetry)")
    ap.add_argument("--rhat-threshold", type=float, default=1.05)
    ap.add_argument("--patience", type=int, default=3)
    ap.add_argument("--emit-consensus", action="store_true",
                    help="materialize the service query layer's posterior "
                         "artifacts in the result: edge-probability matrix "
                         "(core/metrics.edge_posterior over the telemetry "
                         "edge accumulator), MAP DAG under the best order, "
                         "and the thresholded consensus graph (implies "
                         "--telemetry)")
    ap.add_argument("--consensus-threshold", type=float, default=0.5,
                    help="edge-posterior probability cut for the consensus "
                         "graph (in (0, 1])")
    ap.add_argument("--trace-dir", default="experiments/runs",
                    help="JSONL trace directory for --telemetry")
    ap.add_argument("--run-name", default="",
                    help="trace file stem ('' = timestamped)")
    ap.add_argument("--supervise", action="store_true",
                    help="fault-tolerant run supervisor: verified "
                         "checkpoint restore with quarantine/fallback, and "
                         "telemetry-driven chain healing between segments "
                         "(NaN/inf + progress guards, collector "
                         "stuck/diverged flags → straggler cloning)")
    ap.add_argument("--fault-plan", default="",
                    help="deterministic chaos spec fired at segment "
                         "boundaries (grammar in runtime/faults.py), e.g. "
                         "'corrupt@1:bitflip;crash@1:after'")
    ap.add_argument("--heal-patience", type=int, default=1,
                    help="consecutive unhealthy checks before --supervise "
                         "heals a chain (1 = the next segment boundary)")
    args = ap.parse_args(argv)

    truth, data = _network_data(args.network, args.samples, args.q, args.seed,
                                n_synth=args.n)
    n_nodes = truth.shape[0]
    # reject degenerate windows HERE, with a readable message, instead of
    # letting propose_move silently clamp (window > n) or trace garbage
    # (window == 1 has no in-window move) deep inside the jit
    if args.window == 1 or args.window < 0:
        ap.error(f"--window {args.window} is invalid: the bounded-move "
                 "mixture needs window >= 2 (use --window 0 for the legacy "
                 "full-rescore transposition walk)")
    if args.window > n_nodes:
        ap.error(f"--window {args.window} exceeds the network's n="
                 f"{n_nodes} nodes; pick 2 <= window <= {n_nodes} (or 0) — "
                 "oversized windows would only be silently clamped")
    if args.noise:
        data = inject_noise(np.random.default_rng(args.seed + 1), data,
                            args.noise, args.q)
    cfg = LearnConfig(q=args.q, s=args.s, iters=args.iters,
                      chains=args.chains, seed=args.seed,
                      use_kernel=args.use_kernel, window=args.window,
                      mask_cache=not args.no_mask_cache,
                      adapt_window=args.adapt_window, burn_in=args.burn_in,
                      sharded=args.sharded, sharded_tp=args.sharded_tp,
                      exchange_every=args.exchange_every,
                      preprocess=args.preprocess,
                      prune_delta=args.prune_delta,
                      auto_prune=not args.no_auto_prune,
                      cache_dir=(args.cache_dir if args.preprocess == "fused"
                                 else ""),
                      checkpoint_dir=args.checkpoint_dir,
                      checkpoint_every=args.checkpoint_every,
                      telemetry=args.telemetry,
                      trace_every=args.trace_every,
                      check_every=args.check_every,
                      stop_on_converge=args.stop_on_converge,
                      rhat_threshold=args.rhat_threshold,
                      patience=args.patience,
                      emit_consensus=args.emit_consensus,
                      consensus_threshold=args.consensus_threshold,
                      trace_dir=args.trace_dir,
                      run_name=args.run_name,
                      supervise=args.supervise,
                      fault_plan=args.fault_plan,
                      heal_patience=args.heal_patience)
    out = learn_structure(data, cfg)
    fp, tp = roc_point(out["adjacency"], truth)
    out["tp_rate"], out["fp_rate"] = tp, fp
    if out["adaptive_windows"]:
        mode = f"adaptive(w∈{{{','.join(map(str, out['adaptive_windows']))}}})"
    elif out["delta_window"]:
        mode = f"delta(w={out['delta_window']})"
    else:
        mode = "full"
    if out["mask_cache"]:
        mode += "+bitmask"
    if out.get("sharded"):
        mode += f"+sharded({jax.device_count()}dev)"
    if out["exchange_every"]:
        mode += f"+exch({out['exchange_every']})"
    pre = f"pre={out['preprocess_s']:.2f}s"
    if args.preprocess == "fused":
        tags = ["fused"]
        if out.get("auto_pruned"):
            tags.append("auto-pruned")
        if out["preprocess_cache_hit"]:
            tags.append("cache hit")
        pre += f" ({', '.join(tags)})"
    print(f"{args.network}: n={truth.shape[0]} S={out['S']} "
          f"score={out['score']:.2f} TP={tp:.3f} FP={fp:.4f} "
          f"{pre} "
          f"iter={out['iteration_s']:.2f}s "
          f"({out['per_iteration_s']*1e3:.2f} ms/it, {mode}, "
          f"accept={out['accept_rate']:.2f})")
    # one-line run summary: per-chain mixing at a glance
    rates = " ".join(f"{r:.2f}" for r in out["chain_accept_rates"])
    summary = f"chains: accept=[{rates}]"
    if out["window_hist"]:
        summary += f" win_hist={out['window_hist']}"
    if out["exchange_count"]:
        summary += f" exchanges={out['exchange_count']}"
    if out.get("heals"):
        events = " ".join(f"{h['chain']}<-{h['donor']}@{h['iter']}"
                          f"({h['reason']})" for h in out["heals"])
        summary += f" heals=[{events}]"
    if "consensus" in out:
        summary += (f" | consensus: {int(out['consensus'].sum())} edges "
                    f"@ p>={args.consensus_threshold:g}, "
                    f"MAP: {int(out['map_dag'].sum())} edges")
    tele = out.get("telemetry")
    if tele is not None:
        summary += (f" | R̂(score)={tele['score_rhat']:.3f} "
                    f"R̂(edges)={tele['edge_rhat']:.3f}")
        if out["stopped_early"]:
            summary += (f" — converged, stopped at "
                        f"{out['iters_run']}/{args.iters} iters")
        summary += f" → {tele['trace_path']}"
    print(summary)
    return out


if __name__ == "__main__":
    main()
