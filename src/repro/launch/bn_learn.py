"""End-to-end Bayesian-network structure learning driver (the paper's full
pipeline, Fig. 2): preprocess → multi-chain order-MCMC → best-graph exchange.

Usage (also the library entry point used by examples/ and benchmarks/):

  python -m repro.launch.bn_learn --network alarm --iters 2000 --chains 4
  python -m repro.launch.bn_learn --network synth --n 64 --s 3 \
      --preprocess fused --prune-delta 30        # fused pipeline + compression

--preprocess fused routes score-table construction through preprocess/
(count-once-per-subset + LUT scoring, ~20x the reference loop at n = 64 on
CPU) with a disk cache (--cache-dir) so repeat runs skip the stage entirely;
--prune-delta > 0 additionally hash-compresses the table to per-node score
lists, and the MCMC hot path switches to the O(n*K) pruned scorer.

Chains are embarrassingly parallel (DP over the data/pod mesh axes at scale,
vmap locally); the best-graph exchange at the end is the same max+argmax
reduction the scoring kernel uses, one level up. Periodic checkpointing makes
the walk restartable — a killed worker re-joins from the last snapshot.
"""
from __future__ import annotations

import argparse
import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..core import (adjacency_from_best, build_score_table, mcmc_run,
                    random_cpts, roc_point)
from ..core.mcmc import ChainState, exchange_best, init_chain, mcmc_step
from ..core.order_scoring import (delta_window, score_order_blocked,
                                  score_order_delta, score_order_pruned,
                                  score_order_pruned_delta, score_order_sum)
from ..data.bn_sampler import ancestral_sample, inject_noise
from ..data.networks import (alarm_adjacency, stn_adjacency,
                             synthetic_adjacency)
from ..preprocess import SparseScoreTable, build_score_table_fused

__all__ = ["LearnConfig", "learn_structure", "make_score_fn",
           "make_delta_fn", "main"]


@dataclass
class LearnConfig:
    q: int = 2                    # states per variable
    s: int = 4                    # max parent-set size (paper uses 4)
    gamma: float = 0.1            # structure penalty
    ess: float = 1.0              # BDeu equivalent sample size
    iters: int = 1000
    chains: int = 1
    seed: int = 0
    block: int = 4096             # score-table streaming block
    use_kernel: bool = False      # Pallas kernel (interpret=True on CPU)
    scorer: str = "max"           # "max" (paper Eq. 6) | "sum" (baseline [5])
    window: int = 8               # bounded-move window; delta rescoring when
                                  # 2 <= window <= DELTA_CROSSOVER*n (0 = off)
    checkpoint_every: int = 0     # 0 = off
    checkpoint_dir: str = ""
    preprocess: str = "reference"  # "reference" (core/scores host loop) |
                                   # "fused" (preprocess/ pipeline)
    prune_delta: float = 0.0      # > 0: hash-compress the table, keeping per
                                  # node only parent sets within this delta
                                  # of its best (fused pipeline only)
    cache_dir: str = ""           # preprocessing disk cache ("" = off)


def _padded(st, block: int):
    """(table, pst, block) with S padded to a multiple of block — shared by
    the full and delta closures so both see identical blocks."""
    from ..core.sharded_scoring import pad_table
    block = min(block, st.table.shape[1])
    table, pst = pad_table(st.table, st.pst, block)
    return table, pst, block


def make_score_fn(st, cfg: LearnConfig):
    """(pos) -> (score, best_idx, best_ls) closure over either table
    representation: dense ScoreTable (blocked/kernel scorers) or
    preprocess.SparseScoreTable (packed pruned scorer, O(n*K))."""
    if isinstance(st, SparseScoreTable):
        if cfg.scorer == "sum":
            raise ValueError(
                "the sum (logsumexp) baseline scorer needs the dense table: "
                "run without --prune-delta (pruned entries would silently "
                "drop out of the logsumexp)")
        return functools.partial(score_order_pruned, st.kept_ls,
                                 st.kept_parents, st.kept_idx)
    if cfg.scorer == "sum":
        # the Linderman et al. [5] baseline the paper improves on (§III-B)
        return functools.partial(score_order_sum, st.table, st.pst)
    if cfg.use_kernel:
        from ..kernels.order_score import order_score
        return functools.partial(order_score, st.table, st.pst)
    table, pst, block = _padded(st, cfg.block)
    return functools.partial(score_order_blocked, table, pst, block=block)


def make_delta_fn(st, cfg: LearnConfig):
    """(window, delta_fn) for the incremental per-iteration path, or (0, None)
    when it doesn't apply: sum scorer (logsumexp has no per-node max cache)
    or a window the crossover heuristic rejects."""
    if cfg.scorer == "sum":
        return 0, None
    n = st.n if isinstance(st, SparseScoreTable) else st.table.shape[0]
    w = delta_window(n, cfg.window)
    if not w:
        return 0, None
    if isinstance(st, SparseScoreTable):
        kept = (st.kept_ls, st.kept_parents, st.kept_idx)

        def sfn(pos, lo, prev_ls, prev_idx):
            return score_order_pruned_delta(*kept, pos, prev_ls, prev_idx,
                                            lo, window=w)
        return w, sfn
    if cfg.use_kernel:
        from ..kernels.order_score import order_score_delta
        from ..kernels.order_score.ops import pad_for_kernel

        # pre-pad once so the per-iteration call's pad is a no-op (the
        # blocked path hoists its padding the same way via _padded)
        ktable, kpst = pad_for_kernel(st.table, st.pst, 2048)

        def kfn(pos, lo, prev_ls, prev_idx):
            return order_score_delta(ktable, kpst, pos, prev_ls,
                                     prev_idx, lo, window=w)
        return w, kfn
    table, pst, block = _padded(st, cfg.block)

    def fn(pos, lo, prev_ls, prev_idx):
        return score_order_delta(table, pst, pos, prev_ls, prev_idx, lo,
                                 window=w, block=block)
    return w, fn


def learn_structure(data: np.ndarray, cfg: LearnConfig, *,
                    prior_matrix: np.ndarray | None = None) -> dict:
    """Full pipeline. Returns {adjacency, score, preprocess_s, iteration_s,
    per_iteration_s, accept_rate}."""
    n = data.shape[1]
    t0 = time.time()
    cache_hit = False
    if cfg.preprocess == "fused":
        st, pre_info = build_score_table_fused(
            data, q=cfg.q, s=cfg.s, gamma=cfg.gamma, ess=cfg.ess,
            prior_matrix=prior_matrix,
            prune_delta=cfg.prune_delta if cfg.prune_delta > 0 else None,
            cache_dir=cfg.cache_dir or None, return_info=True)
        cache_hit = pre_info["cache_hit"]
    else:
        st = build_score_table(data, q=cfg.q, s=cfg.s, gamma=cfg.gamma,
                               ess=cfg.ess, prior_matrix=prior_matrix)
    jax.block_until_ready(st.kept_ls if isinstance(st, SparseScoreTable)
                          else st.table)
    t_pre = time.time() - t0

    score_fn = make_score_fn(st, cfg)
    window, delta_fn = make_delta_fn(st, cfg)
    key = jax.random.key(cfg.seed)

    checkpointed = bool(cfg.checkpoint_every and cfg.checkpoint_dir)

    t0 = time.time()
    if not checkpointed:
        if cfg.chains == 1:
            state, _ = mcmc_run(key, n, score_fn, cfg.iters,
                                delta_fn=delta_fn, window=window)
            best_score, best_idx = state.best_score, state.best_idx
            accepts = state.accepts
        else:
            keys = jax.random.split(key, cfg.chains)
            run = functools.partial(mcmc_run, n=n, score_fn=score_fn,
                                    iters=cfg.iters, delta_fn=delta_fn,
                                    window=window)
            states, _ = jax.vmap(lambda k: run(k))(keys)
            best_score, best_idx, _ = exchange_best(states)
            accepts = states.accepts.sum()
        jax.block_until_ready(best_score)
    else:
        # checkpointed path: segment the walk, snapshot between segments
        seg = cfg.checkpoint_every
        keys = jax.random.split(key, cfg.chains)
        states = jax.vmap(lambda k: init_chain(k, n, score_fn))(keys)
        # typed PRNG keys are not numpy-serializable: snapshot the key data
        pack = lambda st: jax.tree.map(
            np.asarray, st._replace(key=jax.random.key_data(st.key)))
        unpack = lambda t: ChainState(*t)._replace(
            key=jax.random.wrap_key_data(jnp.asarray(t[0])))
        done = latest_step(cfg.checkpoint_dir)
        if done is not None:
            restored, _ = restore_checkpoint(cfg.checkpoint_dir,
                                             tuple(pack(states)), step=done)
            states = unpack(jax.tree.map(jnp.asarray, tuple(restored)))
        else:
            done = 0

        @jax.jit
        def run_segment(states):
            def body(st, _):
                return jax.vmap(
                    lambda s: mcmc_step(s, score_fn, delta_fn, window))(st), None
            states, _ = jax.lax.scan(body, states, None, length=seg)
            return states

        while done < cfg.iters:
            states = run_segment(states)
            done += seg
            save_checkpoint(cfg.checkpoint_dir, done, tuple(pack(states)))
        best_score, best_idx, _ = exchange_best(states)
        accepts = states.accepts.sum()
    t_iter = time.time() - t0

    adj = adjacency_from_best(np.asarray(best_idx), np.asarray(st.pst))
    total_prop = cfg.iters * max(cfg.chains, 1)
    return {
        "adjacency": adj,
        "delta_window": window,       # 0 = full rescore every iteration
        "score": float(best_score),
        "preprocess_s": t_pre,
        "preprocess_cache_hit": cache_hit,
        "iteration_s": t_iter,
        "per_iteration_s": t_iter / max(cfg.iters, 1),
        "accept_rate": float(accepts) / max(total_prop, 1),
        "S": st.S,
    }


def _network_data(name: str, m: int, q: int, seed: int, n_synth: int = 64):
    rng = np.random.default_rng(seed)
    if name == "synth":
        # synthetic scale-benchmark network (n defaults to 64 — past the
        # paper's headline n > 60 claim)
        adj = synthetic_adjacency(rng, n_synth)
    else:
        adj = {"alarm": alarm_adjacency, "stn": stn_adjacency}[name]()
    cpts = random_cpts(rng, adj, q)
    return adj, ancestral_sample(rng, adj, cpts, m, q)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--network", default="alarm",
                    choices=["alarm", "stn", "synth"])
    ap.add_argument("--n", type=int, default=64,
                    help="node count for --network synth")
    ap.add_argument("--samples", type=int, default=1000)
    ap.add_argument("--iters", type=int, default=1000)
    ap.add_argument("--chains", type=int, default=1)
    ap.add_argument("--q", type=int, default=2)
    ap.add_argument("--s", type=int, default=4)
    ap.add_argument("--noise", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--window", type=int, default=8,
                    help="bounded-move window for delta rescoring (0 = full)")
    ap.add_argument("--preprocess", default="reference",
                    choices=["reference", "fused"],
                    help="score-table construction: core/scores host loop or "
                         "the fused preprocess/ pipeline")
    ap.add_argument("--prune-delta", type=float, default=0.0,
                    help="> 0: hash-compress the score table, keeping per "
                         "node only parent sets within this delta of its "
                         "best (fused preprocessing only)")
    ap.add_argument("--cache-dir", default="experiments/score_cache",
                    help="preprocessing disk cache directory ('' disables); "
                         "only consulted with --preprocess fused")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    args = ap.parse_args(argv)

    truth, data = _network_data(args.network, args.samples, args.q, args.seed,
                                n_synth=args.n)
    if args.noise:
        data = inject_noise(np.random.default_rng(args.seed + 1), data,
                            args.noise, args.q)
    cfg = LearnConfig(q=args.q, s=args.s, iters=args.iters,
                      chains=args.chains, seed=args.seed,
                      use_kernel=args.use_kernel, window=args.window,
                      preprocess=args.preprocess,
                      prune_delta=args.prune_delta,
                      cache_dir=(args.cache_dir if args.preprocess == "fused"
                                 else ""),
                      checkpoint_dir=args.checkpoint_dir,
                      checkpoint_every=args.checkpoint_every)
    out = learn_structure(data, cfg)
    fp, tp = roc_point(out["adjacency"], truth)
    out["tp_rate"], out["fp_rate"] = tp, fp
    mode = (f"delta(w={out['delta_window']})" if out["delta_window"]
            else "full")
    pre = f"pre={out['preprocess_s']:.2f}s"
    if args.preprocess == "fused":
        pre += " (fused, cache hit)" if out["preprocess_cache_hit"] \
            else " (fused)"
    print(f"{args.network}: n={truth.shape[0]} S={out['S']} "
          f"score={out['score']:.2f} TP={tp:.3f} FP={fp:.4f} "
          f"{pre} "
          f"iter={out['iteration_s']:.2f}s "
          f"({out['per_iteration_s']*1e3:.2f} ms/it, {mode}, "
          f"accept={out['accept_rate']:.2f})")
    return out


if __name__ == "__main__":
    main()
