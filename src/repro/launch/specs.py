"""Input/step specifications for every (arch × shape) cell.

`input_specs(arch, shape)` returns ShapeDtypeStruct stand-ins (weak-type
correct, shardable, zero allocation) for each model input, plus the
PartitionSpecs that place them on the mesh. `build_step(...)` returns the
jittable step function the dry-run lowers:

  train_*   -> train_step(params, opt_state, batch)
  prefill_* -> prefill_step(params, tokens[, enc_feats])  (last-token logits + cache)
  decode_*  -> serve_step(params, cache, tokens)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs import get_config, get_shape
from ..configs.base import ModelConfig, ShapeConfig
from ..models import Model
from ..models.attention import KVCache
from ..models.rglru import RGLRUState
from ..models.rwkv6 import RWKVState
from ..optim import AdamWConfig, adamw_init, adamw_update, opt_state_specs

__all__ = ["input_specs", "cache_specs", "build_step", "build_model",
           "batch_spec"]


def build_model(cfg: ModelConfig, mesh, rwkv_chunk: int = 0,
                rwkv_sp: bool = False, moe_gathered: bool = False,
                moe_ep: bool = False, fsdp_only: bool = False,
                use_flash: bool = False) -> Model:
    tp = mesh.shape["model"]
    batch_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    if fsdp_only:
        # small-model strategy: batch occupies every axis, weights are
        # FSDP-gathered per layer, attention/MoE fully token-local
        batch_axes = batch_axes + ("model",)
    return Model(cfg, tp=tp, batch_axes=batch_axes, rwkv_chunk=rwkv_chunk,
                 rwkv_sp=rwkv_sp, moe_gathered=moe_gathered, moe_ep=moe_ep,
                 use_flash=use_flash)


def batch_spec(mesh, batch: int) -> Any:
    """Batch-dim spec; batch-1 cells replicate (latency-bound serving)."""
    axes = [a for a in mesh.axis_names if a in ("pod", "data")]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return P(tuple(axes)) if batch % n == 0 and batch >= n else P()


def _seq_axes(mesh, batch_sp) -> Any:
    """Sequence-dim sharding for decode caches: `model`, plus the batch axes
    when the batch doesn't occupy them (long_500k: whole-pod sequence
    parallelism)."""
    if batch_sp == P():
        return tuple(a for a in mesh.axis_names)
    return "model"


def cache_specs(cache_abstract, mesh, batch_sp) -> Any:
    """PartitionSpecs mirroring Model.init_cache's structure."""
    b = batch_sp if batch_sp != P() else None
    bax = None if b is None else b[0]
    seq_ax = _seq_axes(mesh, batch_sp)

    def rec(node, depth):
        if isinstance(node, KVCache):
            kv = P(bax, seq_ax, None, None) if depth == 0 else \
                 P(None, bax, seq_ax, None, None)
            return KVCache(kv, kv, P())
        if isinstance(node, RGLRUState):
            h = P(bax, "model") if depth == 0 else P(None, bax, "model")
            c = P(bax, None, "model") if depth == 0 else P(None, bax, None, "model")
            return RGLRUState(h, c)
        if isinstance(node, RWKVState):
            wkv = P(bax, "model", None, None) if depth == 0 else \
                  P(None, bax, "model", None, None)
            sh = P(bax, "model") if depth == 0 else P(None, bax, "model")
            return RWKVState(wkv, sh, sh)
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "index":
                    out[k] = P()
                elif k == "enc_out":
                    out[k] = P(bax, None, None)
                elif k == "layers":
                    out[k] = rec(v, 1)
                elif k == "tail":
                    out[k] = {kk: rec(vv, 0) for kk, vv in v.items()}
                else:
                    out[k] = rec(v, depth)
            return out
        raise TypeError(type(node))

    return rec(cache_abstract, 0)


def input_specs(arch: str, shape_name: str, mesh) -> tuple[dict, dict]:
    """(abstract inputs, their PartitionSpecs) for the cell's step function."""
    cfg = get_config(arch)
    shp = get_shape(shape_name)
    B, S = shp.global_batch, shp.seq_len
    bsp = batch_spec(mesh, B)
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    model = build_model(cfg, mesh)

    if shp.mode == "train":
        inputs = {"tokens": tok, "labels": tok}
        specs = {"tokens": bsp, "labels": bsp}
        if cfg.family == "encdec":
            inputs["enc_feats"] = jax.ShapeDtypeStruct(
                (B, S // cfg.enc_seq_divisor, cfg.d_model), jnp.bfloat16)
            specs["enc_feats"] = P(None if bsp == P() else bsp[0], None, None)
        return inputs, specs

    if shp.mode == "prefill":
        inputs = {"tokens": tok}
        specs = {"tokens": bsp}
        if cfg.family == "encdec":
            inputs["enc_feats"] = jax.ShapeDtypeStruct(
                (B, S // cfg.enc_seq_divisor, cfg.d_model), jnp.bfloat16)
            specs["enc_feats"] = P(None if bsp == P() else bsp[0], None, None)
        return inputs, specs

    # decode: one new token against a seq_len cache
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    csp = cache_specs(cache, mesh, bsp)
    inputs = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
              "cache": cache}
    specs = {"tokens": bsp, "cache": csp}
    return inputs, specs


def build_step(arch: str, shape_name: str, mesh, *,
               opt_cfg: AdamWConfig | None = None, rwkv_chunk: int = 0,
               rwkv_sp: bool = False, moe_gathered: bool = False,
               moe_ep: bool = False, use_flash: bool = False,
               fsdp_only: bool = False, microbatch: int = 1,
               accum_dtype=jnp.float32, moment_dtype=None):
    """Returns (step_fn, model). Signature depends on the cell's mode:

    train:   step(params, opt_state, batch) -> (params, opt_state, loss)
    prefill: step(params, tokens[, enc_feats]) -> (last_logits, cache)
    decode:  step(params, cache, tokens) -> (logits, cache)

    microbatch > 1 enables gradient accumulation: the global batch is split
    into `microbatch` chunks scanned sequentially — activation peak drops
    ~microbatch x at the price of one grads-sized accumulator in
    `accum_dtype` (f32 default; bf16 halves it — the memory-fit lever for
    llama3-405b on 16 GB v5e, see EXPERIMENTS.md §Perf).
    """
    cfg = get_config(arch)
    shp = get_shape(shape_name)
    model = build_model(cfg, mesh, rwkv_chunk=rwkv_chunk, rwkv_sp=rwkv_sp,
                        moe_gathered=moe_gathered, moe_ep=moe_ep,
                        fsdp_only=fsdp_only, use_flash=use_flash)
    opt_cfg = opt_cfg or AdamWConfig()
    if moment_dtype is not None:
        opt_cfg = opt_cfg._replace(moment_dtype=moment_dtype)

    if shp.mode == "train":
        if microbatch > 1:
            def train_step_mb(params, opt_state, batch):
                k = microbatch
                mbs = jax.tree.map(
                    lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                    batch)
                acc0 = jax.tree.map(
                    lambda pp: jnp.zeros(pp.shape, accum_dtype), params)

                def body(acc, mb):
                    loss, grads = jax.value_and_grad(model.loss)(params, mb)
                    acc = jax.tree.map(
                        lambda a, g: a + g.astype(accum_dtype), acc, grads)
                    return acc, loss

                acc, losses = jax.lax.scan(body, acc0, mbs)
                grads = jax.tree.map(lambda a: a / k, acc)
                params, opt_state, metrics = adamw_update(
                    grads, opt_state, params, opt_cfg)
                return params, opt_state, {"loss": losses.mean(), **metrics}
            return train_step_mb, model

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            params, opt_state, metrics = adamw_update(
                grads, opt_state, params, opt_cfg)
            return params, opt_state, {"loss": loss, **metrics}
        return train_step, model

    if shp.mode == "prefill":
        def prefill_step(params, tokens, enc_feats=None):
            cache = model.init_cache(tokens.shape[0], shp.seq_len)
            logits, cache = model.prefill(params, tokens, cache,
                                          enc_feats=enc_feats)
            return logits[:, -1:, :], cache
        return prefill_step, model

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return serve_step, model
