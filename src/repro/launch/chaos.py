"""Chaos harness: prove the fault-tolerant run supervisor end to end.

This is the executable form of the robustness acceptance gate (run as
``make chaos-smoke`` in CI):

* **crash-resume determinism** — a run with a mid-walk injected crash
  (``--fault-plan "corrupt@1:bitflip;crash@1:after"``) is resumed by
  re-invoking WITHOUT the crash events (the arm-once discipline from
  runtime/faults.py: the process died, so the resume invocation simply
  doesn't re-arm the crash) and must finish BITWISE-IDENTICAL — best score,
  adjacency, per-chain accept counts — to an uninterrupted run with the
  same seeds. The corrupt event additionally forces the restore through the
  quarantine + fallback path: the newest checkpoint fails digest
  verification, is renamed ``corrupt_step_*``, and the run falls back to
  the previous verified step.
* **heal-within-one-interval** — a NaN-poisoned chain and a stalled chain
  are both healed at the next supervision boundary (one ``heal`` row each
  in the JSONL trace), and the run still finishes with a finite score.
* **trace hygiene** — every emitted JSONL trace re-validates against
  ``bn-telemetry/v1`` (repro.telemetry.validate), including the traces of
  crashed and resumed runs.

The gate runs on the single-device engine in-process and on the sharded
engine in a subprocess (XLA_FLAGS must force the multi-device CPU platform
BEFORE jax imports, so the sharded leg re-executes this module with
``--leg sharded``).

Usage::

    python -m repro.launch.chaos                # full gate (~1 min on CPU)
    python -m repro.launch.chaos --skip-sharded # single-device legs only
"""
from __future__ import annotations

import argparse
import os
import sys


def _build_cfg(workdir: str, name: str, **overrides):
    from .bn_learn import LearnConfig
    base = dict(q=2, s=2, iters=96, chains=4, seed=3, window=4,
                exchange_every=16, trace_every=4, check_every=32,
                telemetry=True, supervise=True, checkpoint_every=32,
                preprocess="reference",
                trace_dir=os.path.join(workdir, "traces"), run_name=name)
    base.update(overrides)
    return LearnConfig(**base)


def _data(n: int = 12, m: int = 200):
    import numpy as np
    rng = np.random.default_rng(0)
    return rng.integers(0, 2, size=(m, n)).astype(np.int8)


def _fingerprint(out: dict):
    return (out["score"], out["adjacency"].tolist(),
            out["chain_accept_rates"])


def _validate_traces(workdir: str) -> int:
    from ..telemetry.validate import validate_file
    tdir = os.path.join(workdir, "traces")
    count = 0
    for f in sorted(os.listdir(tdir)):
        if f.endswith(".jsonl"):
            info = validate_file(os.path.join(tdir, f))
            print(f"  trace {f}: {info['rows']} rows "
                  f"{sorted(info['kinds'].items())}")
            count += 1
    return count


def _crash_resume_leg(workdir: str, *, sharded: bool = False) -> None:
    """Crash + corrupt mid-run, auto-resume, compare bitwise to clean."""
    from ..runtime.faults import InjectedCrash
    from .bn_learn import learn_structure
    tag = "sharded" if sharded else "single"
    data = _data()
    ref = learn_structure(data, _build_cfg(
        workdir, f"{tag}_ref", sharded=sharded,
        checkpoint_dir=os.path.join(workdir, f"ck_{tag}_ref")))
    ckd = os.path.join(workdir, f"ck_{tag}_chaos")
    try:
        learn_structure(data, _build_cfg(
            workdir, f"{tag}_crash", sharded=sharded, checkpoint_dir=ckd,
            fault_plan="corrupt@1:bitflip;crash@1:after"))
        raise AssertionError("fault plan did not crash the run")
    except InjectedCrash as e:
        print(f"  [{tag}] crashed as planned: {e}")
    # resume: same config, crash/corrupt events NOT re-armed
    res = learn_structure(data, _build_cfg(
        workdir, f"{tag}_resume", sharded=sharded, checkpoint_dir=ckd))
    quarantined = [d for d in sorted(os.listdir(ckd))
                   if d.startswith("corrupt_step_")]
    assert quarantined, "corrupt checkpoint was not quarantined"
    print(f"  [{tag}] quarantined: {quarantined}")
    assert _fingerprint(ref) == _fingerprint(res), (
        f"[{tag}] resumed run diverged from the uninterrupted reference: "
        f"{_fingerprint(ref)} != {_fingerprint(res)}")
    print(f"  [{tag}] crash+corrupt resume bitwise-identical "
          f"(score {res['score']:.4f}) OK")


def _heal_leg(workdir: str) -> None:
    """Poisoned + stalled chains healed within one supervision interval."""
    import numpy as np
    from .bn_learn import learn_structure
    data = _data()
    # exchange_every=0: with the in-scan exchange on, the poisoned chain is
    # re-seeded INSIDE the scan (the NaN-safe exchange always makes it the
    # recipient) before the supervisor ever sees the NaN — that's graceful
    # degradation, but this leg wants the supervisor's own guard exercised
    out = learn_structure(data, _build_cfg(
        workdir, "heal", checkpoint_every=0, exchange_every=0,
        fault_plan="poison@1:chain=2:nan;stall@0:chain=1"))
    heals = out["heals"]
    print(f"  heals: {heals}")
    healed = {h["chain"] for h in heals}
    assert {1, 2} <= healed, f"expected chains 1 and 2 healed, got {healed}"
    # "within one supervision interval": the fault lands before segment k,
    # the heal must be logged at the boundary after segment k (check_every
    # iterations later, segments are 32 iters here)
    for h in heals:
        if h["chain"] == 2:
            # poisoned before segment 1 -> healed at boundary 64, and by the
            # supervisor's own NaN/inf guard
            assert h["iter"] == 64 and h["reason"] == "nonfinite", h
        if h["chain"] == 1:
            assert h["iter"] == 64, h   # stall detected at the 2nd boundary
    assert np.isfinite(out["score"]), "healed run must still converge"
    print(f"  heal-within-one-interval OK (score {out['score']:.4f})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workdir", default="",
                    help="scratch dir ('' = a fresh temp dir)")
    ap.add_argument("--skip-sharded", action="store_true",
                    help="skip the forced-multi-device sharded leg")
    ap.add_argument("--leg", default="all", choices=["all", "sharded"],
                    help="internal: 'sharded' runs only the sharded leg "
                         "(expects XLA_FLAGS to pre-force 4 CPU devices)")
    args = ap.parse_args(argv)

    workdir = args.workdir
    if not workdir:
        import tempfile
        workdir = tempfile.mkdtemp(prefix="chaos_")
    os.makedirs(os.path.join(workdir, "traces"), exist_ok=True)

    if args.leg == "sharded":
        _crash_resume_leg(workdir, sharded=True)
        return 0

    print(f"chaos harness (workdir {workdir})")
    print("[1/4] single-device crash+corrupt resume")
    _crash_resume_leg(workdir, sharded=False)
    print("[2/4] chain healing (poison + stall)")
    _heal_leg(workdir)
    if args.skip_sharded:
        print("[3/4] sharded leg SKIPPED (--skip-sharded)")
    else:
        print("[3/4] sharded crash+corrupt resume (subprocess, 4 devices)")
        import subprocess
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=4",
                   JAX_PLATFORMS="cpu")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.chaos", "--leg", "sharded",
             "--workdir", workdir], env=env)
        if proc.returncode:
            print("sharded leg FAILED", file=sys.stderr)
            return proc.returncode
    print("[4/4] re-validating emitted JSONL traces")
    n = _validate_traces(workdir)
    assert n >= 4, f"expected >= 4 traces, found {n}"
    print("chaos harness: ALL LEGS PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
