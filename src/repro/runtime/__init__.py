from .elastic import remesh_plan, reshard_tree
from .straggler import StragglerPolicy, rebalance_chains

__all__ = ["remesh_plan", "reshard_tree", "StragglerPolicy",
           "rebalance_chains"]
