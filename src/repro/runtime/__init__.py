from .elastic import remesh_plan, reshard_tree
from .jax_compat import make_auto_mesh, mesh_context
from .straggler import StragglerPolicy, rebalance_chains

__all__ = ["remesh_plan", "reshard_tree", "StragglerPolicy",
           "rebalance_chains", "make_auto_mesh", "mesh_context"]
