from .elastic import remesh_plan, reshard_tree
from .faults import (FaultEvent, FaultPlan, InjectedCrash, parse_fault_plan)
from .jax_compat import make_auto_mesh, mesh_context
from .straggler import StragglerPolicy, best_finite_chain, rebalance_chains
from .supervisor import RunSupervisor, SupervisedResult

__all__ = ["remesh_plan", "reshard_tree", "StragglerPolicy",
           "best_finite_chain", "rebalance_chains", "make_auto_mesh",
           "mesh_context", "FaultEvent", "FaultPlan", "InjectedCrash",
           "parse_fault_plan", "RunSupervisor", "SupervisedResult"]
