"""Elastic scaling: checkpoints are topology-free (host numpy), so a job can
restart on a different device count. This module plans the new mesh and
re-places state.

At 1000+ nodes the failure model is: a pod (or slice) drops out, the job
controller restarts the program on the surviving slices, `remesh_plan` picks
the largest usable mesh, and `restore_checkpoint(..., shardings=...)`
re-shards every array onto it — the BN path routes that restore through
`checkpoint.restore_latest_verified`, so a snapshot that rotted while the
job was down is quarantined and the next-newest verified one is re-sharded
instead. MCMC chains (BN workload) are re-balanced by runtime.straggler
(driven between segments by runtime.supervisor's health guards); LM
training adjusts gradient accumulation to preserve the global batch.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["remesh_plan", "reshard_tree", "accum_steps_for_batch"]


def remesh_plan(n_devices: int, *, model_parallel: int,
                prefer_pods: int = 1) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Largest (pod, data, model) factorization that fits n_devices.

    model_parallel is fixed by the arch config (param shards must divide
    evenly); the data/pod axes absorb whatever is left — that is the elastic
    dimension.
    """
    if n_devices % model_parallel:
        raise ValueError(f"{n_devices} devices not divisible by "
                         f"model_parallel={model_parallel}")
    rest = n_devices // model_parallel
    pods = prefer_pods if rest % prefer_pods == 0 else 1
    data = rest // pods
    if pods > 1:
        return (pods, data, model_parallel), ("pod", "data", "model")
    return (data, model_parallel), ("data", "model")


def reshard_tree(tree, specs, mesh):
    """Place a host tree onto `mesh` with the given PartitionSpecs."""
    def place(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(place, tree, specs)


def accum_steps_for_batch(global_batch: int, per_step_batch: int) -> int:
    """Gradient-accumulation factor preserving global batch after shrink."""
    if global_batch % per_step_batch:
        raise ValueError("global batch must remain divisible")
    return global_batch // per_step_batch
