"""Version-portability shims for the handful of mesh APIs that moved between
jax releases. The repo targets current jax; these keep it running (and the
tier-1 suite green) on the 0.4.x line too.

* ``AxisType``/``axis_types=`` (explicit-sharding meshes) appeared after
  0.4.x — :func:`make_auto_mesh` passes them when the install supports them
  and silently builds a plain mesh otherwise (Auto is the default semantics
  for everything this repo does: shard_map gets its mesh explicitly).
* ``jax.set_mesh`` replaced the ``with mesh:`` context —
  :func:`mesh_context` returns whichever this install understands.
"""
from __future__ import annotations

import jax

__all__ = ["make_auto_mesh", "mesh_context", "axis_size"]


def axis_size(axis_name) -> int:
    """Static size of a mapped axis (jax.lax.axis_size where it exists)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    from jax._src.core import get_axis_env
    return get_axis_env().axis_size(axis_name)


def make_auto_mesh(shape, axis_names):
    """jax.make_mesh with Auto axis_types when this jax supports them."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axis_names,
                             axis_types=(AxisType.Auto,) * len(axis_names))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axis_names)


def mesh_context(mesh):
    """Context manager installing `mesh` as the ambient mesh."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh            # Mesh is its own context manager on older jax
