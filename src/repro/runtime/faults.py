"""Deterministic chaos: seeded fault injection for the supervised BN run loop.

A :class:`FaultPlan` is a small, fully deterministic schedule of
infrastructure failures, fired by the run supervisor
(runtime/supervisor.py) at segment boundaries — the only points where the
host touches the walk, so every fault lands at a well-defined global
iteration and a crashed run can be compared BITWISE against an
uninterrupted one. Faults never use ambient randomness: targets left
unspecified (which chain, which checkpoint leaf, which byte) are drawn from
a PRNG seeded by the plan, so the same spec string always breaks the same
things.

Spec grammar (``parse_fault_plan``), events joined by ``;``::

    crash@K[:before|after]          kill the process at the checkpoint write
                                    after segment K completes (before = the
                                    snapshot is lost; after = resume from it)
    corrupt@K[:leaf=NAME][:bitflip|truncate]
                                    corrupt the NEWEST checkpoint right after
                                    the write that follows segment K
    poison@K[:chain=C][:nan|inf]    poison chain C's cached scores (score,
                                    cur_ls, best_score) before segment K runs
    stall@K[:chain=C]               freeze chain C's progress from segment K
                                    on (the supervisor replays its snapshot
                                    every boundary until the chain is healed)
    cache@K[:truncate|delete]       corrupt/delete a preprocess cache entry
                                    before segment K runs

Segment indices are 0-based ordinals of COMPLETED segments, counted across
restarts (the supervisor persists the counter in checkpoint metadata), so a
resumed run never re-fires events from before the crash. Crash events are
the one exception to in-process bookkeeping: the process is gone, so the
harness (launch/chaos.py) simply omits the crash from the resume
invocation's plan — the same arm-once discipline real chaos tooling uses.

:class:`InjectedCrash` derives from RuntimeError, NOT SystemExit: the
supervised drivers let it propagate (a real non-zero exit) while the chaos
harness and tests catch it to assert resume behaviour in-process.
"""
from __future__ import annotations

import logging
import os
from dataclasses import dataclass, field

import numpy as np

__all__ = ["InjectedCrash", "FaultEvent", "FaultPlan", "parse_fault_plan",
           "poison_chain_state", "corrupt_checkpoint_dir",
           "corrupt_cache_dir"]

logger = logging.getLogger(__name__)

KINDS = ("crash", "corrupt", "poison", "stall", "cache")
# events applied at the TOP of the loop, before the target segment runs
PRE_SEGMENT_KINDS = ("poison", "stall", "cache")


class InjectedCrash(RuntimeError):
    """Raised by a crash fault: the supervised process dies here."""

    def __init__(self, message: str, code: int = 17):
        super().__init__(message)
        self.code = code


@dataclass(frozen=True)
class FaultEvent:
    kind: str            # one of KINDS
    segment: int         # 0-based segment ordinal the event is keyed to
    mode: str = ""       # before/after, bitflip/truncate/delete, nan/inf
    chain: int = -1      # poison/stall target (-1 = seeded choice)
    leaf: str = ""       # corrupt target leaf name ("" = seeded choice)

    def describe(self) -> str:
        bits = [f"{self.kind}@{self.segment}"]
        if self.mode:
            bits.append(self.mode)
        if self.chain >= 0:
            bits.append(f"chain={self.chain}")
        if self.leaf:
            bits.append(f"leaf={self.leaf}")
        return ":".join(bits)


_DEFAULT_MODE = {"crash": "after", "corrupt": "bitflip", "poison": "nan",
                 "cache": "truncate", "stall": ""}
_VALID_MODE = {"crash": {"before", "after"},
               "corrupt": {"bitflip", "truncate"},
               "poison": {"nan", "inf"},
               "cache": {"truncate", "delete"},
               "stall": set()}


def parse_fault_plan(spec: str, seed: int = 0) -> "FaultPlan":
    """Parse a spec string (grammar in the module docstring) into a plan.
    An empty/whitespace spec yields an empty plan (no faults)."""
    events = []
    for raw in spec.replace(",", ";").split(";"):
        raw = raw.strip()
        if not raw:
            continue
        head, _, rest = raw.partition("@")
        kind = head.strip()
        if kind not in KINDS:
            raise ValueError(f"unknown fault kind {kind!r} in {raw!r} "
                             f"(expected one of {KINDS})")
        toks = rest.split(":")
        try:
            segment = int(toks[0])
        except (ValueError, IndexError):
            raise ValueError(f"fault event {raw!r} needs an integer segment: "
                             f"kind@SEGMENT[:opt]*") from None
        mode, chain, leaf = _DEFAULT_MODE[kind], -1, ""
        for tok in toks[1:]:
            tok = tok.strip()
            if not tok:
                continue
            if tok.startswith("chain="):
                chain = int(tok[6:])
            elif tok.startswith("leaf="):
                leaf = tok[5:]
            elif tok in _VALID_MODE[kind]:
                mode = tok
            else:
                raise ValueError(f"bad option {tok!r} for {kind!r} in {raw!r}")
        events.append(FaultEvent(kind, segment, mode, chain, leaf))
    events.sort(key=lambda e: (e.segment, KINDS.index(e.kind), e.chain,
                               e.leaf))
    return FaultPlan(events=events, seed=seed)


@dataclass
class FaultPlan:
    """The full (deterministic) fault schedule for one supervised run."""
    events: list[FaultEvent] = field(default_factory=list)
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def __bool__(self) -> bool:
        return bool(self.events)

    def pre_segment(self, seg_idx: int) -> list[FaultEvent]:
        """Events applied before segment ``seg_idx`` runs."""
        return [e for e in self.events
                if e.kind in PRE_SEGMENT_KINDS and e.segment == seg_idx]

    def checkpoint_events(self, seg_idx: int
                          ) -> tuple[bool, list[FaultEvent], bool]:
        """(crash_before, corrupt_events, crash_after) for the checkpoint
        write that follows completed segment ``seg_idx``."""
        before = after = False
        corrupts = []
        for e in self.events:
            if e.segment != seg_idx:
                continue
            if e.kind == "crash":
                before |= e.mode == "before"
                after |= e.mode == "after"
            elif e.kind == "corrupt":
                corrupts.append(e)
        return before, corrupts, after

    # ----------------------------------------------------------- appliers
    def poison(self, states, event: FaultEvent):
        """NaN/inf-poison one chain's cached scores (score, cur_ls,
        best_score) on the stacked ChainState. Returns the poisoned stack."""
        import jax.numpy as jnp
        C = int(states.score.shape[0])
        chain = event.chain if event.chain >= 0 else int(self._rng.integers(C))
        bad = jnp.float32(np.nan if event.mode == "nan" else np.inf)
        logger.warning("fault: poisoning chain %d with %s", chain, event.mode)
        return states._replace(
            score=states.score.at[chain].set(bad),
            cur_ls=states.cur_ls.at[chain].set(bad),
            best_score=states.best_score.at[chain].set(bad)), chain

    def pick_chain(self, event: FaultEvent, n_chains: int) -> int:
        return (event.chain if event.chain >= 0
                else int(self._rng.integers(n_chains)))

    def corrupt_checkpoint(self, directory: str, event: FaultEvent) -> str:
        return corrupt_checkpoint_dir(directory, self._rng, leaf=event.leaf,
                                      mode=event.mode)

    def corrupt_cache(self, cache_dir: str, event: FaultEvent) -> str | None:
        return corrupt_cache_dir(cache_dir, self._rng, mode=event.mode)

    def crash(self, where: str):
        raise InjectedCrash(f"fault plan: injected crash {where}")


def poison_chain_state(states, chain: int, mode: str = "nan"):
    """Standalone poison helper (tests): NaN/inf the cached scores of one
    chain in a stacked ChainState."""
    import jax.numpy as jnp
    bad = jnp.float32(np.nan if mode == "nan" else np.inf)
    return states._replace(
        score=states.score.at[chain].set(bad),
        cur_ls=states.cur_ls.at[chain].set(bad),
        best_score=states.best_score.at[chain].set(bad))


def _npy_files(d: str) -> list[str]:
    return sorted(f for f in os.listdir(d) if f.endswith(".npy"))


def _corrupt_file(path: str, rng: np.random.Generator, mode: str) -> None:
    size = os.path.getsize(path)
    if mode == "truncate":
        with open(path, "r+b") as f:
            f.truncate(max(size // 2, 1))
        return
    # bitflip: flip one byte in the DATA region (past the ~128-byte .npy
    # header, so the array still parses and only the digest/values change)
    lo = min(128, max(size - 1, 0))
    off = int(rng.integers(lo, size)) if size > lo else max(size - 1, 0)
    with open(path, "r+b") as f:
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ 0xFF]) if b else b"\xff")


def corrupt_checkpoint_dir(directory: str, rng: np.random.Generator, *,
                           leaf: str = "", mode: str = "bitflip") -> str:
    """Corrupt one leaf array of the NEWEST checkpoint step in ``directory``
    (seeded choice when ``leaf`` is empty). Returns the corrupted path."""
    from ..checkpoint import latest_step
    step = latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint to corrupt in {directory}")
    d = os.path.join(directory, f"step_{step:010d}")
    files = _npy_files(d)
    if leaf:
        target = leaf if leaf.endswith(".npy") else leaf + ".npy"
        if target not in files:
            raise FileNotFoundError(f"leaf {leaf!r} not in {d} "
                                    f"(have {files})")
    else:
        target = files[int(rng.integers(len(files)))]
    path = os.path.join(d, target)
    logger.warning("fault: corrupting checkpoint leaf %s (%s)", path, mode)
    _corrupt_file(path, rng, mode)
    return path


def corrupt_cache_dir(cache_dir: str, rng: np.random.Generator, *,
                      mode: str = "truncate") -> str | None:
    """Corrupt (or delete) one preprocess cache entry under ``cache_dir``.
    Entries are <cache_dir>/<key>/step_0000000000/*.npy; a seeded entry and
    leaf are picked. Returns the corrupted path, or None when the cache is
    empty (a no-op fault, logged)."""
    import shutil
    if not os.path.isdir(cache_dir):
        logger.warning("fault: cache dir %s absent — nothing to corrupt",
                       cache_dir)
        return None
    entries = sorted(e for e in os.listdir(cache_dir)
                     if os.path.isdir(os.path.join(cache_dir, e)))
    if not entries:
        logger.warning("fault: cache dir %s empty — nothing to corrupt",
                       cache_dir)
        return None
    entry = os.path.join(cache_dir, entries[int(rng.integers(len(entries)))])
    if mode == "delete":
        logger.warning("fault: deleting cache entry %s", entry)
        shutil.rmtree(entry)
        return entry
    for root, _, files in os.walk(entry):
        npys = sorted(f for f in files if f.endswith(".npy"))
        if npys:
            path = os.path.join(root, npys[int(rng.integers(len(npys)))])
            logger.warning("fault: truncating cache array %s", path)
            _corrupt_file(path, rng, "truncate")
            return path
    logger.warning("fault: cache entry %s holds no arrays", entry)
    return entry
