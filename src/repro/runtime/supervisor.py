"""Fault-tolerant run supervisor: the segmented BN run loop, hardened.

Every telemetry-aware driver in launch/bn_learn already cuts the walk into
jitted segments (core/mcmc.make_traced_segment_runner) with the host in
between. :class:`RunSupervisor` owns that host loop for the single-device,
adaptive AND sharded engines, and layers four things onto it:

* **verified auto-resume** — restore goes through
  checkpoint.restore_latest_verified: per-leaf digests are re-hashed, a
  corrupt newest step is quarantined and the run falls back to the newest
  step that verifies; transient I/O retries with capped backoff ride along
  from the checkpointer.
* **deterministic fault injection** — a seeded runtime/faults.FaultPlan
  fires crashes around checkpoint writes, corrupts checkpoint leaves or
  preprocess cache entries, NaN/inf-poisons a chain's cached scores and
  stalls a chain's progress, all at segment boundaries so chaos runs stay
  bitwise-comparable to clean ones.
* **telemetry-driven chain healing** — between segments the supervisor folds
  the collector's stuck/diverged flags and its own per-chain NaN/inf +
  progress guards into runtime/straggler.rebalance_chains: a sick slot is
  re-seeded as a clone of the best finite chain with a fresh PRNG key,
  consistency planes are rebuilt for the cloned positions, the chain's
  telemetry leaves (rings, edge counts, window histogram) are re-seeded from
  the donor, and one ``heal`` row per event lands in the JSONL trace.
* **graceful degradation** — a poisoned or stalled chain never aborts the
  run: the in-scan exchange ranks non-finite scores as -inf (core/mcmc), the
  posterior edge accumulator skips non-finite chains (telemetry/taps), and
  the supervisor heals the slot at the next boundary — within one
  supervision interval.

Resume determinism: the supervisor persists its tiny host state (segment
ordinal, per-chain miss counters, progress fingerprints, stalled slots, the
collector's vote state) in the checkpoint metadata, and draws healing keys
as ``fold_in(key(seed), global_iteration)`` — so a run killed at a boundary
and auto-resumed makes byte-identical decisions to one that never died,
which is exactly what the chaos determinism gate (launch/chaos.py,
``make chaos-smoke``) asserts.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import (latest_step, restore_latest_verified,
                          save_checkpoint)
from ..core.mcmc import ChainState
from .faults import FaultPlan
from .straggler import StragglerPolicy, best_finite_chain, rebalance_chains

__all__ = ["RunSupervisor", "SupervisedResult", "pack_tree", "unpack_tree",
           "N_STATE_LEAVES"]

logger = logging.getLogger(__name__)

N_STATE_LEAVES = len(ChainState._fields)


def pack_tree(pack, states, trace):
    """Checkpoint layout with telemetry: the ChainState leaves first (EXACTLY
    the pre-telemetry tuple when trace is None), TraceState leaves appended
    after them — so pre-telemetry snapshots restore through the
    checkpointer's ``allow_missing`` backfill (the trace leaves come back
    from the fresh template), the same schema-evolution path the pre-bitmask
    9-leaf snapshots use."""
    tree = tuple(pack(states))
    if trace is not None:
        tree = tree + tuple(np.asarray(leaf) for leaf in trace)
    return tree


def unpack_tree(unpack, restored, trace):
    """Inverse of :func:`pack_tree`: split the restored tuple back into
    (ChainState, TraceState | None)."""
    restored = tuple(jnp.asarray(leaf) for leaf in restored)
    states = unpack(restored[:N_STATE_LEAVES])
    if trace is not None:
        from ..telemetry import TraceState
        trace = TraceState(*restored[N_STATE_LEAVES:])
    return states, trace


@dataclass
class SupervisedResult:
    states: object            # stacked ChainState after the run
    trace: object             # TraceState | None
    iters_run: int
    stopped: bool             # stop-on-converge fired
    resumed_from: int | None  # checkpoint step the run resumed from
    heals: list = field(default_factory=list)   # heal event dicts


def _raw(states: ChainState) -> ChainState:
    """Typed PRNG keys are not sliceable as numpy: work on key_data."""
    return states._replace(key=jax.random.key_data(states.key))


def _chain_snapshot(states: ChainState, chain: int):
    """Host copy of one chain's slot across every leaf (stall replay)."""
    return jax.tree.map(lambda leaf: np.asarray(leaf[chain]).copy(),
                        _raw(states))


def _impose_chain(states: ChainState, chain: int, snap) -> ChainState:
    new = jax.tree.map(lambda leaf, s: leaf.at[chain].set(jnp.asarray(s)),
                       _raw(states), snap)
    return new._replace(key=jax.random.wrap_key_data(new.key))


def _reseed_trace(trace, healed: np.ndarray, donor: int):
    """Clone the donor's telemetry rows into healed slots (rings, window
    histogram, edge counts) and count the re-seed — the healed chain's
    poisoned/stalled history must not linger in R̂ or the posterior
    accumulator once the chain itself is a clone of the donor."""
    h = jnp.asarray(healed)

    def cp(leaf):
        sel = h.reshape(h.shape + (1,) * (leaf.ndim - 1))
        return jnp.where(sel, leaf[donor][None], leaf)

    return trace._replace(
        scores=cp(trace.scores), accepts=cp(trace.accepts),
        win_hist=cp(trace.win_hist), edge_counts=cp(trace.edge_counts),
        reseeds=trace.reseeds + h.astype(trace.reseeds.dtype))


class RunSupervisor:
    """Owns the segmented host loop for one run (see module docstring).

    Parameters
    ----------
    iters, seg: total iteration budget and segment length (the supervision
        interval — checkpoint_every when checkpointed).
    collector: telemetry Collector or None; checked every boundary.
    faults: FaultPlan or None (chaos injection).
    heal: act on the health guards (--supervise); with heal=False and no
        faults the loop is behaviourally identical to the pre-supervisor
        drivers.
    planes_fn: stacked (C, n) pos -> stacked consistency planes, or None —
        used both after restore (derived-cache reconcile across engine
        variants) and after healing (cloned positions need cloned planes
        REBUILT under this engine's padding).
    pack/unpack: the driver's checkpoint (de)serialisation closures.
    """

    def __init__(self, *, iters: int, seg: int, chains: int,
                 checkpoint_dir: str = "", checkpoint_every: int = 0,
                 collector=None, stop_on_converge: bool = False,
                 faults: FaultPlan | None = None, heal: bool = False,
                 heal_patience: int = 1, seed: int = 0,
                 planes_fn=None, cache_dir: str = "",
                 pack=None, unpack=None):
        self.iters = int(iters)
        self.seg = max(int(seg), 1)
        self.chains = int(chains)
        self.checkpoint_dir = checkpoint_dir
        self.checkpointed = bool(checkpoint_every and checkpoint_dir)
        self.collector = collector
        self.stop_on_converge = bool(stop_on_converge)
        self.faults = faults if faults else None
        self.heal = bool(heal)
        self.policy = StragglerPolicy(patience=max(int(heal_patience), 1))
        self.planes_fn = planes_fn
        self.cache_dir = cache_dir
        self.pack = pack
        self.unpack = unpack
        # healing keys: decorrelated from the chain keys, derived from the
        # GLOBAL iteration so resumed runs draw identical clone keys
        self._heal_key = jax.random.fold_in(jax.random.key(int(seed)), 0x5E9)
        self._missed = np.zeros(self.chains, np.int64)
        self._prev_step: np.ndarray | None = None
        self._stalled: dict[int, object] = {}
        self._seg_done = 0
        self.heals: list[dict] = []
        # incremental-drive carry (armed by begin(), advanced by advance())
        self._run_segment = None
        self._states = self._trace = None
        self._done = 0
        self._stopped = False
        self._resumed_from: int | None = None

    # ------------------------------------------------------------ metadata
    def _state_meta(self) -> dict:
        return {"supervisor": {
            "seg_done": int(self._seg_done),
            "missed": [int(x) for x in self._missed],
            "prev_step": (None if self._prev_step is None
                          else [int(x) for x in self._prev_step]),
            "stalled": sorted(int(c) for c in self._stalled),
            "collector": (self.collector.state_dict()
                          if self.collector is not None else None),
        }}

    def _load_meta(self, metadata: dict, states: ChainState) -> None:
        sup = (metadata or {}).get("supervisor") or {}
        if not sup:
            return
        self._seg_done = int(sup.get("seg_done", self._seg_done))
        if sup.get("missed") is not None:
            self._missed = np.asarray(sup["missed"], np.int64)
        if sup.get("prev_step") is not None:
            self._prev_step = np.asarray(sup["prev_step"], np.int64)
        # a stalled chain was reverted to its snapshot BEFORE the save, so
        # the restored slot IS the snapshot — re-register it verbatim
        for c in sup.get("stalled") or []:
            self._stalled[int(c)] = _chain_snapshot(states, int(c))
        if sup.get("collector") and self.collector is not None:
            self.collector.load_state(sup["collector"])

    # ------------------------------------------------------------- restore
    def _restore(self, states, trace):
        """(states, trace, done, resumed_from): verified auto-resume."""
        if not self.checkpointed or latest_step(self.checkpoint_dir) is None:
            return states, trace, 0, None
        template = pack_tree(self.pack, states, trace)
        try:
            restored, metadata, step = restore_latest_verified(
                self.checkpoint_dir, template, allow_missing=True)
        except FileNotFoundError:
            logger.warning("no checkpoint step verified in %s — starting "
                           "from scratch", self.checkpoint_dir)
            return states, trace, 0, None
        states, trace = unpack_tree(self.unpack, restored, trace)
        states = self._reconcile_planes(states)
        self._load_meta(metadata, states)
        return states, trace, step, step

    def _reconcile_planes(self, states: ChainState) -> ChainState:
        """Derived-cache interop (mirrors launch/bn_learn
        reconcile_mask_planes): the planes leaf is rebuilt from positions
        when this engine uses the bitmask cache and reset to the zero-size
        placeholder when it does not — restored OR healed positions always
        get planes built under this engine's own padding."""
        if self.planes_fn is not None:
            return states._replace(mask_planes=self.planes_fn(states.pos))
        return states._replace(
            mask_planes=jnp.zeros((states.pos.shape[0], 0), jnp.uint32))

    # -------------------------------------------------------------- faults
    def _fire_pre_segment(self, states: ChainState) -> ChainState:
        for event in self.faults.pre_segment(self._seg_done):
            if event.kind == "poison":
                states, chain = self.faults.poison(states, event)
            elif event.kind == "stall":
                chain = self.faults.pick_chain(event, self.chains)
                logger.warning("fault: stalling chain %d from segment %d",
                               chain, self._seg_done)
                self._stalled[chain] = _chain_snapshot(states, chain)
            elif event.kind == "cache":
                if self.cache_dir:
                    self.faults.corrupt_cache(self.cache_dir, event)
                else:
                    logger.warning("fault: no cache dir — %s is a no-op",
                                   event.describe())
        return states

    def _replay_stalls(self, states: ChainState) -> ChainState:
        """A stalled chain's segment progress is thrown away every boundary
        (its snapshot is re-imposed), so from the supervisor's viewpoint the
        chain never advances — the MCMC picture of a worker whose updates
        are lost — until the progress guard heals it."""
        for chain, snap in self._stalled.items():
            states = _impose_chain(states, chain, snap)
        return states

    # ------------------------------------------------------------- healing
    def _health_guard(self, states: ChainState, rec: dict | None):
        """(progressed (C,) bool, reasons (C,) str) from the NaN/inf guard,
        the progress fingerprint, and the collector's stuck/diverged flags."""
        score = np.asarray(states.score, np.float64)
        best = np.asarray(states.best_score, np.float64)
        ls_ok = np.isfinite(np.asarray(states.cur_ls)).all(axis=1)
        finite = np.isfinite(score) & np.isfinite(best) & ls_ok
        step = np.asarray(states.step, np.int64)
        progress = (np.ones(self.chains, bool) if self._prev_step is None
                    else step != self._prev_step)
        stuck = np.zeros(self.chains, bool)
        diverged = np.zeros(self.chains, bool)
        if rec is not None:
            stuck[np.asarray(rec["stuck_chains"], int)] = True
            diverged[np.asarray(rec["diverged_chains"], int)] = True
        progressed = finite & progress & ~stuck & ~diverged
        reasons = np.where(~finite, "nonfinite",
                           np.where(~progress, "stalled",
                                    np.where(stuck, "stuck",
                                             np.where(diverged, "diverged",
                                                      ""))))
        return progressed, reasons

    def _heal(self, states, trace, rec, done: int):
        progressed, reasons = self._health_guard(states, rec)
        best_before = np.asarray(states.best_score)
        key = jax.random.fold_in(self._heal_key, done)
        states, self._missed, healed = rebalance_chains(
            key, states, progressed, self._missed, self.policy,
            return_mask=True)
        if healed.any():
            donor = best_finite_chain(best_before)
            states = self._reconcile_planes(states)
            if trace is not None:
                trace = _reseed_trace(trace, healed, donor)
            for c in np.nonzero(healed)[0]:
                event = {"iter": int(done), "chain": int(c),
                         "donor": int(donor),
                         "reason": str(reasons[c]) or "lagging"}
                self.heals.append(event)
                self._stalled.pop(int(c), None)
                logger.warning("heal: chain %d cloned from %d at iter %d "
                               "(%s)", c, donor, done, event["reason"])
                if self.collector is not None:
                    self.collector.heal(**event)
        self._prev_step = np.asarray(states.step, np.int64).copy()
        return states, trace

    # ----------------------------------------------------------------- run
    def begin(self, run_segment, states, trace) -> "RunSupervisor":
        """Arm the supervisor for incremental driving: verified auto-resume,
        then park the (states, trace) carry until :meth:`advance` is called.

        ``begin``/``advance``/``result`` split :meth:`run` into steps so a
        MULTI-JOB host loop (service/scheduler.py) can interleave segments
        of several supervised runs round-robin on one device budget; one
        call to :meth:`advance` is exactly one trip through the old while
        body, so ``run()`` — begin + advance-until-finished + result —
        is behaviourally unchanged."""
        self._run_segment = run_segment
        states, trace, done, resumed_from = self._restore(states, trace)
        self._states, self._trace = states, trace
        self._done, self._resumed_from = done, resumed_from
        self._stopped = False
        return self

    @property
    def finished(self) -> bool:
        """True once the budget is exhausted or stop-on-converge fired."""
        return self._done >= self.iters or self._stopped

    @property
    def states(self):
        """Current chain stack (valid between begin() and result())."""
        return self._states

    @states.setter
    def states(self, value):
        self._states = value

    @property
    def trace(self):
        """Current TraceState | None (valid between begin() and result())."""
        return self._trace

    @trace.setter
    def trace(self, value):
        self._trace = value

    @property
    def iters_done(self) -> int:
        return self._done

    def advance(self) -> bool:
        """Run ONE supervised segment (chaos injection, segment scan, stall
        replay, collector check, healing, checkpoint). Returns True while
        the run has more segments to go."""
        if self.finished:
            return False
        states, trace, done = self._states, self._trace, self._done
        if self.faults:
            states = self._fire_pre_segment(states)
        length = min(self.seg, self.iters - done)
        states, trace = self._run_segment(states, trace, jnp.int32(done),
                                          length=length)
        done += length
        if self._stalled:
            states = self._replay_stalls(states)
        rec = None
        if self.collector is not None:
            from ..telemetry import drain
            rec = self.collector.check(drain(trace), done)
        if self.heal:
            states, trace = self._heal(states, trace, rec, done)
        crash_before, corrupts, crash_after = (
            self.faults.checkpoint_events(self._seg_done)
            if self.faults else (False, [], False))
        if crash_before:
            self.faults.crash(f"before checkpoint write at iter {done}")
        if self.checkpointed:
            save_checkpoint(self.checkpoint_dir, done,
                            pack_tree(self.pack, states, trace),
                            metadata=self._state_meta())
        for event in corrupts:
            self.faults.corrupt_checkpoint(self.checkpoint_dir, event)
        if crash_after:
            self.faults.crash(f"after checkpoint write at iter {done}")
        self._seg_done += 1
        self._states, self._trace, self._done = states, trace, done
        if self.stop_on_converge and rec is not None and rec["converged"]:
            self._stopped = True
        return not self.finished

    def result(self) -> SupervisedResult:
        return SupervisedResult(states=self._states, trace=self._trace,
                                iters_run=self._done, stopped=self._stopped,
                                resumed_from=self._resumed_from,
                                heals=self.heals)

    def grow(self, extra: int) -> None:
        """Widen the per-chain host bookkeeping after an elastic fleet
        expansion (service/scheduler.expand_fleet): new slots start with a
        clean miss/progress history. The jitted segment runner recompiles
        for the new chain count on its own."""
        if extra <= 0:
            return
        self.chains += int(extra)
        self._missed = np.concatenate(
            [self._missed, np.zeros(extra, np.int64)])
        if self._prev_step is not None:
            self._prev_step = np.concatenate(
                [self._prev_step, np.full(extra, -1, np.int64)])

    def run(self, run_segment, states, trace) -> SupervisedResult:
        """Drive ``run_segment(states, trace, start, length=...)`` to the
        iteration budget (or convergence), supervised."""
        self.begin(run_segment, states, trace)
        while self.advance():
            pass
        return self.result()
