"""Straggler mitigation — now wired into the BN path by the run supervisor.

The BN workload is MCMC: chains are statistically independent, so the system
never *waits* for a slow worker at a correctness barrier. Sync points (the
periodic best-graph exchange) are max-reductions — dropping a straggler's
contribution biases nothing (the running best is monotone); a late
contribution merges at the next exchange.

Policy implemented here:
* a chain that misses `patience` consecutive exchanges is declared straggling;
* its slot is re-seeded by *cloning* the current best chain with a fresh PRNG
  key (chain cloning is the MCMC analogue of speculative re-execution);
* for LM training the analogue hook is backup-worker dispatch, which the
  launcher exposes as `backup_factor` (redundant data-parallel replicas of the
  slowest shard group — documented, not exercised on 1 CPU).

:func:`rebalance_chains` is the healing primitive behind
``bn_learn --supervise`` (runtime/supervisor.py): between jitted segments the
supervisor folds the telemetry collector's stuck/diverged chain flags and its
own per-chain NaN/inf + progress guards into the ``progressed`` vector, and
lagging slots are clones of the best chain — positions, (cur_ls, cur_idx)
caches and consistency planes copied TOGETHER so every derived cache
describes the cloned order by construction. Donor selection is NaN/inf-SAFE:
a poisoned chain (non-finite best_score) can be a recipient but never the
donor.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["StragglerPolicy", "rebalance_chains", "best_finite_chain"]


@dataclass
class StragglerPolicy:
    patience: int = 2            # missed exchanges before re-seed
    backup_factor: float = 0.0   # fraction of redundant DP replicas (LM path)


def best_finite_chain(best_score) -> int:
    """Index of the best chain among those with FINITE best_score — the only
    chains allowed to donate state. Falls back to plain argmax when no chain
    is finite (degenerate: cloning cannot help, but must not crash)."""
    bs = np.asarray(best_score, np.float64)
    finite = np.isfinite(bs)
    if not finite.any():
        return int(np.argmax(np.nan_to_num(bs, nan=-np.inf)))
    return int(np.argmax(np.where(finite, bs, -np.inf)))


def rebalance_chains(key: jax.Array, states, progressed: np.ndarray,
                     missed: np.ndarray, policy: StragglerPolicy,
                     return_mask: bool = False):
    """Clone the best (finite-scored) chain into straggler slots.

    states: stacked ChainState (leading axis = chains); progressed: bool (C,)
    whether a chain reported this round; missed: int (C,) consecutive misses.
    Returns (new_states, new_missed), or (new_states, new_missed, healed)
    with ``return_mask`` — ``healed`` is the bool (C,) mask of re-seeded
    slots (the supervisor logs one ``heal`` telemetry row per True entry and
    re-seeds the matching trace leaves).
    """
    missed = np.where(progressed, 0, missed + 1)
    lagging = missed >= policy.patience
    if not lagging.any():
        return (states, missed, lagging) if return_mask else (states, missed)
    best = best_finite_chain(states.best_score)
    n = len(missed)
    keys = jax.random.split(key, n)

    def fix(leaf):
        leaf = np.asarray(leaf)
        src = leaf[best]
        out = leaf.copy()
        out[lagging] = src
        return jnp.asarray(out)

    # typed PRNG keys are not numpy-convertible: clone via key_data
    new_states = jax.tree.map(fix, states._replace(
        key=jax.random.key_data(states.key)))
    # fresh keys so clones diverge immediately
    new_keys = np.array(new_states.key)          # writable copy
    new_keys[lagging] = np.asarray(jax.random.key_data(keys))[lagging]
    new_states = new_states._replace(
        key=jax.random.wrap_key_data(jnp.asarray(new_keys)))
    missed = np.where(lagging, 0, missed)
    if return_mask:
        return new_states, missed, lagging
    return new_states, missed
