"""Straggler mitigation.

The BN workload is MCMC: chains are statistically independent, so the system
never *waits* for a slow worker at a correctness barrier. Sync points (the
periodic best-graph exchange) are max-reductions — dropping a straggler's
contribution biases nothing (the running best is monotone); a late
contribution merges at the next exchange.

Policy implemented here:
* a chain that misses `patience` consecutive exchanges is declared straggling;
* its slot is re-seeded by *cloning* the current best chain with a fresh PRNG
  key (chain cloning is the MCMC analogue of speculative re-execution);
* for LM training the analogue hook is backup-worker dispatch, which the
  launcher exposes as `backup_factor` (redundant data-parallel replicas of the
  slowest shard group — documented, not exercised on 1 CPU).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["StragglerPolicy", "rebalance_chains"]


@dataclass
class StragglerPolicy:
    patience: int = 2            # missed exchanges before re-seed
    backup_factor: float = 0.0   # fraction of redundant DP replicas (LM path)


def rebalance_chains(key: jax.Array, states, progressed: np.ndarray,
                     missed: np.ndarray, policy: StragglerPolicy):
    """Clone the best chain into straggler slots.

    states: stacked ChainState (leading axis = chains); progressed: bool (C,)
    whether a chain reported this round; missed: int (C,) consecutive misses.
    Returns (new_states, new_missed).
    """
    missed = np.where(progressed, 0, missed + 1)
    lagging = missed >= policy.patience
    if not lagging.any():
        return states, missed
    best = int(np.argmax(np.asarray(states.best_score)))
    n = len(missed)
    keys = jax.random.split(key, n)

    def fix(leaf):
        leaf = np.asarray(leaf)
        src = leaf[best]
        out = leaf.copy()
        out[lagging] = src
        return jnp.asarray(out)

    # typed PRNG keys are not numpy-convertible: clone via key_data
    new_states = jax.tree.map(fix, states._replace(
        key=jax.random.key_data(states.key)))
    # fresh keys so clones diverge immediately
    new_keys = np.array(new_states.key)          # writable copy
    new_keys[lagging] = np.asarray(jax.random.key_data(keys))[lagging]
    new_states = new_states._replace(
        key=jax.random.wrap_key_data(jnp.asarray(new_keys)))
    missed = np.where(lagging, 0, missed)
    return new_states, missed
