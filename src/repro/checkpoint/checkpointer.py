"""Fault-tolerant checkpointing: device-agnostic (host numpy), atomic
(write-to-temp + rename), asynchronous (background writer thread), elastic
(restore re-shards onto whatever mesh is active — checkpoints carry no device
topology). Auto-resume picks the latest complete step.

Layout: <dir>/step_<n>/ with one .npy per flattened leaf + manifest.json
(treedef + shapes + dtypes + user metadata). A checkpoint directory is only
renamed into place after every array and the manifest are fully written, so a
crash mid-write can never produce a readable-but-corrupt checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree):
    # jax.tree.flatten_with_path is newer than some supported jax versions;
    # the tree_util spelling exists on all of them
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["leaf_" + "_".join(_path_str(k) for k in path)
             for path, _ in flat]
    return names, [v for _, v in flat], treedef


def _path_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: dict | None = None) -> str:
    """Blocking save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names, leaves, _ = _flatten_with_paths(tree)
    dtypes = []
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtypes.append(str(leaf.dtype))
        np.save(os.path.join(tmp, name + ".npy"),
                arr.astype(np.float32) if arr.dtype == np.dtype("bfloat16")
                else arr)
    manifest = {"step": step, "names": names, "dtypes": dtypes,
                "metadata": metadata or {}}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic publish
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, _MANIFEST)):
                steps.append(int(name[5:]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like: Any, step: int | None = None,
                       shardings: Any = None,
                       allow_missing: bool = False) -> tuple[Any, dict]:
    """Restore into the structure of `tree_like` (values ignored unless
    `allow_missing` backfills them). If `shardings` is given (pytree of
    NamedSharding), leaves are placed sharded — this is the elastic path: any
    mesh works, the checkpoint is topology-free. Returns (tree, metadata).

    allow_missing=True is the schema-evolution path: leaves of `tree_like`
    with no counterpart in the manifest KEEP the caller's value (callers pass
    freshly-initialised state, so new trailing fields — e.g. the bitmask /
    adaptive-window ChainState leaves added after the 9-field layout — are
    backfilled instead of failing the name check). Leaves present in the
    manifest but absent from `tree_like` still raise: silently DROPPING saved
    state is never safe. The names of backfilled leaves are reported under
    metadata["missing_leaves"]."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    names, cur_leaves, treedef = _flatten_with_paths(tree_like)
    missing = [n for n in names if n not in manifest["names"]]
    if (set(manifest["names"]) - set(names)) or (missing and not allow_missing):
        raise ValueError("checkpoint structure mismatch: "
                         f"{set(manifest['names']) ^ set(names)}")
    dtypes = dict(zip(manifest["names"], manifest["dtypes"]))
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * len(names))
    leaves = []
    for name, cur, sh in zip(names, cur_leaves, sh_leaves):
        if name in dtypes:
            arr = np.load(os.path.join(path, name + ".npy"))
            val = jax.numpy.asarray(arr, dtype=dtypes[name])
        else:
            val = cur                      # backfilled from the caller's init
        if sh is not None:
            val = jax.device_put(val, sh)
        leaves.append(val)
    metadata = dict(manifest["metadata"])
    if missing:
        metadata["missing_leaves"] = missing
    return treedef.unflatten(leaves), metadata


class AsyncCheckpointer:
    """Background writer: save() returns immediately; wait() joins. Keeps at
    most `keep` checkpoints (older ones pruned after a successful write)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, step: int, tree: Any, metadata: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, metadata)
                self._prune()
            except Exception as e:          # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _prune(self) -> None:
        steps = sorted(s for s in (
            int(n[5:]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)
