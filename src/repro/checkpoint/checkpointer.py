"""Fault-tolerant checkpointing: device-agnostic (host numpy), atomic
(unique write-to-temp + ``os.replace`` — safe under CONCURRENT writers
sharing one directory, e.g. deduped service jobs racing on a cache entry:
each stages in its own tmp dir and the second publisher wins whole),
asynchronous (background writer thread), elastic
(restore re-shards onto whatever mesh is active — checkpoints carry no device
topology), and VERIFIED (per-leaf content digests in the manifest).

Layout: <dir>/step_<n>/ with one .npy per flattened leaf + manifest.json
(treedef + shapes + dtypes + per-leaf sha256 digests + user metadata). A
checkpoint directory is only renamed into place after every array and the
manifest are fully written, so a crash mid-write can never produce a
readable-but-corrupt checkpoint. Corruption AFTER publish (bit rot, a chaos
fault, a torn copy) is the digests' job: restore re-hashes every leaf file
and raises :class:`CheckpointCorruptError` on any mismatch or unreadable
array; :func:`restore_latest_verified` turns that into recovery — the bad
step directory is QUARANTINED (renamed ``corrupt_step_<n>.<k>``, out of
``latest_step``'s sight but kept for forensics) and the next-newest step is
tried until one verifies. Transient I/O errors on the checkpoint paths are
retried with capped exponential backoff (:func:`io_retry`) before they are
allowed to surface.
"""
from __future__ import annotations

import hashlib
import io
import json
import logging
import os
import shutil
import threading
import time
import uuid
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "restore_latest_verified", "quarantine_step",
           "CheckpointCorruptError", "io_retry", "AsyncCheckpointer"]

_MANIFEST = "manifest.json"

logger = logging.getLogger(__name__)

# capped exponential backoff for transient I/O errors (NFS hiccups, the
# chaos harness's injected EIO): 4 attempts, 50 ms doubling, 1 s cap
IO_RETRIES = 4
IO_BACKOFF_S = 0.05
IO_BACKOFF_CAP_S = 1.0


class CheckpointCorruptError(RuntimeError):
    """A published checkpoint (or cache entry) failed verification: digest
    mismatch, unreadable/truncated array, or unparseable manifest."""


def io_retry(fn, *args, what: str = "", retries: int = IO_RETRIES,
             backoff_s: float = IO_BACKOFF_S, **kwargs):
    """Run ``fn`` retrying transient OSErrors with capped exponential
    backoff. Non-OSError exceptions (corruption, bugs) propagate
    immediately — retrying cannot fix a bad digest."""
    for attempt in range(retries):
        try:
            return fn(*args, **kwargs)
        except OSError as exc:
            if attempt == retries - 1:
                raise
            delay = min(backoff_s * (2 ** attempt), IO_BACKOFF_CAP_S)
            logger.warning("checkpoint I/O error%s (%s) — retry %d/%d in "
                           "%.2fs", f" [{what}]" if what else "", exc,
                           attempt + 1, retries - 1, delay)
            time.sleep(delay)


def _flatten_with_paths(tree):
    # jax.tree.flatten_with_path is newer than some supported jax versions;
    # the tree_util spelling exists on all of them
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["leaf_" + "_".join(_path_str(k) for k in path)
             for path, _ in flat]
    return names, [v for _, v in flat], treedef


def _path_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: dict | None = None) -> str:
    """Blocking save. Returns the final checkpoint path.

    Every leaf is serialised to .npy bytes in memory first so its sha256
    digest (recorded in the manifest, verified on restore) hashes EXACTLY
    the bytes on disk; the file write itself is wrapped in io_retry."""
    io_retry(os.makedirs, directory, exist_ok=True, what="mkdir")
    final = os.path.join(directory, f"step_{step:010d}")
    # UNIQUE staging dir per writer: concurrent jobs sharing a checkpoint or
    # cache directory (service/jobs.py) must never interleave writes into one
    # tmp path — with the old shared `final + ".tmp"` two same-key cache
    # writers could publish a MIXED tree that passes no digest. A leaked tmp
    # from a crashed writer is invisible to latest_step (the .tmp suffix) and
    # harmless.
    tmp = f"{final}.{os.getpid():x}.{uuid.uuid4().hex[:8]}.tmp"
    os.makedirs(tmp)
    names, leaves, _ = _flatten_with_paths(tree)
    dtypes, digests = [], {}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtypes.append(str(leaf.dtype))
        if arr.dtype == np.dtype("bfloat16"):
            arr = arr.astype(np.float32)
        buf = io.BytesIO()
        np.save(buf, arr)
        data = buf.getvalue()
        digests[name] = hashlib.sha256(data).hexdigest()

        def write(path=os.path.join(tmp, name + ".npy"), data=data):
            with open(path, "wb") as f:
                f.write(data)
        io_retry(write, what=name)
    manifest = {"step": step, "names": names, "dtypes": dtypes,
                "digests": digests, "metadata": metadata or {}}

    def write_manifest():
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
    io_retry(write_manifest, what=_MANIFEST)

    def publish():
        # atomic publish; if another writer of the SAME entry raced us (or a
        # previous save of this step exists), drop the stale target and
        # replace it — second writer wins with a COMPLETE tree either way,
        # readers never observe a partial or mixed checkpoint
        try:
            os.replace(tmp, final)
        except OSError:
            shutil.rmtree(final, ignore_errors=True)
            os.replace(tmp, final)
    io_retry(publish, what="publish")
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                step = int(name[5:])
            except ValueError:
                continue          # quarantined / foreign directory name
            if os.path.exists(os.path.join(directory, name, _MANIFEST)):
                steps.append(step)
    return max(steps) if steps else None


def _read_manifest(path: str) -> dict:
    def read():
        with open(os.path.join(path, _MANIFEST)) as f:
            return f.read()
    try:
        return json.loads(io_retry(read, what=_MANIFEST))
    except (json.JSONDecodeError, UnicodeDecodeError) as exc:
        raise CheckpointCorruptError(
            f"unparseable manifest at {path}: {exc}") from exc


def _load_leaf(path: str, name: str, digest: str | None) -> np.ndarray:
    """Read one leaf file (with I/O retry), verify its digest when the
    manifest carries one (pre-digest snapshots restore unverified), and
    parse the array — any failure is corruption, not a transient error."""
    fname = os.path.join(path, name + ".npy")

    def read():
        with open(fname, "rb") as f:
            return f.read()
    try:
        data = io_retry(read, what=name)
    except FileNotFoundError as exc:
        raise CheckpointCorruptError(f"missing leaf file {fname}") from exc
    if digest is not None:
        got = hashlib.sha256(data).hexdigest()
        if got != digest:
            raise CheckpointCorruptError(
                f"digest mismatch for {fname}: stored {digest[:12]}…, "
                f"recomputed {got[:12]}…")
    try:
        return np.load(io.BytesIO(data), allow_pickle=False)
    except Exception as exc:                      # truncated / garbled .npy
        raise CheckpointCorruptError(
            f"unreadable leaf file {fname}: {exc}") from exc


def restore_checkpoint(directory: str, tree_like: Any, step: int | None = None,
                       shardings: Any = None,
                       allow_missing: bool = False) -> tuple[Any, dict]:
    """Restore into the structure of `tree_like` (values ignored unless
    `allow_missing` backfills them). If `shardings` is given (pytree of
    NamedSharding), leaves are placed sharded — this is the elastic path: any
    mesh works, the checkpoint is topology-free. Returns (tree, metadata).

    Every leaf with a manifest digest is VERIFIED against it; a mismatch or
    unreadable file raises :class:`CheckpointCorruptError` (callers that can
    fall back — the supervisor, the preprocess cache — catch it; see
    :func:`restore_latest_verified`).

    allow_missing=True is the schema-evolution path: leaves of `tree_like`
    with no counterpart in the manifest KEEP the caller's value (callers pass
    freshly-initialised state, so new trailing fields — e.g. the bitmask /
    adaptive-window ChainState leaves added after the 9-field layout — are
    backfilled instead of failing the name check). Leaves present in the
    manifest but absent from `tree_like` still raise: silently DROPPING saved
    state is never safe. The names of backfilled leaves are reported under
    metadata["missing_leaves"]."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    manifest = _read_manifest(path)
    names, cur_leaves, treedef = _flatten_with_paths(tree_like)
    missing = [n for n in names if n not in manifest["names"]]
    if (set(manifest["names"]) - set(names)) or (missing and not allow_missing):
        raise ValueError("checkpoint structure mismatch: "
                         f"{set(manifest['names']) ^ set(names)}")
    dtypes = dict(zip(manifest["names"], manifest["dtypes"]))
    digests = manifest.get("digests", {})     # absent in pre-digest snapshots
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * len(names))
    leaves = []
    for name, cur, sh in zip(names, cur_leaves, sh_leaves):
        if name in dtypes:
            arr = _load_leaf(path, name, digests.get(name))
            val = jax.numpy.asarray(arr, dtype=dtypes[name])
        else:
            val = cur                      # backfilled from the caller's init
        if sh is not None:
            val = jax.device_put(val, sh)
        leaves.append(val)
    metadata = dict(manifest["metadata"])
    if missing:
        metadata["missing_leaves"] = missing
    return treedef.unflatten(leaves), metadata


def quarantine_step(directory: str, step: int) -> str:
    """Move a corrupt step directory out of ``latest_step``'s sight (renamed
    ``corrupt_step_<n>[.k]``, kept for forensics). Returns the new path."""
    src = os.path.join(directory, f"step_{step:010d}")
    dst = os.path.join(directory, f"corrupt_step_{step:010d}")
    k = 0
    while os.path.exists(dst):
        k += 1
        dst = os.path.join(directory, f"corrupt_step_{step:010d}.{k}")
    io_retry(os.rename, src, dst, what="quarantine")
    return dst


def restore_latest_verified(directory: str, tree_like: Any,
                            shardings: Any = None,
                            allow_missing: bool = False
                            ) -> tuple[Any, dict, int]:
    """Restore the newest step that passes digest verification.

    Corrupt steps (digest mismatch, truncated arrays, unparseable manifest)
    are quarantined and the next-newest step is tried — the recovery half of
    the verified-checkpoint contract. Returns (tree, metadata, step); raises
    FileNotFoundError once no verifiable step remains. Structure mismatches
    (ValueError) propagate: they mean the CALLER's template is wrong, not
    that the snapshot rotted."""
    while True:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no verifiable checkpoint in {directory}")
        try:
            tree, metadata = restore_checkpoint(
                directory, tree_like, step=step, shardings=shardings,
                allow_missing=allow_missing)
            return tree, metadata, step
        except CheckpointCorruptError as exc:
            quarantined = quarantine_step(directory, step)
            logger.warning("checkpoint step %d failed verification (%s) — "
                           "quarantined to %s, falling back", step, exc,
                           quarantined)


class AsyncCheckpointer:
    """Background writer: save() returns immediately; wait() joins. Keeps at
    most `keep` checkpoints (older ones pruned after a successful write).

    A writer-thread exception is never lost: it is stashed under a lock and
    re-raised on the NEXT save()/wait() call — callers that fire-and-forget
    saves still hear about a failed write at the following snapshot boundary
    instead of discovering a hole in the trajectory at restore time."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()
        self.last_error: Exception | None = None

    def save(self, step: int, tree: Any, metadata: dict | None = None) -> None:
        self.wait()                    # re-raises a previous writer failure
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, metadata)
                self._prune()
            except Exception as e:          # surfaced on next save()/wait()
                with self._lock:
                    self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        with self._lock:
            err, self.last_error = self.last_error, None
        if err is not None:
            raise err

    def _prune(self) -> None:
        steps = sorted(s for s in (
            _parse_step(n) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
            if s is not None)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)


def _parse_step(name: str) -> int | None:
    try:
        return int(name[5:])
    except ValueError:
        return None
