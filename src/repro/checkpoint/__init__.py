from .checkpointer import (AsyncCheckpointer, CheckpointCorruptError,
                           io_retry, latest_step, quarantine_step,
                           restore_checkpoint, restore_latest_verified,
                           save_checkpoint)

__all__ = ["AsyncCheckpointer", "CheckpointCorruptError", "io_retry",
           "latest_step", "quarantine_step", "restore_checkpoint",
           "restore_latest_verified", "save_checkpoint"]
