"""Shared benchmark utilities: timing, result collection, CSV emission.

``save`` MERGES by row config instead of overwriting: each BENCH_*.json is a
perf trajectory, and the n = 16 CI smoke must land BESIDE the n = 64 gate
rows, never on top of them (the pre-fix writer clobbered the whole file, so
every smoke run erased the gate evidence). Rows are keyed by their
configuration fields (CONFIG_KEYS: n, S, window, devices, ...); a new row
replaces the old row with the SAME config and appends otherwise.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "bench")
# every BENCH_*.json is mirrored to the repo root so the perf trajectory is
# machine-readable without digging into experiments/ (CI and make bench-*
# rely on this)
ROOT_DIR = os.path.join(os.path.dirname(__file__), "..")

# The identity of a benchmark row: every field that selects WHAT was
# measured (problem size, engine knobs, topology), none that reports HOW it
# went (timings, speedups). Fields absent from a row are simply not part of
# its key, so differently-shaped benches coexist in one file.
CONFIG_KEYS = ("n", "q", "s", "m", "S", "iters", "chains", "window",
               "devices", "n_devices", "tp", "dp", "chunk", "block",
               "mode", "variant", "scorer", "delta", "prune_delta",
               "max_keep", "backend", "flip_p")


_HOST_META: dict | None = None


def host_meta() -> dict:
    """Cached machine identity stamped into every bench row by :func:`save`:
    reading a trajectory later, a 1-vCPU CI smoke and a multi-core gate box
    must be tellable apart. Deliberately NOT in CONFIG_KEYS — the host
    describes where a measurement ran, not what was measured, so merge
    identity is unchanged."""
    global _HOST_META
    if _HOST_META is None:
        from repro.telemetry import host_meta as _hm
        _HOST_META = _hm()
    return _HOST_META


def timeit(fn, *args, warmup: int = 1, reps: int = 3) -> float:
    """Median wall seconds of fn(*args) with jax sync."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _row_key(row) -> str:
    """Hashable config identity of one row. Non-dict payload entries (or
    rows with no config field at all) key on their full JSON text — they
    merge by exact identity, which degrades to append-if-changed."""
    if isinstance(row, dict):
        cfg = {k: row[k] for k in CONFIG_KEYS if k in row}
        if cfg:
            return json.dumps(cfg, sort_keys=True, default=float)
    return json.dumps(row, sort_keys=True, default=float)


def merge_rows(existing: list, new: list) -> list:
    """Existing rows with same-config rows replaced by their new
    measurement and genuinely new configs appended (stable order: existing
    first, new appended in their given order)."""
    out = list(existing)
    index = {_row_key(r): i for i, r in enumerate(out)}
    for row in new:
        k = _row_key(row)
        if k in index:
            out[index[k]] = row
        else:
            index[k] = len(out)
            out.append(row)
    return out


def _load_rows(path: str) -> list:
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            prev = json.load(f)
    except (OSError, json.JSONDecodeError):
        return []          # unreadable trajectory: start over, don't crash
    return prev if isinstance(prev, list) else [prev]


def save(name: str, payload) -> None:
    """Merge ``payload`` (a list of row dicts) into the named trajectory
    file(s) by row config — never wholesale-overwrite (see module
    docstring)."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    rows = payload if isinstance(payload, list) else [payload]
    rows = [({**r, "host": host_meta()} if isinstance(r, dict)
             and "host" not in r else r) for r in rows]
    dirs = [RESULTS_DIR] + ([ROOT_DIR] if name.startswith("BENCH_") else [])
    for d in dirs:
        path = os.path.join(d, f"{name}.json")
        merged = merge_rows(_load_rows(path), rows)
        with open(path, "w") as f:
            json.dump(merged, f, indent=1, default=float)


def emit(name: str, rows: list[dict]) -> None:
    """Print a compact aligned table and persist JSON."""
    if not rows:
        print(f"[{name}] no rows")
        return
    cols = list(rows[0])
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows))
              for c in cols}
    print(f"\n== {name} ==")
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))
    save(name, rows)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
