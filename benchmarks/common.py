"""Shared benchmark utilities: timing, result collection, CSV emission."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "bench")
# every BENCH_*.json is mirrored to the repo root so the perf trajectory is
# machine-readable without digging into experiments/ (CI and make bench-*
# rely on this)
ROOT_DIR = os.path.join(os.path.dirname(__file__), "..")


def timeit(fn, *args, warmup: int = 1, reps: int = 3) -> float:
    """Median wall seconds of fn(*args) with jax sync."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def save(name: str, payload) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    dirs = [RESULTS_DIR] + ([ROOT_DIR] if name.startswith("BENCH_") else [])
    for d in dirs:
        with open(os.path.join(d, f"{name}.json"), "w") as f:
            json.dump(payload, f, indent=1, default=float)


def emit(name: str, rows: list[dict]) -> None:
    """Print a compact aligned table and persist JSON."""
    if not rows:
        print(f"[{name}] no rows")
        return
    cols = list(rows[0])
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows))
              for c in cols}
    print(f"\n== {name} ==")
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))
    save(name, rows)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
