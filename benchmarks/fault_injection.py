"""Paper Figure 11: noise tolerance — each data entry flips state with
probability p; ROC of the learned 20-node graph (10,000-iteration sampling in
the paper; iteration count configurable for CPU budgets).

Rows land in BENCH_faults.json through benchmarks.common.save, keyed by
their config (n, m, q, s, iters, chains, flip_p — flip_p is a CONFIG_KEY),
so the trajectory merges like every other bench: a re-run at the same
config replaces its old row, the ``--smoke`` CI row (tiny iteration budget)
lands BESIDE the full-budget rows instead of clobbering them.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import random_cpts, random_dag, roc_point
from repro.data.bn_sampler import ancestral_sample, inject_noise
from repro.launch.bn_learn import LearnConfig, learn_structure

try:
    from .common import emit
except ImportError:                       # run as a script, not a module
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.common import emit


def run(ps=(0.0, 0.01, 0.05, 0.07, 0.1, 0.15), n: int = 20, m: int = 1000,
        q: int = 2, s: int = 4, iters: int = 2000,
        chains: int = 2) -> list[dict]:
    rng = np.random.default_rng(3)
    truth = random_dag(rng, n, max_parents=4)
    clean = ancestral_sample(rng, truth, random_cpts(rng, truth, q), m, q)
    rows = []
    for p in ps:
        data = clean if p == 0 else inject_noise(
            np.random.default_rng(11), clean, p, q)
        out = learn_structure(data, LearnConfig(q=q, s=s, iters=iters, seed=1,
                                                chains=chains))
        fp, tp = roc_point(out["adjacency"], truth)
        rows.append({"n": n, "m": m, "q": q, "s": s, "iters": iters,
                     "chains": chains, "flip_p": p,
                     "tp_rate": tp, "fp_rate": fp, "final_score": out["score"]})
    emit("BENCH_faults", rows)
    return rows


def main(argv=None) -> list[dict]:
    ap = argparse.ArgumentParser(description="Fig. 11 noise-tolerance sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI budget: 2 noise levels, short walk (its "
                         "rows merge beside the full sweep, not over it)")
    ap.add_argument("--iters", type=int, default=0,
                    help="override the iteration budget (0 = default)")
    args = ap.parse_args(argv)
    if args.smoke:
        return run(ps=(0.0, 0.1), n=12, m=300,
                   iters=args.iters or 200, chains=2)
    return run(iters=args.iters or 2000)


if __name__ == "__main__":
    main()
