"""Paper Figure 11: noise tolerance — each data entry flips state with
probability p; ROC of the learned 20-node graph (10,000-iteration sampling in
the paper; iteration count configurable for CPU budgets)."""
from __future__ import annotations

import numpy as np

from repro.core import random_cpts, random_dag, roc_point
from repro.data.bn_sampler import ancestral_sample, inject_noise
from repro.launch.bn_learn import LearnConfig, learn_structure

from .common import emit


def run(ps=(0.0, 0.01, 0.05, 0.07, 0.1, 0.15), n: int = 20, m: int = 1000,
        q: int = 2, iters: int = 2000, chains: int = 2) -> list[dict]:
    rng = np.random.default_rng(3)
    truth = random_dag(rng, n, max_parents=4)
    clean = ancestral_sample(rng, truth, random_cpts(rng, truth, q), m, q)
    rows = []
    for p in ps:
        data = clean if p == 0 else inject_noise(
            np.random.default_rng(11), clean, p, q)
        out = learn_structure(data, LearnConfig(q=q, s=4, iters=iters, seed=1,
                                                chains=chains))
        fp, tp = roc_point(out["adjacency"], truth)
        rows.append({"flip_p": p, "tp_rate": tp, "fp_rate": fp,
                     "score": out["score"]})
    emit("fault_injection", rows)
    return rows


if __name__ == "__main__":
    run()
