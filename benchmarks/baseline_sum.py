"""Paper §III-B: the max-based order score (Eq. 6, ours) vs the SUM-based
order score of Linderman et al. [5] — the baseline the paper improves on.

The paper's three claims, measured here on the same data/seeds:
  1. max needs only compare/assign ops (no exp/log): per-iteration time;
  2. sum can prefer an order whose best graph is NOT the global best:
     best-graph score achieved;
  3. max needs no postprocessing (the best graph falls out of scoring).

Both scorers now run their INCREMENTAL per-iteration path (ISSUE 3: the sum
scorer gained a per-node running-logsumexp cache spliced through the same
splice_window as the max deltas), so the per-iteration comparison is
like-for-like — what remains is the intrinsic exp/log cost, not an
implementation handicap. ``--full`` reverts both to full rescores.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import random_cpts, random_dag, roc_point
from repro.data.bn_sampler import ancestral_sample
from repro.launch.bn_learn import LearnConfig, learn_structure

from .common import emit


def run(n: int = 20, m: int = 1000, q: int = 2, iters: int = 2000,
        chains: int = 2, window: int = 8) -> list[dict]:
    rng = np.random.default_rng(3)
    truth = random_dag(rng, n, max_parents=4)
    data = ancestral_sample(rng, truth, random_cpts(rng, truth, q), m, q)
    rows = []
    for scorer in ("max", "sum"):
        out = learn_structure(data, LearnConfig(
            q=q, s=4, iters=iters, chains=chains, seed=1, scorer=scorer,
            window=window))
        fp, tp = roc_point(out["adjacency"], truth)
        rows.append({
            "scorer": scorer,
            "path": (f"delta(w={out['delta_window']})" if out["delta_window"]
                     else "full") + ("+bitmask" if out["mask_cache"] else ""),
            "graph_score": "n/a (sum-score space)" if scorer == "sum" else
                           round(out["score"], 2),
            "per_iter_ms": out["per_iteration_s"] * 1e3,
            "tp_rate": tp, "fp_rate": fp,
            "postprocessing": "none (paper Eq. 6)" if scorer == "max"
                              else "argmax pass per sampled order",
        })
    emit("baseline_sum", rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full rescore every iteration for both scorers")
    ap.add_argument("--iters", type=int, default=2000)
    args = ap.parse_args()
    run(iters=args.iters, window=0 if args.full else 8)
