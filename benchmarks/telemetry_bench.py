"""Telemetry overhead: the segmented bitmask engine with the in-scan taps ON
vs OFF (ISSUE 7 gate: taps cost <= 5% iters/sec at n = 64).

Both runs use the SAME segmented runner (core/mcmc.make_traced_segment_runner
— the loop every telemetry-aware driver uses), the same keys and therefore
the same proposals; the tapped run additionally carries the TraceState
pytree and pays the per-iteration window-histogram add plus, every
--trace-every iterations, the ring writes and the on-device adjacency
unranking. The tap must be a pure OBSERVER: the final chain states are
asserted bitwise-equal before anything is timed.

  PYTHONPATH=src python benchmarks/telemetry_bench.py [--smoke] [--iters N]

Rows land in BENCH_mcmc.json (mode="telemetry") beside the engine rows,
mirrored to the repo root.
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

try:
    from .common import emit, timeit
except ImportError:                      # run as a plain script
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit, timeit

from repro.core.mcmc import (BitmaskDelta, init_chain,
                             make_traced_segment_runner, mcmc_step)
from repro.core.order_scoring import (build_membership_planes,
                                      build_violation_planes, delta_window,
                                      score_order_blocked,
                                      score_order_delta_bitmask)
from repro.telemetry import init_trace, make_tap

from mcmc_bench import make_problem

WINDOW = 8
CHAINS = 4
GATE_N = 64
GATE_OVERHEAD = 0.05            # taps may cost at most 5% iters/sec


def bench_size(n: int, s: int, iters: int, trace_every: int = 8,
               block: int = 4096) -> dict:
    table, pst, S = make_problem(n, s, block)
    block = min(block, table.shape[1])
    w = delta_window(n, WINDOW)
    assert w, f"n={n} too small for window {WINDOW}"
    score_fn = functools.partial(score_order_blocked, table, pst, block=block)
    cm = build_membership_planes(pst, n)
    planes_fn = functools.partial(build_violation_planes, pst)

    def bitmask_fn(pos, lo, prev_ls, prev_idx, pos_old, planes):
        return score_order_delta_bitmask(table, cm, pos, prev_ls, prev_idx,
                                         lo, pos_old, planes, window=w,
                                         block=block)
    step = lambda st: mcmc_step(st, score_fn, BitmaskDelta(bitmask_fn), w)

    run_plain = make_traced_segment_runner(step)
    run_tapped = make_traced_segment_runner(
        step, tap=make_tap(n, s, trace_every))

    def states0():
        keys = jax.random.split(jax.random.key(0), CHAINS)
        return jax.vmap(
            lambda k: init_chain(k, n, score_fn, planes_fn=planes_fn))(keys)

    # the tap must observe, never steer: same keys + same proposals, final
    # chain states bitwise-equal (never time a bug)
    a, _ = run_plain(states0(), None, jnp.int32(0), length=min(iters, 50))
    b, tr = run_tapped(states0(), init_trace(CHAINS, n), jnp.int32(0),
                       length=min(iters, 50))
    np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))
    np.testing.assert_array_equal(np.asarray(a.score), np.asarray(b.score))
    np.testing.assert_array_equal(np.asarray(a.accepts),
                                  np.asarray(b.accepts))
    assert int(tr.taps) == min(iters, 50) // trace_every, "tap cadence broke"

    t_plain = timeit(lambda: run_plain(states0(), None, jnp.int32(0),
                                       length=iters)[0].score, reps=5)
    t_tap = timeit(lambda: run_tapped(states0(), init_trace(CHAINS, n),
                                      jnp.int32(0), length=iters)[0].score,
                   reps=5)
    return {
        "n": n, "S": S, "window": w, "iters": iters, "chains": CHAINS,
        "mode": "telemetry", "trace_every": trace_every,
        "plain_ms_per_it": t_plain / iters * 1e3,
        "tapped_ms_per_it": t_tap / iters * 1e3,
        "overhead": t_tap / t_plain - 1.0,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes/iters — CI wiring check, seconds")
    ap.add_argument("--iters", type=int, default=0,
                    help="override iterations per timed run")
    ap.add_argument("--s", type=int, default=3, help="max parent-set size")
    ap.add_argument("--trace-every", type=int, default=8)
    args = ap.parse_args(argv)

    if args.smoke:
        sizes, iters = [16], args.iters or 50
    else:
        sizes, iters = [16, 64], args.iters or 300

    rows = [bench_size(n, args.s, iters, args.trace_every) for n in sizes]
    emit("BENCH_mcmc", rows)
    if not args.smoke:
        last = rows[-1]
        print(f"\nn={last['n']}: telemetry taps cost "
              f"{last['overhead'] * 100:.1f}% iters/sec "
              f"(gate <= {GATE_OVERHEAD * 100:g}% at n={GATE_N})")
        if last["n"] == GATE_N and last["overhead"] > GATE_OVERHEAD:
            raise SystemExit(
                f"FAIL: {last['overhead'] * 100:.1f}% > "
                f"{GATE_OVERHEAD * 100:g}% overhead gate")
    return rows


if __name__ == "__main__":
    main()
