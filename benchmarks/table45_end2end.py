"""Paper Tables IV & V: end-to-end learning on the 11-node STN and the
37-node ALARM network — preprocessing vs iteration runtime split (Table IV),
and all-parent-sets vs size-limited preprocessing+scoring (Table V).

All-parent-sets is only feasible for the 11-node graph (s = n−1 = 10); for
20 nodes the paper itself needed 1123 s on a GPP, and the contingency dim
q^s explodes — we run the limited variant and report the skip explicitly.
"""
from __future__ import annotations

import numpy as np

from repro.core import random_cpts, roc_point
from repro.data.bn_sampler import ancestral_sample
from repro.data.networks import alarm_adjacency, stn_adjacency
from repro.launch.bn_learn import LearnConfig, learn_structure

from .common import emit


def _data(adj: np.ndarray, m: int, q: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return ancestral_sample(rng, adj, random_cpts(rng, adj, q), m, q)


def run(iters: int = 1000, m: int = 1000, q: int = 2) -> list[dict]:
    rows = []
    # ---- Table IV: STN (11 nodes) and ALARM (37 nodes), s=4
    for name, adj_fn in (("stn-11", stn_adjacency), ("alarm-37", alarm_adjacency)):
        adj = adj_fn()
        data = _data(adj, m, q, seed=0)
        out = learn_structure(data, LearnConfig(q=q, s=4, iters=iters))
        fp, tp = roc_point(out["adjacency"], adj)
        rows.append({
            "network": name, "parent_sets": "limited(s=4)", "S": out["S"],
            "preprocess_s": out["preprocess_s"],
            "iteration_s": out["iteration_s"],
            "total_s": out["preprocess_s"] + out["iteration_s"],
            "per_iter_ms": out["per_iteration_s"] * 1e3,
            "tp_rate": tp, "fp_rate": fp,
        })
    # ---- Table V: all parent sets vs limited, 11-node graph
    adj = stn_adjacency()
    data = _data(adj, m, q, seed=0)
    out = learn_structure(data, LearnConfig(q=q, s=10, iters=iters))
    fp, tp = roc_point(out["adjacency"], adj)
    rows.append({
        "network": "stn-11", "parent_sets": "all(s=10)", "S": out["S"],
        "preprocess_s": out["preprocess_s"],
        "iteration_s": out["iteration_s"],
        "total_s": out["preprocess_s"] + out["iteration_s"],
        "per_iter_ms": out["per_iteration_s"] * 1e3,
        "tp_rate": tp, "fp_rate": fp,
    })
    rows.append({
        "network": "random-20", "parent_sets": "all(s=19)", "S": "2^19",
        "preprocess_s": "skipped: q^s contingency dim infeasible "
                        "(the memory-saving strategy IS the point)",
        "iteration_s": "-", "total_s": "-", "per_iter_ms": "-",
        "tp_rate": "-", "fp_rate": "-",
    })
    # limited 20-node for the Table V comparison row
    rng = np.random.default_rng(7)
    from repro.core import random_dag
    adj20 = random_dag(rng, 20, max_parents=4)
    data20 = _data(adj20, m, q, seed=7)
    out = learn_structure(data20, LearnConfig(q=q, s=4, iters=iters))
    fp, tp = roc_point(out["adjacency"], adj20)
    rows.append({
        "network": "random-20", "parent_sets": "limited(s=4)", "S": out["S"],
        "preprocess_s": out["preprocess_s"],
        "iteration_s": out["iteration_s"],
        "total_s": out["preprocess_s"] + out["iteration_s"],
        "per_iter_ms": out["per_iteration_s"] * 1e3,
        "tp_rate": tp, "fp_rate": fp,
    })
    emit("table45_end2end", rows)
    return rows


if __name__ == "__main__":
    run()
