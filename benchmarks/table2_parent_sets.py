"""Paper Table II: runtime to generate ALL 2^(n-1) parent sets (bit-vector
method of [4,5]) vs only those with |π| ≤ s=4 (the paper's enumeration).

The paper reports per-iteration generation cost for the last node's candidate
sets; we measure the same quantities: full subset enumeration vs the
combinadic size-limited PST build.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.combinatorics import build_pst, n_parent_sets

from .common import emit

FULL_CAP = 22  # 2^21 subsets ≈ 2M rows; beyond this the point is made


def gen_all_bitvectors(nc: int) -> np.ndarray:
    """All 2^nc subsets as bit masks (the baseline the paper argues against)."""
    masks = np.arange(1 << nc, dtype=np.uint32)
    # materialize the membership matrix like a bit-vector comparison would
    return (masks[:, None] >> np.arange(nc, dtype=np.uint32)[None]) & 1


def run(ns=(15, 17, 19, 21, 23, 25), s: int = 4) -> list[dict]:
    rows = []
    for n in ns:
        nc = n - 1
        t_full = None
        if n <= FULL_CAP:
            t0 = time.perf_counter()
            gen_all_bitvectors(nc)
            t_full = time.perf_counter() - t0
        t0 = time.perf_counter()
        pst, _ = build_pst(nc, s)
        t_lim = time.perf_counter() - t0
        rows.append({
            "n": n, "s": s, "mode": "table2",
            "n_nodes": n,
            "all_sets": 1 << nc,
            "limited_sets": n_parent_sets(nc, s),
            "t_all_s": t_full if t_full is not None else "skipped(>cap)",
            "t_limited_s": t_lim,
            "speedup": (t_full / t_lim) if t_full else "-",
        })
    emit("table2_parent_sets", rows)
    return rows


if __name__ == "__main__":
    run()
