"""Posterior-service scheduling overhead: K jobs run back-to-back as
standalone ``learn_structure`` calls vs interleaved through the
FleetScheduler (ISSUE 10 gate: concurrent scheduling keeps >= 90% of the
sequential AGGREGATE iters/sec at n = 32).

Both sides run the SAME jobs — same data, same config, same seeds — through
the same engine builders, so the only difference is who drives the segment
loop: the in-process while-loop, or the round-robin scheduler tick. The
scheduler adds per-segment host work (job bookkeeping, slot accounting) and
loses locality by alternating jitted runners; the gate caps that tax at 10%
of aggregate throughput. Per-job artifacts are asserted bitwise-equal
between the two drivers before anything is timed (never time a bug).

  PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--iters N]

Rows land in BENCH_mcmc.json (mode="serve", variant="sequential" |
"concurrent") beside the engine / telemetry / supervisor rows, mirrored to
the repo root.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

try:
    from .common import emit
except ImportError:                      # run as a plain script
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit

from repro.launch.bn_learn import learn_structure
from repro.service import (DatasetSpec, FleetScheduler, JobManager,
                           load_dataset, service_config)

JOBS = 2
GATE_N = 32
GATE_RATIO = 0.90               # concurrent >= 90% of sequential iters/sec


def _configs(n: int, iters: int):
    """The K job payloads: same size, different data + walk seeds, telemetry
    cadence fixed so segment boundaries match between drivers."""
    cfg = dict(iters=iters, chains=4, window=8, trace_every=10,
               check_every=max(iters // 4, 10), stop_on_converge=False,
               exchange_every=50)
    out = []
    for k in range(JOBS):
        c = service_config(dict(cfg, seed=11 + k))
        data = load_dataset(DatasetSpec(network="synth", n=n, m=200,
                                        seed=3 + k), c.q)
        out.append((data, c))
    return out

def _sequential(jobs):
    t0 = time.perf_counter()
    results = [learn_structure(data, cfg) for data, cfg in jobs]
    return results, time.perf_counter() - t0


def _concurrent(jobs, tmpdir: str):
    man = JobManager(run_dir=tmpdir)
    sched = FleetScheduler(man, slots=sum(c.chains for _, c in jobs))
    t0 = time.perf_counter()
    handles = [sched.submit(data, cfg)[0] for data, cfg in jobs]
    sched.run()
    dt = time.perf_counter() - t0
    for h in handles:
        assert h.state == "done", f"{h.id}: {h.state} {h.error}"
    return [h.result for h in handles], dt


def bench_size(n: int, iters: int, tmpdir: str) -> list[dict]:
    jobs = _configs(n, iters)
    # warmup = correctness pass: both drivers must produce bitwise-identical
    # artifacts per job (and it absorbs compilation for the timed runs)
    seq, t_seq = _sequential(jobs)
    con, t_con = _concurrent(jobs, tmpdir)
    for k, (a, b) in enumerate(zip(seq, con)):
        for key in ("edge_posterior", "map_dag", "consensus"):
            np.testing.assert_array_equal(
                np.asarray(a[key]), np.asarray(b[key]),
                err_msg=f"job {k}: {key} diverged between drivers")
        assert float(a["score"]) == float(b["score"]), f"job {k}: score"
    # timed passes (compiled caches warm for both drivers)
    _, t_seq = _sequential(jobs)
    _, t_con = _concurrent(jobs, tmpdir + "_timed")
    total_iters = JOBS * iters
    chains = jobs[0][1].chains
    base = {"n": n, "iters": iters, "chains": chains, "window": 8,
            "mode": "serve", "jobs": JOBS}
    return [
        {**base, "variant": "sequential", "wall_s": t_seq,
         "agg_iters_per_s": total_iters / t_seq},
        {**base, "variant": "concurrent", "wall_s": t_con,
         "agg_iters_per_s": total_iters / t_con,
         "ratio_vs_sequential": t_seq / t_con},
    ]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes/iters — CI wiring check, seconds")
    ap.add_argument("--iters", type=int, default=0,
                    help="override iterations per job")
    args = ap.parse_args(argv)

    if args.smoke:
        sizes, iters = [12], args.iters or 80
    else:
        sizes, iters = [12, GATE_N], args.iters or 600

    import tempfile
    rows = []
    for n in sizes:
        rows += bench_size(n, iters, tempfile.mkdtemp(prefix="serve_bench_"))
    emit("BENCH_mcmc", rows)
    if not args.smoke:
        last = rows[-1]
        ratio = last["ratio_vs_sequential"]
        print(f"\nn={last['n']}: concurrent scheduling keeps "
              f"{ratio * 100:.1f}% of sequential aggregate iters/sec "
              f"(gate >= {GATE_RATIO * 100:g}% at n={GATE_N})")
        if last["n"] == GATE_N and ratio < GATE_RATIO:
            raise SystemExit(f"FAIL: {ratio * 100:.1f}% < "
                             f"{GATE_RATIO * 100:g}% throughput gate")
    return rows


if __name__ == "__main__":
    main()
